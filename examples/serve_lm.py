"""Batched serving on the paged continuous-batching stack.

    PYTHONPATH=src python examples/serve_lm.py                  # one process
    PYTHONPATH=src python examples/serve_lm.py --localities 2   # two processes

Requests are submitted as futures (one-sided, HPX semantics); prefill runs
as PRIORITY_HIGH tasks overlapped with the decode continuation chain, KV
lives in a block-pool paged cache, and every request streams its tokens
through a `core.Channel` as the slots advance — first token long before
the request completes.  Engine replicas sit behind the least-loaded router.

With ``--localities 2`` the replicas are real OS processes: locality 0
(this process, the AGAS root) serves alongside a worker locality reached
over the parcelport.  Remote submissions return plain futures (token
channels are per-process), and per-locality token counters are read back
across the wire at the end — both localities serve.
"""
import argparse
import time

import jax
import numpy as np

import repro.core as core
from repro.configs import get_config
from repro.dist.plan import get_plan
from repro.models.model import build_model
from repro.serve.engine import SamplingParams, ServeConfig
from repro.serve.router import Router


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--localities", type=int, default=1,
                    help=">1 spreads engines over OS-process localities")
    args = ap.parse_args()

    scfg = ServeConfig(max_batch=4, cache_len=128, max_new_tokens=12)
    cfg = get_config("qwen25_3b", smoke=True)
    if args.localities > 1:
        from repro import net as rnet

        pools = {"default": 4, "prefill": 2, "io": 1}
        net = rnet.bootstrap(args.localities, pools=pools, worker_pools=pools)
        router = Router.over_localities(net, "qwen25_3b", scfg, smoke=True,
                                        plan="serve")
    else:
        net = None
        core.init(num_workers=4)
        model = build_model(cfg, get_plan("futurized"))
        params = model.init(jax.random.PRNGKey(0))
        router = Router.replicate(model, params, scfg, replicas=2)

    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    if net is None:
        streams = []
        for i in range(10):  # 10 requests, 2×4 slots → continuous batching
            prompt = rng.integers(1, cfg.vocab_size, size=rng.integers(3, 24)).tolist()
            # even requests greedy, odd requests sampled
            sp = SamplingParams(temperature=0.8, top_k=40, top_p=0.95) if i % 2 \
                else SamplingParams()
            streams.append((prompt, sp, *router.submit_stream(prompt, sampling=sp)))
        for prompt, sp, ch, fut in streams:
            toks = list(ch)  # arrives token-by-token as the slot advances
            out = fut.get(timeout=600)
            assert toks == out
            mode = "sampled" if sp.temperature > 0 else "greedy "
            print(f"{mode} prompt[{len(prompt):2d} toks] → {out}")
        dt = time.perf_counter() - t0
        total = int(sum(core.counters.get_value(f"/serve{{engine#{i}}}/tokens/generated")
                        for i in range(2)))
        print(f"\n10 requests, {total} tokens in {dt:.2f}s ({total / dt:.1f} tok/s)")
        print("dispatch:", dict(core.counters.query("/serve{router}/dispatch/*")))
        print("pages in use:",
              dict(core.counters.query("/serve{engine#*}/pages/in_use")))
    else:
        from repro import net as rnet

        # mixed batch: greedy and sampled prompts, futures only (one-sided)
        futures = []
        for i in range(12):
            prompt = rng.integers(1, cfg.vocab_size, size=rng.integers(3, 24)).tolist()
            sp = SamplingParams(temperature=0.8, top_k=40, top_p=0.95) if i % 2 \
                else SamplingParams()
            futures.append((prompt, sp, router.submit(prompt, sampling=sp)))
        total = 0
        for prompt, sp, fut in futures:
            out = fut.get(timeout=600)
            total += len(out)
            mode = "sampled" if sp.temperature > 0 else "greedy "
            print(f"{mode} prompt[{len(prompt):2d} toks] → {out}")
        dt = time.perf_counter() - t0
        print(f"\n12 requests, {total} tokens in {dt:.2f}s ({total / dt:.1f} tok/s)")
        print("dispatch:", dict(core.counters.query("/serve{router}/dispatch/*")))
        per_loc = {}
        for loc in range(args.localities):
            toks = dict(rnet.query_counters(
                loc, "/serve{engine*}/tokens/generated"))
            per_loc[f"locality#{loc}"] = sum(toks.values())
        print("tokens by locality:", per_loc)
        assert all(v > 0 for v in per_loc.values()), \
            "every locality should have served tokens"
        net.shutdown()
    core.finalize()


if __name__ == "__main__":
    main()
