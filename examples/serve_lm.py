"""Batched serving with continuous batching on the AMT runtime.

    PYTHONPATH=src python examples/serve_lm.py

Requests are submitted as futures (one-sided, HPX semantics); the engine
admits them into free slots, prefills each exactly, and decodes the whole
batch per iteration — slots advance independently (per-slot positions).
"""
import time

import jax
import numpy as np

import repro.core as core
from repro.configs import get_config
from repro.dist.plan import get_plan
from repro.models.model import build_model
from repro.serve.engine import Engine, ServeConfig


def main() -> None:
    core.init(num_workers=4)
    cfg = get_config("qwen25_3b", smoke=True)
    model = build_model(cfg, get_plan("futurized"))
    params = model.init(jax.random.PRNGKey(0))
    engine = Engine(model, params,
                    ServeConfig(max_batch=4, cache_len=128, max_new_tokens=12))

    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    futures = []
    for i in range(10):  # 10 requests, 4 slots → continuous batching
        prompt = rng.integers(1, cfg.vocab_size, size=rng.integers(3, 24)).tolist()
        futures.append((prompt, engine.submit(prompt)))
    for prompt, fut in futures:
        out = fut.get(timeout=600)
        print(f"prompt[{len(prompt):2d} toks] → {out}")
    dt = time.perf_counter() - t0
    total = int(core.counters.get_value("/serve{engine#0}/tokens/generated"))
    print(f"\n{len(futures)} requests, {total} tokens in {dt:.2f}s "
          f"({total / dt:.1f} tok/s)")
    print("decode step mean:",
          f"{core.counters.default().timer('/serve{engine#0}/step/duration').get_value() * 1e3:.1f} ms")
    core.finalize()


if __name__ == "__main__":
    main()
