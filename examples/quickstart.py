"""Quickstart: the HPX-style AMT runtime in 60 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp

import repro.core as core
from repro.core import algorithms as alg
from repro.core.dataflow import TaskGraph, dataflow, futurize
from repro.core.executor import par, par_task, vec


def main() -> None:
    # hpx::init — the resource partitioner carves workers into named pools
    # (compute on "default", host I/O progress on "io")
    core.init(policy="local", pools={"default": 4, "io": 1})

    # 1. futures: wait-free asynchronous execution --------------------------
    f = core.spawn(lambda: 21)
    g = f.then_value(lambda x: x * 2)  # continuation, runs on the pool
    print("future chain:", g.get())  # 42

    # 2. futurization: sequential code → dataflow DAG -----------------------
    @futurize
    def mul(a, b):
        return a * b

    @futurize
    def add(a, b):
        return a + b

    print("dataflow DAG:", add(mul(3, 4), mul(5, 6)).get())  # 42

    # explicit task graphs (the tiled-Cholesky pattern)
    graph = TaskGraph()
    graph.add("a", lambda: 2)
    graph.add("b", lambda x: x + 3, deps=["a"])
    graph.add("c", lambda x, y: x * y, deps=["a", "b"])
    print("task graph:", graph.run()["c"].get())  # 10

    # 3. parallel algorithms with execution policies (C++17 style) ----------
    #    policies are pure rewrites: .on(executor) binds resources,
    #    .with_() tunes parameters, par_task returns Futures (two-way)
    data = list(range(1_000))
    print("par reduce:", alg.reduce(par, data))
    io_bound = par.on(core.get_runtime().get_executor("io")).with_(chunk_size=250)
    print("reduce on the io pool:", alg.reduce(io_bound, data))
    print("par_task sort is a Future:", alg.sort(par_task, [3, 1, 2]).get())
    print("vec transform_reduce:",
          int(alg.transform_reduce(vec, jnp.arange(1_000), lambda x: x * x)))

    # 4. AGAS + parcels: send work to data ----------------------------------
    core.agas.register({"weights": jnp.ones((4, 4))}, name="/demo/model")
    fut = core.parcel.apply(lambda obj, s: float(obj["weights"].sum()) * s,
                            "/demo/model", 2.0)
    print("parcel result:", fut.get())  # 32.0

    # 5. performance counters (APEX style, per pool) ------------------------
    for name, value in core.counters.query("/scheduler{default}/tasks/*"):
        print(f"counter {name} = {value:.0f}")
    for name, value in core.counters.query("/scheduler{io}/tasks/executed"):
        print(f"counter {name} = {value:.0f}")

    core.finalize()


if __name__ == "__main__":
    main()
