import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
# 8 placeholder devices so this example can demonstrate real mesh changes
# (must be set before any jax import — same rule as the dry-run).

"""Elastic scaling & fault tolerance: AGAS migration in action.

    PYTHONPATH=src python examples/elastic_migration.py

1. Train on a 4-device mesh (FSDP over 'data').
2. Simulate losing half the fleet → migrate live params+opt onto 2 devices
   (same GID, bumped generation) and KEEP TRAINING.
3. 'Repair' the fleet → restore the async checkpoint onto all 8 devices
   (elastic restart across a different topology).
"""
import jax
import numpy as np

import repro.core as core
from repro.checkpoint import ckpt
from repro.configs import get_config
from repro.core import agas
from repro.data.pipeline import DataConfig
from repro.dist.plan import get_plan
from repro.launch.mesh import make_mesh_shape
from repro.models.model import build_model
from repro.optim.adamw import AdamWConfig
from repro.train import step as step_mod
from repro.train.trainer import TrainConfig, Trainer


def main() -> None:
    core.init(num_workers=4)
    cfg = get_config("starcoder2_3b", smoke=True)
    model = build_model(cfg, get_plan("futurized"))

    mesh4 = make_mesh_shape((4, 2), ("data", "model"))
    mesh2 = make_mesh_shape((2, 1), ("data", "model"))
    mesh8 = make_mesh_shape((8, 1), ("data", "model"))

    trainer = Trainer(model, AdamWConfig(lr=1e-3, total_steps=60),
                      DataConfig(batch_size=8, seq_len=32),
                      TrainConfig(steps=10, log_every=5,
                                  ckpt_dir="checkpoints/elastic"),
                      mesh=mesh4)
    with jax.set_mesh(mesh4):
        trainer.params = jax.device_put(
            trainer.params, model.plan.param_shardings(model.param_specs(), mesh4))
        h1 = trainer.fit(10)
    print(f"[mesh 4x2] 10 steps, loss {h1[-1]['loss']:.3f}")
    print("placement:", next(iter(trainer.params.values())).sharding)
    ck = trainer.checkpoint_async()

    # --- simulate node failure: shrink to 2 devices -------------------------
    rec_before = agas.default().record(trainer.gid)
    with jax.set_mesh(mesh2):
        trainer.elastic_restart(mesh2)
        h2 = trainer.fit(10)
    rec_after = agas.default().record(trainer.gid)
    print(f"[mesh 2x1] survived failure: 10 more steps, loss {h2[-1]['loss']:.3f}")
    print(f"AGAS gid stable: {rec_before.gid == rec_after.gid}, "
          f"generation {rec_before.generation} → {rec_after.generation}")

    # --- fleet repaired: restore checkpoint onto 8 devices -------------------
    ck.get()
    plan = model.plan
    specs = model.param_specs()
    with jax.set_mesh(mesh8):
        shardings = {"params": plan.param_shardings(specs, mesh8),
                     "opt": {"m": plan.param_shardings(specs, mesh8),
                             "v": plan.param_shardings(specs, mesh8),
                             "step": plan.replicated(mesh8)}}
        step, state = ckpt.restore("checkpoints/elastic", shardings=shardings)
    print(f"[mesh 8x1] checkpoint from step {step} restored onto 8 devices; "
          f"placement: {next(iter(state['params'].values())).sharding}")
    core.finalize()


if __name__ == "__main__":
    main()
