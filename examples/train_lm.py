"""End-to-end driver: train a ~100M-parameter LM with the futurized trainer.

    PYTHONPATH=src python examples/train_lm.py --steps 200          # ~100M
    PYTHONPATH=src python examples/train_lm.py --tiny --steps 60    # quick

Demonstrates the full stack: AMT runtime → prefetching data pipeline →
futurized train step (FSDP gather points, donated state) → async
checkpointing → performance counters.
"""
import argparse
import json
import time

import repro.core as core
from repro.configs.base import ModelConfig
from repro.data.pipeline import DataConfig
from repro.dist.plan import get_plan
from repro.models.model import build_model
from repro.models.params import param_count
from repro.optim.adamw import AdamWConfig
from repro.train.trainer import TrainConfig, Trainer


def config_100m() -> ModelConfig:
    """~110M params: a llama-style dense decoder."""
    return ModelConfig(
        name="demo_100m", family="dense",
        num_layers=12, d_model=640, num_heads=10, num_kv_heads=2,
        head_dim=64, d_ff=2560, vocab_size=50304, rope=True,
    )


def config_tiny() -> ModelConfig:
    return ModelConfig(
        name="demo_tiny", family="dense",
        num_layers=4, d_model=128, num_heads=4, num_kv_heads=2,
        head_dim=32, d_ff=512, vocab_size=2048, rope=True,
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--ckpt-dir", default="checkpoints/train_lm")
    args = ap.parse_args()

    core.init(num_workers=4)
    cfg = config_tiny() if args.tiny else config_100m()
    model = build_model(cfg, get_plan("futurized"))
    n = param_count(model.param_specs())
    print(f"model: {cfg.name}  params={n / 1e6:.1f}M")

    trainer = Trainer(
        model,
        AdamWConfig(lr=3e-3, warmup_steps=max(args.steps // 10, 1),
                    total_steps=args.steps, weight_decay=0.01),
        DataConfig(batch_size=args.batch, seq_len=args.seq, prefetch=2),
        TrainConfig(steps=args.steps, log_every=10,
                    ckpt_every=max(args.steps // 4, 1), ckpt_dir=args.ckpt_dir),
    )
    t0 = time.time()
    history = trainer.fit()
    dt = time.time() - t0
    for h in history:
        print(json.dumps(h))
    tokens = args.steps * args.batch * args.seq
    print(f"\n{args.steps} steps / {tokens} tokens in {dt:.1f}s "
          f"({tokens / dt:.0f} tok/s)")
    print("first→last loss:", history[0]["loss"], "→", history[-1]["loss"])
    print("counters:", json.dumps(dict(core.counters.query("/train*")), indent=1))
    core.finalize()


if __name__ == "__main__":
    main()
