"""Tiled Cholesky by futurization — the paper's linear-algebra showcase.

    PYTHONPATH=src python examples/tiled_cholesky.py

The factorization is expressed as a dataflow DAG: each tile op (potrf /
trsm / syrk / gemm) is a task whose inputs are futures of other tiles.
No global barrier anywhere — tasks fire the moment their tiles are ready,
which is exactly the paper's 'constraint-based synchronization'.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))  # for benchmarks/

import repro.core as core
from benchmarks.bench_cholesky import tiled_cholesky


def main() -> None:
    core.init(num_workers=4)
    rng = np.random.default_rng(7)
    N, tile = 1024, 128
    X = rng.standard_normal((N, N)).astype(np.float32)
    A = X @ X.T + N * np.eye(N, dtype=np.float32)

    t0 = time.perf_counter()
    L = tiled_cholesky(A, tile)
    dt = time.perf_counter() - t0
    err = float(np.max(np.abs(L @ L.T - A)) / np.max(np.abs(A)))
    n_tiles = (N // tile) * (N // tile + 1) // 2
    print(f"N={N} tile={tile} ({n_tiles} tiles) in {dt * 1e3:.1f} ms, "
          f"reconstruction rel err {err:.2e}")
    print("tasks executed:",
          int(core.counters.get_value("/scheduler{default}/tasks/executed")))
    core.finalize()


if __name__ == "__main__":
    main()
