"""Checkpointing: async save through the AMT scheduler, elastic restore.

Fault-tolerance story (DESIGN.md §5):

- **async save** — ``save_async`` snapshots device arrays to host
  (``jax.device_get`` waits only for the values, not the trainer) and
  writes .npy files from a task on the resource partitioner's "io" pool;
  the train loop keeps dispatching while I/O runs (overlap, P1/P2) and
  disk writes never steal compute-pool slots.
- **elastic restore** — a checkpoint written on mesh A restores onto mesh B
  with different device count/topology: leaves are loaded host-side and
  ``device_put`` against B's shardings (AGAS migration with the filesystem
  as transport).
- **integrity** — manifest with step, per-leaf shape/dtype and config
  fingerprint; ``latest_step`` scans for resumable checkpoints, torn writes
  are detected by the manifest being written last.
- **by GID, across localities** — ``save_gid`` snapshots any AGAS object
  (local *or* on another locality: the state travels home over the
  parcelport) and records its identity in ``agas.json``; ``restore_gid``
  installs the state on any chosen locality under the original symbolic
  name, re-publishing through the root AGAS table.  This is what lets an
  engine be respawned on a fresh locality: the filesystem is just another
  parcelport with infinite latency.
- **segment-parallel, by GID** — ``save_partitioned`` checkpoints a
  :class:`~repro.container.PartitionedVector` work-to-data: one parcel
  per segment asks the segment's *owner* to write its own ``.npy`` shard
  (no element crosses the wire; writes overlap across localities), and
  ``partitioned.json`` records geometry + per-shard GIDs.
  ``restore_partitioned`` is the mirror: each owner reads its own shard
  back into a fresh AGAS segment — owners are remapped when the restore
  runtime has a different locality count (elastic, like ``restore``'s
  mesh remap).
"""

from __future__ import annotations

import hashlib
import json
import shutil
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

from repro.core import counters as _counters
from repro.core import executor as _executor
from repro.core.future import Future


def _fingerprint(tree: Dict[str, Any]) -> str:
    desc = json.dumps({k: [list(np.shape(v)), str(np.asarray(v).dtype) if not hasattr(v, "dtype") else str(v.dtype)]
                       for k, v in sorted(tree.items())}, sort_keys=True)
    return hashlib.sha256(desc.encode()).hexdigest()[:16]


_SEP = "\x1f"  # unit separator: cannot collide with "/" in param paths


def _flatten(tree: Any, prefix: str = "") -> Dict[str, Any]:
    out: Dict[str, Any] = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}{_SEP}"))
    else:
        out[prefix[: -len(_SEP)]] = tree
    return out


def _unflatten(flat: Dict[str, Any]) -> Any:
    root: Dict[str, Any] = {}
    for path, v in flat.items():
        parts = path.split(_SEP)
        d = root
        for p in parts[:-1]:
            d = d.setdefault(p, {})
        d[parts[-1]] = v
    return root


def save(ckpt_dir: Path, step: int, state: Dict[str, Any]) -> Path:
    """Synchronous save: state is a pytree of arrays (params/opt/etc)."""
    ckpt_dir = Path(ckpt_dir)
    out = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f".tmp_step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    flat = _flatten(state)
    host = jax.device_get(flat)
    manifest = {"step": step, "leaves": {}, "fingerprint": _fingerprint(host)}
    for i, (path, arr) in enumerate(sorted(host.items())):
        arr = np.asarray(arr)
        fname = f"leaf_{i:05d}.npy"
        np.save(tmp / fname, arr)
        manifest["leaves"][path] = {"file": fname, "shape": list(arr.shape),
                                    "dtype": str(arr.dtype)}
    # manifest last: presence ⇒ checkpoint complete (torn-write detection)
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if out.exists():
        shutil.rmtree(out)
    tmp.rename(out)
    _counters.counter("/checkpoint{store#0}/saves/cumulative").increment()
    return out


def save_async(ckpt_dir: Path, step: int, state: Dict[str, Any]) -> Future:
    """Snapshot to host now; write from the resource partitioner's "io"
    pool (trainer keeps going; disk I/O never steals compute slots)."""
    host = jax.device_get(_flatten(state))  # snapshot before mutation

    def _write() -> Path:
        return save(ckpt_dir, step, _unflatten(host))

    return _executor.get_executor("io", fallback="default").async_execute(_write)


def latest_step(ckpt_dir: Path) -> Optional[int]:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = []
    for p in ckpt_dir.glob("step_*"):
        if (p / "manifest.json").exists():
            steps.append(int(p.name.split("_")[1]))
    return max(steps) if steps else None


def save_gid(ckpt_dir: Path, step: int, target: Any,
             timeout: float = 120.0) -> Path:
    """Save an AGAS-registered object's state by GID or symbolic name.

    A locally-resolvable target is snapshotted in-process; otherwise the
    multi-locality runtime (``repro.net``) resolves the owner through the
    root AGAS table and fetches a host copy over the parcelport.  The
    checkpoint directory gains an ``agas.json`` recording the GID and name
    so ``restore_gid`` can re-install the object under its old identity.
    """
    from repro.core import agas as _agas

    a = _agas.default()
    name: Optional[str] = target if isinstance(target, str) else None
    if a.contains(target):
        rec = a.record(target)
        state, gid, name = rec.obj, rec.gid, rec.name
    else:
        from repro import net as _net

        _net.require()
        meta = _net.describe(target, timeout=timeout)
        gid = _agas.GID(*meta["gid"])
        name = name if name is not None else meta["name"]
        # describe cached the resolution: the fetch goes straight to the owner
        state = _net.fetch(gid, timeout=timeout)
    out = save(ckpt_dir, step, state)
    (out / "agas.json").write_text(json.dumps(
        {"gid": [gid.locality, gid.seq], "name": name}))
    return out


def restore_gid(ckpt_dir: Path, step: Optional[int] = None,
                locality: Optional[int] = None,
                timeout: float = 120.0) -> Tuple[int, Any]:
    """Restore a ``save_gid`` checkpoint onto ``locality`` (default: here).

    The state is registered (or rebound) under the checkpoint's symbolic
    name at the target locality — publishing through the root AGAS table —
    and the *new* GID is returned: the object was re-homed, so it carries
    the identity of the locality that now owns it (elastic respawn, not
    resurrection of a dead process's address space)."""
    from repro.core import agas as _agas

    step, state = restore(ckpt_dir, step)
    d = Path(ckpt_dir) / f"step_{step:08d}"
    meta_path = d / "agas.json"
    meta = json.loads(meta_path.read_text()) if meta_path.exists() else {}
    name = meta.get("name")

    from repro import net as _net

    net = _net.current()
    if locality is not None and net is None:
        raise RuntimeError(
            f"restore_gid(locality={locality}) needs a multi-locality "
            "runtime: call repro.net.bootstrap(n) first")
    if net is None or locality is None or locality == net.locality:
        a = _agas.default()
        if name is not None and a.contains(name):
            gid = a.gid_of(name)
            a.rebind(gid, state)
        else:
            gid = a.register(state, name=name)
        return step, gid
    from repro.net import remote as _remote

    key = _net.run_on(locality, _remote._install_state, name,
                      state).get(timeout=timeout)
    return step, _agas.GID(*key)


# --------------------------------------------------- partitioned containers
from repro.core import parcel as _parcel  # noqa: E402  (actions below)


@_parcel.action
def _write_segment_shard(obj: Any, dirpath: str, fname: str) -> Dict[str, Any]:
    """Object-targeted: runs at the segment's owner — each locality writes
    its own shard (the single-host analogue of per-node burst buffers)."""
    from repro.core import agas as _agas

    arr = np.asarray(obj)
    np.save(Path(dirpath) / fname, arr)
    return {"file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype),
            "locality": _agas.default().locality}


@_parcel.action
def _read_segment_shard(rt: Any, dirpath: str, fname: str,
                        seg_name: str) -> list:
    """Runs at the chosen restore owner: load the shard, register it."""
    from repro.core import agas as _agas

    arr = np.load(Path(dirpath) / fname)
    gid = _agas.default().register(arr, name=seg_name)
    return [gid.locality, gid.seq]


def save_partitioned(ckpt_dir: Path, step: int, pv: Any,
                     timeout: float = 120.0) -> Path:
    """Checkpoint a PartitionedVector segment-parallel: one parcel per
    segment, the *owner* writes its shard (zero element bytes on the wire,
    I/O overlapped across localities).  Torn writes are detected the same
    way as :func:`save`: ``partitioned.json`` is written last."""
    from repro import net as _net

    ckpt_dir = Path(ckpt_dir)
    out = ckpt_dir / f"pvec_{step:08d}"
    tmp = ckpt_dir / f".tmp_pvec_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    futs = [_net.apply_remote(_write_segment_shard, pv.segment_gid(j),
                              str(tmp), f"shard_{j:05d}.npy")
            for j in range(pv.nsegments)]
    shards = [f.get(timeout=timeout) for f in futs]
    manifest = {"step": step, "name": pv.name, "dtype": pv.dtype.str,
                "element_shape": list(pv.element_shape),
                "dist": pv.dist.to_meta(), "shards": shards}
    (tmp / "partitioned.json").write_text(json.dumps(manifest))
    if out.exists():
        shutil.rmtree(out)
    tmp.rename(out)
    _counters.counter("/checkpoint{store#0}/saves/cumulative").increment()
    return out


def latest_partitioned_step(ckpt_dir: Path) -> Optional[int]:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in ckpt_dir.glob("pvec_*")
             if (p / "partitioned.json").exists()]
    return max(steps) if steps else None


def restore_partitioned(ckpt_dir: Path, step: Optional[int] = None,
                        name: Optional[str] = None,
                        timeout: float = 120.0) -> Tuple[int, Any]:
    """Rebuild a PartitionedVector from its shards, each read by the
    locality that will own it (owner ``o`` of the saving run maps to
    ``o % n_localities`` of this run — elastic restore across different
    locality counts).  ``name`` overrides the saved symbolic name (e.g.
    to restore next to a still-live original)."""
    from repro import net as _net
    from repro.container.distribution import Distribution
    from repro.container.partitioned_vector import PartitionedVector

    net = _net.require()
    ckpt_dir = Path(ckpt_dir)
    if step is None:
        step = latest_partitioned_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no partitioned checkpoint under {ckpt_dir}")
    d = ckpt_dir / f"pvec_{step:08d}"
    manifest = json.loads((d / "partitioned.json").read_text())
    name = name or manifest["name"]
    meta = dict(manifest["dist"])
    # restore where the data lived at SAVE time (each shard records the
    # locality that wrote it — rebalances survive a save/restore cycle),
    # not the creation-time owners the geometry happens to carry
    meta["owners"] = [s["locality"] % net.n_localities
                      for s in manifest["shards"]]
    dist = Distribution.from_meta(meta)
    futs = [_net.run_on(dist.owners[j], _read_segment_shard, str(d),
                        shard["file"], f"{name}/seg{j}")
            for j, shard in enumerate(manifest["shards"])]
    keys = [tuple(f.get(timeout=timeout)) for f in futs]
    pv = PartitionedVector.from_parts(name, dist, manifest["dtype"],
                                      tuple(manifest["element_shape"]), keys)
    _counters.counter("/checkpoint{store#0}/restores/cumulative").increment()
    return manifest["step"], pv


def restore(ckpt_dir: Path, step: Optional[int] = None,
            shardings: Optional[Any] = None) -> Tuple[int, Dict[str, Any]]:
    """Load a checkpoint; with ``shardings`` (pytree matching the state),
    leaves are placed onto the (possibly different) target mesh — elastic
    restart."""
    ckpt_dir = Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    d = ckpt_dir / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    flat = {}
    for path, meta in manifest["leaves"].items():
        flat[path] = np.load(d / meta["file"])
    state = _unflatten(flat)
    if shardings is not None:
        flat_sh = _flatten(shardings)
        state = _unflatten({
            p: jax.device_put(v, flat_sh[p]) if p in flat_sh else v
            for p, v in _flatten(state).items()
        })
    _counters.counter("/checkpoint{store#0}/restores/cumulative").increment()
    return manifest["step"], state
