"""repro — an HPX-style Asynchronous Many-Task (AMT) runtime for JAX on TPU pods.

Reproduction of: "HPX — An open source C++ Standard Library for Parallelism
and Concurrency" (Heller, Diehl, Byerly, Biddiscombe, Kaiser), adapted from a
C++ cluster runtime to a JAX/XLA TPU-pod training & serving framework.

Public API mirrors the HPX surface:

  repro.core.init / finalize / Runtime     — runtime bring-up (hpx::init)
  repro.core.spawn / async_ / dataflow     — task spawning & futurization
  repro.core.Future / when_all / when_any  — asynchronous primitives
  repro.core.agas                          — Active Global Address Space
  repro.core.parcel                        — active messages (send work to data)
  repro.core.counters                      — APEX-style performance counters
  repro.core.algorithms                    — C++17-style parallel algorithms
"""

from repro import _compat  # noqa: F401  (backfills old-JAX API gaps; must be first)

__version__ = "1.0.0"
