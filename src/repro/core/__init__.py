"""repro.core — the paper's contribution: an HPX-style AMT runtime for JAX.

Surface mirrors HPX:

    init / finalize / Runtime            hpx::init / hpx::finalize
    spawn / async_                       hpx::async            -> Future
    dataflow / futurize / TaskGraph      hpx::dataflow         (futurization)
    Future / Promise / when_all / when_any / make_ready_future
    agas                                 Active Global Address Space
    parcel                               active messages (send work to data)
    counters                             APEX-style performance counters
    algorithms / executor                C++17 parallel algorithms + policies
    migration                            object migration / elastic resharding
"""

from repro.core import agas, algorithms, counters, executor, migration, parcel
from repro.core.dataflow import TaskGraph, dataflow, futurize
from repro.core.executor import (
    ExecutionPolicy,
    Executor,
    MeshExecutor,
    PriorityExecutor,
    SequencedExecutor,
    ThreadPoolExecutor,
    get_executor,
)
from repro.core.future import (
    Channel,
    ChannelClosed,
    Future,
    FutureError,
    Promise,
    make_exceptional_future,
    make_ready_future,
    unwrap,
    wait_all,
    when_all,
    when_any,
)
from repro.core.scheduler import (
    PRIORITY_HIGH,
    PRIORITY_LOW,
    PRIORITY_NORMAL,
    Runtime,
    ThreadPool,
    async_,
    current_runtime,
    finalize,
    get_runtime,
    init,
    spawn,
)

__all__ = [
    "agas", "algorithms", "counters", "executor", "migration", "parcel",
    "TaskGraph", "dataflow", "futurize",
    "ExecutionPolicy", "Executor", "MeshExecutor", "PriorityExecutor",
    "SequencedExecutor", "ThreadPoolExecutor", "get_executor",
    "Channel", "ChannelClosed",
    "Future", "FutureError", "Promise", "make_exceptional_future",
    "make_ready_future", "unwrap", "wait_all", "when_all", "when_any",
    "PRIORITY_HIGH", "PRIORITY_LOW", "PRIORITY_NORMAL", "Runtime",
    "ThreadPool", "async_",
    "current_runtime", "finalize", "get_runtime", "init", "spawn",
]
