"""APEX-style performance-counter framework (HPX §2.4).

HPX exposes *intrinsic* performance counters under hierarchical symbolic
names such as ``/threads{locality#0/total}/count/cumulative``; counters are
registered with AGAS so they are readable from any locality, and they feed
runtime-adaptivity decisions.

This module is the TPU/JAX adaptation: counters sample host-side runtime
metrics (task counts, steals, queue depths, step latencies) *and*
HLO-derived metrics (collective bytes, FLOPs) published by the dry-run /
trainer.  They are registered into :mod:`repro.core.agas` under their
symbolic name so they resolve exactly like any other global object.

Counter kinds
-------------
- ``Counter``        monotonically increasing value (``.../cumulative``)
- ``Gauge``          instantaneous value (``.../instantaneous``)
- ``TimerCounter``   accumulates durations; exposes count/total/mean/max
- callable counters  lazily evaluated on read (e.g. queue length probes)
"""

from __future__ import annotations

import fnmatch
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterable, List, Optional, Tuple


class Counter:
    """Monotonic cumulative counter."""

    __slots__ = ("name", "_value", "_lock")

    def __init__(self, name: str, initial: float = 0.0):
        self.name = name
        self._value = initial
        self._lock = threading.Lock()

    def increment(self, by: float = 1.0) -> None:
        with self._lock:
            self._value += by

    # HPX counters are read through a uniform ``get_value`` interface.
    def get_value(self) -> float:
        with self._lock:
            return self._value

    def reset(self) -> None:
        with self._lock:
            self._value = 0.0


class Gauge:
    """Instantaneous value counter."""

    __slots__ = ("name", "_value", "_lock")

    def __init__(self, name: str, initial: float = 0.0):
        self.name = name
        self._value = initial
        self._lock = threading.Lock()

    def set(self, value: float) -> None:
        with self._lock:
            self._value = value

    def get_value(self) -> float:
        with self._lock:
            return self._value

    def reset(self) -> None:
        self.set(0.0)


class TimerCounter:
    """Duration accumulator: count/total/mean/max, with EMA for adaptivity.

    The exponentially-weighted mean is what the straggler detector and the
    auto-tuner consume (cheap, windowless).
    """

    __slots__ = ("name", "count", "total", "max", "ema", "ema_alpha", "_lock")

    def __init__(self, name: str, ema_alpha: float = 0.2):
        self.name = name
        self.count = 0
        self.total = 0.0
        self.max = 0.0
        self.ema: Optional[float] = None
        self.ema_alpha = ema_alpha
        self._lock = threading.Lock()

    def add(self, seconds: float) -> None:
        with self._lock:
            self.count += 1
            self.total += seconds
            self.max = max(self.max, seconds)
            self.ema = (
                seconds
                if self.ema is None
                else self.ema_alpha * seconds + (1.0 - self.ema_alpha) * self.ema
            )

    def time(self):
        """Context manager measuring a block."""
        return _TimerCtx(self)

    def get_value(self) -> float:  # mean, for the uniform interface
        with self._lock:
            return self.total / self.count if self.count else 0.0

    def stats(self) -> Dict[str, float]:
        with self._lock:
            mean = self.total / self.count if self.count else 0.0
            return {
                "count": float(self.count),
                "total": self.total,
                "mean": mean,
                "max": self.max,
                "ema": self.ema if self.ema is not None else 0.0,
            }

    def reset(self) -> None:
        with self._lock:
            self.count = 0
            self.total = 0.0
            self.max = 0.0
            self.ema = None


class _TimerCtx:
    __slots__ = ("timer", "t0")

    def __init__(self, timer: TimerCounter):
        self.timer = timer

    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.timer.add(time.perf_counter() - self.t0)
        return False


@dataclass
class CounterRegistry:
    """Registry of hierarchically-named counters (the APEX analogue).

    Names follow the HPX convention ``/object{instance}/metric``, e.g.::

        /scheduler{default}/tasks/executed
        /scheduler{io}/tasks/stolen
        /agas{root}/objects/count
        /train{step}/duration
        /parcel{port#0}/bytes/sent
    """

    _counters: Dict[str, Any] = field(default_factory=dict)
    _lock: threading.RLock = field(default_factory=threading.RLock)

    def register(self, counter: Any, name: Optional[str] = None) -> Any:
        name = name or counter.name
        with self._lock:
            self._counters[name] = counter
        # Publish into AGAS so the counter resolves like a global object.
        try:  # deferred import: agas depends on nothing here
            from repro.core import agas as _agas

            _agas.default().register_name(f"/counters{name}", counter, replace=True)
        except Exception:
            pass  # AGAS not initialised (e.g. unit tests on bare registry)
        return counter

    def counter(self, name: str) -> Counter:
        """Get-or-create a cumulative counter."""
        with self._lock:
            c = self._counters.get(name)
            if c is None:
                c = Counter(name)
                self._counters[name] = c
            return c

    def gauge(self, name: str) -> Gauge:
        with self._lock:
            c = self._counters.get(name)
            if c is None:
                c = Gauge(name)
                self._counters[name] = c
            return c

    def timer(self, name: str) -> TimerCounter:
        with self._lock:
            c = self._counters.get(name)
            if c is None:
                c = TimerCounter(name)
                self._counters[name] = c
            return c

    def register_callable(self, name: str, fn: Callable[[], float]) -> None:
        """Lazily-evaluated counter (e.g. instantaneous queue length)."""
        with self._lock:
            self._counters[name] = _CallableCounter(name, fn)

    def get(self, name: str) -> Optional[Any]:
        with self._lock:
            return self._counters.get(name)

    def get_value(self, name: str) -> float:
        c = self.get(name)
        if c is None:
            raise KeyError(f"no such performance counter: {name}")
        return c.get_value()

    def query(self, pattern: str) -> List[Tuple[str, float]]:
        """Glob query, HPX ``--hpx:print-counter`` style: ``/scheduler*``.

        The ``(name, counter)`` pairs are copied under the lock, then
        evaluated outside it: ``get_value`` may run a callable counter that
        takes other locks or registers further counters (pump threads do),
        so evaluating while holding the registry lock would deadlock or
        die with "dict changed size during iteration"."""
        with self._lock:
            items = [(n, self._counters[n]) for n in sorted(self._counters)
                     if fnmatch.fnmatch(n, pattern)]
        return [(n, c.get_value()) for n, c in items]

    def names(self) -> List[str]:
        with self._lock:
            return sorted(self._counters)

    def reset_all(self) -> None:
        with self._lock:
            for c in self._counters.values():
                if hasattr(c, "reset"):
                    c.reset()

    def snapshot(self, pattern: str = "*") -> Dict[str, float]:
        """Consistent point-in-time copy: membership is fixed under the
        lock, values are read outside it (see :meth:`query` for why).  This
        is also the payload of the remote-snapshot action — a locality's
        counters are read across the parcelport via
        ``repro.net.query_counters``."""
        return dict(self.query(pattern))


class _CallableCounter:
    __slots__ = ("name", "_fn")

    def __init__(self, name: str, fn: Callable[[], float]):
        self.name = name
        self._fn = fn

    def get_value(self) -> float:
        return float(self._fn())

    def reset(self) -> None:
        pass


_default: Optional[CounterRegistry] = None
_default_lock = threading.Lock()


def default() -> CounterRegistry:
    """Process-wide registry (lives across runtime init/finalize)."""
    global _default
    with _default_lock:
        if _default is None:
            _default = CounterRegistry()
        return _default


def counter(name: str) -> Counter:
    return default().counter(name)


def gauge(name: str) -> Gauge:
    return default().gauge(name)


def timer(name: str) -> TimerCounter:
    return default().timer(name)


def query(pattern: str) -> List[Tuple[str, float]]:
    return default().query(pattern)


def get_value(name: str) -> float:
    return default().get_value(name)
