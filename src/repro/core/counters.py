"""APEX-style performance-counter framework (HPX §2.4).

HPX exposes *intrinsic* performance counters under hierarchical symbolic
names such as ``/threads{locality#0/total}/count/cumulative``; counters are
registered with AGAS so they are readable from any locality, and they feed
runtime-adaptivity decisions.

This module is the TPU/JAX adaptation: counters sample host-side runtime
metrics (task counts, steals, queue depths, step latencies) *and*
HLO-derived metrics (collective bytes, FLOPs) published by the dry-run /
trainer.  They are registered into :mod:`repro.core.agas` under their
symbolic name so they resolve exactly like any other global object.

Counter kinds
-------------
- ``Counter``        monotonically increasing value (``.../cumulative``)
- ``Gauge``          instantaneous value (``.../instantaneous``)
- ``TimerCounter``   accumulates durations; exposes count/total/mean/max
- ``Histogram``      log-bucketed distribution; exposes p50/p95/p99
- callable counters  lazily evaluated on read (e.g. queue length probes)

Every counter created through the default registry — whether via
``register`` or the ``counter()/gauge()/timer()/histogram()`` get-or-create
helpers — is published into AGAS under ``/counters<name>``, so
``net.query_counters`` resolves all of them, not just the explicitly
registered few.
"""

from __future__ import annotations

import fnmatch
import logging
import math
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterable, List, Optional, Tuple

_log = logging.getLogger(__name__)


class Counter:
    """Monotonic cumulative counter."""

    __slots__ = ("name", "_value", "_lock")

    def __init__(self, name: str, initial: float = 0.0):
        self.name = name
        self._value = initial
        self._lock = threading.Lock()

    def increment(self, by: float = 1.0) -> None:
        with self._lock:
            self._value += by

    # HPX counters are read through a uniform ``get_value`` interface.
    def get_value(self) -> float:
        with self._lock:
            return self._value

    def reset(self) -> None:
        with self._lock:
            self._value = 0.0


class Gauge:
    """Instantaneous value counter."""

    __slots__ = ("name", "_value", "_lock")

    def __init__(self, name: str, initial: float = 0.0):
        self.name = name
        self._value = initial
        self._lock = threading.Lock()

    def set(self, value: float) -> None:
        with self._lock:
            self._value = value

    def get_value(self) -> float:
        with self._lock:
            return self._value

    def reset(self) -> None:
        self.set(0.0)


class Histogram:
    """Log-bucketed distribution counter: p50/p95/p99 at O(1) per sample.

    Positive samples land in bucket ``floor(log(v) / log(growth))`` —
    geometric buckets, so the quantile estimate (the bucket's geometric
    midpoint, clamped to the observed [min, max]) carries a bounded
    *relative* error of ``growth**0.5`` (≈4% at the default growth 1.08)
    across the full dynamic range, from microseconds to minutes.  This is
    the same trick HDR-style histograms and APEX task timers use.  Samples
    ``<= 0`` are counted in a separate underflow bucket.
    """

    __slots__ = ("name", "growth", "_log_growth", "_buckets", "_zero",
                 "count", "_sum", "_min", "_max", "_lock")

    def __init__(self, name: str, growth: float = 1.08):
        if growth <= 1.0:
            raise ValueError(f"histogram growth must be > 1, got {growth}")
        self.name = name
        self.growth = growth
        self._log_growth = math.log(growth)
        self._buckets: Dict[int, int] = {}
        self._zero = 0  # samples <= 0 (log-bucketing needs positives)
        self.count = 0
        self._sum = 0.0
        self._min = math.inf
        self._max = -math.inf
        self._lock = threading.Lock()

    def add(self, value: float) -> None:
        v = float(value)
        with self._lock:
            self.count += 1
            self._sum += v
            if v < self._min:
                self._min = v
            if v > self._max:
                self._max = v
            if v <= 0.0:
                self._zero += 1
            else:
                idx = int(math.floor(math.log(v) / self._log_growth))
                self._buckets[idx] = self._buckets.get(idx, 0) + 1

    def _quantile_locked(self, q: float) -> float:
        if self.count == 0:
            return 0.0
        q = min(1.0, max(0.0, q))
        # nearest-rank at 0-based index floor(q*(n-1)) — matches a sorted
        # array oracle, which is what the property test checks against
        target = int(math.floor(q * (self.count - 1))) + 1
        cum = self._zero
        if cum >= target:
            return self._min if self._min < 0.0 else 0.0
        for idx in sorted(self._buckets):
            cum += self._buckets[idx]
            if cum >= target:
                mid = math.exp((idx + 0.5) * self._log_growth)
                return min(max(mid, self._min), self._max)
        return self._max  # pragma: no cover - counts always sum to count

    def quantile(self, q: float) -> float:
        with self._lock:
            return self._quantile_locked(q)

    def percentiles(self) -> Dict[str, float]:
        with self._lock:
            return {"p50": self._quantile_locked(0.50),
                    "p95": self._quantile_locked(0.95),
                    "p99": self._quantile_locked(0.99)}

    def stats(self) -> Dict[str, float]:
        with self._lock:
            return {
                "count": float(self.count),
                "mean": self._sum / self.count if self.count else 0.0,
                "min": self._min if self.count else 0.0,
                "max": self._max if self.count else 0.0,
                "p50": self._quantile_locked(0.50),
                "p95": self._quantile_locked(0.95),
                "p99": self._quantile_locked(0.99),
            }

    def get_value(self) -> float:  # median, for the uniform interface
        return self.quantile(0.5)

    def _buckets_locked(self) -> List[Tuple[float, int]]:
        out: List[Tuple[float, int]] = []
        if self._zero:
            out.append((0.0, self._zero))
        for idx in sorted(self._buckets):
            out.append((math.exp((idx + 1) * self._log_growth),
                        self._buckets[idx]))
        return out

    def buckets(self) -> List[Tuple[float, int]]:
        """Occupied buckets as ``[(upper_bound, count), ...]`` ascending —
        the raw material for a native Prometheus histogram.  Bucket ``idx``
        holds samples in ``[growth**idx, growth**(idx+1))`` so its upper
        bound is ``growth**(idx+1)``; samples ``<= 0`` surface as an
        explicit leading ``(0.0, n)`` bucket."""
        with self._lock:
            return self._buckets_locked()

    def export(self) -> Dict[str, Any]:
        """Typed export record (kind + raw buckets + sum/count) — what the
        OpenMetrics exposition tier ships over the wire, since
        ``snapshot_stats`` collapses the distribution to quantiles.  One
        lock hold: bucket counts always sum to ``count`` (the +Inf bucket
        of the rendered histogram must equal ``_count`` exactly)."""
        with self._lock:
            return {"kind": "histogram", "sum": self._sum, "count": self.count,
                    "buckets": self._buckets_locked()}

    def reset(self) -> None:
        with self._lock:
            self._buckets.clear()
            self._zero = 0
            self.count = 0
            self._sum = 0.0
            self._min = math.inf
            self._max = -math.inf


class TimerCounter:
    """Duration accumulator: count/total/mean/max, with EMA for adaptivity.

    The exponentially-weighted mean is what the straggler detector and the
    auto-tuner consume (cheap, windowless).  With ``percentiles=True`` the
    timer additionally feeds a :class:`Histogram`, so ``stats()`` reports
    p50/p95/p99 — the serve-engine latency timers use this to answer "why
    is p99 bad" without a trace.
    """

    __slots__ = ("name", "count", "total", "max", "ema", "ema_alpha",
                 "_hist", "_lock")

    def __init__(self, name: str, ema_alpha: float = 0.2,
                 percentiles: bool = False):
        self.name = name
        self.count = 0
        self.total = 0.0
        self.max = 0.0
        self.ema: Optional[float] = None
        self.ema_alpha = ema_alpha
        self._hist = Histogram(name) if percentiles else None
        self._lock = threading.Lock()

    def add(self, seconds: float) -> None:
        with self._lock:
            self.count += 1
            self.total += seconds
            self.max = max(self.max, seconds)
            self.ema = (
                seconds
                if self.ema is None
                else self.ema_alpha * seconds + (1.0 - self.ema_alpha) * self.ema
            )
        if self._hist is not None:  # histogram has its own lock
            self._hist.add(seconds)

    def time(self):
        """Context manager measuring a block."""
        return _TimerCtx(self)

    def enable_percentiles(self) -> None:
        """Attach a histogram to an already-created timer (idempotent)."""
        with self._lock:
            if self._hist is None:
                self._hist = Histogram(self.name)

    def quantile(self, q: float) -> float:
        """Histogram quantile in seconds (0.0 without percentiles=True) —
        the live p99 the flight-recorder trigger polls."""
        h = self._hist
        return h.quantile(q) if h is not None else 0.0

    def get_value(self) -> float:  # mean, for the uniform interface
        with self._lock:
            return self.total / self.count if self.count else 0.0

    def stats(self) -> Dict[str, float]:
        with self._lock:
            mean = self.total / self.count if self.count else 0.0
            out = {
                "count": float(self.count),
                "total": self.total,
                "mean": mean,
                "max": self.max,
                "ema": self.ema if self.ema is not None else 0.0,
            }
        if self._hist is not None:
            out.update(self._hist.percentiles())
        return out

    def export(self) -> Dict[str, Any]:
        """Typed export record: with ``percentiles=True`` the attached
        histogram's raw buckets ride along (rendered as a native Prometheus
        histogram in seconds); without, count/total still expose the
        ``_count``/``_sum`` pair."""
        h = self._hist
        if h is not None:
            rec = h.export()
            rec["kind"] = "timer"
            return rec
        with self._lock:
            return {"kind": "timer", "sum": self.total,
                    "count": self.count, "buckets": None}

    def reset(self) -> None:
        with self._lock:
            self.count = 0
            self.total = 0.0
            self.max = 0.0
            self.ema = None
        if self._hist is not None:
            self._hist.reset()


class _TimerCtx:
    __slots__ = ("timer", "t0")

    def __init__(self, timer: TimerCounter):
        self.timer = timer

    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.timer.add(time.perf_counter() - self.t0)
        return False


@dataclass
class CounterRegistry:
    """Registry of hierarchically-named counters (the APEX analogue).

    Names follow the HPX convention ``/object{instance}/metric``, e.g.::

        /scheduler{default}/tasks/executed
        /scheduler{io}/tasks/stolen
        /agas{root}/objects/count
        /train{step}/duration
        /parcel{port#0}/bytes/sent
    """

    _counters: Dict[str, Any] = field(default_factory=dict)
    _lock: threading.RLock = field(default_factory=threading.RLock)

    def _publish(self, name: str, counter: Any) -> None:
        """Mirror a counter into AGAS under ``/counters<name>`` — the ONE
        registration path every creation route funnels through, so anything
        in the registry resolves via ``net.query_counters`` name lookup.

        Must be called OUTSIDE ``self._lock``: AGAS construction creates its
        own gauges through this registry, so publishing while holding the
        registry lock inverts the lock order against ``agas.default()``.
        Bare registries (unit tests) stay out of the global namespace.
        """
        if self is not _default:
            return
        from repro.core import agas as _agas

        inst = _agas.peek()
        if inst is None:
            # The one expected miss: AGAS not constructed yet (or mid-
            # construction on this very thread).  agas.default() runs a
            # republish sweep right after construction, so nothing is lost.
            return
        try:
            inst.register_name(f"/counters{name}", counter, replace=True)
        except Exception:
            _log.exception("failed to publish counter %r into AGAS", name)

    def register(self, counter: Any, name: Optional[str] = None) -> Any:
        name = name or counter.name
        with self._lock:
            self._counters[name] = counter
        self._publish(name, counter)
        return counter

    def _get_or_create(self, name: str, factory: Callable[[str], Any]) -> Any:
        created = None
        with self._lock:
            c = self._counters.get(name)
            if c is None:
                c = created = factory(name)
                self._counters[name] = c
        if created is not None:
            self._publish(name, created)
        return c

    def counter(self, name: str) -> Counter:
        """Get-or-create a cumulative counter."""
        return self._get_or_create(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get_or_create(name, Gauge)

    def timer(self, name: str, percentiles: bool = False) -> TimerCounter:
        t = self._get_or_create(
            name, lambda n: TimerCounter(n, percentiles=percentiles))
        if percentiles and isinstance(t, TimerCounter):
            t.enable_percentiles()  # upgrade a pre-existing plain timer
        return t

    def histogram(self, name: str, growth: float = 1.08) -> Histogram:
        return self._get_or_create(name, lambda n: Histogram(n, growth=growth))

    def register_callable(self, name: str, fn: Callable[[], float],
                          kind: str = "gauge") -> None:
        """Lazily-evaluated counter (e.g. instantaneous queue length).

        ``kind`` declares the exposition semantics: ``"gauge"`` (default,
        may go up or down) or ``"counter"`` (monotonic — e.g. the
        scheduler's cumulative busy/idle time, computed on read)."""
        c = _CallableCounter(name, fn, kind=kind)
        with self._lock:
            self._counters[name] = c
        self._publish(name, c)

    def get(self, name: str) -> Optional[Any]:
        with self._lock:
            return self._counters.get(name)

    def get_value(self, name: str) -> float:
        c = self.get(name)
        if c is None:
            raise KeyError(f"no such performance counter: {name}")
        return c.get_value()

    def query(self, pattern: str) -> List[Tuple[str, float]]:
        """Glob query, HPX ``--hpx:print-counter`` style: ``/scheduler*``.

        The ``(name, counter)`` pairs are copied under the lock, then
        evaluated outside it: ``get_value`` may run a callable counter that
        takes other locks or registers further counters (pump threads do),
        so evaluating while holding the registry lock would deadlock or
        die with "dict changed size during iteration"."""
        with self._lock:
            items = [(n, self._counters[n]) for n in sorted(self._counters)
                     if fnmatch.fnmatch(n, pattern)]
        return [(n, c.get_value()) for n, c in items]

    def names(self) -> List[str]:
        with self._lock:
            return sorted(self._counters)

    def reset_all(self) -> None:
        with self._lock:
            for c in self._counters.values():
                if hasattr(c, "reset"):
                    c.reset()

    def snapshot(self, pattern: str = "*") -> Dict[str, float]:
        """Consistent point-in-time copy: membership is fixed under the
        lock, values are read outside it (see :meth:`query` for why).  This
        is also the payload of the remote-snapshot action — a locality's
        counters are read across the parcelport via
        ``repro.net.query_counters``."""
        return dict(self.query(pattern))

    def snapshot_stats(self, pattern: str = "*") -> Dict[str, Dict[str, float]]:
        """Like :meth:`snapshot` but keeps full per-counter statistics:
        timers/histograms contribute mean/max/p50/p95/p99, scalar kinds a
        single ``{"value": v}``.  Payload of ``net.query_counter_stats`` and
        the ``--print-counters`` end-of-run report."""
        with self._lock:
            items = [(n, self._counters[n]) for n in sorted(self._counters)
                     if fnmatch.fnmatch(n, pattern)]
        out: Dict[str, Dict[str, float]] = {}
        for n, c in items:
            stats = c.stats() if hasattr(c, "stats") else None
            out[n] = stats if stats is not None else {"value": c.get_value()}
        return out

    def snapshot_export(self, pattern: str = "*") -> Dict[str, Dict[str, Any]]:
        """Typed export records for every matching counter — the payload of
        ``net.query_counter_export`` and the ``/metrics`` endpoint.  Unlike
        :meth:`snapshot_stats` this keeps histogram *buckets* (native
        Prometheus rendering needs them) and each counter's kind.
        Membership is fixed under the lock, values read outside it (see
        :meth:`query`); a counter whose read raises contributes an
        ``{"kind": "error"}`` record instead of killing the scrape."""
        with self._lock:
            items = [(n, self._counters[n]) for n in sorted(self._counters)
                     if fnmatch.fnmatch(n, pattern)]
        out: Dict[str, Dict[str, Any]] = {}
        for n, c in items:
            try:
                out[n] = export_record(c)
            except Exception as e:  # noqa: BLE001 — probe racing teardown
                out[n] = {"kind": "error", "error": repr(e)}
        return out

    def republish_to_agas(self) -> int:
        """Publish every registered counter into AGAS (idempotent rebinds).

        ``agas.default()`` calls this right after constructing the instance:
        counters created before AGAS existed (the scheduler's, typically)
        become resolvable the moment the resolver is up."""
        with self._lock:
            items = list(self._counters.items())
        for n, c in items:
            self._publish(n, c)
        return len(items)


class _CallableCounter:
    __slots__ = ("name", "_fn", "kind")

    def __init__(self, name: str, fn: Callable[[], float],
                 kind: str = "gauge"):
        self.name = name
        self._fn = fn
        self.kind = kind

    def get_value(self) -> float:
        return float(self._fn())

    def reset(self) -> None:
        pass


def export_record(c: Any) -> Dict[str, Any]:
    """One counter -> a typed, wire-friendly export record.

    ``kind`` drives the OpenMetrics rendering: ``counter`` (monotonic,
    ``_total`` suffix), ``gauge``, ``histogram``/``timer`` (native
    Prometheus histogram from the log buckets).  Callable counters carry
    their declared kind; reading one may raise (a probe racing teardown),
    which the caller maps to an error record rather than dropping the
    whole sweep."""
    if isinstance(c, (Histogram, TimerCounter)):
        return c.export()
    if isinstance(c, Counter):
        return {"kind": "counter", "value": c.get_value()}
    if isinstance(c, _CallableCounter):
        return {"kind": c.kind, "value": c.get_value()}
    return {"kind": "gauge", "value": c.get_value()}


_default: Optional[CounterRegistry] = None
_default_lock = threading.Lock()


def default() -> CounterRegistry:
    """Process-wide registry (lives across runtime init/finalize)."""
    global _default
    with _default_lock:
        if _default is None:
            _default = CounterRegistry()
        return _default


def counter(name: str) -> Counter:
    return default().counter(name)


def gauge(name: str) -> Gauge:
    return default().gauge(name)


def timer(name: str, percentiles: bool = False) -> TimerCounter:
    return default().timer(name, percentiles=percentiles)


def histogram(name: str, growth: float = 1.08) -> Histogram:
    return default().histogram(name, growth=growth)


def query(pattern: str) -> List[Tuple[str, float]]:
    return default().query(pattern)


def get_value(name: str) -> float:
    return default().get_value(name)
