"""Parcels — one-sided active messages / RPC (HPX P4, paper §2.3).

A parcel ships *a function invocation* to where the data lives ("send work
to data, not data to work"); the destination never polls, and the result
comes back through a future.

TPU/JAX adaptation — two transport planes:

1. **Host plane** (this module): an :class:`Action` is a registered, named
   function; ``apply(action, target_gid, *args)`` resolves the target via
   AGAS and runs the action *against the live object*, returning a Future.
   Since the target object may be a sharded ``jax.Array`` pytree, "executing
   where the data lives" is real: the action body runs jitted computations
   whose operands never leave their shards.

2. **Device plane**: inside an XLA program, parcel transport *is* a
   collective.  ``shard_parcel`` wraps ``jax.experimental.shard_map`` so an
   action body executes per-shard with explicit collectives available; the
   flagship production user is MoE expert dispatch (``models/moe.py``) where
   tokens are parcels ``all_to_all``-routed to expert localities.

Zero-copy serialization of the C++ runtime [Biddiscombe et al. 2017] maps to
XLA buffer donation — see ``train/step.py`` (donated state) — so a parcel
never copies what it can alias.
"""

from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple

from repro.core import agas as _agas
from repro.core import counters as _counters
from repro.core import scheduler as _sched
from repro.core.future import Future


class ActionRegistry:
    """Named action table (HPX: ``HPX_REGISTER_ACTION``).

    Resolution is *lazy across processes*: a worker locality receiving a
    parcel for an action it has never imported resolves the dotted default
    name (``module.qualname``) by importing the module — the action-table
    analogue of HPX's registration macros running at static-init time in
    every locality's binary.
    """

    def __init__(self) -> None:
        self._actions: Dict[str, Callable[..., Any]] = {}
        self._lock = threading.Lock()

    def register(self, fn: Callable[..., Any], name: Optional[str] = None) -> str:
        name = name or f"{fn.__module__}.{fn.__qualname__}"
        with self._lock:
            if name in self._actions and self._actions[name] is not fn:
                raise KeyError(f"action name already registered: {name!r}")
            self._actions[name] = fn
        return name

    def resolve(self, name: str) -> Callable[..., Any]:
        with self._lock:
            fn = self._actions.get(name)
        if fn is not None:
            return fn
        self._import_defining_module(name)
        with self._lock:
            fn = self._actions.get(name)
        if fn is not None:
            return fn
        # plain module-level function (registered ad hoc at the sender, so
        # no decorator ran here): walk module attributes by qualname
        fn = self._locate_by_qualname(name)
        if fn is not None:
            self.register(fn, name)
            return fn
        raise KeyError(f"unknown action: {name!r}")

    def _locate_by_qualname(self, name: str) -> Optional[Callable[..., Any]]:
        import sys

        parts = name.split(".")
        for cut in range(len(parts) - 1, 0, -1):
            mod = sys.modules.get(".".join(parts[:cut]))
            if mod is None:
                continue
            obj: Any = mod
            try:
                for attr in parts[cut:]:
                    obj = getattr(obj, attr)
            except AttributeError:
                continue
            if callable(obj):
                return obj
        return None

    def _import_defining_module(self, name: str) -> None:
        """Import the longest module prefix of ``module.qualname`` so the
        ``@action`` decorators at its top level run and self-register."""
        import importlib

        parts = name.split(".")
        for cut in range(len(parts) - 1, 0, -1):
            modname = ".".join(parts[:cut])
            try:
                importlib.import_module(modname)
                return
            except ModuleNotFoundError as e:
                missing_is_target = e.name and (
                    modname == e.name or modname.startswith(e.name + "."))
                if not missing_is_target:
                    raise  # a real dependency failure inside the module
                continue  # qualname segment, not a module — try shorter

    def names(self):
        with self._lock:
            return sorted(self._actions)


_registry = ActionRegistry()


def action(fn: Callable[..., Any] = None, *, name: Optional[str] = None):
    """Decorator registering an action; the wrapper keeps the plain call.

    >>> @action
    ... def scale(obj, s): return obj * s
    """

    def deco(f: Callable[..., Any]) -> Callable[..., Any]:
        f._action_name = _registry.register(f, name)  # type: ignore[attr-defined]
        return f

    return deco(fn) if fn is not None else deco


@dataclass
class Parcel:
    """destination GID + action + arguments (+ continuation promise)."""

    action_name: str
    target: Any  # GID or symbolic name
    args: Tuple[Any, ...] = ()
    kwargs: Dict[str, Any] = field(default_factory=dict)


class ParcelPort:
    """Local parcel port: decodes parcels and spawns the action as a task.

    In HPX the parcelport moves bytes between nodes; in a single-controller
    JAX program every shard is addressable from the controller, so the
    "network" hop is the device placement of the target object — the action
    body's jitted ops execute on the target's devices.  The port still gives
    us HPX semantics: one-sided, asynchronous, future-returning, counted.
    """

    def __init__(self, name: str = "port#0", resolver: Optional[_agas.AGAS] = None):
        self.name = name
        self.resolver = resolver or _agas.default()
        reg = _counters.default()
        self.c_sent = reg.counter(f"/parcel{{{name}}}/count/sent")
        self.c_actions = reg.counter(f"/parcel{{{name}}}/actions/executed")

    def send(self, parcel: Parcel) -> Future[Any]:
        """Deliver a parcel: resolve target, run action where the data is.

        With a multi-locality runtime up (:mod:`repro.net`), a parcel whose
        target does not resolve locally is handed to the installed remote
        route — the transport resolves the owning locality through the
        distributed AGAS tier and ships the invocation over the parcelport.
        """
        self.c_sent.increment()
        resolver = self.resolver
        route = _remote_route
        if route is not None and not resolver.contains(parcel.target):
            remote_future = route(parcel)
            if remote_future is not None:
                return remote_future

        def _deliver() -> Any:
            rec = resolver.record(parcel.target)
            fn = _registry.resolve(parcel.action_name)
            self.c_actions.increment()
            return fn(rec.obj, *parcel.args, **parcel.kwargs)

        return _sched.get_runtime().spawn(_deliver)

    def apply(self, fn: Callable[..., Any], target, *args: Any, **kwargs: Any) -> Future[Any]:
        """``hpx::async(action, gid, args...)`` convenience."""
        name = getattr(fn, "_action_name", None) or _registry.register(fn)
        return self.send(Parcel(name, target, args, kwargs))


_port: Optional[ParcelPort] = None
_port_lock = threading.Lock()

# Remote transport hook, installed by repro.net when localities are real
# processes: fn(parcel) -> Future | None (None = "target is local after all").
_remote_route = None


def set_remote_route(fn) -> None:
    """Install/uninstall (``None``) the cross-locality delivery path."""
    global _remote_route
    _remote_route = fn


def default_port() -> ParcelPort:
    global _port
    with _port_lock:
        if _port is None:
            _port = ParcelPort()
        return _port


def apply(fn: Callable[..., Any], target, *args: Any, **kwargs: Any) -> Future[Any]:
    """Module-level one-sided invoke: run ``fn(object_at(target), *args)``."""
    return default_port().apply(fn, target, *args, **kwargs)


# ----------------------------------------------------------------- device plane
def shard_parcel(mesh, body: Callable[..., Any], in_specs, out_specs, check_vma: bool = False):
    """Device-plane parcel: execute ``body`` at every shard of the operands.

    Thin wrapper over ``shard_map`` so call sites read as parcel semantics
    ("ship this function to the shards") and so the import point for the
    transport is unique.  Collectives available inside ``body`` —
    ``jax.lax.all_to_all`` (MoE token parcels), ``psum``/``ppermute`` — are
    the transport layer.
    """
    from jax.sharding import use_mesh  # noqa: F401  (documents requirement)
    import jax

    return jax.shard_map(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                         check_vma=check_vma)
