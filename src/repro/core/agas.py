"""AGAS — Active Global Address Space (HPX P3, paper §2.2).

Every distributed object lives in AGAS under a *GID* (global id); access is
location-transparent, and objects may *migrate* between localities for load
balancing, with AGAS responsible for address resolution.

TPU/JAX adaptation: a "locality" is a placement — a ``jax.sharding.Sharding``
over some mesh (or host memory).  An AGAS record therefore binds::

    GID → (symbolic name, pytree of arrays, placement metadata, generation)

Migration (see :mod:`repro.core.migration`) re-`device_put`s the pytree to a
new sharding and bumps the record's generation — the GID is stable across
migrations, exactly the paper's "independence of whether an object is located
remotely or local".  Model/optimizer state, KV caches and performance
counters are all registered here; the checkpoint layer saves/restores *by
GID*, which is what makes elastic restart (restore onto a different mesh)
a pure AGAS operation.

Multi-locality tier (:mod:`repro.net`): when localities are real OS
processes, each process runs one AGAS instance whose ``locality`` id seeds
every GID it mints (``set_default_locality`` pins it before first use in a
worker process).  The net tier observes this instance through *hooks* —
``add_hook(fn)`` registers ``fn(event, record)`` called on ``register`` /
``rebind`` / ``unregister``, always *outside* the AGAS lock so a hook may
send parcels — and installs foreign-minted GIDs after a cross-process
migration via :meth:`AGAS.adopt`.  Core stays transport-free; the hooks are
the entire coupling surface.
"""

from __future__ import annotations

import itertools
import threading
from dataclasses import dataclass, field
from typing import Any, Dict, Iterator, List, Optional, Tuple


@dataclass(frozen=True)
class GID:
    """Global identifier: (locality id, sequence number) like HPX msb/lsb."""

    locality: int
    seq: int

    def __repr__(self) -> str:
        return f"gid{{{self.locality:04x}:{self.seq:012x}}}"


@dataclass
class AgasRecord:
    gid: GID
    obj: Any
    name: Optional[str] = None
    placement: Optional[Any] = None  # sharding / mesh descriptor / "host"
    generation: int = 0  # bumped on every migration
    meta: Dict[str, Any] = field(default_factory=dict)


class AGAS:
    """The resolver: GID ↔ object ↔ symbolic name."""

    def __init__(self, locality: int = 0):
        self.locality = locality
        self._seq = itertools.count(1)
        self._records: Dict[GID, AgasRecord] = {}
        self._names: Dict[str, GID] = {}
        self._lock = threading.RLock()
        self._hooks: List[Any] = []  # fn(event, record), fired outside _lock
        # AGAS exposes its own counters (paper: counters are read *via* AGAS)
        from repro.core import counters as _counters

        reg = _counters.default()
        self._c_objects = reg.gauge(f"/agas{{locality#{locality}}}/objects/count")
        self._c_migrations = reg.counter(f"/agas{{locality#{locality}}}/migrations/cumulative")
        self._c_resolutions = reg.counter(f"/agas{{locality#{locality}}}/resolutions/cumulative")

    # --------------------------------------------------------------- hooks
    def add_hook(self, fn) -> None:
        """Observe mutations: ``fn(event, record)`` with event one of
        ``register`` / ``rebind`` / ``unregister``.  Hooks run *outside* the
        AGAS lock, so they may resolve, register, or send parcels."""
        with self._lock:
            self._hooks.append(fn)

    def remove_hook(self, fn) -> None:
        with self._lock:
            if fn in self._hooks:
                self._hooks.remove(fn)

    def _fire(self, event: str, rec: AgasRecord) -> None:
        with self._lock:
            hooks = list(self._hooks)
        for h in hooks:
            h(event, rec)

    # ------------------------------------------------------------ register
    def register(
        self,
        obj: Any,
        name: Optional[str] = None,
        placement: Optional[Any] = None,
        **meta: Any,
    ) -> GID:
        """Give ``obj`` a global identity; optionally bind a symbolic name."""
        with self._lock:
            if name is not None and name in self._names:
                # check BEFORE inserting: a raced bind must not leave an
                # orphan record behind (register_name retries rely on this)
                raise KeyError(f"AGAS name already bound: {name!r}")
            gid = GID(self.locality, next(self._seq))
            rec = AgasRecord(gid=gid, obj=obj, name=name, placement=placement, meta=dict(meta))
            self._records[gid] = rec
            if name is not None:
                self._names[name] = gid
            self._c_objects.set(len(self._records))
        self._fire("register", rec)
        return gid

    def adopt(
        self,
        gid: GID,
        obj: Any,
        name: Optional[str] = None,
        placement: Optional[Any] = None,
        generation: int = 0,
        **meta: Any,
    ) -> AgasRecord:
        """Install an object under a *foreign-minted* GID (the receiving end
        of a cross-locality migration: the GID stays stable, this locality
        becomes the owner, the generation carries over pre-bumped)."""
        with self._lock:
            if gid in self._records:
                raise KeyError(f"AGAS already holds {gid}")
            rec = AgasRecord(gid=gid, obj=obj, name=name, placement=placement,
                             generation=generation, meta=dict(meta))
            self._records[gid] = rec
            if name is not None:
                self._names[name] = gid  # rebind: the name follows the object
            self._c_objects.set(len(self._records))
        self._fire("register", rec)
        return rec

    def register_name(self, name: str, obj: Any, replace: bool = False, **meta: Any) -> GID:
        """Bind-or-rebind a symbolic name (used for counters).

        The fresh-bind path runs ``register`` outside the lock (hooks may
        send parcels), so a concurrent binder can win the name in between;
        with ``replace=True`` the loser retries as a rebind instead of
        surfacing the spurious already-bound error."""
        while True:
            with self._lock:
                existing = self._names.get(name)
                if existing is not None:
                    if not replace:
                        raise KeyError(f"AGAS name already bound: {name!r}")
                    rec = self._records[existing]
                    rec.obj = obj
                    rec.meta.update(meta)
                    return existing
            try:
                return self.register(obj, name=name, **meta)
            except KeyError:
                if not replace:
                    raise
                continue  # lost the bind race — rebind on the next pass

    def unregister(self, gid: GID) -> None:
        with self._lock:
            rec = self._records.pop(gid, None)
            if rec is None:
                raise KeyError(f"unknown {gid}")
            if rec.name is not None and self._names.get(rec.name) == gid:
                # only drop the binding we still own — adopt() may have
                # rebound the name to another record ("the name follows
                # the object"), and that live binding must survive
                del self._names[rec.name]
            self._c_objects.set(len(self._records))
        self._fire("unregister", rec)

    # ------------------------------------------------------------- resolve
    def resolve(self, gid_or_name) -> Any:
        """GID/name → live object (the one-sided access path)."""
        return self.record(gid_or_name).obj

    def record(self, gid_or_name) -> AgasRecord:
        with self._lock:
            self._c_resolutions.increment()
            gid = self._names[gid_or_name] if isinstance(gid_or_name, str) else gid_or_name
            return self._records[gid]

    def gid_of(self, name: str) -> GID:
        with self._lock:
            return self._names[name]

    def contains(self, gid_or_name) -> bool:
        with self._lock:
            if isinstance(gid_or_name, str):
                return gid_or_name in self._names
            return gid_or_name in self._records

    # ------------------------------------------------------------- migrate
    def rebind(self, gid: GID, obj: Any, placement: Optional[Any] = None) -> int:
        """Install a migrated object under the same GID. Returns new generation."""
        with self._lock:
            rec = self._records[gid]
            rec.obj = obj
            if placement is not None:
                rec.placement = placement
            rec.generation += 1
            gen = rec.generation
            self._c_migrations.increment()
        self._fire("rebind", rec)
        return gen

    # ------------------------------------------------------------- queries
    def names(self, prefix: str = "") -> List[str]:
        with self._lock:
            return sorted(n for n in self._names if n.startswith(prefix))

    def __len__(self) -> int:
        with self._lock:
            return len(self._records)

    def __iter__(self) -> Iterator[AgasRecord]:
        with self._lock:
            return iter(list(self._records.values()))


_default: Optional[AGAS] = None
_default_locality = 0
_lock = threading.Lock()


def set_default_locality(locality: int) -> None:
    """Pin the locality id the process-wide AGAS instance mints GIDs with.

    Must run before :func:`default` first constructs the instance — worker
    processes call this first thing in their entry point (see
    ``repro.net.locality``) so every GID they mint is attributable."""
    global _default_locality
    with _lock:
        if _default is not None and _default.locality != locality:
            raise RuntimeError(
                f"default AGAS already initialised with locality "
                f"{_default.locality}, cannot re-pin to {locality}")
        _default_locality = locality


def peek() -> Optional[AGAS]:
    """The process-wide instance if it exists, WITHOUT constructing one.

    Counter publishing uses this: during ``AGAS.__init__`` (which creates
    gauges through the counter registry) the instance is not yet visible
    here, so the publish path skips instead of re-entering ``default()``
    and deadlocking on the non-reentrant module lock."""
    return _default


def default() -> AGAS:
    global _default
    created = None
    with _lock:
        if _default is None:
            _default = created = AGAS(locality=_default_locality)
        inst = _default
    if created is not None:
        # Sweep pre-existing counters into the fresh resolver, outside the
        # module lock (register_name takes the instance lock + fires hooks).
        from repro.core import counters as _counters

        _counters.default().republish_to_agas()
    return inst


def register(obj: Any, name: Optional[str] = None, **kw: Any) -> GID:
    return default().register(obj, name=name, **kw)


def resolve(gid_or_name) -> Any:
    return default().resolve(gid_or_name)
