"""AGAS — Active Global Address Space (HPX P3, paper §2.2).

Every distributed object lives in AGAS under a *GID* (global id); access is
location-transparent, and objects may *migrate* between localities for load
balancing, with AGAS responsible for address resolution.

TPU/JAX adaptation: a "locality" is a placement — a ``jax.sharding.Sharding``
over some mesh (or host memory).  An AGAS record therefore binds::

    GID → (symbolic name, pytree of arrays, placement metadata, generation)

Migration (see :mod:`repro.core.migration`) re-`device_put`s the pytree to a
new sharding and bumps the record's generation — the GID is stable across
migrations, exactly the paper's "independence of whether an object is located
remotely or local".  Model/optimizer state, KV caches and performance
counters are all registered here; the checkpoint layer saves/restores *by
GID*, which is what makes elastic restart (restore onto a different mesh)
a pure AGAS operation.
"""

from __future__ import annotations

import itertools
import threading
from dataclasses import dataclass, field
from typing import Any, Dict, Iterator, List, Optional, Tuple


@dataclass(frozen=True)
class GID:
    """Global identifier: (locality id, sequence number) like HPX msb/lsb."""

    locality: int
    seq: int

    def __repr__(self) -> str:
        return f"gid{{{self.locality:04x}:{self.seq:012x}}}"


@dataclass
class AgasRecord:
    gid: GID
    obj: Any
    name: Optional[str] = None
    placement: Optional[Any] = None  # sharding / mesh descriptor / "host"
    generation: int = 0  # bumped on every migration
    meta: Dict[str, Any] = field(default_factory=dict)


class AGAS:
    """The resolver: GID ↔ object ↔ symbolic name."""

    def __init__(self, locality: int = 0):
        self.locality = locality
        self._seq = itertools.count(1)
        self._records: Dict[GID, AgasRecord] = {}
        self._names: Dict[str, GID] = {}
        self._lock = threading.RLock()
        # AGAS exposes its own counters (paper: counters are read *via* AGAS)
        from repro.core import counters as _counters

        reg = _counters.default()
        self._c_objects = reg.gauge(f"/agas{{locality#{locality}}}/objects/count")
        self._c_migrations = reg.counter(f"/agas{{locality#{locality}}}/migrations/cumulative")
        self._c_resolutions = reg.counter(f"/agas{{locality#{locality}}}/resolutions/cumulative")

    # ------------------------------------------------------------ register
    def register(
        self,
        obj: Any,
        name: Optional[str] = None,
        placement: Optional[Any] = None,
        **meta: Any,
    ) -> GID:
        """Give ``obj`` a global identity; optionally bind a symbolic name."""
        with self._lock:
            gid = GID(self.locality, next(self._seq))
            rec = AgasRecord(gid=gid, obj=obj, name=name, placement=placement, meta=dict(meta))
            self._records[gid] = rec
            if name is not None:
                if name in self._names:
                    raise KeyError(f"AGAS name already bound: {name!r}")
                self._names[name] = gid
            self._c_objects.set(len(self._records))
            return gid

    def register_name(self, name: str, obj: Any, replace: bool = False, **meta: Any) -> GID:
        """Bind-or-rebind a symbolic name (used for counters)."""
        with self._lock:
            if name in self._names:
                if not replace:
                    raise KeyError(f"AGAS name already bound: {name!r}")
                gid = self._names[name]
                rec = self._records[gid]
                rec.obj = obj
                rec.meta.update(meta)
                return gid
            return self.register(obj, name=name, **meta)

    def unregister(self, gid: GID) -> None:
        with self._lock:
            rec = self._records.pop(gid, None)
            if rec is None:
                raise KeyError(f"unknown {gid}")
            if rec.name is not None:
                self._names.pop(rec.name, None)
            self._c_objects.set(len(self._records))

    # ------------------------------------------------------------- resolve
    def resolve(self, gid_or_name) -> Any:
        """GID/name → live object (the one-sided access path)."""
        return self.record(gid_or_name).obj

    def record(self, gid_or_name) -> AgasRecord:
        with self._lock:
            self._c_resolutions.increment()
            gid = self._names[gid_or_name] if isinstance(gid_or_name, str) else gid_or_name
            return self._records[gid]

    def gid_of(self, name: str) -> GID:
        with self._lock:
            return self._names[name]

    def contains(self, gid_or_name) -> bool:
        with self._lock:
            if isinstance(gid_or_name, str):
                return gid_or_name in self._names
            return gid_or_name in self._records

    # ------------------------------------------------------------- migrate
    def rebind(self, gid: GID, obj: Any, placement: Optional[Any] = None) -> int:
        """Install a migrated object under the same GID. Returns new generation."""
        with self._lock:
            rec = self._records[gid]
            rec.obj = obj
            if placement is not None:
                rec.placement = placement
            rec.generation += 1
            self._c_migrations.increment()
            return rec.generation

    # ------------------------------------------------------------- queries
    def names(self, prefix: str = "") -> List[str]:
        with self._lock:
            return sorted(n for n in self._names if n.startswith(prefix))

    def __len__(self) -> int:
        with self._lock:
            return len(self._records)

    def __iter__(self) -> Iterator[AgasRecord]:
        with self._lock:
            return iter(list(self._records.values()))


_default: Optional[AGAS] = None
_lock = threading.Lock()


def default() -> AGAS:
    global _default
    with _lock:
        if _default is None:
            _default = AGAS()
        return _default


def register(obj: Any, name: Optional[str] = None, **kw: Any) -> GID:
    return default().register(obj, name=name, **kw)


def resolve(gid_or_name) -> Any:
    return default().resolve(gid_or_name)
