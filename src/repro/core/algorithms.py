"""C++17-style parallel algorithms over the executor hierarchy (HPX P6).

The paper: C++17 "support for parallel algorithms was added, which
coincidentally covers the need for data parallel algorithms"; HPX provides
the reference implementation.  We provide the JAX analogue:

    for_each, transform, reduce, transform_reduce, inclusive_scan,
    exclusive_scan, sort, count_if, all_of/any_of, copy, fill,
    min_element, max_element

Each takes an :class:`~repro.core.executor.ExecutionPolicy`; the policy is
a pure rewrite object and every lowering dispatches through the bound
executor's ``bulk_async_execute``:

- ``seq``      — one chunk on a :class:`SequencedExecutor` (the oracle);
- ``par``      — chunks on a :class:`ThreadPoolExecutor` (named pool of the
  resource partitioner; ``par.on(rt.get_executor("io"))`` redirects);
- ``par_task`` — same lowering, *two-way*: returns a ``Future`` instead of
  joining (HPX ``par(task)``);
- ``vec``      — vectorized via ``jax.vmap`` / jnp.  Non-traceable bodies
  raise instead of silently degrading to a host loop;
- ``vec.on(MeshExecutor(mesh, axis))`` — device plane: input sharded over a
  mesh axis, bodies run per shard, reductions finish with the matching
  collective (DESIGN.md §3.1).

A data argument that is a *partitioned vector* (``repro.container``) takes
none of these lowerings: the algorithm dispatches to the segmented layer
(:mod:`repro.container.segmented`), which ships the body to each segment's
owning locality as parcels and combines partials on the caller through
``dataflow`` — work goes to data, the policy's ``task`` flag still selects
one-way vs two-way.

Under vec/mesh, binary ``op`` arguments must be jax-traceable and combine
*batched slices elementwise* (``operator.add``, ``operator.mul``,
``jnp.minimum``, element-batched ``jnp.matmul``, …) — exactly
``jax.lax.associative_scan``'s combinator contract.  Host-only ops belong
under ``seq``/``par``; passing them here raises loudly.
"""

from __future__ import annotations

import builtins
import operator
from typing import Any, Callable, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.executor import (
    ExecutionPolicy,
    Executor,
    MeshExecutor,
    PriorityExecutor,
    SequencedExecutor,
    ThreadPoolExecutor,
    par,
    par_task,
    seq,
    seq_task,
    vec,
)
from repro.core.future import Future, Promise, make_ready_future, when_all

_SEQ_EXEC = SequencedExecutor()


# ------------------------------------------------------------------ dispatch
def _is_segmented(data: Any) -> bool:
    """Partitioned containers carry the ``is_segmented`` marker; their
    algorithms lower to per-segment parcels (work-to-data) instead of the
    local chunk/vmap lowerings below."""
    return getattr(data, "is_segmented", False)


def _seg_dispatch(name: str, policy: ExecutionPolicy, data: Any,
                  *args: Any, **kwargs: Any) -> Any:
    from repro.container import segmented  # deferred: container is optional

    return getattr(segmented, name)(policy, data, *args, **kwargs)


def _as_policy(policy: Any) -> ExecutionPolicy:
    if isinstance(policy, ExecutionPolicy):
        return policy
    raise TypeError(
        f"expected an ExecutionPolicy (seq/par/par_task/vec or "
        f"policy.on(executor)), got {policy!r}")


def _mode(policy: ExecutionPolicy) -> str:
    ex = policy.executor
    if ex is not None and ex.plane == "device":
        return "device"
    if policy.flavor == "vec":
        return "vec"
    return "host"


def _host_executor(policy: ExecutionPolicy) -> Executor:
    ex = policy.executor
    if ex is None:
        ex = _SEQ_EXEC if policy.flavor == "seq" else ThreadPoolExecutor()
    if policy.priority is not None:
        ex = PriorityExecutor(ex, policy.priority)
    return ex


def _chunks(n: int, chunk: int) -> List[tuple]:
    return [(i, min(i + chunk, n)) for i in range(0, n, chunk)]


def _chunk_size(policy: ExecutionPolicy, n: int, ex: Executor) -> int:
    if policy.flavor == "seq":
        # sequenced stays sequenced even when bound to a pool executor
        # (HPX seq.on(exec): one in-order task on that executor)
        return max(1, n)
    if policy.chunk_size:
        return policy.chunk_size
    p = max(1, ex.parallelism)
    return max(1, n) if p <= 1 else max(1, n // (4 * p))


def _bulk(policy: ExecutionPolicy, n: int,
          chunk_fn: Callable[[int, int], Any]) -> List[Future]:
    """Lower a loop of ``n`` iterations to per-chunk executor tasks."""
    ex = _host_executor(policy)
    return ex.bulk_async_execute(chunk_fn, _chunks(n, _chunk_size(policy, n, ex)))


def _join(policy: ExecutionPolicy, futs: List[Future],
          combine: Callable[[List[Any]], Any]):
    """Combine chunk results; under a ``task`` policy the combination is a
    continuation — posted on the *policy's own executor*, so a workload
    bound to a named pool never leaks its combine onto another pool."""
    if policy.task:
        return _then_on(policy, when_all(futs),
                        lambda ready: combine([f.get() for f in ready]))
    return combine([f.get() for f in futs])


def _offload(policy: ExecutionPolicy, thunk: Callable[[], Any]):
    """Produce a vec/device value, honoring the policy bindings: a bound
    *host* executor runs the whole vectorized dispatch as one task on that
    pool (``vec.on(rt.get_executor("io"))`` — never silently inline), and
    ``task`` policies get a Future."""
    ex = policy.executor
    if ex is not None and ex.plane == "host":
        if policy.priority is not None:
            ex = PriorityExecutor(ex, policy.priority)
        fut = ex.async_execute(thunk)
        return fut if policy.task else fut.get()
    return make_ready_future(thunk()) if policy.task else thunk()


class _LoweringError(ValueError):
    """A vec/mesh lowering violated its contract (already actionable)."""


def _traced(name: str, what: str, apply: Callable[[], Any]) -> Any:
    """Run a jax lowering; translate tracer failures into a loud, actionable
    error instead of silently degrading to a host loop."""
    try:
        return apply()
    except _LoweringError:
        raise
    except (jax.errors.JAXTypeError, jax.errors.TracerArrayConversionError,
            TypeError, ValueError) as e:
        raise ValueError(
            f"{name}: {what} is not usable under the vec/mesh policies — it "
            f"must be jax-traceable and combine/transform array elements "
            f"(side effects and Python-only control flow cannot vectorize). "
            f"Use the seq/par policies for host-only bodies.") from e


def _device_ex(policy: ExecutionPolicy) -> MeshExecutor:
    return policy.executor  # type: ignore[return-value]


# ---------------------------------------------------------------- for_each
def for_each(policy: ExecutionPolicy, data: Sequence[Any],
             fn: Callable[[Any], Any]) -> Any:
    """Apply ``fn`` to every element (result discarded).

    Under ``vec``/mesh the body is vectorized with ``jax.vmap`` as a
    side-effect-free application — a body that cannot trace raises
    (module contract: no silent sequential fallback).  Host side effects
    belong under ``seq``/``par``."""
    policy = _as_policy(policy)
    if _is_segmented(data):
        return _seg_dispatch("for_each", policy, data, fn)
    m = _mode(policy)
    if m in ("vec", "device"):
        def thunk() -> None:
            arr = jnp.asarray(data)
            if arr.shape[0]:
                dex = _device_ex(policy) if m == "device" else None
                out = _traced(
                    "for_each", f"body {getattr(fn, '__name__', fn)!r}",
                    (lambda: dex.vmap_apply(fn, arr)) if dex is not None
                    else (lambda: jax.vmap(fn)(arr)))
                jax.block_until_ready(out)
            return None

        return _offload(policy, thunk)

    n = len(data)

    def _run(lo: int, hi: int) -> None:
        for i in range(lo, hi):
            fn(data[i])

    return _join(policy, _bulk(policy, n, _run), lambda parts: None)


# ---------------------------------------------------------------- transform
def transform(policy: ExecutionPolicy, data: Any, fn: Callable[[Any], Any]) -> Any:
    policy = _as_policy(policy)
    if _is_segmented(data):
        return _seg_dispatch("transform", policy, data, fn)
    m = _mode(policy)
    if m in ("vec", "device"):
        def thunk():
            arr = jnp.asarray(data)
            if m == "device":
                return _traced("transform", "body",
                               lambda: _device_ex(policy).vmap_apply(fn, arr))
            return _traced("transform", "body", lambda: jax.vmap(fn)(arr))

        return _offload(policy, thunk)

    n = len(data)

    def _run(lo: int, hi: int) -> List[Any]:
        return [fn(data[i]) for i in range(lo, hi)]

    return _join(policy, _bulk(policy, n, _run),
                 lambda parts: [x for p in parts for x in p])


# ------------------------------------------------------------------- reduce
def _vec_tree_reduce(name: str, op: Callable, arr):
    """Pairwise associative fold, vectorized: O(log n) batched ``op`` calls.

    ``op`` must combine equal-length batched slices elementwise (the same
    contract as :func:`jax.lax.associative_scan`'s combinator)."""

    def _fold():
        a = arr
        while a.shape[0] > 1:
            half = a.shape[0] // 2
            # combine *adjacent* pairs — (x0⊕x1), (x2⊕x3), … — so operand
            # order is preserved for associative non-commutative ops
            combined = op(a[0:2 * half:2], a[1:2 * half:2])
            if combined.shape != (half,) + a.shape[1:]:
                raise _LoweringError(
                    f"op changed the element shape {(half,) + a.shape[1:]} "
                    f"-> {combined.shape}; it must combine batched slices "
                    f"elementwise")
            a = (jnp.concatenate([combined, a[2 * half:]], axis=0)
                 if a.shape[0] % 2 else combined)
        return a[0]

    return _traced(name, f"op {op!r}", _fold)


def reduce(
    policy: ExecutionPolicy,
    data: Any,
    init: Any = 0,
    op: Callable[[Any, Any], Any] = operator.add,
) -> Any:
    policy = _as_policy(policy)
    if _is_segmented(data):
        return _seg_dispatch("reduce", policy, data, init, op)
    m = _mode(policy)
    if m in ("vec", "device"):
        def thunk():
            arr = jnp.asarray(data)
            if arr.shape[0] == 0:
                return init
            if op is operator.add:  # axis=0: elements may be batched arrays
                total = (_device_ex(policy).sum_total(arr) if m == "device"
                         else jnp.sum(arr, axis=0))
            else:
                total = _vec_tree_reduce("reduce", op, arr)
            return op(init, total)

        return _offload(policy, thunk)

    n = len(data)

    def _run(lo: int, hi: int) -> Any:
        acc = data[lo]
        for i in range(lo + 1, hi):
            acc = op(acc, data[i])
        return acc

    def _combine(parts: List[Any]) -> Any:
        acc = init
        for p in parts:  # op must be associative (C++ requirement)
            acc = op(acc, p)
        return acc

    return _join(policy, _bulk(policy, n, _run), _combine)


def transform_reduce(
    policy: ExecutionPolicy,
    data: Any,
    fn: Callable[[Any], Any],
    init: Any = 0,
    op: Callable[[Any, Any], Any] = operator.add,
) -> Any:
    policy = _as_policy(policy)
    if _is_segmented(data):
        return _seg_dispatch("transform_reduce", policy, data, fn, init, op)
    m = _mode(policy)
    if m in ("vec", "device"):
        def thunk():
            arr = jnp.asarray(data)
            if arr.shape[0] == 0:
                return init
            dex = _device_ex(policy) if m == "device" else None
            mapped = _traced(
                "transform_reduce", "body",
                (lambda: dex.vmap_apply(fn, arr)) if dex is not None
                else (lambda: jax.vmap(fn)(arr)))
            if op is operator.add:
                total = (dex.sum_total(mapped) if dex is not None
                         else jnp.sum(mapped, axis=0))
            else:
                total = _vec_tree_reduce("transform_reduce", op, mapped)
            return op(init, total)

        return _offload(policy, thunk)

    n = len(data)

    def _run(lo: int, hi: int) -> Any:
        acc = fn(data[lo])
        for i in range(lo + 1, hi):
            acc = op(acc, fn(data[i]))
        return acc

    def _combine(parts: List[Any]) -> Any:
        acc = init
        for p in parts:
            acc = op(acc, p)
        return acc

    return _join(policy, _bulk(policy, n, _run), _combine)


# -------------------------------------------------------------------- scans
def _local_inclusive(data: Any, op: Callable, lo: int, hi: int) -> List[Any]:
    """In-order inclusive scan of one chunk (the two-pass scans' pass 1)."""
    out: List[Any] = []
    acc: Optional[Any] = None
    for i in range(lo, hi):
        acc = data[i] if acc is None else op(acc, data[i])
        out.append(acc)
    return out


_NO_SEED = object()


def _two_pass_scan(ex: Executor, bounds: List[tuple], data: Any, op: Callable,
                   exclusive: bool, init: Any = _NO_SEED) -> List[Any]:
    """Shared two-pass parallel scan: local inclusive scans per chunk, a
    sequential fold of chunk totals into per-chunk offsets (seeded with
    ``init`` for exclusive scans), then a bulk offset-apply pass."""
    locals_ = [f.get() for f in ex.bulk_async_execute(
        lambda lo, hi: _local_inclusive(data, op, lo, hi), bounds)]
    offsets: List[Any] = [init] * len(bounds)
    carry = init
    for c in range(len(bounds) - 1):
        carry = (locals_[c][-1] if carry is _NO_SEED
                 else op(carry, locals_[c][-1]))
        offsets[c + 1] = carry

    def _apply(c: int) -> List[Any]:
        off = offsets[c]
        if exclusive:  # chunk c emits [off, off⊕x0, ..., off⊕x_{k-2}]
            return [off] + [op(off, v) for v in locals_[c][:-1]]
        if off is _NO_SEED:
            return locals_[c]
        return [op(off, v) for v in locals_[c]]

    parts = [f.get() for f in ex.bulk_async_execute(_apply, range(len(bounds)))]
    return [x for p in parts for x in p]


def _assoc_scan(name: str, op: Callable, arr):
    """``jax.lax.associative_scan`` with the combinator applied directly to
    batched slices (its documented contract) and loud failure for ops that
    cannot lower — never a silent host loop."""

    def _scan():
        out = jax.lax.associative_scan(op, arr)
        if out.shape != arr.shape:
            raise _LoweringError(
                f"op changed the scan shape {arr.shape} -> {out.shape}; it "
                f"must combine batched slices elementwise")
        return out

    return _traced(name, f"op {op!r}", _scan)


def inclusive_scan(policy: ExecutionPolicy, data: Any,
                   op: Callable = operator.add) -> Any:
    policy = _as_policy(policy)
    if _is_segmented(data):
        return _seg_dispatch("inclusive_scan", policy, data, op)
    m = _mode(policy)
    if m in ("vec", "device"):
        def thunk():
            arr = jnp.asarray(data)
            if m == "device":
                arr = _device_ex(policy).put(arr)
            if arr.shape[0] == 0:
                return arr
            return (jnp.cumsum(arr, axis=0) if op is operator.add
                    else _assoc_scan("inclusive_scan", op, arr))

        return _offload(policy, thunk)

    if policy.task:  # two-way: run the joining scan as one pool task
        eager = policy.with_(task=False)
        return _host_executor(policy).async_execute(
            lambda: inclusive_scan(eager, data, op))

    n = len(data)
    ex = _host_executor(policy)
    chunk = _chunk_size(policy, n, ex)
    if ex.parallelism <= 1 or chunk >= n:
        out: List[Any] = []
        acc: Optional[Any] = None
        for x in data:
            acc = x if acc is None else op(acc, x)
            out.append(acc)
        return out

    return _two_pass_scan(ex, _chunks(n, chunk), data, op, exclusive=False)


def exclusive_scan(policy: ExecutionPolicy, data: Any, init: Any = 0,
                   op: Callable = operator.add) -> Any:
    policy = _as_policy(policy)
    if _is_segmented(data):
        return _seg_dispatch("exclusive_scan", policy, data, init, op)
    m = _mode(policy)
    if m in ("vec", "device"):
        def thunk():
            arr = jnp.asarray(data)
            if m == "device":
                arr = _device_ex(policy).put(arr)
            if arr.shape[0] == 0:  # C++: empty exclusive scan writes nothing
                return arr
            # promote like the seq oracle would (a float init over int data
            # yields floats — never silently truncate init to the data
            # dtype), and broadcast init to the element shape
            dt = jnp.result_type(arr.dtype, jnp.asarray(init).dtype)
            arr2 = arr.astype(dt)
            init_el = jnp.broadcast_to(jnp.asarray(init, dtype=dt),
                                       arr2.shape[1:])[None]
            if op is operator.add:
                return jnp.concatenate(
                    [init_el, init_el + jnp.cumsum(arr2, axis=0)[:-1]])
            # scan [init, x0, ..., x_{n-2}]: prefix folds seeded with init
            ext = jnp.concatenate([init_el, arr2[:-1]])
            return _assoc_scan("exclusive_scan", op, ext)

        return _offload(policy, thunk)

    if policy.task:
        eager = policy.with_(task=False)
        return _host_executor(policy).async_execute(
            lambda: exclusive_scan(eager, data, init, op))

    n = len(data)
    ex = _host_executor(policy)
    chunk = _chunk_size(policy, n, ex)
    if ex.parallelism <= 1 or chunk >= n:
        out: List[Any] = []
        acc = init
        for x in data:
            out.append(acc)
            acc = op(acc, x)
        return out

    return _two_pass_scan(ex, _chunks(n, chunk), data, op,
                          exclusive=True, init=init)


# --------------------------------------------------------------------- sort
def sort(policy: ExecutionPolicy, data: Any) -> Any:
    """Parallel merge-ish sort: chunk-sort on pool tasks, k-way merge."""
    policy = _as_policy(policy)
    if _is_segmented(data):
        return _seg_dispatch("sort", policy, data)
    m = _mode(policy)
    if m in ("vec", "device"):
        def thunk():
            arr = jnp.asarray(data)
            if m == "device":
                arr = _device_ex(policy).put(arr)
            return jnp.sort(arr)

        return _offload(policy, thunk)

    n = len(data)

    def _run(lo: int, hi: int) -> List[Any]:
        return builtins.sorted(data[lo:hi])

    import heapq

    return _join(policy, _bulk(policy, n, _run),
                 lambda parts: list(heapq.merge(*parts)))


# --------------------------------------------------------------- predicates
def count_if(policy: ExecutionPolicy, data: Any,
             pred: Callable[[Any], Any]) -> Any:
    policy = _as_policy(policy)
    if _is_segmented(data):
        return _seg_dispatch("count_if", policy, data, pred)
    body = (  # one lowering: transform_reduce owns the vec/device dispatch
        (lambda x: jnp.int32(pred(x))) if _mode(policy) in ("vec", "device")
        else (lambda x: 1 if pred(x) else 0))
    res = transform_reduce(policy, data, body, init=0)
    return _then_on(policy, res, int) if policy.task else int(res)


def _then_on(policy: ExecutionPolicy, fut: Future,
             fn: Callable[[Any], Any]) -> Future:
    """Continuation on the *policy's* executor (``Future.then`` would land
    on the global default pool, leaking off the bound pool)."""
    ex = _host_executor(policy)
    promise: Promise = Promise()

    def _fire(ready: Future) -> None:
        def _run() -> None:
            try:
                promise.set_value(fn(ready.get()))
            except BaseException as e:  # noqa: BLE001
                promise.set_exception(e)

        ex.post(_run)

    fut._on_ready(_fire)
    return promise.future()


def _predicate_result(policy: ExecutionPolicy, counted: Any,
                      check: Callable[[int], bool]):
    if isinstance(counted, Future):
        return _then_on(policy, counted, check)
    return check(counted)


def all_of(policy: ExecutionPolicy, data: Any, pred: Callable[[Any], Any]) -> Any:
    n = len(data)
    return _predicate_result(policy, count_if(policy, data, pred),
                             lambda c: c == n)


def any_of(policy: ExecutionPolicy, data: Any, pred: Callable[[Any], Any]) -> Any:
    return _predicate_result(policy, count_if(policy, data, pred),
                             lambda c: c > 0)


# --------------------------------------------------------------------- fill
def fill(policy: ExecutionPolicy, data: Any, value: Any) -> Any:
    """Assign ``value`` to every element (C++ ``std::fill``).

    Host policies mutate ``data`` in place (it must be a mutable sequence)
    and return it; vec/mesh return a new filled array of ``data``'s shape
    and dtype (arrays are immutable under jax)."""
    policy = _as_policy(policy)
    if _is_segmented(data):
        return _seg_dispatch("fill", policy, data, value)
    m = _mode(policy)
    if m in ("vec", "device"):
        def thunk():
            arr = jnp.asarray(data)
            if m == "device":
                arr = _device_ex(policy).put(arr)
            return jnp.full(arr.shape, value, dtype=arr.dtype)

        return _offload(policy, thunk)

    n = len(data)

    def _run(lo: int, hi: int) -> None:
        for i in range(lo, hi):
            data[i] = value

    return _join(policy, _bulk(policy, n, _run), lambda parts: data)


# ---------------------------------------------------------------- extrema
def _extremum(policy: ExecutionPolicy, data: Any, name: str,
              host_pick: Callable, jnp_pick: Callable) -> Any:
    policy = _as_policy(policy)
    if _is_segmented(data):
        return _seg_dispatch(name, policy, data)
    if len(data) == 0:  # C++ returns last; we are value-returning, so raise
        raise ValueError(f"{name} of an empty range")
    m = _mode(policy)
    if m in ("vec", "device"):
        def thunk():
            arr = jnp.asarray(data)
            if m == "device":
                arr = _device_ex(policy).put(arr)
            return jnp_pick(arr, axis=0)  # scalars → the element; batched
            # elements → elementwise extremum (no total order on arrays)

        return _offload(policy, thunk)

    def _run(lo: int, hi: int) -> Any:
        return host_pick(data[i] for i in range(lo, hi))

    return _join(policy, _bulk(policy, len(data), _run), host_pick)


def min_element(policy: ExecutionPolicy, data: Any) -> Any:
    """Smallest element's value (C++ ``min_element``, dereferenced)."""
    return _extremum(policy, data, "min_element", builtins.min, jnp.min)


def max_element(policy: ExecutionPolicy, data: Any) -> Any:
    """Largest element's value (C++ ``max_element``, dereferenced)."""
    return _extremum(policy, data, "max_element", builtins.max, jnp.max)


# --------------------------------------------------------------------- copy
def copy(policy: ExecutionPolicy, data: Any) -> Any:
    policy = _as_policy(policy)
    m = _mode(policy)
    if m in ("vec", "device"):
        def thunk():
            arr = jnp.asarray(data)
            if m == "device":
                arr = _device_ex(policy).put(arr)
            return jnp.array(arr, copy=True)

        return _offload(policy, thunk)
    n = len(data)

    def _run(lo: int, hi: int) -> List[Any]:
        return list(data[lo:hi])

    return _join(policy, _bulk(policy, n, _run),
                 lambda parts: [x for p in parts for x in p])
