"""C++17-style parallel algorithms with execution policies (HPX P6).

The paper: C++17 "support for parallel algorithms was added, which
coincidentally covers the need for data parallel algorithms"; HPX provides
the reference implementation.  We provide the JAX analogue:

    for_each, transform, reduce, transform_reduce, inclusive_scan,
    exclusive_scan, sort, count_if, all_of/any_of, copy

Each takes an :class:`~repro.core.executor.ExecutionPolicy`:

- ``seq``  — plain Python/jnp loop (specification oracle);
- ``par``  — chunks dispatched as AMT scheduler tasks (host parallel);
- ``vec``  — jnp/vmap vectorized;
- ``mesh`` — input sharded over a mesh axis; the body runs on-device
  per shard, reductions finish with the matching collective.  This is the
  device-plane data-parallel executor of DESIGN.md §2.

All algorithms return *values* under ``seq``/``vec``/``mesh`` and under
``par`` as well (they internally join their tasks): parallelism is an
implementation detail of the algorithm, exactly the C++ standard's stance.
"""

from __future__ import annotations

import builtins
import operator
from typing import Any, Callable, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import scheduler as _sched
from repro.core.executor import ExecutionPolicy, par, seq, vec
from repro.core.future import wait_all


def _chunks(n: int, chunk: int) -> List[tuple]:
    return [(i, min(i + chunk, n)) for i in range(0, n, chunk)]


def _default_chunk(policy: ExecutionPolicy, n: int) -> int:
    if policy.chunk_size:
        return policy.chunk_size
    rt = _sched.get_runtime()
    return max(1, n // (4 * rt.num_workers))


# ---------------------------------------------------------------- for_each
def for_each(policy: ExecutionPolicy, data: Sequence[Any], fn: Callable[[Any], None]) -> None:
    if policy.kind in ("seq", "vec"):
        for x in data:
            fn(x)
        return
    if policy.kind == "par":
        n = len(data)
        chunk = _default_chunk(policy, n)
        rt = _sched.get_runtime()

        def _run(lo: int, hi: int) -> None:
            for i in range(lo, hi):
                fn(data[i])

        wait_all([rt.spawn(_run, lo, hi) for lo, hi in _chunks(n, chunk)])
        return
    raise ValueError(f"for_each: unsupported policy {policy.kind}")


# ---------------------------------------------------------------- transform
def transform(policy: ExecutionPolicy, data: Any, fn: Callable[[Any], Any]) -> Any:
    if policy.kind == "seq":
        return [fn(x) for x in data]
    if policy.kind == "vec":
        return jax.vmap(fn)(jnp.asarray(data))
    if policy.kind == "par":
        n = len(data)
        chunk = _default_chunk(policy, n)
        rt = _sched.get_runtime()

        def _run(lo: int, hi: int) -> List[Any]:
            return [fn(data[i]) for i in range(lo, hi)]

        futs = [rt.spawn(_run, lo, hi) for lo, hi in _chunks(n, chunk)]
        out: List[Any] = []
        for f in futs:
            out.extend(f.get())
        return out
    if policy.kind == "mesh":
        arr = jnp.asarray(data)
        sharding = jax.sharding.NamedSharding(
            policy.mesh, jax.sharding.PartitionSpec(policy.axis)
        )
        arr = jax.device_put(arr, sharding)
        return jax.jit(jax.vmap(fn), out_shardings=sharding)(arr)
    raise ValueError(f"transform: unsupported policy {policy.kind}")


# ------------------------------------------------------------------- reduce
def reduce(
    policy: ExecutionPolicy,
    data: Any,
    init: Any = 0,
    op: Callable[[Any, Any], Any] = operator.add,
) -> Any:
    if policy.kind == "seq":
        acc = init
        for x in data:
            acc = op(acc, x)
        return acc
    if policy.kind == "vec":
        arr = jnp.asarray(data)
        if op is operator.add:
            return init + jnp.sum(arr)
        acc = init
        for x in arr:  # generic op: no vectorized shortcut
            acc = op(acc, x)
        return acc
    if policy.kind == "par":
        n = len(data)
        chunk = _default_chunk(policy, n)
        rt = _sched.get_runtime()

        def _run(lo: int, hi: int) -> Any:
            acc = data[lo]
            for i in range(lo + 1, hi):
                acc = op(acc, data[i])
            return acc

        futs = [rt.spawn(_run, lo, hi) for lo, hi in _chunks(n, chunk)]
        acc = init
        for f in futs:  # op must be associative (C++ requirement)
            acc = op(acc, f.get())
        return acc
    if policy.kind == "mesh":
        arr = jnp.asarray(data)
        mesh, axis = policy.mesh, policy.axis
        sharding = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec(axis))
        arr = jax.device_put(arr, sharding)

        def _body(x):  # per-shard partial + collective finish
            return jax.lax.psum(jnp.sum(x), axis)

        total = jax.jit(
            jax.shard_map(
                _body,
                mesh=mesh,
                in_specs=jax.sharding.PartitionSpec(axis),
                out_specs=jax.sharding.PartitionSpec(),
            )
        )(arr)
        return init + total
    raise ValueError(f"reduce: unsupported policy {policy.kind}")


def transform_reduce(
    policy: ExecutionPolicy,
    data: Any,
    fn: Callable[[Any], Any],
    init: Any = 0,
    op: Callable[[Any, Any], Any] = operator.add,
) -> Any:
    if policy.kind == "vec":
        return init + jnp.sum(jax.vmap(fn)(jnp.asarray(data)))
    if policy.kind == "mesh":
        return reduce(policy, transform(policy, data, fn), init=init, op=op)
    return reduce(policy, [fn(x) for x in data] if policy.kind == "seq" else transform(policy, data, fn), init=init, op=op)


# -------------------------------------------------------------------- scans
def inclusive_scan(policy: ExecutionPolicy, data: Any, op: Callable = operator.add) -> Any:
    if policy.kind in ("vec", "mesh"):
        arr = jnp.asarray(data)
        if op is operator.add:
            return jnp.cumsum(arr)
        return jax.lax.associative_scan(jax.vmap(op), arr)
    out: List[Any] = []
    acc: Optional[Any] = None
    for x in data:
        acc = x if acc is None else op(acc, x)
        out.append(acc)
    return out


def exclusive_scan(policy: ExecutionPolicy, data: Any, init: Any = 0, op: Callable = operator.add) -> Any:
    if policy.kind in ("vec", "mesh"):
        arr = jnp.asarray(data)
        if op is operator.add:
            return jnp.concatenate([jnp.asarray([init], dtype=arr.dtype), init + jnp.cumsum(arr)[:-1]])
    out: List[Any] = []
    acc = init
    for x in data:
        out.append(acc)
        acc = op(acc, x)
    return out


# --------------------------------------------------------------------- sort
def sort(policy: ExecutionPolicy, data: Any) -> Any:
    """Parallel merge-ish sort: chunk-sort on tasks, k-way merge on host."""
    if policy.kind == "seq":
        return builtins.sorted(data)
    if policy.kind in ("vec", "mesh"):
        return jnp.sort(jnp.asarray(data))
    n = len(data)
    chunk = _default_chunk(policy, n)
    rt = _sched.get_runtime()
    futs = [rt.spawn(lambda lo=lo, hi=hi: builtins.sorted(data[lo:hi])) for lo, hi in _chunks(n, chunk)]
    import heapq

    return list(heapq.merge(*[f.get() for f in futs]))


# --------------------------------------------------------------- predicates
def count_if(policy: ExecutionPolicy, data: Any, pred: Callable[[Any], bool]) -> int:
    if policy.kind == "vec":
        return int(jnp.sum(jax.vmap(pred)(jnp.asarray(data))))
    return int(transform_reduce(policy, data, lambda x: 1 if pred(x) else 0, init=0))


def all_of(policy: ExecutionPolicy, data: Any, pred: Callable[[Any], bool]) -> bool:
    return count_if(policy, data, pred) == len(data)


def any_of(policy: ExecutionPolicy, data: Any, pred: Callable[[Any], bool]) -> bool:
    return count_if(policy, data, pred) > 0


def copy(policy: ExecutionPolicy, data: Any) -> Any:
    if policy.kind in ("vec", "mesh"):
        return jnp.array(jnp.asarray(data), copy=True)
    return list(data)
