"""Object migration & elastic resharding (HPX P3: "load balancing through
object migration").

In HPX an object migrates between process address spaces while its GID stays
valid.  Here an object is a pytree of ``jax.Array`` leaves and a "locality"
is a sharding; migration is ``device_put`` onto the new placement (XLA emits
the minimal resharding collective) plus an AGAS generation bump.

This single primitive gives us the framework's fault-tolerance story:

- **elastic restart** — checkpoint written on mesh A restores onto mesh B
  (different chip count / topology): ``checkpoint.restore`` loads host
  arrays and calls :func:`migrate_tree` with B's shardings;
- **shrink-on-failure** — on a simulated node loss, the trainer rebuilds a
  smaller mesh and migrates live state onto it;
- **load rebalancing** — AGAS-registered KV caches move between serving
  meshes as request load shifts.
"""

from __future__ import annotations

from typing import Any, Optional

import jax

from repro.core import agas as _agas
from repro.core import counters as _counters


def migrate_tree(tree: Any, shardings: Any) -> Any:
    """Reshard every leaf of ``tree`` onto the matching sharding.

    ``shardings`` is either a single sharding (applied to all leaves) or a
    pytree of shardings matching ``tree``'s structure.
    """
    _counters.counter("/migration/trees/cumulative").increment()
    return jax.device_put(tree, shardings)


def migrate(gid_or_name, shardings: Any, resolver: Optional[_agas.AGAS] = None) -> int:
    """Migrate an AGAS-registered object to a new placement.

    The GID remains valid; readers that re-resolve see the new placement
    (HPX semantics: AGAS is responsible for address resolution after
    migration).  Returns the new generation number.
    """
    resolver = resolver or _agas.default()
    rec = resolver.record(gid_or_name)
    moved = migrate_tree(rec.obj, shardings)
    return resolver.rebind(rec.gid, moved, placement=shardings)


def migrate_to_mesh(gid_or_name, new_mesh, spec_fn, resolver: Optional[_agas.AGAS] = None) -> int:
    """Migrate onto a *different mesh* (elastic scaling).

    ``spec_fn(path_free_leaf) -> PartitionSpec`` is usually
    ``lambda leaf: plan.sharding_for(leaf, new_mesh)`` from
    :mod:`repro.dist.plan` (bind the TARGET mesh — the divisibility guard
    must see the destination axis sizes); we rebuild
    NamedShardings against ``new_mesh`` and reshard.
    """
    resolver = resolver or _agas.default()
    rec = resolver.record(gid_or_name)
    shardings = jax.tree.map(
        lambda leaf: jax.sharding.NamedSharding(new_mesh, spec_fn(leaf)), rec.obj
    )
    moved = migrate_tree(rec.obj, shardings)
    return resolver.rebind(rec.gid, moved, placement=new_mesh)
