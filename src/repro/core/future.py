"""Futures, promises and composition primitives (HPX P1).

HPX's central abstraction is the *future*: a proxy for a value that will be
computed asynchronously, enabling wait-free composition via ``.then()``,
``when_all`` / ``when_any`` and ``dataflow`` (see :mod:`repro.core.dataflow`).

JAX note: a ``jax.Array`` produced by a jitted computation is *already* a
future — XLA dispatch is asynchronous and the host only blocks when the value
is read.  ``repro.core.Future`` is the host-plane complement: it sequences
*host* work (step dispatch, I/O, checkpointing, serving continuations) on the
AMT scheduler, while device work overlaps underneath.  ``Future.get`` on a
value containing ``jax.Array`` leaves therefore composes both planes.

Deadlock-freedom: ``Future.get`` called *from a scheduler worker thread*
does not merely block — it runs a *help-along* loop, executing pending tasks
while it waits.  This mirrors HPX's user-level thread suspension (the paper's
"oversubscribing execution resources"): a blocked logical task never wastes
its execution resource.
"""

from __future__ import annotations

import threading
from enum import Enum
from typing import Any, Callable, Generic, Iterable, List, Optional, Sequence, TypeVar

T = TypeVar("T")
U = TypeVar("U")


class FutureState(Enum):
    PENDING = 0
    READY = 1
    FAILED = 2


class FutureError(RuntimeError):
    pass


class Future(Generic[T]):
    """Read side of a :class:`Promise`. One-shot, many readers."""

    __slots__ = ("_state", "_value", "_exc", "_cbs", "_cond")

    def __init__(self) -> None:
        self._state = FutureState.PENDING
        self._value: Optional[T] = None
        self._exc: Optional[BaseException] = None
        self._cbs: List[Callable[["Future[T]"], None]] = []
        self._cond = threading.Condition()

    # -- state ----------------------------------------------------------
    def is_ready(self) -> bool:
        with self._cond:
            return self._state is not FutureState.PENDING

    def has_value(self) -> bool:
        with self._cond:
            return self._state is FutureState.READY

    def has_exception(self) -> bool:
        with self._cond:
            return self._state is FutureState.FAILED

    # -- completion (used by Promise) ------------------------------------
    def _set(self, value: Optional[T], exc: Optional[BaseException]) -> None:
        with self._cond:
            if self._state is not FutureState.PENDING:
                raise FutureError("promise already satisfied")
            self._value = value
            self._exc = exc
            self._state = FutureState.FAILED if exc is not None else FutureState.READY
            cbs, self._cbs = self._cbs, []
            self._cond.notify_all()
        for cb in cbs:
            cb(self)

    # -- access -----------------------------------------------------------
    def get(self, timeout: Optional[float] = None) -> T:
        """Wait for and return the value (re-raises a stored exception).

        From a worker thread this *helps along* — executes queued tasks while
        waiting, so nested blocking cannot starve the pool.
        """
        from repro.core import scheduler as _sched  # deferred, avoids cycle

        rt = _sched.current_runtime()
        if rt is not None and rt.on_worker_thread():
            rt._help_until(self, timeout)  # executes tasks until ready
        with self._cond:
            if not self._cond.wait_for(
                lambda: self._state is not FutureState.PENDING, timeout
            ):
                raise TimeoutError("future.get timed out")
            if self._exc is not None:
                raise self._exc
            return self._value  # type: ignore[return-value]

    def wait(self, timeout: Optional[float] = None) -> bool:
        from repro.core import scheduler as _sched

        rt = _sched.current_runtime()
        if rt is not None and rt.on_worker_thread():
            rt._help_until(self, timeout)
        with self._cond:
            return self._cond.wait_for(
                lambda: self._state is not FutureState.PENDING, timeout
            )

    def wait_passive(self, timeout: Optional[float] = None) -> bool:
        """Plain blocking wait, never helps along (used *by* the help loop)."""
        with self._cond:
            return self._cond.wait_for(
                lambda: self._state is not FutureState.PENDING, timeout
            )

    def exception(self) -> Optional[BaseException]:
        with self._cond:
            return self._exc

    # -- composition ------------------------------------------------------
    def _on_ready(self, cb: Callable[["Future[T]"], None]) -> None:
        """Run ``cb(self)`` when ready (immediately if already ready).

        The callback NEVER runs under the future's lock — neither from
        ``_set`` (completion) nor from the already-ready fast path here —
        so a callback may itself call ``get``/``then``/``on_ready`` on this
        future without deadlocking.  This is what makes the callback a safe
        remote-completion hook: the net layer forwards results over the
        parcelport from inside one."""
        run_now = False
        with self._cond:
            if self._state is FutureState.PENDING:
                self._cbs.append(cb)
            else:
                run_now = True
        if run_now:
            cb(self)

    def on_ready(self, cb: Callable[["Future[T]"], None]) -> None:
        """Public completion hook (value *or* exception): ``cb(self)`` runs
        exactly once, on the completing thread (or inline when already
        ready), outside the future's lock.  Unlike :meth:`then` it spawns
        no task — use it for cheap bookkeeping (counter updates, result
        forwarding); use ``then`` for real continuations."""
        self._on_ready(cb)

    def then(self, fn: Callable[["Future[T]"], U], priority: Optional[int] = None) -> "Future[U]":
        """HPX ``future::then`` — attach a continuation, get a new future.

        ``fn`` receives the *ready future* (HPX semantics, lets continuations
        inspect exceptions).  The continuation is a real task on the
        scheduler, so chains parallelize across workers.
        """
        from repro.core import scheduler as _sched

        promise: Promise[U] = Promise()

        def _launch(ready: "Future[T]") -> None:
            def _run() -> None:
                try:
                    promise.set_value(fn(ready))
                except BaseException as e:  # noqa: BLE001 — futures carry any error
                    promise.set_exception(e)

            rt = _sched.current_runtime()
            if rt is not None:
                rt.spawn_raw(_run, priority=priority)
            else:  # no runtime: degrade to inline execution
                _run()

        self._on_ready(_launch)
        return promise.future()

    def then_value(self, fn: Callable[[T], U]) -> "Future[U]":
        """Convenience: continuation over the *value* (propagates errors)."""
        return self.then(lambda f: fn(f.get()))


class Promise(Generic[T]):
    """Write side: satisfied exactly once."""

    __slots__ = ("_future",)

    def __init__(self) -> None:
        self._future: Future[T] = Future()

    def future(self) -> Future[T]:
        return self._future

    def set_value(self, value: T) -> None:
        self._future._set(value, None)

    def set_exception(self, exc: BaseException) -> None:
        self._future._set(None, exc)

    def set_from(self, ready: "Future[T]") -> None:
        """Copy a *ready* future's outcome (value or exception) into this
        promise — the completion relay used when a result crosses a retry
        loop or the parcelport (remote completion)."""
        exc = ready.exception()
        if exc is not None:
            self._future._set(None, exc)
        else:
            self._future._set(ready._value, None)


class ChannelClosed(FutureError):
    """Raised by :meth:`Channel.get` once the channel is closed and drained."""


class Channel(Generic[T]):
    """HPX ``hpx::lcos::channel<T>`` — an ordered multi-value pipe.

    Producers :meth:`set` values; consumers :meth:`get` them FIFO (each
    ``get`` is backed by a :class:`Future`, so consumers on scheduler
    workers *help along* instead of blocking the pool).  :meth:`close`
    ends the stream: buffered values still drain, then ``get`` raises
    :class:`ChannelClosed` and iteration stops.  The serve engine streams
    one token per ``set`` and closes on request completion.
    """

    __slots__ = ("_buf", "_waiters", "_closed", "_close_exc", "_lock")

    def __init__(self) -> None:
        self._buf: List[T] = []
        self._waiters: List[Promise[T]] = []
        self._closed = False
        self._close_exc: Optional[BaseException] = None
        self._lock = threading.Lock()

    def set(self, value: T) -> None:
        """Push one value (wakes the oldest waiter, else buffers)."""
        with self._lock:
            if self._closed:
                raise ChannelClosed("set() on closed channel")
            waiter = self._waiters.pop(0) if self._waiters else None
            if waiter is None:
                self._buf.append(value)
        if waiter is not None:
            waiter.set_value(value)

    def _end_exc(self) -> BaseException:
        return self._close_exc or ChannelClosed("channel closed")

    def close(self, exc: Optional[BaseException] = None) -> None:
        """End the stream. Buffered values remain readable; blocked and
        future ``get``s observe :class:`ChannelClosed` — or ``exc``, when
        given: the error takes the FIFO position *after* everything already
        buffered, so a producer failing mid-stream delivers every token it
        produced and then the failure, in order.  Blocked readers (buffer
        necessarily empty) see it immediately.  A second close keeps the
        first outcome."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
            self._close_exc = exc
            waiters, self._waiters = self._waiters, []
        end = self._end_exc()
        for w in waiters:
            w.set_exception(end)

    def is_closed(self) -> bool:
        with self._lock:
            return self._closed

    def get_future(self) -> Future[T]:
        """Future for the next value, HPX ``channel::get`` semantics."""
        promise: Promise[T] = Promise()
        with self._lock:
            if self._buf:
                value, ok = self._buf.pop(0), True
            elif self._closed:
                value, ok = None, False
            else:
                self._waiters.append(promise)
                return promise.future()
        if ok:
            promise.set_value(value)  # type: ignore[arg-type]
        else:
            promise.set_exception(self._end_exc())
        return promise.future()

    def get(self, timeout: Optional[float] = None) -> T:
        return self.get_future().get(timeout)

    def try_get(self):
        """Non-blocking: (True, value) or (False, None)."""
        with self._lock:
            if self._buf:
                return True, self._buf.pop(0)
            return False, None

    def __iter__(self):
        while True:
            try:
                yield self.get()
            except ChannelClosed:
                return


def make_ready_future(value: T) -> Future[T]:
    p: Promise[T] = Promise()
    p.set_value(value)
    return p.future()


def make_exceptional_future(exc: BaseException) -> Future[Any]:
    p: Promise[Any] = Promise()
    p.set_exception(exc)
    return p.future()


def when_all(futures: Sequence[Future[Any]]) -> Future[List[Future[Any]]]:
    """Future that becomes ready when *all* inputs are ready.

    Like HPX, the result is the list of (ready) input futures — exceptions
    are observed by the consumer, not swallowed here.
    """
    futures = list(futures)
    promise: Promise[List[Future[Any]]] = Promise()
    if not futures:
        promise.set_value([])
        return promise.future()
    remaining = [len(futures)]
    lock = threading.Lock()

    def _one_done(_f: Future[Any]) -> None:
        with lock:
            remaining[0] -= 1
            done = remaining[0] == 0
        if done:
            promise.set_value(futures)

    for f in futures:
        f._on_ready(_one_done)
    return promise.future()


def when_any(futures: Sequence[Future[Any]]) -> Future[int]:
    """Future ready when *any* input is; value = index of the winner."""
    futures = list(futures)
    if not futures:
        raise ValueError("when_any of empty sequence")
    promise: Promise[int] = Promise()
    fired = threading.Event()

    def _make(i: int) -> Callable[[Future[Any]], None]:
        def _cb(_f: Future[Any]) -> None:
            if not fired.is_set():
                # benign race: Event + one-shot promise; double-set guarded
                try:
                    promise.set_value(i)
                    fired.set()
                except FutureError:
                    pass

        return _cb

    for i, f in enumerate(futures):
        f._on_ready(_make(i))
    return promise.future()


def wait_all(futures: Iterable[Future[Any]], timeout: Optional[float] = None) -> None:
    when_all(list(futures)).wait(timeout)


def unwrap(value: Any) -> Any:
    """Recursively resolve Futures inside (nested) lists/tuples/dicts."""
    if isinstance(value, Future):
        return unwrap(value.get())
    if isinstance(value, (list, tuple)):
        return type(value)(unwrap(v) for v in value)
    if isinstance(value, dict):
        return {k: unwrap(v) for k, v in value.items()}
    return value
