"""Executors & execution policies (HPX P6 substrate).

C++17 parallel algorithms take an *execution policy*; HPX extends these
with *executors* that bind a policy to concrete execution resources, and a
resource partitioner that carves workers into named thread pools.  This
module is that surface:

**Executors** (where work runs) — all expose the HPX executor protocol
``post`` / ``async_execute`` / ``sync_execute`` / ``bulk_async_execute``:

- :class:`SequencedExecutor`   — inline, in the calling thread;
- :class:`ThreadPoolExecutor`  — a named pool of the resource partitioner
  (:meth:`repro.core.scheduler.Runtime.get_executor` hands these out);
- :class:`PriorityExecutor`    — wraps any executor with a scheduler
  priority (HPX ``annotating_executor`` / thread_priority);
- :class:`MeshExecutor`        — the device plane: data sharded over a mesh
  axis, bodies dispatched as sharded ``vmap``/``shard_map`` computations
  (TPU analogue of HPX distributed executors).

**Policies** (how algorithms lower) are *pure rewrite objects* — they carry
no resources of their own, only a lowering flavor plus executor/parameter
bindings:

    par.on(rt.get_executor("io"))              # bind to a resource
    par.with_(chunk_size=1024, priority=2)     # tune parameters
    par_task                                    # two-way: algorithms
                                                #   return Futures
    vec.on(MeshExecutor(mesh, "data"))         # device-plane lowering

Legacy spelling (``ExecutionPolicy(kind="mesh", mesh=..., axis=...)``,
``par.on(mesh)`` with a raw mesh) still works behind a thin deprecation
shim that rewrites it onto the executor hierarchy; :func:`mesh_policy` is
the supported convenience for ``vec.on(MeshExecutor(mesh, axis))``.
"""

from __future__ import annotations

import warnings
from typing import Any, Callable, List, Optional, Sequence, Tuple

from repro.core import scheduler as _sched
from repro.core.future import Future, make_exceptional_future, make_ready_future


# ------------------------------------------------------------------ executors
class Executor:
    """HPX executor protocol.

    ``plane`` distinguishes host executors (chunked Python bodies on a
    thread pool) from device executors (whole-array sharded dispatch).
    ``bulk_async_execute(fn, args_seq)`` launches one task per element of
    ``args_seq`` (a tuple element is splatted as ``fn(*elem)``) — the
    algorithms library lowers every parallel loop through it.
    """

    plane = "host"

    # -- submission core (subclasses implement) ---------------------------
    def _submit(self, fn: Callable[..., Any], args: Tuple[Any, ...],
                kwargs: dict, priority: Optional[int]) -> Future[Any]:
        raise NotImplementedError

    def _post(self, fn: Callable[..., Any], args: Tuple[Any, ...],
              kwargs: dict, priority: Optional[int]) -> None:
        """Fire-and-forget core.  Failures must stay loud: inline executors
        propagate, pool executors report via ``/scheduler{pool}/tasks/failed``
        — never an exception parked in a Future nobody reads."""
        fn(*args, **kwargs)

    @property
    def parallelism(self) -> int:
        """Concurrent tasks this executor can make progress on (chunking hint)."""
        return 1

    # -- HPX executor surface ---------------------------------------------
    def post(self, fn: Callable[..., Any], *args: Any, **kwargs: Any) -> None:
        """Fire-and-forget (``hpx::post``)."""
        self._post(fn, args, kwargs, None)

    def async_execute(self, fn: Callable[..., Any], *args: Any, **kwargs: Any) -> Future[Any]:
        """Schedule ``fn(*args, **kwargs)``; returns its Future (``hpx::async``)."""
        return self._submit(fn, args, kwargs, None)

    def sync_execute(self, fn: Callable[..., Any], *args: Any, **kwargs: Any) -> Any:
        """Schedule and join (``hpx::sync``)."""
        return self.async_execute(fn, *args, **kwargs).get()

    def bulk_async_execute(self, fn: Callable[..., Any],
                           args_seq: Sequence[Any]) -> List[Future[Any]]:
        """One task per element; tuples splat as ``fn(*elem)``."""
        return [
            self._submit(fn, a if isinstance(a, tuple) else (a,), {}, None)
            for a in args_seq
        ]


class SequencedExecutor(Executor):
    """Runs everything inline in the calling thread (the ``seq`` resource).

    Futures it returns are already resolved — it exists so sequential and
    parallel lowerings share one code path in the algorithms library."""

    def _submit(self, fn, args, kwargs, priority):
        try:
            return make_ready_future(fn(*args, **kwargs))
        except BaseException as e:  # noqa: BLE001 — futures carry any error
            return make_exceptional_future(e)

    def sync_execute(self, fn, *args, **kwargs):
        return fn(*args, **kwargs)


class ThreadPoolExecutor(Executor):
    """Binds a *named* pool of the resource partitioner.

    The pool is resolved late — at submission, against ``runtime`` (or the
    global runtime when ``runtime`` is None) — so a module-level executor
    stays valid across runtime restarts.  ``fallback`` names a pool to use
    when the requested one was never partitioned (e.g. "io" consumers on a
    bare single-pool runtime)."""

    def __init__(self, pool: Optional[str] = None, *,
                 runtime: Optional["_sched.Runtime"] = None,
                 fallback: Optional[str] = None,
                 priority: Optional[int] = None):
        self.pool_name = pool
        self.fallback = fallback
        self.priority = priority
        self._runtime = runtime

    def _pool(self) -> "_sched.ThreadPool":
        rt = self._runtime if self._runtime is not None else _sched.get_runtime()
        return rt.pool(self.pool_name, fallback=self.fallback)

    @property
    def parallelism(self) -> int:
        return self._pool().num_workers

    def _submit(self, fn, args, kwargs, priority):
        prio = priority if priority is not None else self.priority
        return self._pool().spawn(
            fn, *args,
            priority=_sched.PRIORITY_NORMAL if prio is None else prio,
            **kwargs)

    def _post(self, fn, args, kwargs, priority):
        prio = priority if priority is not None else self.priority
        if args or kwargs:
            self._pool().spawn_raw(lambda: fn(*args, **kwargs), priority=prio)
        else:
            self._pool().spawn_raw(fn, priority=prio)

    def __repr__(self) -> str:
        return f"ThreadPoolExecutor({self.pool_name!r})"


class PriorityExecutor(Executor):
    """Wraps any executor, stamping a scheduler priority on its tasks
    (HPX ``thread_priority`` annotation).  Priority-oblivious executors
    (sequenced, mesh) run unchanged."""

    def __init__(self, inner: Executor, priority: int):
        self.inner = inner
        self.priority = priority

    @property
    def plane(self) -> str:  # type: ignore[override]
        return self.inner.plane

    @property
    def parallelism(self) -> int:
        return self.inner.parallelism

    def _submit(self, fn, args, kwargs, priority):
        return self.inner._submit(fn, args, kwargs,
                                  self.priority if priority is None else priority)

    def _post(self, fn, args, kwargs, priority):
        self.inner._post(fn, args, kwargs,
                         self.priority if priority is None else priority)

    def __repr__(self) -> str:
        return f"PriorityExecutor({self.inner!r}, priority={self.priority})"


class MeshExecutor(Executor):
    """Device-plane executor: data sharded over one mesh axis, algorithm
    bodies dispatched as sharded ``vmap`` / ``shard_map`` computations
    (the TPU analogue of an HPX distributed executor).

    Host-protocol calls (``post``/``async_execute``) run the Python callable
    inline — XLA dispatch is already asynchronous, so the host side of a
    device computation never needs a worker thread."""

    plane = "device"

    def __init__(self, mesh: Any, axis: str = "data"):
        self.mesh = mesh
        self.axis = axis

    @property
    def parallelism(self) -> int:
        try:
            return int(self.mesh.shape[self.axis])
        except Exception:  # noqa: BLE001 — unknown mesh flavor
            return 1

    def _submit(self, fn, args, kwargs, priority):
        try:
            return make_ready_future(fn(*args, **kwargs))
        except BaseException as e:  # noqa: BLE001
            return make_exceptional_future(e)

    # -- device-plane dispatch (used by repro.core.algorithms) -------------
    def sharding(self):
        import jax

        return jax.sharding.NamedSharding(
            self.mesh, jax.sharding.PartitionSpec(self.axis))

    def put(self, arr):
        """Shard an array over the executor's mesh axis."""
        import jax

        return jax.device_put(arr, self.sharding())

    def vmap_apply(self, fn: Callable[[Any], Any], arr):
        """Elementwise map: sharded in, sharded out, body per element."""
        import jax

        return jax.jit(jax.vmap(fn), out_shardings=self.sharding())(self.put(arr))

    def sum_total(self, arr):
        """Global sum: per-shard partial + psum finish (collective)."""
        import jax
        import jax.numpy as jnp

        shard_map = getattr(jax, "shard_map", None)
        if shard_map is None:  # jax<0.5 spelling
            from jax.experimental.shard_map import shard_map

        def _body(x):  # axis=0: elements may be batched arrays
            return jax.lax.psum(jnp.sum(x, axis=0), self.axis)

        return jax.jit(
            shard_map(
                _body,
                mesh=self.mesh,
                in_specs=jax.sharding.PartitionSpec(self.axis),
                out_specs=jax.sharding.PartitionSpec(),
            )
        )(self.put(arr))

    def __repr__(self) -> str:
        return f"MeshExecutor(axis={self.axis!r}, mesh={self.mesh!r})"


def get_executor(pool: Optional[str] = None, priority: Optional[int] = None,
                 fallback: Optional[str] = None,
                 runtime: Optional["_sched.Runtime"] = None) -> Executor:
    """Executor over a named pool of the resource partitioner.

    This (via ``Runtime.get_executor``) is the sanctioned way for code
    outside :mod:`repro.core` to reach scheduler pools."""
    ex: Executor = ThreadPoolExecutor(pool, runtime=runtime, fallback=fallback)
    if priority is not None:
        ex = PriorityExecutor(ex, priority)
    return ex


# ------------------------------------------------------------------- policies
_FLAVORS = ("seq", "par", "vec")


def _warn_legacy(msg: str) -> None:
    warnings.warn(msg, DeprecationWarning, stacklevel=3)


class ExecutionPolicy:
    """A pure rewrite object: lowering flavor + executor/parameter bindings.

    - ``flavor``     "seq" (inline loop), "par" (chunked over an executor's
      pool), "vec" (vectorized via ``jax.vmap`` / jnp);
    - ``executor``   where chunks/arrays go (None → seq inline, par default
      pool; a device-plane executor switches any flavor to sharded array
      lowering);
    - ``chunk_size`` / ``priority``  executor parameters (``with_``);
    - ``task``       two-way execution: algorithms return ``Future``s
      instead of joining (HPX ``par(task)``).
    """

    __slots__ = ("flavor", "executor", "chunk_size", "priority", "task")

    def __init__(self, flavor: Optional[str] = None, chunk_size: Optional[int] = None,
                 mesh: Any = None, axis: Optional[str] = None, *,
                 kind: Optional[str] = None,
                 executor: Optional[Executor] = None,
                 priority: Optional[int] = None, task: bool = False):
        if kind is not None:  # legacy keyword spelling
            _warn_legacy(
                "ExecutionPolicy(kind=...) is deprecated; use the policy "
                "objects (seq/par/vec/par_task) with .on(executor)/.with_()")
            flavor = flavor or kind
        if flavor == "mesh" or mesh is not None:  # legacy device-plane spelling
            _warn_legacy(
                "ExecutionPolicy('mesh', mesh=..., axis=...) is deprecated; "
                "use vec.on(MeshExecutor(mesh, axis))")
            if mesh is None:
                raise ValueError("mesh policy requires a mesh")
            executor = MeshExecutor(mesh, axis or "data")
            flavor = "vec"
        flavor = flavor or "seq"
        if flavor not in _FLAVORS:
            raise ValueError(f"unknown policy flavor {flavor!r}; choose from {_FLAVORS}")
        object.__setattr__(self, "flavor", flavor)
        object.__setattr__(self, "executor", executor)
        object.__setattr__(self, "chunk_size", None if chunk_size is None else int(chunk_size))
        object.__setattr__(self, "priority", priority)
        object.__setattr__(self, "task", bool(task))

    def __setattr__(self, name: str, value: Any) -> None:
        raise AttributeError("ExecutionPolicy is immutable; use .on()/.with_()")

    def _replace(self, **kw: Any) -> "ExecutionPolicy":
        cur = {s: getattr(self, s) for s in self.__slots__}
        cur.update(kw)
        return ExecutionPolicy(cur["flavor"], chunk_size=cur["chunk_size"],
                               executor=cur["executor"],
                               priority=cur["priority"], task=cur["task"])

    # -- rewrites ---------------------------------------------------------
    def on(self, executor: Any, axis: str = "data") -> "ExecutionPolicy":
        """Bind to an executor (HPX ``policy.on(exec)``).

        Legacy: a raw ``jax.sharding.Mesh`` is accepted and wrapped in a
        :class:`MeshExecutor` with a deprecation warning."""
        if not isinstance(executor, Executor):
            _warn_legacy(
                "policy.on(mesh) with a raw mesh is deprecated; pass "
                "MeshExecutor(mesh, axis)")
            executor = MeshExecutor(executor, axis)
        return self._replace(executor=executor)

    def with_(self, chunk_size: Optional[int] = None,
              priority: Optional[int] = None,
              task: Optional[bool] = None) -> "ExecutionPolicy":
        """Rebind executor parameters (HPX ``policy.with_(params)``)."""
        kw: dict = {}
        if chunk_size is not None:
            kw["chunk_size"] = int(chunk_size)
        if priority is not None:
            kw["priority"] = priority
        if task is not None:
            kw["task"] = bool(task)
        return self._replace(**kw)

    def with_chunk_size(self, n: int) -> "ExecutionPolicy":
        """Back-compat alias for ``with_(chunk_size=n)``."""
        return self.with_(chunk_size=n)

    # -- legacy readers ---------------------------------------------------
    @property
    def kind(self) -> str:
        """Legacy tag: "mesh" when bound to a device-plane executor."""
        if self.executor is not None and self.executor.plane == "device":
            return "mesh"
        return self.flavor

    @property
    def mesh(self) -> Any:
        ex = self.executor
        return getattr(ex, "mesh", None)

    @property
    def axis(self) -> Optional[str]:
        ex = self.executor
        return getattr(ex, "axis", None)

    def __repr__(self) -> str:
        bits = [self.flavor]
        if self.task:
            bits.append("task")
        if self.executor is not None:
            bits.append(f"on={self.executor!r}")
        if self.chunk_size is not None:
            bits.append(f"chunk_size={self.chunk_size}")
        if self.priority is not None:
            bits.append(f"priority={self.priority}")
        return f"ExecutionPolicy({', '.join(bits)})"

    def __eq__(self, other: Any) -> bool:
        return (isinstance(other, ExecutionPolicy)
                and all(getattr(self, s) == getattr(other, s) for s in self.__slots__))

    def __hash__(self) -> int:
        return hash((self.flavor, id(self.executor), self.chunk_size,
                     self.priority, self.task))


seq = ExecutionPolicy("seq")
par = ExecutionPolicy("par")
vec = ExecutionPolicy("vec")
seq_task = ExecutionPolicy("seq", task=True)
par_task = ExecutionPolicy("par", task=True)  # HPX par(task): two-way algorithms


def mesh_policy(mesh: Any, axis: str = "data") -> ExecutionPolicy:
    """Device-plane policy: ``vec`` lowered through a :class:`MeshExecutor`."""
    return vec._replace(executor=MeshExecutor(mesh, axis))
