"""Execution policies & executors (HPX P6 substrate).

C++17 parallel algorithms take an *execution policy*; HPX extends these with
*executors* binding policies to concrete resources.  Ours:

- ``seq``            sequential, in the calling thread;
- ``par``            chunked across the AMT scheduler's workers (host);
- ``vec``            vectorized via jax.vmap / jnp (SIMD analogue);
- ``mesh(mesh,axis)``  device-parallel: data sharded over a mesh axis, the
                       algorithm body executes per-shard (TPU analogue of
                       HPX distributed executors).

``par.on(executor)`` / ``with_chunk_size`` mirror the HPX spelling.
"""

from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Any, Optional


@dataclass(frozen=True)
class ExecutionPolicy:
    kind: str  # "seq" | "par" | "vec" | "mesh"
    chunk_size: Optional[int] = None
    mesh: Any = None
    axis: Optional[str] = None

    def with_chunk_size(self, n: int) -> "ExecutionPolicy":
        return replace(self, chunk_size=int(n))

    def on(self, mesh: Any, axis: str = "data") -> "ExecutionPolicy":
        """Bind to a device mesh → a distributed (device-plane) policy."""
        return replace(self, kind="mesh", mesh=mesh, axis=axis)


seq = ExecutionPolicy("seq")
par = ExecutionPolicy("par")
vec = ExecutionPolicy("vec")


def mesh_policy(mesh: Any, axis: str = "data") -> ExecutionPolicy:
    return ExecutionPolicy("mesh", mesh=mesh, axis=axis)
