"""Futurization: ``dataflow`` and explicit task graphs (HPX P1).

The paper: *"Using Futurization, developers can express complex data flow
execution trees that generate millions of HPX tasks that by definition
execute in the proper order."*

``dataflow(fn, *args)`` schedules ``fn`` when every Future among its
(arbitrarily nested) arguments is ready; the call itself never blocks.
Sequential code is *futurized* by replacing values with futures — the
dependency DAG then schedules itself.

``TaskGraph`` is the explicit-DAG convenience used by the tiled-Cholesky
example/benchmark (the paper's "Linear Algebra Building Blocks"): nodes are
tasks, edges are futures, and the graph executes with exactly the
constraint-based (non-global-barrier) synchronization the paper advocates.

JAX note: when ``fn`` is a jitted function, the *host* task completes as soon
as XLA dispatch returns — device execution continues asynchronously and
downstream device work is sequenced by XLA's own dataflow.  Host and device
dependency graphs compose transparently, which is precisely the paper's
"overlapping communication and computation" pattern on a TPU system.
"""

from __future__ import annotations

import threading
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.core import scheduler as _sched
from repro.core.future import Future, Promise, unwrap, when_all


def _collect_futures(obj: Any, out: List[Future]) -> None:
    if isinstance(obj, Future):
        out.append(obj)
    elif isinstance(obj, (list, tuple)):
        for v in obj:
            _collect_futures(v, out)
    elif isinstance(obj, dict):
        for v in obj.values():
            _collect_futures(v, out)


def dataflow(fn: Callable[..., Any], *args: Any, priority: Optional[int] = None,
             executor: Optional[Any] = None, **kwargs: Any) -> Future[Any]:
    """Schedule ``fn(*args)`` once all Future arguments are ready.

    Future arguments are replaced by their values (``unwrap``), including
    inside nested containers — HPX ``hpx::dataflow`` semantics.  With
    ``executor`` the fire task runs on that executor (e.g. a named pool of
    the resource partitioner) instead of the default pool; ``priority``
    composes with it (the executor is wrapped in a ``PriorityExecutor``).
    """
    if executor is not None and priority is not None:
        from repro.core.executor import PriorityExecutor  # deferred: no cycle

        executor = PriorityExecutor(executor, priority)
    deps: List[Future] = []
    _collect_futures(args, deps)
    _collect_futures(kwargs, deps)
    promise: Promise[Any] = Promise()

    def _fire(_ready) -> None:
        def _run() -> None:
            try:
                promise.set_value(fn(*unwrap(list(args)), **unwrap(kwargs)))
            except BaseException as e:  # noqa: BLE001
                promise.set_exception(e)

        if executor is not None:
            executor.post(_run)
            return
        rt = _sched.current_runtime()
        if rt is not None:
            rt.spawn_raw(_run, priority=priority)
        else:
            _run()

    when_all(deps)._on_ready(_fire)
    return promise.future()


def futurize(fn: Callable[..., Any]) -> Callable[..., Future[Any]]:
    """Decorator: calls become dataflow tasks returning futures.

    >>> @futurize
    ... def add(a, b): return a + b
    >>> add(add(1, 2), 3).get()
    6
    """

    def wrapper(*args: Any, **kwargs: Any) -> Future[Any]:
        return dataflow(fn, *args, **kwargs)

    wrapper.__name__ = getattr(fn, "__name__", "futurized")
    wrapper.__wrapped__ = fn  # type: ignore[attr-defined]
    return wrapper


class TaskGraph:
    """Explicit dataflow DAG with named nodes.

    >>> g = TaskGraph()
    >>> a = g.add("a", lambda: 1)
    >>> b = g.add("b", lambda x: x + 1, deps=["a"])
    >>> g.run()["b"].get()
    2
    """

    def __init__(self) -> None:
        self._nodes: Dict[str, Tuple[Callable, List[str]]] = {}
        self._order: List[str] = []

    def add(self, name: str, fn: Callable[..., Any], deps: Sequence[str] = ()) -> str:
        if name in self._nodes:
            raise ValueError(f"duplicate task graph node {name!r}")
        for d in deps:
            if d not in self._nodes:
                raise ValueError(f"dependency {d!r} of {name!r} not yet defined")
        self._nodes[name] = (fn, list(deps))
        self._order.append(name)
        return name

    def run(self) -> Dict[str, Future[Any]]:
        """Launch every node as a dataflow task; returns name → Future."""
        futures: Dict[str, Future[Any]] = {}
        for name in self._order:  # insertion order is a topological order
            fn, deps = self._nodes[name]
            futures[name] = dataflow(fn, *[futures[d] for d in deps])
        return futures

    def __len__(self) -> int:
        return len(self._nodes)
