"""Work-stealing task scheduler + resource partitioner (HPX P2, paper §2.1).

The paper's thread manager offers interchangeable scheduling policies:

- ``static``       one queue per core, **no stealing**;
- ``local``        (default) one queue per core + work stealing from
                   neighbours + high-priority queues;
- ``hierarchical`` a tree of queues — tasks enqueue at the root and
                   *trickle down* as cores fetch work.

HPX's *resource partitioner* carves the machine's processing units into
**named thread pools** so different concerns never compete for the same
workers (the HPX+LCI case study keeps communication progress off the
compute pool).  Ours is :class:`Runtime`: a container of named
:class:`ThreadPool`\\ s, e.g.::

    rt = init(pools={"default": 4, "io": 1, "prefill": 1})
    ex = rt.get_executor("io")          # the only public way into a pool
    ex.async_execute(write_checkpoint)  # host I/O never steals compute slots

TPU adaptation: there are no user-level threads inside an XLA program, so
these pools run on the *host orchestration plane*: they drive data pipeline
stages, device-step dispatch (which is async in JAX — the host thread
returns immediately while the TPU computes), checkpoint I/O and serving
continuations.  The paper's "oversubscribing execution resources" maps to
launching many more logical tasks than workers; blocked tasks *help along*
(see :meth:`ThreadPool._help_until`), the analogue of HPX suspending a
user-level thread instead of an OS thread.

Performance counters published per pool (HPX names, §2.4)::

    /scheduler{<pool>}/tasks/spawned
    /scheduler{<pool>}/tasks/executed
    /scheduler{<pool>}/tasks/stolen
    /scheduler{<pool>}/tasks/pending        (instantaneous)
    /scheduler{<pool>}/task/duration        (timer)

Utilization accounting (HPX ``/threads{...}/idle-rate`` parity): every
worker accumulates *monotonic* busy/idle wall time at its own state
transitions — two clock reads per task, no locks, written only by the
owning worker and read racily by the counters (a torn read is one task
wide).  Published per pool::

    /scheduler{<pool>}/idle-rate            fraction [0,1] since pool start
    /scheduler{<pool>}/utilization          1 - idle-rate
    /scheduler{<pool>}/time/busy            cumulative busy seconds (counter)
    /scheduler{<pool>}/time/idle            cumulative idle seconds (counter)
    /scheduler{<pool>}/steals/victim#V/thief#T   steal matrix (counters)
    /scheduler{<pool>}/queue/worker#I/depth      per-worker queue gauge
    /scheduler{<pool>}/queue/high/depth          shared hi-prio queue gauge

The cumulative ``time/*`` counters are the windowed form: the fleet
sampler's positive-delta *rates* of busy vs idle give utilization over
any window (``FleetView.pool_utilization``), which is what adaptive
policies predicate on — the instantaneous fraction counters are the
since-birth summary an operator reads.  ``accounting=False`` disables
the transition bookkeeping (and skips registering the counters) for A/B
overhead measurement; the measured cost is gated ≤2% on the algorithms
bench (``BENCH_algorithms.json: sched_accounting``).

Outside :mod:`repro.core`, tasks reach a pool exclusively through the
executors of :mod:`repro.core.executor` (``Runtime.get_executor``); the
``spawn``/``spawn_raw`` entry points here are the runtime's internal
substrate (enforced by ``tests/test_api_guard.py``).
"""

from __future__ import annotations

import collections
import random
import threading
import time
from typing import Any, Callable, Deque, Dict, List, Optional, Tuple

from repro.core import counters as _counters
from repro.core.future import Future, Promise
from repro.obs import trace as _trace

# Task priorities (HPX: thread_priority::{low,normal,high,boost}).
PRIORITY_LOW = 0
PRIORITY_NORMAL = 1
PRIORITY_HIGH = 2

_POLICIES = ("static", "local", "hierarchical")

DEFAULT_POOL = "default"

# Worker-thread identity: which pool owns the calling thread (module-level so
# a Runtime can route help-along to whichever of its pools is blocking).
_tls = threading.local()


class _Task:
    __slots__ = ("fn", "promise", "priority")

    def __init__(self, fn: Callable[[], Any], promise: Optional[Promise], priority: int):
        self.fn = fn
        self.promise = promise
        self.priority = priority

    def run(self) -> None:
        if self.promise is None:
            self.fn()
            return
        try:
            self.promise.set_value(self.fn())
        except BaseException as e:  # noqa: BLE001
            self.promise.set_exception(e)


class ThreadPool:
    """One named worker pool: per-worker queues, stealing, counters.

    This is the unit the resource partitioner hands out.  Pools are reached
    through :meth:`Runtime.get_executor`; direct construction is for the
    runtime (and scheduler micro-benchmarks/tests).
    """

    def __init__(
        self,
        name: str = DEFAULT_POOL,
        num_workers: int = 4,
        policy: str = "local",
        steal_seed: int = 0,
        accounting: bool = True,
    ):
        if policy not in _POLICIES:
            raise ValueError(f"unknown scheduling policy {policy!r}; choose from {_POLICIES}")
        self.policy = policy
        self.num_workers = max(1, int(num_workers))
        self.name = name
        self._runtime: Optional["Runtime"] = None  # owning partitioner, if any
        self._queues: List[Deque[_Task]] = [collections.deque() for _ in range(self.num_workers)]
        self._hi_queue: Deque[_Task] = collections.deque()  # shared high-priority queue
        self._root_queue: Deque[_Task] = collections.deque()  # hierarchical root
        self._lock = threading.Lock()
        self._work_available = threading.Condition(self._lock)
        self._shutdown = False
        self._threads: List[threading.Thread] = []
        self._rng = random.Random(steal_seed)
        self._rr = 0

        # --- utilization accounting (single-writer per worker, racy reads)
        self.accounting = bool(accounting)
        now = time.perf_counter()
        self._busy = [0.0] * self.num_workers   # cumulative busy seconds
        self._idle = [0.0] * self.num_workers   # cumulative idle seconds
        self._mark = [now] * self.num_workers   # last state-transition time
        self._state = [0] * self.num_workers    # 0 = idle, 1 = busy
        # victim -> thief steal matrix; incremented under self._lock (the
        # steal itself happens there), read via steal_matrix()/counters
        self._steals: Dict[Tuple[int, int], int] = {}

        reg = _counters.default()
        p = f"/scheduler{{{name}}}"
        self.c_spawned = reg.counter(f"{p}/tasks/spawned")
        self.c_executed = reg.counter(f"{p}/tasks/executed")
        self.c_stolen = reg.counter(f"{p}/tasks/stolen")
        self.c_failed = reg.counter(f"{p}/tasks/failed")
        self.t_task = reg.timer(f"{p}/task/duration")
        reg.register_callable(f"{p}/tasks/pending", self._pending_count)
        if self.accounting:
            reg.register_callable(f"{p}/idle-rate", self.idle_rate)
            reg.register_callable(f"{p}/utilization", self.utilization)
            reg.register_callable(f"{p}/time/busy",
                                  lambda: self.time_totals()[0],
                                  kind="counter")
            reg.register_callable(f"{p}/time/idle",
                                  lambda: self.time_totals()[1],
                                  kind="counter")
            reg.register_callable(f"{p}/queue/high/depth",
                                  lambda: float(len(self._hi_queue)))
            for i in range(self.num_workers):
                reg.register_callable(
                    f"{p}/queue/worker#{i}/depth",
                    lambda q=self._queues[i]: float(len(q)))
            # the steal matrix is published pairwise only on small pools —
            # a 64-worker pool would mint 4k counters for no reader
            if self.policy == "local" and 1 < self.num_workers <= 16:
                for v in range(self.num_workers):
                    for t in range(self.num_workers):
                        if v == t:
                            continue
                        reg.register_callable(
                            f"{p}/steals/victim#{v}/thief#{t}",
                            lambda k=(v, t): float(self._steals.get(k, 0)),
                            kind="counter")

        for i in range(self.num_workers):
            t = threading.Thread(target=self._worker, args=(i,), daemon=True,
                                 name=f"repro-{name}-w{i}")
            self._threads.append(t)
            t.start()

    # ------------------------------------------------------------------ api
    def spawn(
        self,
        fn: Callable[..., Any],
        *args: Any,
        priority: int = PRIORITY_NORMAL,
        worker_hint: Optional[int] = None,
        **kwargs: Any,
    ) -> Future[Any]:
        """``hpx::async`` — schedule ``fn(*args, **kwargs)``, return a Future."""
        promise: Promise[Any] = Promise()
        task = _Task((lambda: fn(*args, **kwargs)) if (args or kwargs) else fn, promise, priority)
        self._enqueue(task, worker_hint)
        return promise.future()

    def spawn_raw(self, fn: Callable[[], Any], priority: Optional[int] = None,
                  worker_hint: Optional[int] = None) -> None:
        """Fire-and-forget task with no promise (continuation plumbing)."""
        self._enqueue(_Task(fn, None, priority if priority is not None else PRIORITY_NORMAL), worker_hint)

    def on_worker_thread(self) -> bool:
        return getattr(_tls, "pool", None) is self

    def current_worker(self) -> Optional[int]:
        return getattr(_tls, "worker_id", None) if self.on_worker_thread() else None

    def pending(self) -> int:
        return int(self._pending_count())

    # ------------------------------------------------- utilization accounting
    def utilization_snapshot(self) -> Dict[str, Any]:
        """Per-worker busy/idle seconds with a live correction for the
        in-progress interval (a worker 10 s into a long task reads as 10 s
        busier than its last transition recorded).  Reads are lock-free and
        may tear by one task — monotonic accumulators make that benign."""
        now = time.perf_counter()
        busy, idle = [], []
        for i in range(self.num_workers):
            b, d, m, s = (self._busy[i], self._idle[i],
                          self._mark[i], self._state[i])
            live = max(0.0, now - m)
            busy.append(b + (live if s else 0.0))
            idle.append(d + (0.0 if s else live))
        return {"busy": busy, "idle": idle}

    def time_totals(self) -> Tuple[float, float]:
        """(cumulative busy seconds, cumulative idle seconds) across all
        workers — the monotonic counters whose *rates* give windowed
        utilization."""
        snap = self.utilization_snapshot()
        return sum(snap["busy"]), sum(snap["idle"])

    def idle_rate(self) -> float:
        """Fraction of worker wall time spent idle since pool start
        (HPX ``/threads{...}/idle-rate``, as a [0,1] fraction)."""
        busy, idle = self.time_totals()
        total = busy + idle
        return idle / total if total > 0.0 else 0.0

    def utilization(self) -> float:
        return 1.0 - self.idle_rate()

    def steal_matrix(self) -> Dict[Tuple[int, int], int]:
        """Copy of the (victim, thief) -> count steal matrix."""
        with self._lock:
            return dict(self._steals)

    def shutdown(self, wait: bool = True) -> None:
        with self._lock:
            if self._shutdown:
                return
            self._shutdown = True
            self._work_available.notify_all()
        if wait:
            for t in self._threads:
                t.join(timeout=10.0)

    # ----------------------------------------------------------- internals
    def _pending_count(self) -> float:
        with self._lock:
            return float(
                sum(len(q) for q in self._queues) + len(self._hi_queue) + len(self._root_queue)
            )

    def _enqueue(self, task: _Task, worker_hint: Optional[int]) -> None:
        self.c_spawned.increment()
        with self._lock:
            if task.priority >= PRIORITY_HIGH:
                self._hi_queue.append(task)
            elif self.policy == "hierarchical":
                # tasks always enqueue at the root and trickle down
                self._root_queue.append(task)
            else:
                wid = worker_hint
                if wid is None:
                    wid = self.current_worker()  # child tasks stay local (work-first)
                if wid is None:
                    wid = self._rr % self.num_workers
                    self._rr += 1
                self._queues[wid % self.num_workers].append(task)
            self._work_available.notify()

    def _try_pop(self, wid: int) -> Optional[_Task]:
        """Pop under self._lock. Order: high-prio, own queue (LIFO), then
        policy-dependent acquisition (steal FIFO / trickle from root)."""
        if self._hi_queue:
            return self._hi_queue.popleft()
        q = self._queues[wid]
        if q:
            return q.pop()  # LIFO for locality
        if self.policy == "hierarchical":
            if self._root_queue:
                task = self._root_queue.popleft()
                # trickle a small batch down into the local queue
                for _ in range(min(3, len(self._root_queue))):
                    q.append(self._root_queue.popleft())
                return task
            return None
        if self.policy == "local":
            # steal FIFO (oldest = largest granularity) from a random victim
            offs = self._rng.randrange(1, self.num_workers) if self.num_workers > 1 else 0
            for k in range(self.num_workers - 1):
                vid = (wid + offs + k) % self.num_workers
                if vid == wid:
                    continue
                victim = self._queues[vid]
                if victim:
                    self.c_stolen.increment()
                    key = (vid, wid)
                    self._steals[key] = self._steals.get(key, 0) + 1
                    if _trace._enabled:
                        _trace.instant("task/steal", "sched", pool=self.name,
                                       thief=wid, victim=vid)
                    return victim.popleft()
        return None  # static: never steal

    def _run_task(self, task: _Task) -> None:
        if _trace._enabled:
            with _trace.span("task/run", "sched", pool=self.name):
                self._run_task_body(task)
        else:  # hot path: one flag test, zero tracing cost
            self._run_task_body(task)

    def _run_task_body(self, task: _Task) -> None:
        with self.t_task.time():
            try:
                task.run()
            except BaseException:  # noqa: BLE001 — promise-less task raised:
                # report loudly but never kill the worker (a dead worker on a
                # 1-worker pool would silently hang every later task)
                import traceback

                self.c_failed.increment()
                traceback.print_exc()
        self.c_executed.increment()

    def _worker(self, wid: int) -> None:
        _tls.pool = self
        _tls.worker_id = wid
        acct = self.accounting
        perf = time.perf_counter  # bound method: the accounting hot path
        while True:
            with self._lock:
                task = self._try_pop(wid)
                if task is None:
                    if self._shutdown:
                        return
                    self._work_available.wait(timeout=0.05)
                    continue
            if acct:
                # idle -> busy transition (two clock reads per task total;
                # written only by this worker, read racily by counters)
                now = perf()
                self._idle[wid] += now - self._mark[wid]
                self._mark[wid] = now
                self._state[wid] = 1
            self._run_task(task)
            if acct:
                now = perf()
                self._busy[wid] += now - self._mark[wid]
                self._mark[wid] = now
                self._state[wid] = 0

    def _help_until(self, future: Future, timeout: Optional[float]) -> None:
        """Help-along loop: a worker blocked on ``future`` executes other
        tasks from *its own pool* instead of idling (HPX user-thread
        suspension analogue)."""
        wid = self.current_worker()
        if wid is None:
            return
        import time as _time

        deadline = None if timeout is None else _time.perf_counter() + timeout
        while not future.is_ready():
            with self._lock:
                task = self._try_pop(wid)
            if task is not None:
                self._run_task(task)
            else:
                if deadline is not None and _time.perf_counter() > deadline:
                    return
                future.wait_passive(0.002)

    def drain(self, timeout: float = 60.0) -> None:
        """Block until no tasks are pending (test/benchmark helper)."""
        import time as _time

        deadline = _time.perf_counter() + timeout
        while self._pending_count() > 0:
            if _time.perf_counter() > deadline:
                raise TimeoutError("scheduler drain timed out")
            _time.sleep(0.001)


class Runtime:
    """An HPX-style runtime instance: the resource partitioner's output.

    Holds one or more named :class:`ThreadPool`\\ s.  Use as a context
    manager, or via module-level :func:`init`/:func:`finalize`::

        with Runtime(pools={"default": 4, "io": 1}) as rt:
            f = rt.get_executor("io").async_execute(lambda: 2 + 2)
            assert f.get() == 4

    Single-pool construction (``Runtime(num_workers=4)``) is kept for the
    scheduler tests/benchmarks; the partitioned form is ``pools={...}``.
    Pools are reached through :meth:`get_executor` — the queues themselves
    are not part of the public surface.
    """

    def __init__(
        self,
        num_workers: int = 4,
        policy: str = "local",
        pool_name: str = DEFAULT_POOL,
        steal_seed: int = 0,
        pools: Optional[Dict[str, int]] = None,
        accounting: bool = True,
    ):
        if pools is None:
            pools = {pool_name: num_workers}
        if not pools:
            raise ValueError("resource partitioner needs at least one pool")
        self._pools: Dict[str, ThreadPool] = {}
        self._pool_lock = threading.Lock()
        self.policy = policy
        self.accounting = bool(accounting)
        self._default_name = (
            pool_name if pool_name in pools
            else (DEFAULT_POOL if DEFAULT_POOL in pools else next(iter(pools)))
        )
        for name, n in pools.items():
            p = ThreadPool(name=name, num_workers=n, policy=policy,
                           steal_seed=steal_seed, accounting=accounting)
            p._runtime = self
            self._pools[name] = p

    # -------------------------------------------------- resource partitioner
    def pool_names(self) -> List[str]:
        with self._pool_lock:
            return list(self._pools)

    def pool(self, name: str = None, fallback: Optional[str] = None) -> ThreadPool:
        """Resolve a named pool (``None`` → the default pool).

        ``fallback`` names a pool to use when ``name`` was never partitioned
        (lets consumers declare an affinity — "io", "prefill" — that
        degrades gracefully on an unpartitioned runtime); a fallback that is
        itself unpartitioned resolves to the runtime's default pool."""
        name = name or self._default_name
        with self._pool_lock:
            p = self._pools.get(name)
            if p is None and fallback is not None:
                p = (self._pools.get(fallback)
                     or self._pools.get(self._default_name))
            if p is None:
                raise KeyError(
                    f"no thread pool {name!r} in this runtime (pools: "
                    f"{sorted(self._pools)}); partition it via "
                    f"init(pools={{...}}) or Runtime.add_pool")
            return p

    def add_pool(self, name: str, num_workers: int, policy: Optional[str] = None) -> ThreadPool:
        """Idempotently add a pool to a live runtime (elastic partitioning).

        Returns the existing pool unchanged if ``name`` is already
        partitioned — consumers use this to declare the pools they need."""
        with self._pool_lock:
            p = self._pools.get(name)
            if p is None:
                p = ThreadPool(name=name, num_workers=num_workers,
                               policy=policy or self.policy,
                               accounting=self.accounting)
                p._runtime = self
                self._pools[name] = p
            return p

    def get_executor(self, pool: str = None, priority: Optional[int] = None,
                     fallback: Optional[str] = None):
        """The sanctioned entry point to a pool: an executor bound to it.

        Returns a :class:`~repro.core.executor.ThreadPoolExecutor` (wrapped
        in a :class:`~repro.core.executor.PriorityExecutor` when ``priority``
        is given)."""
        from repro.core import executor as _executor  # deferred, avoids cycle

        return _executor.get_executor(pool, priority=priority,
                                      fallback=fallback, runtime=self)

    # ------------------------------------------- default-pool compatibility
    @property
    def pool_name(self) -> str:
        return self._default_name

    @property
    def num_workers(self) -> int:
        return self.pool().num_workers

    def spawn(self, fn: Callable[..., Any], *args: Any, **kwargs: Any) -> Future[Any]:
        return self.pool().spawn(fn, *args, **kwargs)

    def spawn_raw(self, fn: Callable[[], Any], priority: Optional[int] = None,
                  worker_hint: Optional[int] = None) -> None:
        self.pool().spawn_raw(fn, priority=priority, worker_hint=worker_hint)

    def on_worker_thread(self) -> bool:
        # lock-free hot path: Future.get/wait probe this on every join
        p = getattr(_tls, "pool", None)
        return p is not None and p._runtime is self

    def current_worker(self) -> Optional[int]:
        return getattr(_tls, "worker_id", None) if self.on_worker_thread() else None

    def pending(self) -> int:
        with self._pool_lock:
            pools = list(self._pools.values())
        return sum(p.pending() for p in pools)

    def _help_until(self, future: Future, timeout: Optional[float]) -> None:
        """Route help-along to whichever of our pools owns the calling
        worker thread (a blocked io worker helps io, not compute)."""
        p = getattr(_tls, "pool", None)
        if p is not None and p._runtime is self:
            p._help_until(future, timeout)

    def drain(self, timeout: float = 60.0) -> None:
        with self._pool_lock:
            pools = list(self._pools.values())
        for p in pools:
            p.drain(timeout)

    def shutdown(self, wait: bool = True) -> None:
        with self._pool_lock:
            pools = list(self._pools.values())
        for p in pools:
            p.shutdown(wait=wait)
        global _runtime
        with _runtime_lock:
            if _runtime is self:
                _runtime = None

    def __enter__(self) -> "Runtime":
        _set_runtime(self)
        return self

    def __exit__(self, *exc) -> bool:
        self.shutdown()
        return False


# --------------------------------------------------------------- module api
_runtime: Optional[Runtime] = None
_runtime_lock = threading.Lock()

# Pools a bare init() partitions: compute + one host-I/O progress worker
# (checkpoint writes, prefetch assembly) so I/O never steals compute slots.
DEFAULT_POOLS = {"io": 1}


def _set_runtime(rt: Runtime) -> None:
    global _runtime
    with _runtime_lock:
        _runtime = rt


def init(num_workers: int = 4, policy: str = "local",
         pools: Optional[Dict[str, int]] = None) -> Runtime:
    """``hpx::init`` — bring up (or return) the global runtime.

    ``pools`` is the resource-partitioner spec (name → workers), e.g.
    ``init(pools={"default": 8, "io": 1, "prefill": 2})``, honored exactly
    as given (an explicit partition never grows hidden pools; consumers
    with a pool affinity fall back to the runtime's default pool).
    Omitted, it defaults to ``{"default": num_workers, **DEFAULT_POOLS}``.
    On an already-running runtime the requested pools are added
    idempotently (elastic partitioning), never shrunk."""
    global _runtime
    with _runtime_lock:
        rt = _runtime
        if rt is None:
            if pools is None:
                pools = {DEFAULT_POOL: num_workers, **DEFAULT_POOLS}
            rt = _runtime = Runtime(policy=policy, pools=pools)
            return rt
    # existing runtime: elastic, idempotent partition growth
    if pools:
        for name, n in pools.items():
            rt.add_pool(name, n, policy=policy)
    return rt


def finalize() -> None:
    """``hpx::finalize`` — tear down the global runtime."""
    global _runtime
    with _runtime_lock:
        rt, _runtime = _runtime, None
    if rt is not None:
        rt.shutdown()


def current_runtime() -> Optional[Runtime]:
    return _runtime


def get_runtime() -> Runtime:
    """Global runtime, creating a default one on first use."""
    return init()


def spawn(fn: Callable[..., Any], *args: Any, executor: Any = None,
          **kwargs: Any) -> Future[Any]:
    """``hpx::async`` — on ``executor`` when given, else the default pool."""
    if executor is not None:
        return executor.async_execute(fn, *args, **kwargs)
    return get_runtime().spawn(fn, *args, **kwargs)


async_ = spawn  # HPX spelling
