"""Lightweight work-stealing task scheduler (HPX P2, paper §2.1).

The paper's thread manager offers interchangeable scheduling policies:

- ``static``       one queue per core, **no stealing**;
- ``local``        (default) one queue per core + work stealing from
                   neighbours + high-priority queues;
- ``hierarchical`` a tree of queues — tasks enqueue at the root and
                   *trickle down* as cores fetch work.

TPU adaptation: there are no user-level threads inside an XLA program, so
this scheduler runs on the *host orchestration plane*: it drives data
pipeline stages, device-step dispatch (which is async in JAX — the host
thread returns immediately while the TPU computes), checkpoint I/O and
serving continuations.  The paper's "oversubscribing execution resources"
maps to spawning many more logical tasks than workers; blocked tasks
*help along* (see :meth:`Runtime._help_until`), the analogue of HPX
suspending a user-level thread instead of an OS thread.

Performance counters published (HPX names, §2.4):

    /scheduler{pool#0}/tasks/spawned
    /scheduler{pool#0}/tasks/executed
    /scheduler{pool#0}/tasks/stolen
    /scheduler{pool#0}/tasks/pending        (instantaneous)
    /scheduler{pool#0}/task/duration        (timer)
"""

from __future__ import annotations

import collections
import random
import threading
from typing import Any, Callable, Deque, List, Optional

from repro.core import counters as _counters
from repro.core.future import Future, Promise

# Task priorities (HPX: thread_priority::{low,normal,high,boost}).
PRIORITY_LOW = 0
PRIORITY_NORMAL = 1
PRIORITY_HIGH = 2

_POLICIES = ("static", "local", "hierarchical")


class _Task:
    __slots__ = ("fn", "promise", "priority")

    def __init__(self, fn: Callable[[], Any], promise: Optional[Promise], priority: int):
        self.fn = fn
        self.promise = promise
        self.priority = priority

    def run(self) -> None:
        if self.promise is None:
            self.fn()
            return
        try:
            self.promise.set_value(self.fn())
        except BaseException as e:  # noqa: BLE001
            self.promise.set_exception(e)


class Runtime:
    """An HPX-style runtime instance (thread pool + scheduler policy).

    Use as a context manager, or via module-level :func:`init`/:func:`finalize`::

        with Runtime(num_workers=4, policy="local") as rt:
            f = rt.spawn(lambda: 2 + 2)
            assert f.get() == 4
    """

    def __init__(
        self,
        num_workers: int = 4,
        policy: str = "local",
        pool_name: str = "pool#0",
        steal_seed: int = 0,
    ):
        if policy not in _POLICIES:
            raise ValueError(f"unknown scheduling policy {policy!r}; choose from {_POLICIES}")
        self.policy = policy
        self.num_workers = max(1, int(num_workers))
        self.pool_name = pool_name
        self._queues: List[Deque[_Task]] = [collections.deque() for _ in range(self.num_workers)]
        self._hi_queue: Deque[_Task] = collections.deque()  # shared high-priority queue
        self._root_queue: Deque[_Task] = collections.deque()  # hierarchical root
        self._lock = threading.Lock()
        self._work_available = threading.Condition(self._lock)
        self._shutdown = False
        self._threads: List[threading.Thread] = []
        self._tls = threading.local()
        self._rng = random.Random(steal_seed)
        self._spawn_rr = 0

        reg = _counters.default()
        p = f"/scheduler{{{pool_name}}}"
        self.c_spawned = reg.counter(f"{p}/tasks/spawned")
        self.c_executed = reg.counter(f"{p}/tasks/executed")
        self.c_stolen = reg.counter(f"{p}/tasks/stolen")
        self.t_task = reg.timer(f"{p}/task/duration")
        reg.register_callable(f"{p}/tasks/pending", self._pending_count)

        for i in range(self.num_workers):
            t = threading.Thread(target=self._worker, args=(i,), daemon=True, name=f"repro-{pool_name}-w{i}")
            self._threads.append(t)
            t.start()

    # ------------------------------------------------------------------ api
    def spawn(
        self,
        fn: Callable[..., Any],
        *args: Any,
        priority: int = PRIORITY_NORMAL,
        worker_hint: Optional[int] = None,
        **kwargs: Any,
    ) -> Future[Any]:
        """``hpx::async`` — schedule ``fn(*args, **kwargs)``, return a Future."""
        promise: Promise[Any] = Promise()
        task = _Task((lambda: fn(*args, **kwargs)) if (args or kwargs) else fn, promise, priority)
        self._enqueue(task, worker_hint)
        return promise.future()

    def spawn_raw(self, fn: Callable[[], Any], priority: Optional[int] = None,
                  worker_hint: Optional[int] = None) -> None:
        """Fire-and-forget task with no promise (continuation plumbing)."""
        self._enqueue(_Task(fn, None, priority if priority is not None else PRIORITY_NORMAL), worker_hint)

    def on_worker_thread(self) -> bool:
        return getattr(self._tls, "worker_id", None) is not None

    def current_worker(self) -> Optional[int]:
        return getattr(self._tls, "worker_id", None)

    def pending(self) -> int:
        return int(self._pending_count())

    def shutdown(self, wait: bool = True) -> None:
        with self._lock:
            if self._shutdown:
                return
            self._shutdown = True
            self._work_available.notify_all()
        if wait:
            for t in self._threads:
                t.join(timeout=10.0)
        global _runtime
        with _runtime_lock:
            if _runtime is self:
                _runtime = None

    def __enter__(self) -> "Runtime":
        _set_runtime(self)
        return self

    def __exit__(self, *exc) -> bool:
        self.shutdown()
        return False

    # ----------------------------------------------------------- internals
    def _pending_count(self) -> float:
        with self._lock:
            return float(
                sum(len(q) for q in self._queues) + len(self._hi_queue) + len(self._root_queue)
            )

    def _enqueue(self, task: _Task, worker_hint: Optional[int]) -> None:
        self.c_spawned.increment()
        with self._lock:
            if task.priority >= PRIORITY_HIGH:
                self._hi_queue.append(task)
            elif self.policy == "hierarchical":
                # tasks always enqueue at the root and trickle down
                self._root_queue.append(task)
            else:
                wid = worker_hint
                if wid is None:
                    wid = self.current_worker()  # child tasks stay local (work-first)
                if wid is None:
                    wid = self._spawn_rr % self.num_workers
                    self._spawn_rr += 1
                self._queues[wid % self.num_workers].append(task)
            self._work_available.notify()

    def _try_pop(self, wid: int) -> Optional[_Task]:
        """Pop under self._lock. Order: high-prio, own queue (LIFO), then
        policy-dependent acquisition (steal FIFO / trickle from root)."""
        if self._hi_queue:
            return self._hi_queue.popleft()
        q = self._queues[wid]
        if q:
            return q.pop()  # LIFO for locality
        if self.policy == "hierarchical":
            if self._root_queue:
                task = self._root_queue.popleft()
                # trickle a small batch down into the local queue
                for _ in range(min(3, len(self._root_queue))):
                    q.append(self._root_queue.popleft())
                return task
            return None
        if self.policy == "local":
            # steal FIFO (oldest = largest granularity) from a random victim
            offs = self._rng.randrange(1, self.num_workers) if self.num_workers > 1 else 0
            for k in range(self.num_workers - 1):
                vid = (wid + offs + k) % self.num_workers
                if vid == wid:
                    continue
                victim = self._queues[vid]
                if victim:
                    self.c_stolen.increment()
                    return victim.popleft()
        return None  # static: never steal

    def _run_task(self, task: _Task) -> None:
        with self.t_task.time():
            task.run()
        self.c_executed.increment()

    def _worker(self, wid: int) -> None:
        self._tls.worker_id = wid
        while True:
            with self._lock:
                task = self._try_pop(wid)
                if task is None:
                    if self._shutdown:
                        return
                    self._work_available.wait(timeout=0.05)
                    continue
            self._run_task(task)

    def _help_until(self, future: Future, timeout: Optional[float]) -> None:
        """Help-along loop: a worker blocked on ``future`` executes other
        tasks instead of idling (HPX user-thread suspension analogue)."""
        wid = self.current_worker()
        if wid is None:
            return
        import time as _time

        deadline = None if timeout is None else _time.perf_counter() + timeout
        while not future.is_ready():
            with self._lock:
                task = self._try_pop(wid)
            if task is not None:
                self._run_task(task)
            else:
                if deadline is not None and _time.perf_counter() > deadline:
                    return
                future.wait_passive(0.002)

    def drain(self, timeout: float = 60.0) -> None:
        """Block until no tasks are pending (test/benchmark helper)."""
        import time as _time

        deadline = _time.perf_counter() + timeout
        while self._pending_count() > 0:
            if _time.perf_counter() > deadline:
                raise TimeoutError("scheduler drain timed out")
            _time.sleep(0.001)


# --------------------------------------------------------------- module api
_runtime: Optional[Runtime] = None
_runtime_lock = threading.Lock()


def _set_runtime(rt: Runtime) -> None:
    global _runtime
    with _runtime_lock:
        _runtime = rt


def init(num_workers: int = 4, policy: str = "local") -> Runtime:
    """``hpx::init`` — bring up (or return) the global runtime."""
    global _runtime
    with _runtime_lock:
        if _runtime is None:
            _runtime = Runtime(num_workers=num_workers, policy=policy)
        return _runtime


def finalize() -> None:
    """``hpx::finalize`` — tear down the global runtime."""
    global _runtime
    with _runtime_lock:
        rt, _runtime = _runtime, None
    if rt is not None:
        rt.shutdown()


def current_runtime() -> Optional[Runtime]:
    return _runtime


def get_runtime() -> Runtime:
    """Global runtime, creating a default one on first use."""
    return init()


def spawn(fn: Callable[..., Any], *args: Any, **kwargs: Any) -> Future[Any]:
    """``hpx::async`` on the global runtime."""
    return get_runtime().spawn(fn, *args, **kwargs)


async_ = spawn  # HPX spelling
