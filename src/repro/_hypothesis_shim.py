"""Minimal, dependency-free stand-in for the ``hypothesis`` library.

The test suite uses a small slice of hypothesis (``given`` / ``settings`` /
a handful of strategies) for property tests.  The pinned container does not
ship hypothesis and installing packages is off-limits, so ``tests/conftest.py``
installs this module under ``sys.modules["hypothesis"]`` **only when the real
library is absent** — with hypothesis installed, the genuine article wins and
this file is inert.

Scope (deliberately tiny):

- deterministic example generation (seeded per test name) — no shrinking,
  no database, no health checks;
- strategies: ``integers``, ``floats``, ``booleans``, ``just``,
  ``sampled_from``, ``lists``, ``tuples``, ``one_of``, ``data``;
- ``@given`` supports positional and keyword strategies and cooperates with
  pytest fixtures (fixture params keep their place in the exposed
  signature, strategy params are filled per example);
- ``@settings(max_examples=..., deadline=...)`` honours ``max_examples``.
"""

from __future__ import annotations

import functools
import inspect
import itertools
import random
import sys
import zlib
from typing import Any, Callable, List, Optional, Sequence

__all__ = ["given", "settings", "strategies", "assume", "HealthCheck"]

_DEFAULT_MAX_EXAMPLES = 100


class _Unsatisfied(Exception):
    """Raised by assume(False); the example is silently discarded."""


def assume(condition: bool) -> bool:
    if not condition:
        raise _Unsatisfied()
    return True


class HealthCheck:  # accepted, ignored
    all = classmethod(lambda cls: [])
    too_slow = "too_slow"
    filter_too_much = "filter_too_much"


# --------------------------------------------------------------- strategies
class SearchStrategy:
    def example_from(self, rnd: random.Random) -> Any:
        raise NotImplementedError

    # combinators mirroring hypothesis' API
    def map(self, fn: Callable[[Any], Any]) -> "SearchStrategy":
        return _Mapped(self, fn)

    def filter(self, pred: Callable[[Any], bool]) -> "SearchStrategy":
        return _Filtered(self, pred)


class _Mapped(SearchStrategy):
    def __init__(self, base, fn):
        self.base, self.fn = base, fn

    def example_from(self, rnd):
        return self.fn(self.base.example_from(rnd))


class _Filtered(SearchStrategy):
    def __init__(self, base, pred):
        self.base, self.pred = base, pred

    def example_from(self, rnd):
        for _ in range(100):
            v = self.base.example_from(rnd)
            if self.pred(v):
                return v
        raise _Unsatisfied()


class _Integers(SearchStrategy):
    def __init__(self, min_value=-(2 ** 31), max_value=2 ** 31 - 1):
        self.lo, self.hi = min_value, max_value

    def example_from(self, rnd):
        # bias toward boundaries now and then, like hypothesis does
        r = rnd.random()
        if r < 0.05:
            return self.lo
        if r < 0.10:
            return self.hi
        return rnd.randint(self.lo, self.hi)


class _Floats(SearchStrategy):
    def __init__(self, min_value=None, max_value=None, allow_nan=False,
                 allow_infinity=False, width=64):
        self.lo = -1e6 if min_value is None else min_value
        self.hi = 1e6 if max_value is None else max_value
        self.width = width

    def example_from(self, rnd):
        r = rnd.random()
        if r < 0.05:
            v = self.lo
        elif r < 0.10:
            v = self.hi
        elif r < 0.15 and self.lo <= 0.0 <= self.hi:
            v = 0.0
        else:
            v = rnd.uniform(self.lo, self.hi)
        if self.width == 32:
            import struct

            v = struct.unpack("f", struct.pack("f", v))[0]
            v = min(max(v, self.lo), self.hi)
        return v


class _Booleans(SearchStrategy):
    def example_from(self, rnd):
        return rnd.random() < 0.5


class _Just(SearchStrategy):
    def __init__(self, value):
        self.value = value

    def example_from(self, rnd):
        return self.value


class _SampledFrom(SearchStrategy):
    def __init__(self, elements: Sequence[Any]):
        self.elements = list(elements)
        if not self.elements:
            raise ValueError("sampled_from requires a non-empty sequence")

    def example_from(self, rnd):
        return rnd.choice(self.elements)


class _Lists(SearchStrategy):
    def __init__(self, elements: SearchStrategy, min_size=0, max_size=None,
                 unique=False):
        self.elements = elements
        self.min_size = min_size
        self.max_size = max_size if max_size is not None else min_size + 10
        self.unique = unique

    def example_from(self, rnd):
        n = rnd.randint(self.min_size, self.max_size)
        out: List[Any] = []
        seen = set()
        attempts = 0
        while len(out) < n and attempts < 100 * (n + 1):
            v = self.elements.example_from(rnd)
            attempts += 1
            if self.unique:
                key = v if isinstance(v, (int, float, str, bool, tuple, type(None))) else repr(v)
                if key in seen:
                    continue
                seen.add(key)
            out.append(v)
        if len(out) < self.min_size:
            raise _Unsatisfied()
        return out


class _Tuples(SearchStrategy):
    def __init__(self, *parts: SearchStrategy):
        self.parts = parts

    def example_from(self, rnd):
        return tuple(p.example_from(rnd) for p in self.parts)


class _OneOf(SearchStrategy):
    def __init__(self, *options: SearchStrategy):
        self.options = options

    def example_from(self, rnd):
        return rnd.choice(self.options).example_from(rnd)


class DataObject:
    """Interactive draws (``st.data()``)."""

    def __init__(self, rnd: random.Random):
        self._rnd = rnd

    def draw(self, strategy: SearchStrategy, label: Optional[str] = None):
        return strategy.example_from(self._rnd)


class _Data(SearchStrategy):
    def example_from(self, rnd):
        return DataObject(rnd)


class _StrategiesModule:
    """Exposed as both ``hypothesis.strategies`` and ``st`` import alias."""

    SearchStrategy = SearchStrategy

    @staticmethod
    def integers(min_value=-(2 ** 31), max_value=2 ** 31 - 1):
        return _Integers(min_value, max_value)

    @staticmethod
    def floats(min_value=None, max_value=None, *, allow_nan=False,
               allow_infinity=False, width=64, **_ignored):
        return _Floats(min_value, max_value, allow_nan, allow_infinity, width)

    @staticmethod
    def booleans():
        return _Booleans()

    @staticmethod
    def just(value):
        return _Just(value)

    @staticmethod
    def sampled_from(elements):
        return _SampledFrom(elements)

    @staticmethod
    def lists(elements, *, min_size=0, max_size=None, unique=False,
              **_ignored):
        return _Lists(elements, min_size, max_size, unique)

    @staticmethod
    def tuples(*parts):
        return _Tuples(*parts)

    @staticmethod
    def one_of(*options):
        return _OneOf(*options)

    @staticmethod
    def data():
        return _Data()


strategies = _StrategiesModule()


# ------------------------------------------------------------------ runner
class settings:  # noqa: N801 — mirrors hypothesis' lowercase class
    def __init__(self, max_examples: int = _DEFAULT_MAX_EXAMPLES,
                 deadline=None, **_ignored):
        self.max_examples = max_examples

    def __call__(self, fn):
        fn._shim_settings = self  # read by @given (inner or outer position)
        return fn


def given(*arg_strategies: SearchStrategy, **kw_strategies: SearchStrategy):
    def decorate(fn):
        inner_settings = getattr(fn, "_shim_settings", None)
        params = list(inspect.signature(fn).parameters)
        if arg_strategies:
            # strategies fill the RIGHTMOST positional params (hypothesis rule)
            n_fix = len(params) - len(arg_strategies)
            fixture_names = params[:n_fix]
            strat_names = params[n_fix:]
        else:
            fixture_names = [p for p in params if p not in kw_strategies]
            strat_names = [p for p in params if p in kw_strategies]
        strat_map = dict(zip(strat_names, arg_strategies)) if arg_strategies \
            else dict(kw_strategies)

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            cfg = getattr(wrapper, "_shim_settings", None) or inner_settings
            max_examples = cfg.max_examples if cfg else _DEFAULT_MAX_EXAMPLES
            base = zlib.crc32(fn.__qualname__.encode())
            ran = 0
            for i in itertools.count():
                if ran >= max_examples or i >= 10 * max_examples:
                    break
                rnd = random.Random(base + 0x9E3779B1 * i)
                drawn = {}
                try:
                    for name in strat_names:
                        drawn[name] = strat_map[name].example_from(rnd)
                except _Unsatisfied:
                    continue
                try:
                    fn(*args, **kwargs, **drawn)
                except _Unsatisfied:
                    continue
                except Exception:
                    print(f"Falsifying example ({fn.__qualname__}): {drawn!r}",
                          file=sys.stderr)
                    raise
                ran += 1
            if ran == 0 and strat_names:
                # mirror hypothesis' Unsatisfiable: never silently pass a
                # property whose body was never executed
                raise AssertionError(
                    f"Unable to satisfy assumptions of {fn.__qualname__}: "
                    f"0 of {max_examples} examples ran")
            return None

        # pytest must only see the fixture params, not the strategy params
        wrapper.__signature__ = inspect.Signature([
            inspect.Parameter(n, inspect.Parameter.POSITIONAL_OR_KEYWORD)
            for n in fixture_names
        ])
        if hasattr(wrapper, "__wrapped__"):
            del wrapper.__wrapped__  # keep introspection on our signature
        wrapper.hypothesis_shim = True
        return wrapper

    return decorate


def install_if_missing() -> bool:
    """Register this module as ``hypothesis`` unless the real one exists.

    Returns True when the shim was installed."""
    try:
        import hypothesis  # noqa: F401

        return False
    except ImportError:
        mod = sys.modules[__name__]
        sys.modules["hypothesis"] = mod
        sys.modules["hypothesis.strategies"] = strategies  # type: ignore[assignment]
        return True
