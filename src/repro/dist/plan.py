"""Sharding plans: logical-axis → mesh-axis resolution (DESIGN.md §2).

A :class:`ShardingPlan` is the *whole* distribution strategy of a step —
which mesh axis every logical tensor axis lands on, where the gather point
sits (bulk/BSP vs per-layer/futurized), the remat policy, and the collective
dtype boundaries.  Models never name mesh axes: they constrain activations
and declare parameters by **logical** axes (``embed``, ``mlp``, ``kv_seq``,
…, see ``models/params.py``) and the plan resolves them against whatever
mesh is active.  That indirection is what lets the same model run under the
paper's BSP baseline and the futurized/optimized AMT schedules unchanged.

Resolution rules (exercised by ``tests/test_plan.py``):

- **FCFS mesh-axis allocation** — axes are resolved left-to-right and each
  mesh axis is used at most once per spec; a logical axis whose mesh axis
  was already consumed replicates instead.  (``("experts","embed","mlp")``
  with experts and mlp both → ``model``: experts wins, mlp replicates.)
- **divisibility guard** — a dim that the assigned mesh axes do not divide
  falls back toward replication (axes are dropped right-to-left until the
  product divides), so odd vocab/head counts never wedge GSPMD.
- **trailing-``None`` trimming** — specs are canonicalized by dropping
  trailing replicated entries (``P("model","data",None)`` → ``P("model",
  "data")``).

The registry (``get_plan``) holds the four production plans:

    bsp        gather-upfront, full remat, no FSDP — the barrier-heavy
               MPI+X baseline of the paper
    futurized  FSDP with per-layer gather/reduce-scatter inside the scan —
               the AMT analogue (overlap via async collectives)
    optimized  futurized + KV/seq sharding + bf16 collective boundaries +
               selective remat (beyond-paper, EXPERIMENTS.md §Perf)
    serve      TP-only inference plan: weights whole per shard, KV cache
               sequence-sharded over the model axis
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Any, Dict, Mapping, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import _compat

# A rule value: mesh-axis name, preference-ordered tuple of mesh axes (the
# dim is sharded over every present one jointly), or None (replicate).
Rule = Union[str, Tuple[str, ...], None]


def _active_mesh() -> Optional[Any]:
    """The ambient mesh (``jax.set_mesh`` / legacy ``with mesh:``), or None.

    Used by :meth:`ShardingPlan.constrain` and by grouped-local MoE dispatch
    (``models/moe.py``) — model code runs unchanged on bare CPU (no mesh →
    constraints are no-ops) and on production meshes.
    """
    return _compat.active_mesh()


def _mesh_sizes(mesh: Any) -> Dict[str, int]:
    """{axis name: size} for a concrete Mesh or an AbstractMesh."""
    return dict(mesh.shape)


@dataclass(frozen=True)
class ShardingPlan:
    """A named distribution strategy; immutable (ablate with
    ``dataclasses.replace``, see ``launch/dryrun.py`` variants)."""

    name: str
    rules: Dict[str, Rule] = field(default_factory=dict)
    fsdp: bool = True                  # params sharded over the data axis
    gather_upfront: bool = False       # BSP: bulk all-gather before the scan
    remat_policy: str = "none"         # none | dots | full
    bf16_boundaries: bool = False      # bf16 cotangents at collective edges
    compress_pod_grads: bool = False   # pod-axis bf16 gradient reduction
    microbatches: int = 1              # grad-accumulation chunks

    # ------------------------------------------------------------- resolve
    def spec(self, axes: Sequence[Optional[str]], shape: Sequence[int],
             mesh: Any) -> P:
        """Resolve logical ``axes`` for a tensor of ``shape`` on ``mesh``.

        FCFS over mesh axes, divisibility-guarded, trailing-None trimmed.
        ``mesh`` may be a concrete ``Mesh`` or an ``AbstractMesh`` (the
        dry-run resolves specs before any device exists).
        """
        assert len(axes) == len(shape), (axes, shape)
        sizes = _mesh_sizes(mesh)
        used: set = set()
        entries: list = []
        for ax, dim in zip(axes, shape):
            assigned: list = []
            for cand in self._candidates(ax):
                if cand in sizes and cand not in used and cand not in assigned:
                    assigned.append(cand)
            # divisibility guard: drop axes (least-preferred first) until
            # the joint degree divides the dim; empty ⇒ replicate
            while assigned and dim % math.prod(sizes[a] for a in assigned):
                assigned.pop()
            if assigned:
                used.update(assigned)
                entries.append(assigned[0] if len(assigned) == 1
                               else tuple(assigned))
            else:
                entries.append(None)
        while entries and entries[-1] is None:  # canonical trailing trim
            entries.pop()
        return P(*entries)

    def _candidates(self, ax: Optional[str]) -> Tuple[str, ...]:
        if ax is None:
            return ()
        rule = self.rules.get(ax)
        if rule is None:
            return ()
        if isinstance(rule, str):
            return (rule,)
        return tuple(rule)

    # ----------------------------------------------------------- shardings
    def sharding(self, axes: Sequence[Optional[str]], shape: Sequence[int],
                 mesh: Any) -> NamedSharding:
        return NamedSharding(mesh, self.spec(axes, shape, mesh))

    def replicated(self, mesh: Any) -> NamedSharding:
        return NamedSharding(mesh, P())

    def param_shardings(self, specs: Mapping[str, Any], mesh: Any
                        ) -> Dict[str, NamedSharding]:
        """Sharding pytree for a ``{path: ParamSpec}`` dict (one source of
        truth: the spec's logical axes)."""
        return {p: self.sharding(s.axes, s.shape, mesh)
                for p, s in specs.items()}

    def sharding_for(self, leaf: Any, mesh: Optional[Any] = None) -> P:
        """Spec for a path-free leaf (elastic migration of opaque pytrees,
        ``core/migration.py``): batch-shard dim 0 over the data axes when
        divisible, otherwise replicate.

        Pass the TARGET mesh explicitly when migrating
        (``lambda l: plan.sharding_for(l, new_mesh)``): the divisibility
        guard must run against the destination's axis sizes, and the
        ambient-mesh fallback may still be the source mesh."""
        mesh = mesh if mesh is not None else _active_mesh()
        shape = getattr(leaf, "shape", ())
        if mesh is None or not shape:
            return P()
        return self.spec(("batch",) + (None,) * (len(shape) - 1), shape, mesh)

    # ----------------------------------------------------------- constrain
    def constrain(self, x: jax.Array, axes: Sequence[Optional[str]]
                  ) -> jax.Array:
        """``with_sharding_constraint`` against the active mesh; identity
        when no mesh is set (single-host tests / CPU smoke runs)."""
        mesh = _active_mesh()
        if mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, self.sharding(axes, x.shape, mesh))


# ---------------------------------------------------------------- registry
def _tp_rules(**overrides: Rule) -> Dict[str, Rule]:
    """The shared tensor-parallel core every plan builds on."""
    rules: Dict[str, Rule] = {
        # -------- parameters (logical axes from models/params.py)
        "embed": "data",          # FSDP axis (overridden off for bsp/serve)
        "vocab": "model",
        "heads": "model",
        "kv_heads": "model",
        "mlp": "model",
        "experts": "model",       # EP rides the model axis
        "ssm_inner": "model",
        "lru": "model",
        # "layers" is never sharded: absent ⇒ replicate
        # -------- activations
        "batch": ("pod", "data"),
        "seq": None,              # gathered for attention
        "seq_sp": None,           # sequence-parallel residual stream
        "kv_seq": None,           # decode-time KV cache sequence dim
        "expert_cap": None,
    }
    rules.update(overrides)
    return rules


def bsp_plan(**overrides: Any) -> ShardingPlan:
    """The paper's baseline: bulk-synchronous steps — params gathered
    up-front (one global barrier), full remat, gradients reduced at the
    end.  TP still applies (the baseline is MPI+X, not single-chip)."""
    return replace(ShardingPlan(
        name="bsp",
        rules=_tp_rules(embed=None),
        fsdp=False,
        gather_upfront=True,
        remat_policy="full",
    ), **overrides)


def futurized_plan(**overrides: Any) -> ShardingPlan:
    """The AMT analogue: FSDP over ``data``, per-layer gather inside the
    scan, per-layer reduce-scatter in backward — XLA overlaps the async
    collectives with compute exactly like an HPX dataflow graph."""
    return replace(ShardingPlan(
        name="futurized",
        rules=_tp_rules(),
        fsdp=True,
        gather_upfront=False,
        remat_policy="none",
    ), **overrides)


def optimized_plan(**overrides: Any) -> ShardingPlan:
    """Futurized + beyond-paper perf: KV-cache/sequence sharding over the
    model axis, bf16 collective boundaries, selective remat.  Pod-axis
    gradient compression stays off by default (XLA CPU crash at 512
    devices; see EXPERIMENTS §Perf — TPU is the target)."""
    return replace(ShardingPlan(
        name="optimized",
        rules=_tp_rules(kv_seq="model", seq_sp="model"),
        fsdp=True,
        gather_upfront=False,
        remat_policy="dots",
        bf16_boundaries=True,
        compress_pod_grads=False,
    ), **overrides)


def serve_plan(**overrides: Any) -> ShardingPlan:
    """Inference: TP-only (weights whole per shard — no per-step gathers to
    overlap at batch-1 latencies) + sequence-sharded KV cache, which makes
    GSPMD emit the flash-decoding partial-softmax combine."""
    return replace(ShardingPlan(
        name="serve",
        rules=_tp_rules(embed=None, kv_seq="model"),
        fsdp=False,
        gather_upfront=True,
        remat_policy="none",
    ), **overrides)


_REGISTRY = {
    "bsp": bsp_plan,
    "futurized": futurized_plan,
    "optimized": optimized_plan,
    "serve": serve_plan,
}


def get_plan(name: str, **overrides: Any) -> ShardingPlan:
    """Look up a plan by name; keyword overrides are applied with
    ``dataclasses.replace`` (e.g. ``get_plan("futurized",
    microbatches=4)``).  Raises ``KeyError`` for unknown names."""
    if name not in _REGISTRY:
        raise KeyError(
            f"unknown plan {name!r}; available: {sorted(_REGISTRY)}")
    return _REGISTRY[name](**overrides)
