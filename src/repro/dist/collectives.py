"""Pod-axis manual collectives (DESIGN.md §2, §5).

GSPMD derives every *intra-pod* collective from the sharding plan; the
*inter-pod* (DCI) hop is the one place we drop to manual control, because
it is the slow wire and the one worth compressing.  The tools here:

- :func:`pod_manual_value_and_grad` — a partial-manual ``shard_map`` over
  the ``pod`` mesh axis: each pod runs the (GSPMD-auto) backward on its
  batch shard, then gradients cross the DCI as **bf16** via an explicit
  ``psum`` — half the wire bytes of the fp32 reduction XLA would emit.
- :func:`make_error_feedback` — unbiased error-feedback compression for
  a gradient stream whose quantization point the caller controls (e.g.
  microbatch accumulation before the reduction): the quantization
  residual is carried to the next step, so the *sum* of compressed
  gradients equals the true sum exactly
  (``tests/test_train.py::test_error_feedback_unbiased_over_steps``).
- :func:`all_gather_tree` — explicit pod-axis all-gather (metrics /
  debugging inside manual regions).

The 512-device CPU emulation of the compressed path crashes inside XLA
(tracked in EXPERIMENTS §Perf); TPU is the target, and the unit tests pin
the math on a 1×1 host mesh.
"""

from __future__ import annotations

from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def _pod_axis(mesh: Any) -> str:
    """The inter-pod mesh axis; falls back to the leading axis on meshes
    without an explicit ``pod`` dimension (single-pod test meshes)."""
    return "pod" if "pod" in mesh.axis_names else mesh.axis_names[0]


def pod_manual_value_and_grad(loss_fn: Callable, mesh: Any,
                              compress: bool = True) -> Callable:
    """``value_and_grad(loss_fn)`` with a manual pod-axis reduction.

    Returns ``f(params, batch) -> (loss, grads)``.  ``batch`` leaves are
    sharded over the pod axis (dim 0); ``params`` are replicated across
    pods (each pod holds its FSDP/TP shard under the *auto* axes, which
    stay GSPMD-managed — this is a partial-manual ``shard_map``).  With
    ``compress=True`` gradients ride the DCI as bf16 — the ring sum itself
    runs at wire precision (that is the bandwidth win); only the final
    mean/cast back to the param dtype is fp32.  The per-step rounding here
    is NOT error-corrected: :func:`make_error_feedback` is the primitive
    for callers that own a quantization point outside the reduction (e.g.
    a grad-accumulation stream) and can carry its residual across steps.
    """
    axis = _pod_axis(mesh)
    n_pods = dict(mesh.shape)[axis]
    auto = frozenset(a for a in mesh.axis_names if a != axis)

    def vg(params, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        # equal-size pod shards ⇒ global mean = mean of pod means
        loss = jax.lax.psum(loss, axis) / n_pods

        def reduce_grad(g: jax.Array) -> jax.Array:
            if compress:
                wire = g.astype(jnp.bfloat16)           # half-width DCI hop
                total = jax.lax.psum(wire, axis)
                return (total.astype(jnp.float32) / n_pods).astype(g.dtype)
            return jax.lax.psum(g, axis) / n_pods

        return loss, jax.tree.map(reduce_grad, grads)

    return shard_map(vg, mesh,
                     in_specs=(P(), P(axis)),
                     out_specs=(P(), P()),
                     check_rep=False, auto=auto)


def all_gather_tree(tree: Any, mesh: Any, axis: str | None = None,
                    tiled: bool = False) -> Any:
    """Explicit pod-axis all-gather of a pytree (manual-region utility).

    Rank-0 leaves (per-pod scalar metrics) are replicated in and gathered
    into a ``(n_pods,)`` vector; array leaves are sharded on dim 0."""
    axis = axis or _pod_axis(mesh)
    in_specs = jax.tree.map(
        lambda x: P(axis) if jnp.ndim(x) > 0 else P(), tree)

    def gather(t):
        return jax.tree.map(
            lambda x: jax.lax.all_gather(x, axis, tiled=tiled and jnp.ndim(x) > 0),
            t)

    auto = frozenset(a for a in mesh.axis_names if a != axis)
    # partial-auto shard_map only has a jit lowering (no eager impl)
    return jax.jit(shard_map(gather, mesh, in_specs=(in_specs,),
                             out_specs=P(), check_rep=False,
                             auto=auto))(tree)


# ------------------------------------------------------- error feedback
def make_error_feedback(wire_dtype: Any = jnp.bfloat16
                        ) -> Tuple[Callable, Callable]:
    """Unbiased error-feedback compression for a gradient stream.

    Returns ``(init, compress)``:

        residual = init(grads_like)            # zeros, fp32
        q, residual = compress(grads, residual)

    Each step quantizes ``grads + residual`` to ``wire_dtype`` and carries
    the rounding error forward.  Telescoping makes the stream exact:
    ``Σ dequant(q_t) + residual_T == Σ g_t`` (the bf16 rounding error of
    step *t* is re-injected at step *t+1*, so drift stays bounded at the
    wire dtype's ulp instead of growing with the horizon).
    """

    def init(grads: Any) -> Any:
        return jax.tree.map(
            lambda g: jnp.zeros(jnp.shape(g), jnp.float32), grads)

    def compress(grads: Any, residual: Any) -> Tuple[Any, Any]:
        carried = jax.tree.map(
            lambda g, e: g.astype(jnp.float32) + e, grads, residual)
        q = jax.tree.map(lambda s: s.astype(wire_dtype), carried)
        new_residual = jax.tree.map(
            lambda s, qq: s - qq.astype(jnp.float32), carried, q)
        return q, new_residual

    return init, compress
