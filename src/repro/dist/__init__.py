"""repro.dist — distributed execution: sharding plans, pod collectives,
and static HLO collective analysis.

Three cooperating modules (DESIGN.md §2/§5/§7):

    plan           ShardingPlan + registry (bsp / futurized / optimized /
                   serve) — logical-axis → mesh-axis resolution
    collectives    pod-axis manual collectives (shard_map) + error-feedback
                   gradient compression
    hlo_analysis   static profiler over post-SPMD HLO text: per-collective
                   wire bytes (while-loop trip counts applied), dot FLOPs,
                   HBM traffic — feeds analysis/roofline.py
"""

from repro.dist import collectives, hlo_analysis, plan
from repro.dist.plan import (
    ShardingPlan,
    bsp_plan,
    futurized_plan,
    get_plan,
    optimized_plan,
    serve_plan,
)

__all__ = [
    "collectives", "hlo_analysis", "plan",
    "ShardingPlan", "bsp_plan", "futurized_plan", "get_plan",
    "optimized_plan", "serve_plan",
]
