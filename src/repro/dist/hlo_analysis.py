"""Static profiler over post-SPMD HLO text (DESIGN.md §7).

``compiled.cost_analysis()`` counts while-loop bodies **once** and does not
report collective bytes at all, so the dry-run (``launch/dryrun.py``) and
the roofline (``analysis/roofline.py``) use this parser instead.  It walks
the HLO module text of a jitted function and produces:

- a per-collective inventory (:meth:`HloModule.collectives`): operand
  bytes, ring-model wire bytes per device, group size, loop **trip counts
  applied**, and an intra-pod (ICI) vs cross-pod (DCI) classification;
- exact matmul FLOPs (:meth:`HloModule.dot_flops`), trip counts applied;
- an HBM traffic proxy (:meth:`HloModule.memory_traffic`).

The communication-needs methodology mirrors *HPX+LCI* (Yan et al., 2025):
classify every transfer the program will issue, then model which ones the
runtime can overlap.  Shapes in post-SPMD HLO are already per-device, so
every figure here is per-device too.

Wire-byte model (bidirectional ring, the TPU ICI topology):

    all-reduce          2 · B · (g−1)/g      (reduce-scatter + all-gather)
    all-gather          B_operand · (g−1)
    reduce-scatter      B_result  · (g−1)
    all-to-all          B · (g−1)/g
    collective-permute  B

with ``B`` the per-device operand bytes and ``g`` the replica-group size.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

# Devices per pod; groups spanning pods cross the DCI.  Single source of
# truth is launch/mesh.py (16×16 production pods); fall back if unimportable
# so this module stays usable on archived HLO without the launch stack.
try:
    from repro.launch.mesh import POD_SIZE
except Exception:  # noqa: BLE001
    POD_SIZE = 256

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

# f32[8,128]{1,0} — dtype, dims, optional layout (ignored)
_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\](?:\{[^}]*\})?")

# %name = <type> opcode(operands), attrs
_INSTR_RE = re.compile(
    r"^\s*(ROOT\s+)?%?([\w.\-]+)\s*=\s*"
    r"((?:\((?:[^()]|\([^()]*\))*\))|(?:[a-z][a-z0-9]*\[[0-9,]*\](?:\{[^}]*\})?))\s+"
    r"([a-z][a-z0-9\-]*)\((.*)$")

_COMP_RE = re.compile(  # params may hold /*index=N*/ comments — match greedily
    r"^\s*(ENTRY\s+)?%?([\w.\-]+)\s*(?:\(.*\))?\s*->.*\{\s*$")

_COLLECTIVE_KINDS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _shape_bytes(type_str: str) -> int:
    """Total bytes of a (possibly tuple) HLO type string."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            n = int(np.prod([int(d) for d in dims.split(",")]))
        total += n * _DTYPE_BYTES.get(dtype, 4)
    return total


def _result_dims(type_str: str) -> Tuple[int, ...]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return ()
    dims = m.group(2)
    return tuple(int(d) for d in dims.split(",")) if dims else ()


@dataclass
class Instruction:
    name: str
    opcode: str
    type_str: str
    operands: List[str]
    attrs: str
    is_root: bool

    @property
    def result_bytes(self) -> int:
        return _shape_bytes(self.type_str)


@dataclass
class CollectiveOp:
    """One collective instruction, loop trip count attached."""

    kind: str
    name: str
    operand_bytes: int
    result_bytes: int
    group_size: int
    trip_count: int
    crosses_pod: bool

    @property
    def wire_bytes_per_device(self) -> int:
        """Ring-model wire bytes for ONE invocation (multiply by
        ``trip_count`` for the per-step total)."""
        g = max(self.group_size, 1)
        if self.kind == "all-reduce":
            return 2 * self.operand_bytes * (g - 1) // g
        if self.kind == "all-gather":
            return self.operand_bytes * (g - 1)
        if self.kind == "reduce-scatter":
            return self.result_bytes * (g - 1)
        if self.kind == "all-to-all":
            return self.operand_bytes * (g - 1) // g
        return self.operand_bytes  # collective-permute

    @property
    def total_wire_bytes(self) -> int:
        return self.wire_bytes_per_device * self.trip_count


@dataclass
class CollectiveSummary:
    ops: List[CollectiveOp] = field(default_factory=list)

    def count(self) -> int:
        """Collective launches per step (trip counts applied)."""
        return sum(o.trip_count for o in self.ops)

    def total_wire(self, crosses_pod: Optional[bool] = None) -> int:
        return sum(o.total_wire_bytes for o in self.ops
                   if crosses_pod is None or o.crosses_pod == crosses_pod)

    def total_operand(self) -> int:
        return sum(o.operand_bytes * o.trip_count for o in self.ops)

    def by_kind(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for o in self.ops:
            out[o.kind] = out.get(o.kind, 0) + o.total_wire_bytes
        return out


# ----------------------------------------------------------------- parsing
def _parse_computations(text: str) -> Tuple[Dict[str, List[Instruction]], str]:
    """{computation name: instructions}, plus the entry computation name."""
    comps: Dict[str, List[Instruction]] = {}
    entry = ""
    current: Optional[str] = None
    for line in text.splitlines():
        stripped = line.strip()
        if current is None:
            m = _COMP_RE.match(line)
            if m and stripped.endswith("{"):
                current = m.group(2)
                comps[current] = []
                if m.group(1):
                    entry = current
            continue
        if stripped == "}":
            current = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        is_root, name, type_str, opcode, rest = m.groups()
        # split "operands), attrs" at the matching close paren
        depth, split = 1, len(rest)
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    split = i
                    break
        operand_str, attrs = rest[:split], rest[split + 1:]
        operands = []
        for tok in _split_top_level(operand_str):
            tok = tok.strip()
            if not tok:
                continue
            # operands may be "%name" or "f32[8,8] %name"
            name_m = re.search(r"%([\w.\-]+)\s*$", tok)
            operands.append(name_m.group(1) if name_m else tok)
        comps[current].append(Instruction(
            name=name, opcode=opcode, type_str=type_str,
            operands=operands, attrs=attrs, is_root=bool(is_root)))
    if not entry and comps:
        entry = next(reversed(comps))
    return comps, entry


def _split_top_level(s: str) -> List[str]:
    """Split on commas not nested in (), {}, or []."""
    out, depth, start = [], 0, 0
    for i, ch in enumerate(s):
        if ch in "({[":
            depth += 1
        elif ch in ")}]":
            depth -= 1
        elif ch == "," and depth == 0:
            out.append(s[start:i])
            start = i + 1
    out.append(s[start:])
    return out


# ----------------------------------------------------------- replica groups
def _parse_replica_groups(attrs: str, n_devices: int) -> List[List[int]]:
    """Replica groups in literal ``{{0,1},{2,3}}`` or iota-v2
    ``[R,C]<=[dims]T(perm)`` form; empty ⇒ one group of all devices."""
    m = re.search(
        r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?",
        attrs)
    if m:
        rows, cols = int(m.group(1)), int(m.group(2))
        dims = [int(d) for d in m.group(3).split(",")]
        ids = np.arange(int(np.prod(dims))).reshape(dims)
        if m.group(4):
            ids = ids.transpose([int(p) for p in m.group(4).split(",")])
        return ids.reshape(rows, cols).tolist()
    m = re.search(  # nested literal: {{0,1},{2,3}}
        r"replica_groups=\{(\{[\d,\s]*\}(?:\s*,\s*\{[\d,\s]*\})*)\}", attrs)
    if m:
        return [[int(x) for x in g.replace(" ", "").split(",") if x]
                for g in re.findall(r"\{([\d,\s]*)\}", m.group(1))]
    m = re.search(r"replica_groups=\{([\d,\s]*)\}", attrs)
    if m:
        body = m.group(1).replace(" ", "")
        if not body:
            return [list(range(n_devices))]
        return [[int(x) for x in body.split(",") if x]]
    return [list(range(n_devices))]


def _crosses_pod(groups: List[List[int]], n_devices: int) -> bool:
    if n_devices <= POD_SIZE:
        return False
    for g in groups:
        pods = {d // POD_SIZE for d in g}
        if len(pods) > 1:
            return True
    return False


# -------------------------------------------------------------- trip counts
def _loop_trip_count(cond: List[Instruction]) -> int:
    """Trip count of a canonical counted loop: the condition compares the
    induction variable against an s32 constant with LT/LE.  Returns 1 when
    the pattern is not recognized (conservative: count the body once)."""
    consts = {i.name: i for i in cond if i.opcode == "constant"}
    root = next((i for i in cond if i.is_root), None)
    if root is None or root.opcode != "compare":
        return 1
    direction = "LT"
    m = re.search(r"direction=(\w+)", root.attrs)
    if m:
        direction = m.group(1)
    for op in root.operands:
        if op in consts and consts[op].operands:
            lit = consts[op].operands[0]  # `constant(12)` → "12"
            if re.fullmatch(r"-?\d+", lit):
                n = int(lit)
                return max(n + 1 if direction == "LE" else n, 1)
    return 1


# ------------------------------------------------------------------ module
class HloModule:
    """Parsed HLO module text + device count for pod classification.

    ``HloModule(text, n_devices)`` — ``n_devices`` is the total device
    count the module was compiled for (pods = ``n_devices / 256``).
    """

    def __init__(self, text: str, n_devices: int):
        self.text = text
        self.n_devices = int(n_devices)
        self._comps, self._entry = _parse_computations(text)
        self._multipliers = self._computation_multipliers()

    # ---------------------------------------------------------- structure
    def _call_edges(self, comp: str) -> List[Tuple[str, int]]:
        """(callee, per-invocation factor) edges of one computation.

        Traversed: while bodies (× trip count), call targets, conditional
        branches, and generic async-start wrappers (XLA hides the real
        collective opcode inside the wrapped computation).  Fusion bodies
        and reducer ``to_apply``s are NOT edges: their internals live in
        registers, and the fusion/reduce instruction carries the cost.
        """
        edges: List[Tuple[str, int]] = []
        for instr in self._comps.get(comp, ()):
            if instr.opcode == "while":
                cm = re.search(r"condition=%?([\w.\-]+)", instr.attrs)
                bm = re.search(r"body=%?([\w.\-]+)", instr.attrs)
                trip = 1
                if cm and cm.group(1) in self._comps:
                    trip = _loop_trip_count(self._comps[cm.group(1)])
                if bm:
                    edges.append((bm.group(1), trip))
                if cm:
                    edges.append((cm.group(1), 1))
            elif instr.opcode == "call":
                cm = re.search(r"to_apply=%?([\w.\-]+)", instr.attrs)
                if cm:
                    edges.append((cm.group(1), 1))
            elif instr.opcode == "conditional" and \
                    "branch_computations" in instr.attrs:
                body = instr.attrs.split("branch_computations={")[-1]
                for cname in re.findall(r"%?([\w.\-]+)", body.split("}")[0]):
                    edges.append((cname, 1))
            elif instr.opcode == "async-start":
                cm = re.search(r"calls=%?([\w.\-]+)", instr.attrs)
                if cm:
                    edges.append((cm.group(1), 1))
        return [(c, f) for c, f in edges if c in self._comps]

    def _computation_multipliers(self) -> Dict[str, int]:
        """How many times each computation runs per step: entry ×1, while
        bodies × trip count (nested loops multiply), multipliers SUMMED
        over distinct call sites (the call graph is a DAG)."""
        order: List[str] = []
        seen: set = set()

        def topo(comp: str) -> None:  # postorder DFS from the entry
            if comp in seen:
                return
            seen.add(comp)
            for callee, _f in self._call_edges(comp):
                topo(callee)
            order.append(comp)

        topo(self._entry)
        mult: Dict[str, int] = {self._entry: 1}
        for comp in reversed(order):  # callers before callees
            m = mult.get(comp, 0)
            if not m:
                continue
            for callee, factor in self._call_edges(comp):
                mult[callee] = mult.get(callee, 0) + m * factor
        return mult

    def _iter_instructions(self):
        for comp, instrs in self._comps.items():
            m = self._multipliers.get(comp)
            if m is None:
                continue  # unreachable (dead computations, reducers)
            for instr in instrs:
                yield comp, m, instr

    # --------------------------------------------------------- collectives
    def collectives(self) -> CollectiveSummary:
        ops: List[CollectiveOp] = []
        for _comp, mult, instr in self._iter_instructions():
            kind = next((k for k in _COLLECTIVE_KINDS
                         if instr.opcode == k or instr.opcode == k + "-start"),
                        None)
            if kind is None:
                continue
            if kind == "collective-permute":
                pairs = re.findall(r"\{(\d+),(\d+)\}",
                                   instr.attrs.split("source_target_pairs=")[-1]
                                   if "source_target_pairs" in instr.attrs
                                   else "")
                groups = [[int(a), int(b)] for a, b in pairs] or \
                    [list(range(min(self.n_devices, 2)))]
                group_size = 2
            else:
                groups = _parse_replica_groups(instr.attrs, self.n_devices)
                group_size = len(groups[0]) if groups and groups[0] else 1
            result_bytes = instr.result_bytes
            if instr.opcode.endswith("-start") and \
                    instr.type_str.lstrip().startswith("("):
                # async pairs return (operand alias, result, scratch…); the
                # result is the largest array component — except for
                # reduce-scatter, where the operand is the largest and the
                # result is 1/group_size of it
                parts = [_shape_bytes(m.group(0))
                         for m in _SHAPE_RE.finditer(instr.type_str)]
                if parts:
                    result_bytes = max(parts)
                    if kind == "reduce-scatter":
                        result_bytes //= max(group_size, 1)
            if kind == "all-gather":
                operand_bytes = result_bytes // max(group_size, 1)
            elif kind == "reduce-scatter":
                operand_bytes = result_bytes * max(group_size, 1)
            else:
                operand_bytes = result_bytes
            ops.append(CollectiveOp(
                kind=kind, name=instr.name,
                operand_bytes=operand_bytes, result_bytes=result_bytes,
                group_size=group_size, trip_count=mult,
                crosses_pod=_crosses_pod(groups, self.n_devices)))
        return CollectiveSummary(ops)

    # --------------------------------------------------------------- flops
    def dot_flops(self) -> int:
        """Exact matmul FLOPs per device, loop trip counts applied:
        2 · |result| · |contracting dims| per dot."""
        shapes: Dict[Tuple[str, str], Tuple[int, ...]] = {}
        for comp, _m, instr in self._iter_instructions():
            shapes[(comp, instr.name)] = _result_dims(instr.type_str)
        total = 0
        for comp, mult, instr in self._iter_instructions():
            if instr.opcode != "dot":
                continue
            result = _result_dims(instr.type_str)
            lhs = shapes.get((comp, instr.operands[0]), ()) \
                if instr.operands else ()
            m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", instr.attrs)
            contract = 1
            if m and m.group(1) and lhs:
                for d in m.group(1).split(","):
                    di = int(d)
                    if di < len(lhs):
                        contract *= lhs[di]
            # scalar results (fully-contracted dots) have empty dims → 1
            total += 2 * (int(np.prod(result)) if result else 1) * contract * mult
        return int(total)

    # -------------------------------------------------------------- memory
    _TRAFFIC_SKIP = {
        "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
        "while", "call", "conditional", "iota", "after-all", "partition-id",
        "replica-id",
    }

    def memory_traffic(self) -> int:
        """HBM traffic proxy per device: result bytes of every materializing
        instruction, trip counts applied (loop bodies dominate a step).
        In-place updates (dynamic-update-slice) count the update operand,
        not the whole aliased buffer."""
        shapes: Dict[Tuple[str, str], int] = {}
        for comp, _m, instr in self._iter_instructions():
            shapes[(comp, instr.name)] = instr.result_bytes
        total = 0
        for comp, mult, instr in self._iter_instructions():
            if instr.opcode in self._TRAFFIC_SKIP:
                continue
            nbytes = instr.result_bytes
            if instr.opcode == "dynamic-update-slice" and len(instr.operands) > 1:
                nbytes = shapes.get((comp, instr.operands[1]), nbytes)
            elif instr.opcode == "fusion" and "dynamic-update-slice" in instr.name:
                # in-place-update fusion (XLA names fusions by root op): the
                # traffic is the update, i.e. the smallest operand
                op_bytes = [shapes[(comp, o)] for o in instr.operands
                            if (comp, o) in shapes]
                if op_bytes:
                    nbytes = min(min(op_bytes), nbytes)
            total += nbytes * mult
        return int(total)


# ------------------------------------------------------------- entry points
def parse_module(text: str, n_devices: int) -> HloModule:
    """Parse jitted-fn HLO text (``compiled.as_text()``)."""
    return HloModule(text, n_devices)


def parse_collectives(text: str, n_devices: int) -> CollectiveSummary:
    """Shortcut: the collective inventory of an HLO module."""
    return HloModule(text, n_devices).collectives()
