"""PartitionedVector — an AGAS-backed distributed array (HPX
``hpx::partitioned_vector``).

The paper's "send work to data instead of data to work" needs a data
structure whose pieces *live somewhere*: a partitioned vector has a fixed
global length cut into segments by a :class:`~repro.container.distribution.
Distribution`; each segment is a host array registered in AGAS at its
owning locality.  The client object here is a *handle* — plain data
(name, geometry, segment GIDs), picklable, valid on any locality:

- **geometry** (which global indices live in which segment) is immutable
  and cached forever — :func:`attach` resolves a name to a handle once per
  locality and caches it;
- **placement** (which locality holds a segment *now*) is never stored in
  the handle at all: every segment op is an object-targeted parcel on the
  segment's GID, riding PR 4's generation-invalidated resolution cache —
  a segment moved by :meth:`move_segment`/:meth:`rebalance` self-heals on
  first touch, exactly like any migrated AGAS object.

Element access (``get``/``set``/``slice``) ships index ranges out and raw
array bytes back through the parcelport's zero-copy buffer path;
``fill_with`` ships a *generator function* out instead, so bulk
initialization moves ~zero element bytes (the work-to-data primitive the
data pipeline builds on).  Whole-container reads (:meth:`to_array`) exist
as the explicit fetch-all baseline the benchmark compares against.
"""

from __future__ import annotations

import itertools
import threading
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import agas as _agas
from repro.core import counters as _counters
from repro.core import parcel as _parcel
from repro.core.dataflow import dataflow
from repro.core.future import Future
from repro.container import distribution as _dist

_TIMEOUT = 120.0


# ---------------------------------------------------------- segment actions
# Module-level: worker localities resolve these by dotted name.  Selections
# are ``None`` (whole segment), ``("range", lo, hi)`` (contiguous), or an
# index array (cyclic covers).
def _select(obj: np.ndarray, sel: Any) -> np.ndarray:
    if sel is None:
        return obj
    if isinstance(sel, (tuple, list)) and len(sel) == 3 and sel[0] == "range":
        return obj[int(sel[1]):int(sel[2])]
    return obj[np.asarray(sel, dtype=np.int64)]


@_parcel.action
def _create_segment(rt: Any, name: str, size: int, dtype: str,
                    element_shape: Sequence[int]) -> List[int]:
    """Runs at the owner: allocate a zeroed segment, register it in AGAS
    (publishing to the root table), return its GID key."""
    arr = np.zeros((size, *element_shape), dtype=np.dtype(dtype))
    gid = _agas.default().register(arr, name=name)
    return [gid.locality, gid.seq]


@_parcel.action
def _seg_read(obj: np.ndarray, sel: Any = None) -> np.ndarray:
    """Object-targeted: ship selected elements home (zero-copy buffers)."""
    return np.ascontiguousarray(_select(obj, sel))


@_parcel.action
def _seg_write(obj: np.ndarray, sel: Any, values: Any) -> int:
    values = np.asarray(values, dtype=obj.dtype)
    if sel is None:
        obj[...] = values
    elif isinstance(sel, (tuple, list)) and len(sel) == 3 and sel[0] == "range":
        obj[int(sel[1]):int(sel[2])] = values
    else:
        obj[np.asarray(sel, dtype=np.int64)] = values
    return int(values.shape[0]) if values.ndim else 1


@_parcel.action
def _seg_free(obj: np.ndarray, key: List[int]) -> bool:
    """Object-targeted: drop the segment from its owner's AGAS (and the
    root table, via the unregister hook)."""
    _agas.default().unregister(_agas.GID(*key))
    return True


@_parcel.action
def _unregister_name(rt: Any, name: str) -> bool:
    a = _agas.default()
    if not a.contains(name):
        return False
    a.unregister(a.gid_of(name))
    return True


@_parcel.action
def _seg_generate(obj: np.ndarray, fn: Callable[..., Any], dist_meta: Dict,
                  seg: int, args: Tuple[Any, ...]) -> int:
    """Work-to-data bulk init: the *generator* crosses the wire (a pickled
    function reference), the element bytes never do.  ``fn(global_idx,
    *args)`` must return ``(len(global_idx), *element_shape)`` values."""
    idx = _dist.Distribution.from_meta(dist_meta).global_indices(seg)
    obj[...] = np.asarray(fn(idx, *args), dtype=obj.dtype)
    return int(idx.shape[0])


# ------------------------------------------------------------------- handle
_attach_cache: Dict[str, "PartitionedVector"] = {}
_attach_lock = threading.Lock()
_derived_seq = itertools.count(1)


def _publish_descriptor(name: str, dist: _dist.Distribution, dtype: str,
                        element_shape: Tuple[int, ...],
                        keys: List[Tuple[int, int]]) -> None:
    _agas.default().register(
        {"container": "partitioned_vector", "dtype": dtype,
         "element_shape": list(element_shape), "dist": dist.to_meta(),
         "segments": [list(k) for k in keys]}, name=name)


def derived_name(base: str) -> str:
    """Collision-free name for a container derived from ``base`` (transform
    / scan results): unique per (locality, counter)."""
    return f"{base}~d{_agas.default().locality}.{next(_derived_seq)}"


def _base_name(name: str) -> str:
    """Counter key: derived vectors share their source's counters, so a
    loop of transforms/scans never grows the counter registry."""
    return name.split("~d", 1)[0]


def _check_shippable(body: Any) -> None:
    """Bodies/ops cross the wire pickled *by reference* (module.qualname);
    a lambda or closure would fail deep in the parcelport — fail loudly at
    the call site instead, with the fix in the message."""
    if callable(body) and "<" in getattr(body, "__qualname__", ""):
        raise ValueError(
            f"partitioned-vector bodies ship to the data: "
            f"{getattr(body, '__qualname__', body)!r} is a lambda/closure, "
            f"which cannot cross localities. Define it at module level.")


class PartitionedVector:
    """Client handle to a distributed vector; see module docstring."""

    is_segmented = True  # duck-typed dispatch marker for core.algorithms

    def __init__(self, name: str, dist: _dist.Distribution, dtype: str,
                 element_shape: Tuple[int, ...],
                 segment_keys: List[Tuple[int, int]]):
        self.name = name
        self.dist = dist
        self.dtype = np.dtype(dtype)
        self.element_shape = tuple(element_shape)
        self.segment_keys = [tuple(k) for k in segment_keys]
        self._c_ops = _counters.counter(
            f"/container{{{_base_name(name)}}}/parcels/segment_ops")

    # ------------------------------------------------------------ lifecycle
    @classmethod
    def create(cls, name: str, length: int, dtype: Any = np.float64,
               distribution: Any = "block",
               localities: Optional[Sequence[int]] = None,
               element_shape: Sequence[int] = (),
               timeout: float = _TIMEOUT) -> "PartitionedVector":
        """Allocate segments at their owners (parallel parcels), publish a
        descriptor under ``name`` so any locality can :func:`attach`."""
        from repro import net as _net

        net = _net.require()
        if localities is None:
            localities = [loc.id for loc in net.localities]
        dist = _dist.make(distribution, length, localities)
        dt = np.dtype(dtype).str
        futs = [
            _net.run_on(dist.owners[j], _create_segment, f"{name}/seg{j}",
                        dist.sizes[j], dt, tuple(element_shape))
            for j in range(dist.nsegments)
        ]
        keys = [tuple(f.get(timeout=timeout)) for f in futs]
        pv = cls(name, dist, dt, tuple(element_shape), keys)
        _publish_descriptor(name, dist, dt, pv.element_shape, keys)
        _counters.gauge(f"/container{{{name}}}/elements/total").set(length)
        with _attach_lock:  # a re-created name must not serve a stale handle
            _attach_cache.pop(name, None)
        return pv

    @classmethod
    def from_parts(cls, name: str, dist: _dist.Distribution, dtype: Any,
                   element_shape: Sequence[int],
                   segment_keys: List[Tuple[int, int]],
                   publish: bool = True) -> "PartitionedVector":
        """Assemble a handle around segments that already exist in AGAS
        (checkpoint restore, derived results) and optionally publish its
        descriptor so other localities can :func:`attach`."""
        dt = np.dtype(dtype).str
        pv = cls(name, dist, dt, tuple(element_shape), segment_keys)
        if publish:
            _publish_descriptor(name, dist, dt, pv.element_shape,
                                pv.segment_keys)
        return pv

    @classmethod
    def attach(cls, name: str, timeout: float = _TIMEOUT,
               refresh: bool = False) -> "PartitionedVector":
        """Resolve ``name`` → handle from any locality.  The geometry is
        immutable, so the handle is cached per process; segment placement
        is *not* part of the handle and stays fresh via the net tier's
        resolution cache.  The cache covers a vector's lifetime, not a
        name's: if a name was freed and re-created *by another locality*,
        pass ``refresh=True`` to re-fetch the descriptor (the creating
        locality's own cache is invalidated automatically)."""
        if refresh:
            with _attach_lock:
                _attach_cache.pop(name, None)
        with _attach_lock:
            hit = _attach_cache.get(name)
        if hit is not None:
            return hit
        from repro import net as _net

        meta = _net.fetch(name, timeout=timeout)
        if not (isinstance(meta, dict)
                and meta.get("container") == "partitioned_vector"):
            raise TypeError(f"{name!r} is not a partitioned vector")
        pv = cls(name, _dist.Distribution.from_meta(meta["dist"]),
                 meta["dtype"], tuple(meta["element_shape"]),
                 [tuple(k) for k in meta["segments"]])
        with _attach_lock:
            _attach_cache.setdefault(name, pv)
        return pv

    # ------------------------------------------------------------- geometry
    def __len__(self) -> int:
        return self.dist.length

    @property
    def nsegments(self) -> int:
        return self.dist.nsegments

    def segment_gid(self, j: int) -> _agas.GID:
        return _agas.GID(*self.segment_keys[j])

    def __repr__(self) -> str:
        return (f"PartitionedVector({self.name!r}, len={len(self)}, "
                f"dtype={self.dtype.name}, {self.dist.kind}"
                f"x{self.nsegments})")

    # ------------------------------------------------------------ transport
    def _apply(self, fn: Callable[..., Any], j: int, *args: Any) -> Future:
        """Object-targeted parcel on segment ``j`` — runs wherever the
        segment lives *now* (stale placements self-heal via the root)."""
        from repro import net as _net

        self._c_ops.increment()
        return _net.apply_remote(fn, self.segment_gid(j), *args)

    # -------------------------------------------------------- element access
    def _norm_index(self, i: int) -> int:
        return i + len(self) if i < 0 else i  # python-sequence semantics

    def get(self, i: int, timeout: float = _TIMEOUT) -> Any:
        seg, loc = self.dist.segment_of(self._norm_index(i))
        out = self._apply(_seg_read, seg, ("range", loc, loc + 1)
                          ).get(timeout=timeout)[0]
        return out.item() if self.element_shape == () else out

    def set(self, i: int, value: Any, timeout: float = _TIMEOUT) -> None:
        seg, loc = self.dist.segment_of(self._norm_index(i))
        self._apply(_seg_write, seg, ("range", loc, loc + 1),
                    np.asarray([value])).get(timeout=timeout)

    def __getitem__(self, i):
        if isinstance(i, slice):
            lo, hi, step = i.indices(len(self))
            if step != 1:
                raise IndexError("partitioned vectors support unit-step slices")
            return self.slice(lo, hi)
        return self.get(int(i))

    def __setitem__(self, i, value) -> None:
        if isinstance(i, slice):
            lo, hi, step = i.indices(len(self))
            if step != 1:
                raise IndexError("partitioned vectors support unit-step slices")
            self.set_slice(lo, hi, value)
        else:
            self.set(int(i), value)

    def slice(self, lo: int, hi: int, timeout: float = _TIMEOUT) -> np.ndarray:
        """Gather ``[lo, hi)`` in global order (parallel segment reads,
        combined on the caller through ``dataflow``)."""
        runs = self.dist.locate_range(lo, hi)
        out = np.empty((hi - lo, *self.element_shape), dtype=self.dtype)
        futs = [self._apply(_seg_read, s, _as_sel(local)) for s, local, _ in runs]

        def place(*parts):
            for (_s, _local, pos), part in zip(runs, parts):
                out[pos] = part
            return out

        return dataflow(place, *futs).get(timeout=timeout)

    def set_slice(self, lo: int, hi: int, values: Any,
                  timeout: float = _TIMEOUT) -> None:
        values = np.asarray(values)
        if values.shape[:1] != (hi - lo,):
            raise ValueError(
                f"set_slice: {hi - lo} elements expected, got {values.shape}")
        runs = self.dist.locate_range(lo, hi)
        futs = [self._apply(_seg_write, s, _as_sel(local), values[pos])
                for s, local, pos in runs]
        for f in futs:
            f.get(timeout=timeout)

    def to_array(self, timeout: float = _TIMEOUT) -> np.ndarray:
        """Fetch-all: every element travels to the caller.  This is the
        data-to-work baseline — segmented algorithms exist to avoid it."""
        futs = [self._apply(_seg_read, j) for j in range(self.nsegments)]

        def place(*parts):
            dt = np.result_type(*[p.dtype for p in parts]) if parts else self.dtype
            out = np.empty((len(self), *self.element_shape), dtype=dt)
            for j, part in enumerate(parts):
                out[self.dist.global_indices(j)] = part
            return out

        return dataflow(place, *futs).get(timeout=timeout)

    def fill_with(self, fn: Callable[..., Any], *args: Any,
                  timeout: float = _TIMEOUT) -> "PartitionedVector":
        """Bulk init where the data lives: ``fn(global_idx, *args)`` runs at
        each owner against its own segment.  ``fn`` must be a module-level
        (picklable-by-reference) function."""
        _check_shippable(fn)
        meta = self.dist.to_meta()
        futs = [self._apply(_seg_generate, j, fn, meta, j, args)
                for j in range(self.nsegments)]
        for f in futs:
            f.get(timeout=timeout)
        return self

    def local_segments(self) -> List[Tuple[int, np.ndarray]]:
        """Segments owned by *this* locality, as live zero-copy arrays."""
        a = _agas.default()
        return [(j, a.resolve(self.segment_gid(j)))
                for j in range(self.nsegments) if a.contains(self.segment_gid(j))]

    def free(self, timeout: float = _TIMEOUT) -> None:
        """Release the vector: unregister every segment at its owner and
        drop the published descriptor.  Derived results (``transform``,
        the scans) are fresh vectors — free them when transient, or they
        live for the runtime's lifetime."""
        from repro import net as _net

        futs = [self._apply(_seg_free, j, list(self.segment_keys[j]))
                for j in range(self.nsegments)]
        for f in futs:
            f.get(timeout=timeout)
        a = _agas.default()
        if a.contains(self.name):
            a.unregister(a.gid_of(self.name))
        else:  # descriptor published from another locality
            try:
                from repro.net import remote as _remote

                _net.run_on(_remote.owner_of(self.name), _unregister_name,
                            self.name).get(timeout=timeout)
            except Exception:  # noqa: BLE001 — already gone
                pass
        with _attach_lock:
            _attach_cache.pop(self.name, None)

    # ------------------------------------------------------------- placement
    def owner_of(self, j: int) -> int:
        from repro.net import remote as _remote

        return _remote.owner_of(self.segment_gid(j))

    def owners(self) -> List[int]:
        return [self.owner_of(j) for j in range(self.nsegments)]

    def move_segment(self, j: int, dest: int,
                     timeout: float = _TIMEOUT) -> int:
        """Relocate one segment (GID stays valid; generation bumps)."""
        from repro import net as _net

        return _net.migrate_remote(self.segment_gid(j), dest, timeout=timeout)

    def rebalance(self, localities: Optional[Sequence[int]] = None,
                  timeout: float = _TIMEOUT) -> List[int]:
        """Spread segments round-robin over ``localities`` (default: all).
        Concurrent readers never observe a gap — each move rides
        ``migrate_remote``'s install-publish-unregister ordering."""
        from repro import net as _net

        if localities is None:
            localities = [loc.id for loc in _net.require().localities]
        targets = [localities[j % len(localities)] for j in range(self.nsegments)]
        for j, dest in enumerate(targets):
            self.move_segment(j, dest, timeout=timeout)
        return targets


def _as_sel(local_idx: np.ndarray) -> Any:
    """Compact wire form of a local-index cover: contiguous runs travel as
    ``("range", lo, hi)`` (3 ints), scattered covers as the index array."""
    if local_idx.size and np.all(np.diff(local_idx) == 1):
        return ("range", int(local_idx[0]), int(local_idx[-1]) + 1)
    return local_idx
