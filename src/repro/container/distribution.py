"""Distribution policies for partitioned containers (HPX
``hpx::container_distribution_policy``).

A distribution fixes the *geometry* of a :class:`PartitionedVector`: how a
global index space of ``length`` elements is cut into segments and which
locality initially owns each segment.  Geometry is immutable for the
container's lifetime — segments may *move* between localities
(``move_segment`` / ``rebalance``), but which global indices live in which
segment never changes, so the client-side segment map can be cached
forever; only the owner placement is subject to PR 4's generation-based
resolution-cache invalidation.

Three policies, matching HPX:

- ``block``    — near-equal contiguous chunks, one per target locality
  (``container_layout(localities)``);
- ``cyclic``   — element ``i`` lives in segment ``i % S`` at local offset
  ``i // S`` (round-robin dealing);
- ``explicit`` — caller-supplied contiguous segment sizes and owners
  (``container_layout(block_sizes, localities)``).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence, Tuple

import numpy as np


@dataclass(frozen=True)
class Distribution:
    """Immutable segment geometry: ``kind`` ∈ {block, cyclic, explicit}."""

    kind: str
    length: int
    sizes: Tuple[int, ...]   # per-segment element counts
    owners: Tuple[int, ...]  # *initial* owner locality per segment

    @property
    def nsegments(self) -> int:
        return len(self.sizes)

    @property
    def contiguous(self) -> bool:
        """True when every segment holds one contiguous global range (block
        and explicit layouts) — the precondition for the distributed
        two-pass scan; cyclic interleaves and falls back to gather."""
        return self.kind != "cyclic"

    @property
    def offsets(self) -> Tuple[int, ...]:
        """Contiguous layouts: global index of each segment's first slot."""
        out, acc = [], 0
        for s in self.sizes:
            out.append(acc)
            acc += s
        return tuple(out)

    # ------------------------------------------------------------- mapping
    def segment_of(self, i: int) -> Tuple[int, int]:
        """Global index → (segment, local offset)."""
        if not 0 <= i < self.length:
            raise IndexError(f"index {i} out of range [0, {self.length})")
        if self.kind == "cyclic":
            s = self.nsegments
            return i % s, i // s
        cum = np.cumsum(self.sizes)
        seg = int(np.searchsorted(cum, i, side="right"))
        return seg, i - (int(cum[seg - 1]) if seg else 0)

    def global_indices(self, seg: int) -> np.ndarray:
        """Global index of each local slot of ``seg`` (increasing order)."""
        n = self.sizes[seg]
        if self.kind == "cyclic":
            return seg + self.nsegments * np.arange(n, dtype=np.int64)
        return self.offsets[seg] + np.arange(n, dtype=np.int64)

    def locate_range(self, lo: int, hi: int) -> List[Tuple[int, np.ndarray, np.ndarray]]:
        """Cover ``[lo, hi)`` → ``[(segment, local_idx, out_pos), ...]``:
        read segment[local_idx] and place it at out_pos of the result."""
        if not 0 <= lo <= hi <= self.length:
            raise IndexError(f"slice [{lo}, {hi}) out of range [0, {self.length})")
        out: List[Tuple[int, np.ndarray, np.ndarray]] = []
        if lo == hi:
            return out
        if self.kind == "cyclic":
            g = np.arange(lo, hi, dtype=np.int64)
            segs = g % self.nsegments
            for s in range(self.nsegments):
                mask = segs == s
                if mask.any():
                    out.append((s, g[mask] // self.nsegments,
                                np.nonzero(mask)[0]))
            return out
        offs = self.offsets
        for s, size in enumerate(self.sizes):
            a, b = max(lo, offs[s]), min(hi, offs[s] + size)
            if a < b:
                out.append((s, np.arange(a - offs[s], b - offs[s], dtype=np.int64),
                            np.arange(a - lo, b - lo, dtype=np.int64)))
        return out

    def to_meta(self) -> dict:
        return {"kind": self.kind, "length": self.length,
                "sizes": list(self.sizes), "owners": list(self.owners)}

    @classmethod
    def from_meta(cls, meta: dict) -> "Distribution":
        return cls(meta["kind"], meta["length"], tuple(meta["sizes"]),
                   tuple(meta["owners"]))


def _split(length: int, parts: int) -> List[int]:
    q, r = divmod(length, parts)
    return [q + 1 if i < r else q for i in range(parts)]


def block(length: int, localities: Sequence[int]) -> Distribution:
    """Near-equal contiguous chunks, one segment per locality."""
    owners = tuple(localities)
    if not owners:
        raise ValueError("block distribution needs at least one locality")
    return Distribution("block", length, tuple(_split(length, len(owners))), owners)


def cyclic(length: int, localities: Sequence[int]) -> Distribution:
    """Round-robin: element ``i`` → segment ``i % S``, offset ``i // S``."""
    owners = tuple(localities)
    if not owners:
        raise ValueError("cyclic distribution needs at least one locality")
    s = len(owners)
    sizes = tuple((length - j + s - 1) // s for j in range(s))
    return Distribution("cyclic", length, sizes, owners)


def explicit(sizes: Sequence[int], owners: Sequence[int]) -> Distribution:
    """Caller-chosen contiguous segment sizes and initial owners."""
    if len(sizes) != len(owners):
        raise ValueError("explicit distribution: len(sizes) != len(owners)")
    if any(s < 0 for s in sizes):
        raise ValueError("explicit distribution: negative segment size")
    return Distribution("explicit", int(sum(sizes)), tuple(int(s) for s in sizes),
                        tuple(int(o) for o in owners))


def make(policy, length: int, localities: Sequence[int]) -> Distribution:
    """Normalize a policy spec: a Distribution passes through, ``"block"`` /
    ``"cyclic"`` build over ``localities``, a sequence of sizes builds an
    explicit layout round-robined over ``localities``."""
    if isinstance(policy, Distribution):
        if policy.length != length:
            raise ValueError(
                f"distribution length {policy.length} != vector length {length}")
        return policy
    if policy == "block":
        return block(length, localities)
    if policy == "cyclic":
        return cyclic(length, localities)
    if isinstance(policy, (list, tuple)):
        owners = [localities[j % len(localities)] for j in range(len(policy))]
        d = explicit(policy, owners)
        if d.length != length:
            raise ValueError(
                f"explicit sizes sum to {d.length}, expected {length}")
        return d
    raise ValueError(f"unknown distribution policy: {policy!r}")
