"""repro.container — distributed containers: send work to data.

The paper names "sending work to data instead of data to work" as a core
HPX design pattern; this package is its data-structure half:

    PartitionedVector.create(name, n, ...)   AGAS-backed distributed array
    PartitionedVector.attach(name)           handle from any locality
    pv.get/set/slice/to_array                element access over parcels
    pv.fill_with(fn, ...)                    owner-side bulk init (0 bytes)
    pv.move_segment/rebalance                placement moves (GIDs stable)
    distribution.block/cyclic/explicit       segment geometry policies

The algorithm half lives in :mod:`repro.container.segmented` and is
reached through ``repro.core.algorithms``: every parallel algorithm
(``for_each``/``transform``/``reduce``/``transform_reduce``/scans/
``count_if``/``all_of``/``any_of``/``sort``/``fill``/``min_element``/
``max_element``) detects a partitioned vector and lowers to per-segment
parcels executed where each segment lives, partials combined on the
caller through ``dataflow``.

Requires a multi-locality runtime (``repro.net.bootstrap``) — the
degenerate 1-locality bootstrap gives the same API in one process.
"""

from repro.container import distribution, segmented
from repro.container.distribution import Distribution, block, cyclic, explicit
from repro.container.partitioned_vector import PartitionedVector

__all__ = [
    "Distribution", "PartitionedVector",
    "block", "cyclic", "explicit",
    "distribution", "segmented",
]
