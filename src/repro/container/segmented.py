"""Segmented parallel algorithms over :class:`PartitionedVector` — the
work-to-data lowering of ``repro.core.algorithms`` (HPX's segmented
algorithm layer on ``partitioned_vector``).

Every public function here has the same shape as its ``core.algorithms``
counterpart, which dispatches to it whenever the data argument is a
partitioned vector.  The lowering is uniform:

1. **ship the body, not the bytes** — one object-targeted parcel per
   segment carries the (pickled-by-reference) body/op to the segment's
   owning locality, where it runs on that locality's own executor pools
   (parcels execute via the owner's resource partitioner);
2. **combine on the caller through dataflow** — per-segment partials come
   home as small scalars/keys and a ``dataflow`` continuation folds them;
   under a ``task`` policy the un-joined Future is returned (two-way).

Result placement follows HPX: ``transform`` and the scans produce a *new*
partitioned vector with the same geometry, each result segment registered
at the source segment's owner — results stay distributed, nothing gathers.

Correctness contracts per distribution:

- order-free algorithms (``reduce``/``transform_reduce`` with their C++
  GENERALIZED_SUM associativity+commutativity-up-to-grouping license,
  ``count_if``, ``all_of``/``any_of``, ``min/max_element``, ``fill``,
  ``for_each``, elementwise ``transform``) are segment-decomposable under
  every distribution;
- the **scans** are order-dependent: on contiguous layouts (block /
  explicit) they run the true two-pass distributed scan — local inclusive
  scan per segment, an exclusive carry combine of segment totals on the
  caller, then a parallel offset-fixup parcel per segment.  On cyclic
  layouts segments interleave in global order, so scans fall back to
  gather → scan → scatter (correct, and loudly documented as the
  non-work-to-data path);
- ``sort`` distributes the O(n log n) per-segment sorts, then merges the
  sorted runs on the caller and scatters the result back in place.
"""

from __future__ import annotations

import builtins
import heapq
import operator
from typing import Any, Callable, List, Optional, Tuple

import numpy as np

from repro.core import agas as _agas
from repro.core import executor as _executor
from repro.core.dataflow import dataflow
from repro.core.executor import ExecutionPolicy
from repro.core.future import Future
from repro.core import parcel as _parcel
from repro.container.partitioned_vector import (
    PartitionedVector,
    _check_shippable,
    _publish_descriptor,
    _seg_read,
    _TIMEOUT,
    derived_name,
)


def _apply_on(key, fn: Callable[..., Any], *args: Any) -> Future:
    """Object-targeted parcel on an arbitrary segment key (used for result
    segments that are not part of a client handle yet)."""
    from repro import net as _net

    return _net.apply_remote(fn, _agas.GID(*key), *args)


def _compute(fn: Callable[[], Any]) -> Any:
    """Run a segment body on the owner's compute pool (the parcel itself
    executes on the "io" pool — heavy loops hop to "default")."""
    from repro.obs import trace as _trace

    if _trace._enabled:
        # segment bodies are closures inside the _seg_* actions; the
        # enclosing function name is the algorithm ("for_each", "reduce")
        label = getattr(fn, "__qualname__", "segment").split(".")[0]
        with _trace.span(f"segment:{label.lstrip('_')}", "container"):
            return _executor.get_executor("default").sync_execute(fn)
    return _executor.get_executor("default").sync_execute(fn)


# ---------------------------------------------------------- segment actions
@_parcel.action
def _seg_for_each(obj: np.ndarray, fn: Callable[[Any], Any]) -> int:
    def run() -> int:
        for v in obj:
            fn(v)
        return int(obj.shape[0])

    return _compute(run)


@_parcel.action
def _seg_transform(obj: np.ndarray, fn: Callable[[Any], Any],
                   name: str) -> Tuple[List[int], str]:
    """Map a segment in place at its owner; register the result segment
    *here* (the result vector inherits the source's placement)."""

    def run():
        vals = [fn(v) for v in obj]
        out = np.asarray(vals) if vals else np.empty((0, *obj.shape[1:]),
                                                     dtype=obj.dtype)
        gid = _agas.default().register(out, name=name)
        return [gid.locality, gid.seq], out.dtype.str

    return _compute(run)


@_parcel.action
def _seg_reduce(obj: np.ndarray, op: Callable[[Any, Any], Any]) -> Any:
    def run():
        if obj.shape[0] == 0:
            return None
        if op is operator.add:
            return obj.sum(axis=0)
        acc = obj[0]
        for i in range(1, obj.shape[0]):
            acc = op(acc, obj[i])
        return acc

    return _compute(run)


@_parcel.action
def _seg_transform_reduce(obj: np.ndarray, fn: Callable[[Any], Any],
                          op: Callable[[Any, Any], Any]) -> Any:
    def run():
        if obj.shape[0] == 0:
            return None
        acc = fn(obj[0])
        for i in range(1, obj.shape[0]):
            acc = op(acc, fn(obj[i]))
        return acc

    return _compute(run)


@_parcel.action
def _seg_count_if(obj: np.ndarray, pred: Callable[[Any], Any]) -> int:
    return _compute(lambda: sum(1 for v in obj if pred(v)))


@_parcel.action
def _seg_fill(obj: np.ndarray, value: Any) -> int:
    obj[...] = value
    return int(obj.shape[0])


@_parcel.action
def _seg_extremum(obj: np.ndarray, which: str) -> Any:
    if obj.shape[0] == 0:
        return None
    return _compute(lambda: (obj.min() if which == "min" else obj.max()))


@_parcel.action
def _seg_scan_local(obj: np.ndarray, op: Callable[[Any, Any], Any],
                    name: str) -> Tuple[List[int], Any, str]:
    """Two-pass scan, pass 1: local inclusive scan registered at the owner;
    returns (result-segment key, segment total or None when empty, dtype)."""

    def run():
        if obj.shape[0] == 0:
            out = np.empty((0, *obj.shape[1:]), dtype=obj.dtype)
        elif op is operator.add:  # vectorized fast path
            out = np.cumsum(obj, axis=0)
        else:
            vals: List[Any] = []
            acc: Any = None
            for v in obj:
                acc = v if acc is None else op(acc, v)
                vals.append(acc)
            out = np.asarray(vals)
        gid = _agas.default().register(out, name=name)
        return ([gid.locality, gid.seq],
                (out[-1] if out.shape[0] else None), out.dtype.str)

    return _compute(run)


@_parcel.action
def _seg_apply_offset(obj: np.ndarray, key: List[int],
                      op: Callable[[Any, Any], Any], off: Any,
                      exclusive: bool) -> Optional[str]:
    """Two-pass scan, pass 2: fold the carried-in offset into the locally
    scanned segment.  ``off is None`` ⇒ no offset (first inclusive chunk).
    The fixup rebinds (dtype may promote: a float carry over int data);
    returns the rebound dtype, or None when nothing was rebound."""

    def run() -> Optional[str]:
        if obj.shape[0] == 0 or (off is None and not exclusive):
            return None  # no rebind: pass-1 dtype stands
        if exclusive:  # [off, off⊕x0, ..., off⊕x_{k-2}] from local inclusive
            if op is operator.add:
                head = np.broadcast_to(np.asarray(off), obj.shape[1:])[None]
                vals = np.concatenate([head, np.asarray(off) + obj[:-1]])
            else:
                vals = np.asarray([off] + [op(off, v) for v in obj[:-1]])
        else:
            vals = (np.asarray(off) + obj if op is operator.add
                    else np.asarray([op(off, v) for v in obj]))
        vals = np.asarray(vals)
        _agas.default().rebind(_agas.GID(*key), vals)
        return vals.dtype.str

    return _compute(run)


@_parcel.action
def _seg_adopt_values(obj: np.ndarray, name: str, values: Any) -> Tuple[List[int], str]:
    """Register ``values`` at this (the source segment's) locality — the
    scatter half of the cyclic-scan fallback."""
    out = np.asarray(values)
    gid = _agas.default().register(out, name=name)
    return [gid.locality, gid.seq], out.dtype.str


@_parcel.action
def _seg_sort_inplace(obj: np.ndarray) -> int:
    _compute(obj.sort)
    return int(obj.shape[0])


# ------------------------------------------------------------------ plumbing
def _deliver(policy: ExecutionPolicy, fut: Future) -> Any:
    """Honor two-way policies: ``task`` returns the Future, else join."""
    return fut if policy.task else fut.get(timeout=_TIMEOUT)


def _fanout(pv: PartitionedVector, fn: Callable[..., Any], *args: Any,
            seg_args: Optional[Callable[[int], Tuple[Any, ...]]] = None,
            only_nonempty: bool = True) -> Tuple[List[int], List[Future]]:
    for a in args:
        _check_shippable(a)
    segs = [j for j in range(pv.nsegments)
            if pv.dist.sizes[j] or not only_nonempty]
    return segs, [pv._apply(fn, j, *args, *(seg_args(j) if seg_args else ()))
                  for j in segs]


def _derived(pv: PartitionedVector, keyed: List[Tuple[List[int], str]],
             segs: List[int], name: str) -> PartitionedVector:
    """Assemble the client handle for a result vector whose segments were
    registered owner-side.  Empty source segments produced no remote call;
    register their (empty) result segments locally-ownerless is wrong, so
    they are created at the *initial* owner via the same geometry."""
    from repro import net as _net
    from repro.container.partitioned_vector import _create_segment

    keys: List[Optional[Tuple[int, int]]] = [None] * pv.nsegments
    dtypes = []
    for j, (key, dt) in zip(segs, keyed):
        keys[j] = tuple(key)
        dtypes.append(np.dtype(dt))
    dt = np.result_type(*dtypes).str if dtypes else pv.dtype.str
    empty = [j for j in range(pv.nsegments) if keys[j] is None]
    # empty segments produced no remote call; allocate their zero-length
    # result segments at the source's initial owner so the result vector's
    # placement mirrors the source everywhere
    futs = [_net.run_on(pv.dist.owners[j], _create_segment,
                        f"{name}/seg{j}", 0, dt, pv.element_shape)
            for j in empty]
    for j, f in zip(empty, futs):
        keys[j] = tuple(f.get(timeout=_TIMEOUT))
    out = PartitionedVector(name, pv.dist, dt, pv.element_shape, keys)
    _publish_descriptor(name, pv.dist, dt, out.element_shape, out.segment_keys)
    return out


# ------------------------------------------------------------- order-free ops
def for_each(policy: ExecutionPolicy, pv: PartitionedVector,
             fn: Callable[[Any], Any]) -> Any:
    _segs, futs = _fanout(pv, _seg_for_each, fn)
    return _deliver(policy, dataflow(lambda *parts: None, *futs))


def transform(policy: ExecutionPolicy, pv: PartitionedVector,
              fn: Callable[[Any], Any]) -> Any:
    """→ new PartitionedVector, same geometry, segments at the same owners
    as the source (zero element bytes on the wire)."""
    name = derived_name(pv.name)
    segs, futs = _fanout(pv, _seg_transform, fn,
                         seg_args=lambda j: (f"{name}/seg{j}",))
    return _deliver(policy, dataflow(
        lambda *keyed: _derived(pv, list(keyed), segs, name), *futs))


def _fold_parts(init: Any, parts, op: Callable[[Any, Any], Any]) -> Any:
    acc = init
    for p in parts:
        if p is None:  # empty segment
            continue
        acc = op(acc, p)
    return acc


def reduce(policy: ExecutionPolicy, pv: PartitionedVector, init: Any = 0,
           op: Callable[[Any, Any], Any] = operator.add) -> Any:
    _segs, futs = _fanout(pv, _seg_reduce, op)
    return _deliver(policy, dataflow(
        lambda *parts: _fold_parts(init, parts, op), *futs))


def transform_reduce(policy: ExecutionPolicy, pv: PartitionedVector,
                     fn: Callable[[Any], Any], init: Any = 0,
                     op: Callable[[Any, Any], Any] = operator.add) -> Any:
    _segs, futs = _fanout(pv, _seg_transform_reduce, fn, op)
    return _deliver(policy, dataflow(
        lambda *parts: _fold_parts(init, parts, op), *futs))


def count_if(policy: ExecutionPolicy, pv: PartitionedVector,
             pred: Callable[[Any], Any]) -> Any:
    _segs, futs = _fanout(pv, _seg_count_if, pred)
    return _deliver(policy, dataflow(lambda *parts: int(sum(parts)), *futs))


def fill(policy: ExecutionPolicy, pv: PartitionedVector, value: Any) -> Any:
    _segs, futs = _fanout(pv, _seg_fill, value)
    return _deliver(policy, dataflow(lambda *parts: pv, *futs))


def _extremum(policy: ExecutionPolicy, pv: PartitionedVector,
              which: str) -> Any:
    if len(pv) == 0:
        raise ValueError(f"{which}_element of an empty partitioned vector")
    _segs, futs = _fanout(pv, _seg_extremum, which)
    pick = builtins.min if which == "min" else builtins.max

    def combine(*parts):
        vals = [p for p in parts if p is not None]
        return pick(vals)

    return _deliver(policy, dataflow(combine, *futs))


def min_element(policy: ExecutionPolicy, pv: PartitionedVector) -> Any:
    return _extremum(policy, pv, "min")


def max_element(policy: ExecutionPolicy, pv: PartitionedVector) -> Any:
    return _extremum(policy, pv, "max")


# ------------------------------------------------------------------- scans
def _carries(totals: List[Any], op: Callable[[Any, Any], Any],
             exclusive: bool, init: Any) -> List[Any]:
    """Exclusive carry combine of segment totals (the caller-side middle
    pass).  Inclusive: chunk 0 gets no offset (None); exclusive: chunk 0
    is seeded with ``init``."""
    offs: List[Any] = [init if exclusive else None] * len(totals)
    carry: Any = init if exclusive else None
    for j in range(len(totals) - 1):
        t = totals[j]
        if t is not None:
            carry = t if carry is None else op(carry, t)
        offs[j + 1] = carry
    return offs


def _scan_contiguous(policy: ExecutionPolicy, pv: PartitionedVector,
                     op: Callable[[Any, Any], Any], exclusive: bool,
                     init: Any) -> Any:
    name = derived_name(pv.name)
    segs, futs = _fanout(pv, _seg_scan_local, op,
                         seg_args=lambda j: (f"{name}/seg{j}",))

    def fixup(*keyed) -> PartitionedVector:
        keys: dict = {}
        totals: List[Any] = [None] * pv.nsegments
        dts: dict = {}
        for j, (key, total, dt) in zip(segs, keyed):
            keys[j], totals[j], dts[j] = key, total, dt
        offs = _carries(totals, op, exclusive, init)
        fixed = [j for j in range(pv.nsegments) if j in keys]
        fix = [_apply_on(keys[j], _seg_apply_offset, list(keys[j]), op,
                         offs[j], exclusive) for j in fixed]
        for j, f in zip(fixed, fix):
            rebound_dt = f.get(timeout=_TIMEOUT)
            if rebound_dt is not None:  # the fixup may promote the dtype
                dts[j] = rebound_dt
        keyed_dt = [(keys[j], dts[j]) for j in fixed]
        return _derived(pv, keyed_dt, segs, name)

    return _deliver(policy, dataflow(fixup, *futs))


def _scan_gather(policy: ExecutionPolicy, pv: PartitionedVector,
                 op: Callable[[Any, Any], Any], exclusive: bool,
                 init: Any) -> Any:
    """Cyclic layouts interleave global order across segments, so the
    two-pass decomposition does not apply: gather, scan at the caller,
    scatter the result back to the source owners (documented fallback —
    O(n) wire bytes, still a distributed *result*)."""
    name = derived_name(pv.name)

    def run() -> PartitionedVector:
        data = pv.to_array()
        out: List[Any] = []
        if exclusive:
            acc = init
            for v in data:
                out.append(acc)
                acc = op(acc, v)
        else:
            acc = None
            for v in data:
                acc = v if acc is None else op(acc, v)
                out.append(acc)
        arr = (np.asarray(out) if out
               else np.empty((0, *pv.element_shape), dtype=pv.dtype))
        segs = list(range(pv.nsegments))
        futs = [pv._apply(_seg_adopt_values, j, f"{name}/seg{j}",
                          arr[pv.dist.global_indices(j)]) for j in segs]
        keyed = [f.get(timeout=_TIMEOUT) for f in futs]
        return _derived(pv, keyed, segs, name)

    if policy.task:
        return _executor.get_executor("default").async_execute(run)
    return run()


def inclusive_scan(policy: ExecutionPolicy, pv: PartitionedVector,
                   op: Callable[[Any, Any], Any] = operator.add) -> Any:
    if pv.dist.contiguous:
        return _scan_contiguous(policy, pv, op, exclusive=False, init=None)
    return _scan_gather(policy, pv, op, exclusive=False, init=None)


def exclusive_scan(policy: ExecutionPolicy, pv: PartitionedVector,
                   init: Any = 0,
                   op: Callable[[Any, Any], Any] = operator.add) -> Any:
    if pv.dist.contiguous:
        return _scan_contiguous(policy, pv, op, exclusive=True, init=init)
    return _scan_gather(policy, pv, op, exclusive=True, init=init)


# -------------------------------------------------------------------- sort
def sort(policy: ExecutionPolicy, pv: PartitionedVector) -> Any:
    """In-place: distributed per-segment sorts, k-way merge on the caller,
    scatter back in global order.  Returns ``pv``."""
    if pv.element_shape != ():
        raise ValueError("sort needs scalar elements (no total order on "
                         "array-valued elements)")

    def run() -> PartitionedVector:
        segs, futs = _fanout(pv, _seg_sort_inplace)
        for f in futs:
            f.get(timeout=_TIMEOUT)
        reads = [pv._apply(_seg_read, j) for j in segs]  # issue all, then join
        runs = [f.get(timeout=_TIMEOUT) for f in reads]
        merged = np.fromiter(heapq.merge(*[r.tolist() for r in runs]),
                             dtype=pv.dtype, count=len(pv))
        if len(pv):
            pv.set_slice(0, len(pv), merged)
        return pv

    if policy.task:
        return _executor.get_executor("default").async_execute(run)
    return run()
