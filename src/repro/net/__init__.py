"""repro.net — the multi-locality runtime: localities as OS processes.

The subsystem that makes "locality" mean what the paper means (§2.2–2.3):
a separate runtime instance reached only through parcels.

    bootstrap(n)            fork n-1 worker runtimes; caller = AGAS root
    running(n)              context-managed bootstrap (leak-proof teardown)
    apply_remote(a, gid)    one-sided invoke where the object lives
    run_on(loc, fn, ...)    invoke against a locality's runtime itself
    migrate_remote(gid, L)  move an object; GID stays valid (gen bump)
    query_counters(loc, p)  a locality's performance counters, via parcel
    fetch(gid)              host snapshot of a (remote) object's state
    current() / require()   the process's NetRuntime, if bootstrapped

Layering: :mod:`repro.net.parcelport` moves zero-copy frames,
:mod:`repro.net.locality` runs the per-process endpoint + bootstrap, and
:mod:`repro.net.remote` adds the distributed AGAS tier on top.  This
package is the *only* place in the tree allowed to open sockets or start
processes (enforced by ``tests/test_api_guard.py``).
"""

from repro.net.locality import (
    ROOT,
    Locality,
    NetRuntime,
    UnknownGid,
    bootstrap,
    current,
    require,
    running,
)
from repro.net.parcelport import NetConfig, PortClosed
from repro.net.remote import (
    apply_remote,
    describe,
    fetch,
    migrate_remote,
    owner_of,
    query_counter_export,
    query_counter_stats,
    query_counters,
    run_on,
)

__all__ = [
    "ROOT", "Locality", "NetConfig", "NetRuntime", "UnknownGid", "PortClosed",
    "bootstrap", "current", "require", "running",
    "apply_remote", "describe", "fetch", "migrate_remote", "owner_of",
    "query_counter_export", "query_counter_stats", "query_counters", "run_on",
]
