"""Localities as real OS processes (HPX §2.2: the unit of distribution).

Until this subsystem, a "locality" in this repo was a sharding — every
parcel, AGAS record and migration lived inside one OS process.  Here
:func:`bootstrap` makes localities *processes*: it forks ``n-1`` worker
runtimes (``multiprocessing`` spawn — never ``fork``, which would duplicate
live scheduler threads mid-lock) and wires every worker to locality 0 over
the parcelport.  Locality 0 is the **AGAS root**: it owns the authoritative
GID → owner-locality table (see :mod:`repro.net.remote`) and acts as the
frame switch for worker↔worker traffic (hub-and-spoke, the LCI study's
"put the progress engine where the wires meet").

Topology::

        locality#1 ══╗
        locality#2 ══╣══ locality#0 (root: AGAS table + frame switch)
        locality#3 ══╝
         each ══: 1 priority lane + N bulk lanes (NetConfig.stripes)
         each process: NetRuntime + AMT scheduler + parcelport Port

Every process runs the full single-process stack (scheduler pools,
executors, AGAS, counters) plus one :class:`NetRuntime`:

- **send side** — ``send_parcel(dst, action, target, args)`` allocates a
  sequence number, parks a :class:`~repro.core.future.Promise` in the
  pending table and hands the frame to the peer's
  :class:`~repro.net.parcelport.Channel`, which picks the protocol tier
  (eager+coalesced vs rendezvous+striped) and applies backpressure; the
  returned Future is completed by the matching result frame.
- **receive side** — the port's progress thread delivers parsed frames;
  parcel decode+execution is posted into the scheduler's "io" pool (a
  blocked action helps along, so nested remote calls cannot deadlock the
  pool), result frames complete pending promises inline, and each
  executed parcel returns its CREDIT to the sender (the backpressure
  ack).
- **integration** — ``bootstrap`` installs the AGAS hook (registrations
  publish to the root table) and the core parcel remote-route, so
  ``repro.core.parcel.apply`` transparently crosses process boundaries.
"""

from __future__ import annotations

import itertools
import os
import socket
import threading
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

from repro.core import agas as _agas
from repro.core import counters as _counters
from repro.core import executor as _executor
from repro.core import parcel as _parcel
from repro.core.future import Future, Promise
from repro.net import parcelport as _pp
from repro.obs import trace as _trace

ROOT = 0

_GidKey = Tuple[int, int]  # (locality, seq) — the wire form of a GID


@dataclass(frozen=True)
class Locality:
    """Handle to one locality (HPX ``hpx::naming::id_type`` of a locality)."""

    id: int

    def __repr__(self) -> str:
        return f"locality#{self.id}"


class UnknownGid(RuntimeError):
    """The target GID does not resolve at the locality that was asked.

    Carried across the wire as the stale-resolution signal: the caller
    invalidates its cached placement and re-resolves through the root
    (generation-based invalidation — see ``repro.net.remote``).
    """

    @property
    def key(self) -> _GidKey:
        return self.args[0]

    @property
    def locality(self) -> int:
        return self.args[1]


def _gid_key(gid: _agas.GID) -> _GidKey:
    return (gid.locality, gid.seq)


class _RuntimeHooks(_pp.PortHooks):
    """The :class:`NetRuntime` side of the port's callback surface."""

    __slots__ = ("net",)

    def __init__(self, net: "NetRuntime"):
        self.net = net

    def deliver(self, fr: _pp.Frame, channel: _pp.Channel) -> None:
        self.net._on_frame(fr, channel)

    def route(self, dst: int) -> _pp.Channel:
        return self.net._route_to(dst)

    def forward_failed(self, fr: _pp.Frame) -> None:
        self.net._forward_failed(fr)

    def on_forwarded(self) -> None:
        self.net.c_forwarded.increment()

    def on_close(self, channel: _pp.Channel) -> None:
        self.net._on_conn_close(channel)


class NetRuntime:
    """Per-process endpoint of the multi-locality runtime."""

    def __init__(self, locality: int, n_localities: int,
                 config: Optional[_pp.NetConfig] = None):
        self.locality = locality
        self.n_localities = n_localities
        self.config = config or _pp.NetConfig.from_env()
        self._port = _pp.Port(locality, _RuntimeHooks(self), self.config)
        self._conns: Dict[int, _pp.Channel] = {}
        # seq → (promise, destination locality): the dst lets a dead-peer
        # notification fail exactly the calls that can no longer complete
        self._pending: Dict[int, Tuple[Promise, int]] = {}
        self._pending_lock = threading.Lock()
        self._seq = itertools.count(1)
        self._stop = threading.Event()
        self._procs: Dict[int, Any] = {}  # root only: lid → Process handle
        self._hook_installed = False
        # elastic topology (root authoritative, gossiped via TOPO frames):
        # n_localities is the size of the id space ever assigned; retired
        # ids are never reused, so GIDs minted by a dead locality stay
        # unambiguous forever.
        self._retired: set = set()
        self._expect_down: set = set()  # retirements in progress (no re-DOWN)
        self._topo_lock = threading.Lock()
        # observers of peer departure (crash or retirement): the serve/fleet
        # layers abort relay streams and evict engines pinned to the peer
        self._peer_down_hooks: List[Any] = []

        # distributed-AGAS state (root: authoritative; workers: cache only)
        self._table: Dict[_GidKey, Tuple[int, int]] = {}  # key → (owner, gen)
        self._names: Dict[str, _GidKey] = {}
        self._table_lock = threading.Lock()
        self._cache: Dict[_GidKey, Tuple[int, int]] = {}
        self._name_cache: Dict[str, _GidKey] = {}
        self._cache_lock = threading.Lock()

        # parcels execute on the "io" pool (falling back to the default
        # pool on unpartitioned runtimes); help-along keeps blocked actions
        # from wedging it.  Executors are the only sanctioned pool entry.
        self._exec = _executor.get_executor("io", fallback="default")

        reg = _counters.default()
        p = f"/net{{locality#{locality}}}"
        self.c_actions = reg.counter(f"{p}/actions/executed")
        self.c_forwarded = reg.counter(f"{p}/parcels/forwarded")
        self.c_stale = reg.counter(f"{p}/resolutions/stale")
        self.c_cache_hits = reg.counter(f"{p}/resolutions/cache_hits")
        self.c_root_lookups = reg.counter(f"{p}/resolutions/root_lookups")

    # ------------------------------------------------------------- topology
    @property
    def localities(self) -> List[Locality]:
        """Live localities (retired ids are skipped, never reassigned)."""
        return [Locality(i) for i in range(self.n_localities)
                if i not in self._retired]

    def live_ids(self) -> List[int]:
        return [loc.id for loc in self.localities]

    def is_root(self) -> bool:
        return self.locality == ROOT

    def is_live(self, lid: int) -> bool:
        return 0 <= lid < self.n_localities and lid not in self._retired

    def add_peer_down_hook(self, cb) -> None:
        """``cb(lid)`` fires on this locality whenever peer ``lid`` leaves
        the fleet — crash (DOWN broadcast / connection drop) or orderly
        retirement.  May fire more than once per peer; observers must be
        idempotent."""
        self._peer_down_hooks.append(cb)

    def _notify_peer_down(self, lid: int) -> None:
        for cb in list(self._peer_down_hooks):
            try:
                cb(lid)
            except Exception:  # noqa: BLE001 — observers must not break net
                pass

    # ------------------------------------------------------------ send side
    def send_parcel(self, dst: int, action_name: str,
                    target: Optional[_GidKey], args: Tuple[Any, ...] = (),
                    kwargs: Optional[Dict[str, Any]] = None,
                    want_result: bool = True) -> Optional[Future]:
        """One-sided invoke on locality ``dst``: run ``action`` against the
        object at ``target`` (``None`` → the destination's NetRuntime).
        Returns the result Future, or ``None`` for fire-and-forget."""
        if not (0 <= dst < self.n_localities):
            raise ValueError(f"no such locality: {dst}")
        kwargs = kwargs or {}
        promise: Optional[Promise] = Promise() if want_result else None

        if dst == self.locality:  # local shortcut — no wire, no pending slot
            self._exec.post(self._execute_local, action_name, target,
                            args, kwargs, promise)
            return promise.future() if promise else None

        seq = 0
        if want_result:
            seq = next(self._seq)
            with self._pending_lock:
                self._pending[seq] = (promise, dst)

        header = {"t": _pp.PARCEL, "src": self.locality, "dst": dst,
                  "seq": seq, "a": action_name,
                  "g": list(target) if target is not None else None}
        fid = None
        if _trace._enabled:
            # the parcel's trace context: a fresh flow id the receiver uses
            # both as its spans' parent and as the Perfetto flow-arrow id
            fid = _trace.new_id()
            header["tc"] = list(fid)
        try:
            if fid is not None:
                with _trace.span(f"send:{action_name.rsplit('.', 1)[-1]}",
                                 "net", flow_out=fid, dst=dst):
                    self._route_to(dst).send(header, (args, kwargs))
            else:
                self._route_to(dst).send(header, (args, kwargs))
        except BaseException:
            # ANY send-side failure (port closed, unpicklable args,
            # backpressure block timeout) surfaces synchronously — reclaim
            # the pending slot or it leaks for the runtime's lifetime
            if seq:
                with self._pending_lock:
                    self._pending.pop(seq, None)
            raise
        return promise.future() if promise else None

    def _route_to(self, dst: int) -> _pp.Channel:
        conn = self._conns.get(dst)
        if conn is None:
            conn = self._conns.get(ROOT)  # workers reach peers via the root
        if conn is None or conn.closed:
            raise _pp.PortClosed(f"no route to locality#{dst}")
        return conn

    # --------------------------------------------------------- receive side
    def _on_frame(self, fr: _pp.Frame, channel: _pp.Channel) -> None:
        """Progress-thread delivery of one application frame addressed to
        this locality (the port already forwarded, unpacked containers,
        and ran the transport protocols)."""
        header = fr.header
        t = header["t"]
        if t == _pp.PARCEL:
            # decode + execute on the io pool: unpickling user payloads
            # must not stall the progress loop
            self._exec.post(self._handle_parcel, fr)
        elif t == _pp.RESULT:
            # pop BEFORE decoding: a payload that fails to unpickle (e.g.
            # an exception class not importable here) must fail the caller
            # immediately, not leave it blocked until its own timeout
            with self._pending_lock:
                entry = self._pending.pop(header["seq"], None)
            if entry is None:
                return
            promise = entry[0]
            try:
                payload = _pp.decode_payload(header, fr.rest)
            except BaseException as e:  # noqa: BLE001
                promise.set_exception(RuntimeError(
                    f"result from locality#{header.get('src')} could not "
                    f"be decoded: {e!r}"))
                return
            if header.get("ok"):
                promise.set_value(payload)
            else:
                promise.set_exception(payload)
        elif t == _pp.BYE:
            self._stop.set()
        elif t == _pp.DOWN:
            # the root's dead-peer broadcast: in-flight calls to that
            # locality can never complete, nor can rendezvous with it
            peer = header.get("peer")
            if peer is not None:
                with self._topo_lock:
                    self._retired.add(peer)
                self._port.drop_transfers(peer)
                self._fail_pending_for(peer, f"locality#{peer} went away")
                self._notify_peer_down(peer)
        elif t == _pp.TOPO:
            # the root's topology broadcast: the id space grew (elastic
            # join).  FIFO ordering on the root channel guarantees this
            # arrives before any parcel that *mentions* the new locality.
            with self._topo_lock:
                self.n_localities = max(self.n_localities, int(header["n"]))

    def _handle_parcel(self, fr: _pp.Frame) -> None:
        """io-pool side of a received parcel: decode, run, ack credit."""
        header = fr.header
        try:
            payload = _pp.decode_payload(header, fr.rest)
        except BaseException as e:  # noqa: BLE001 — tell the sender
            if header.get("seq"):
                self._send_result(header, None, RuntimeError(
                    f"locality#{self.locality} could not decode parcel "
                    f"args for action {header.get('a')!r}: {e!r}"))
            self._return_credit(header, fr.credit_bytes)
            return
        args, kwargs = payload if payload is not None else ((), {})
        try:
            self._execute_parcel(header, args, kwargs)
        finally:
            # end-to-end flow control: budget bytes flow back only after
            # the parcel *executed* — queue depth here pushes back there
            self._return_credit(header, fr.credit_bytes)

    def _return_credit(self, header: Dict[str, Any], nbytes: int) -> None:
        src = header.get("src", self.locality)
        if nbytes <= 0 or src == self.locality:
            return  # rendezvous-assembled parcels never consumed credit
        try:
            self._route_to(src).send_control(
                {"t": _pp.CREDIT, "src": self.locality, "dst": src,
                 "n": nbytes})
        except _pp.PortClosed:
            pass  # sender is gone; its ledger died with it

    def _forward_failed(self, fr: _pp.Frame) -> None:
        """Root switch could not forward ``fr`` (destination is down):
        bounce an error result to every parcel the frame carried."""
        for h in _pp.failed_parcel_headers(fr):
            if h.get("seq"):
                self._send_result(h, None, _pp.PortClosed(
                    f"locality#{h.get('dst')} is down"))

    def _resolve_target(self, target: Optional[_GidKey]) -> Any:
        if target is None:
            return self
        gid = _agas.GID(*target)
        resolver = _agas.default()
        if not resolver.contains(gid):
            raise UnknownGid(tuple(target), self.locality)
        return resolver.resolve(gid)

    def _execute_parcel(self, header: Dict[str, Any], args: Tuple[Any, ...],
                        kwargs: Dict[str, Any]) -> None:
        """Run one decoded parcel on a pool worker; reply if a result is
        wanted.  Never raises — failures travel back as result frames."""
        if _trace._enabled:
            # adopt the sender's trace context: this span (and everything
            # the action does) records the parcel as its parent, and the
            # flow-finish here matches the sender's flow-start
            tc = header.get("tc")
            fid = tuple(tc) if tc else None
            action = str(header.get("a", "?")).rsplit(".", 1)[-1]
            with _trace.with_context(fid), \
                    _trace.span(f"execute:{action}", "net", flow_in=fid,
                                src=header.get("src", -1)):
                self._execute_parcel_body(header, args, kwargs)
        else:
            self._execute_parcel_body(header, args, kwargs)

    def _execute_parcel_body(self, header: Dict[str, Any],
                             args: Tuple[Any, ...],
                             kwargs: Dict[str, Any]) -> None:
        try:
            target = header.get("g")
            obj = self._resolve_target(tuple(target) if target else None)
            fn = _parcel._registry.resolve(header["a"])
            value, exc = fn(obj, *args, **kwargs), None
            self.c_actions.increment()
        except BaseException as e:  # noqa: BLE001 — ship it back
            value, exc = None, e
            if isinstance(e, UnknownGid):
                self.c_stale.increment()
        if header.get("seq"):
            self._send_result(header, value, exc)
        elif exc is not None:
            import traceback

            traceback.print_exception(type(exc), exc, exc.__traceback__)

    def _execute_local(self, action_name: str, target: Optional[_GidKey],
                       args: Tuple[Any, ...], kwargs: Dict[str, Any],
                       promise: Optional[Promise]) -> None:
        try:
            obj = self._resolve_target(target)
            fn = _parcel._registry.resolve(action_name)
            value = fn(obj, *args, **kwargs)
            self.c_actions.increment()
            if promise is not None:
                promise.set_value(value)
        except BaseException as e:  # noqa: BLE001
            if promise is not None:
                promise.set_exception(e)

    def _send_result(self, req_header: Dict[str, Any], value: Any,
                     exc: Optional[BaseException]) -> None:
        reply = {"t": _pp.RESULT, "src": self.locality,
                 "dst": req_header["src"], "seq": req_header["seq"],
                 "ok": exc is None}
        try:
            if req_header["src"] == self.locality:
                raise _pp.PortClosed("result loop")  # unreachable by design
            # the channel picks the tier: big results (fetch of a large
            # array) take the rendezvous/striped path like any bulk parcel,
            # and unpicklable outcomes degrade to a picklable RuntimeError
            self._route_to(req_header["src"]).send(
                reply, value if exc is None else exc)
        except _pp.PortClosed:
            pass  # requester is gone; nothing to tell

    # ------------------------------------------------ distributed AGAS tier
    # Root-side authoritative table.  Workers call these through the
    # _root_* actions in repro.net.remote; the root's own AGAS hook calls
    # them directly (no wire hop at the root).
    def publish_local(self, key: _GidKey, owner: int, generation: int,
                      name: Optional[str]) -> int:
        with self._table_lock:
            cur = self._table.get(key)
            if cur is not None and cur[1] > generation:
                return cur[1]  # stale publish raced a newer one: keep newest
            self._table[key] = (owner, generation)
            if name is not None:
                self._names[name] = key
            return generation

    def unpublish_local(self, key: _GidKey, owner: int) -> bool:
        """Drop ``key`` only while ``owner`` still owns it (an unregister
        racing a migration must not erase the new owner's entry)."""
        with self._table_lock:
            cur = self._table.get(key)
            if cur is None or cur[0] != owner:
                return False
            del self._table[key]
            for n, k in list(self._names.items()):
                if k == key:
                    del self._names[n]
            return True

    def lookup_local(self, key: _GidKey) -> Tuple[int, int]:
        with self._table_lock:
            cur = self._table.get(key)
        if cur is None:
            raise UnknownGid(tuple(key), self.locality)
        return cur

    def lookup_name_local(self, name: str) -> _GidKey:
        with self._table_lock:
            key = self._names.get(name)
        if key is None:
            raise KeyError(f"AGAS root: name not published: {name!r}")
        return key

    # Per-locality resolution cache (generation-based invalidation).
    def cache_get(self, key: _GidKey) -> Optional[Tuple[int, int]]:
        with self._cache_lock:
            hit = self._cache.get(key)
        if hit is not None:
            self.c_cache_hits.increment()
        return hit

    def cache_put(self, key: _GidKey, owner: int, generation: int) -> None:
        with self._cache_lock:
            cur = self._cache.get(key)
            if cur is None or generation >= cur[1]:
                self._cache[key] = (owner, generation)

    def cache_invalidate(self, key: _GidKey) -> None:
        with self._cache_lock:
            self._cache.pop(key, None)
            for name, k in list(self._name_cache.items()):
                if k == key:
                    del self._name_cache[name]

    def name_cache_get(self, name: str) -> Optional[_GidKey]:
        with self._cache_lock:
            return self._name_cache.get(name)

    def name_cache_put(self, name: str, key: _GidKey) -> None:
        with self._cache_lock:
            self._name_cache[name] = key

    # ------------------------------------------------------------ AGAS hook
    def _agas_hook(self, event: str, rec: _agas.AgasRecord) -> None:
        """Publish local AGAS mutations to the root table.

        Counter registrations (names under ``/counters``) stay local —
        they are read remotely via the counter-snapshot action instead of
        being mirrored (thousands of entries, zero cross-process readers
        of the *objects*)."""
        name = rec.name
        if name is not None and name.startswith("/counters"):
            return
        from repro.net import remote as _remote

        key = _gid_key(rec.gid)
        if event in ("register", "rebind"):
            if self.is_root():
                self.publish_local(key, self.locality, rec.generation, name)
            else:
                self.send_parcel(ROOT, _remote.ROOT_PUBLISH, None,
                                 (list(key), self.locality, rec.generation,
                                  name)).get(timeout=60)
        elif event == "unregister":
            if self.is_root():
                self.unpublish_local(key, self.locality)
            else:
                self.send_parcel(ROOT, _remote.ROOT_UNPUBLISH, None,
                                 (list(key), self.locality),
                                 want_result=False)

    def _install(self) -> None:
        _agas.default().add_hook(self._agas_hook)
        self._hook_installed = True
        from repro.net import remote as _remote

        _parcel.set_remote_route(lambda p: _remote.route_parcel(self, p))
        _set_current(self)
        # publish objects registered before the net came up (root only
        # mutates its own table; workers usually boot before registering)
        for rec in _agas.default():
            self._agas_hook("register", rec)

    # ------------------------------------------------------ elastic topology
    def spawn_locality(self, pools: Optional[Dict[str, int]] = None,
                       timeout: float = 120.0) -> int:
        """Grow the fleet: spawn one new worker locality into the *running*
        runtime (root only).  The worker gets the next never-used id, dials
        home exactly like bootstrap (HELLO per lane), and every existing
        worker learns the enlarged id space through a TOPO broadcast that
        FIFO-precedes any parcel mentioning the newcomer.  Returns the new
        locality id."""
        if not self.is_root():
            raise RuntimeError("spawn_locality is root-only")
        import multiprocessing as _mp

        with self._topo_lock:
            lid = self.n_localities
            self.n_localities = lid + 1
        cfg = self.config
        nlanes = 1 + max(0, cfg.stripes)
        listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        listener.bind(("127.0.0.1", 0))
        listener.listen(nlanes)
        listener.settimeout(timeout)
        port = listener.getsockname()[1]

        ctx = _mp.get_context("spawn")
        proc = ctx.Process(
            target=_worker_main,
            args=(lid, lid + 1, port,
                  dict(pools) if pools else None, cfg),
            daemon=True, name=f"repro-locality-{lid}")
        proc.start()
        half_open: Dict[int, Dict[int, socket.socket]] = {}
        try:
            _accept_worker_lanes(self, listener, 1, nlanes, timeout,
                                 half_open)
        except BaseException as e:
            for lanes in half_open.values():
                for s in lanes.values():
                    try:
                        s.close()
                    except OSError:
                        pass
            proc.terminate()
            proc.join(timeout=5.0)
            with self._topo_lock:
                self._retired.add(lid)  # the id is burned, not reusable
            if isinstance(e, (OSError, socket.timeout)):
                raise RuntimeError(
                    f"spawn_locality: locality#{lid} failed to dial home "
                    f"within {timeout}s") from e
            raise
        finally:
            listener.close()
        self._procs[lid] = proc
        # existing workers must accept parcels addressed to the newcomer
        # before anything can mention it — TOPO rides the same FIFO channel
        for dst, conn in list(self._conns.items()):
            if dst == lid or conn.closed:
                continue
            try:
                conn.send({"t": _pp.TOPO, "src": self.locality, "dst": dst,
                           "seq": 0, "n": self.n_localities})
            except _pp.PortClosed:
                pass
        return lid

    def retire_locality(self, lid: int, timeout: float = 30.0) -> None:
        """Shrink the fleet: orderly shutdown of one worker locality (root
        only).  The caller is responsible for *draining* first — migrating
        or completing everything the locality owns; this layer fails any
        still-pending calls, BYEs the worker, reaps the process, purges its
        entries from the root AGAS table, and broadcasts DOWN so peers drop
        rendezvous state.  The id is never reused."""
        if not self.is_root():
            raise RuntimeError("retire_locality is root-only")
        if lid == ROOT:
            raise ValueError("cannot retire the root locality")
        if not self.is_live(lid):
            raise ValueError(f"locality#{lid} is not live")
        with self._topo_lock:
            self._expect_down.add(lid)
            self._retired.add(lid)
        conn = self._conns.get(lid)
        if conn is not None and not conn.closed:
            try:
                conn.send({"t": _pp.BYE, "src": self.locality, "dst": lid,
                           "seq": 0})
            except _pp.PortClosed:
                pass
            self._port.flush(timeout=min(timeout, 10.0))
        proc = self._procs.pop(lid, None)
        if proc is not None:
            proc.join(timeout=timeout)
            if proc.is_alive():
                proc.terminate()
                proc.join(timeout=5.0)
        self._fail_pending_for(lid, f"locality#{lid} retired")
        self._port.drop_transfers(lid)
        self._notify_peer_down(lid)
        # purge everything the dead locality still owned from the root
        # table: resolvers must get UnknownGid, not a route to a ghost
        with self._table_lock:
            doomed = [k for k, (owner, _g) in self._table.items()
                      if owner == lid]
            for k in doomed:
                del self._table[k]
                for n, key in list(self._names.items()):
                    if key == k:
                        del self._names[n]
        for k in doomed:
            self.cache_invalidate(k)
        for dst, other in list(self._conns.items()):
            if dst == lid or other.closed:
                continue
            try:
                other.send({"t": _pp.DOWN, "src": self.locality, "dst": dst,
                            "seq": 0, "peer": lid})
            except _pp.PortClosed:
                pass

    # ------------------------------------------------------------- shutdown
    def shutdown(self, timeout: float = 30.0) -> None:
        """Tear down the net: BYE every worker, join processes, uninstall."""
        if self.is_root():
            for dst, conn in list(self._conns.items()):
                if not conn.closed:
                    try:
                        conn.send({"t": _pp.BYE, "src": self.locality,
                                   "dst": dst, "seq": 0})
                    except _pp.PortClosed:
                        pass
            # the BYE (and anything coalesced ahead of it) must hit the
            # wire before the workers are reaped
            self._port.flush(timeout=min(timeout, 10.0))
            for proc in self._procs.values():
                proc.join(timeout=timeout)
            for proc in self._procs.values():
                if proc.is_alive():
                    proc.terminate()
                    proc.join(timeout=5.0)
        self._port.close()
        if self._hook_installed:
            _agas.default().remove_hook(self._agas_hook)
            self._hook_installed = False
        _parcel.set_remote_route(None)
        with self._pending_lock:
            pending, self._pending = dict(self._pending), {}
        for promise, _dst in pending.values():
            try:
                promise.set_exception(_pp.PortClosed("net runtime shut down"))
            except Exception:  # noqa: BLE001 — already completed
                pass
        _clear_current(self)

    def _fail_pending_for(self, dst: Optional[int], reason: str) -> None:
        """Fail in-flight calls that can no longer complete (``None`` =
        every destination — the worker losing its root link)."""
        with self._pending_lock:
            doomed = [seq for seq, (_p, d) in self._pending.items()
                      if dst is None or d == dst]
            entries = [self._pending.pop(seq) for seq in doomed]
        for promise, _d in entries:
            try:
                promise.set_exception(_pp.PortClosed(reason))
            except Exception:  # noqa: BLE001 — already completed
                pass

    def _on_conn_close(self, conn: _pp.Channel) -> None:
        if not self.is_root() and conn.peer_id == ROOT:
            # root went away: nothing in flight can ever complete
            self._fail_pending_for(None, "lost connection to the root")
            self._notify_peer_down(ROOT)
            self._stop.set()
        elif self.is_root():
            # a worker died: fail fast the calls routed to it (new sends
            # already raise PortClosed synchronously) and broadcast DOWN so
            # the other workers fail their worker↔worker calls too.  An
            # orderly retirement (retire_locality) already did all of this
            # before the connection dropped — don't re-broadcast.
            dead = conn.peer_id
            with self._topo_lock:
                expected = dead in self._expect_down
                self._retired.add(dead)
            if expected:
                return
            self._fail_pending_for(dead, f"locality#{dead} went away")
            self._notify_peer_down(dead)
            for dst, other in list(self._conns.items()):
                if other is conn or other.closed:
                    continue
                try:
                    other.send({"t": _pp.DOWN, "src": self.locality,
                                "dst": dst, "seq": 0, "peer": dead})
                except _pp.PortClosed:
                    pass


# ------------------------------------------------------------ current() api
_current: Optional[NetRuntime] = None
_current_lock = threading.Lock()


def _set_current(net: NetRuntime) -> None:
    global _current
    with _current_lock:
        if _current is not None:
            raise RuntimeError("a multi-locality runtime is already up")
        _current = net


def _clear_current(net: NetRuntime) -> None:
    global _current
    with _current_lock:
        if _current is net:
            _current = None


def current() -> Optional[NetRuntime]:
    return _current


def require() -> NetRuntime:
    net = current()
    if net is None:
        raise RuntimeError(
            "no multi-locality runtime: call repro.net.bootstrap(n) first")
    return net


# ---------------------------------------------------------------- bootstrap
def _accept_worker_lanes(net: NetRuntime, listener: socket.socket,
                         n_workers: int, nlanes: int, timeout: float,
                         half_open: Dict[int, Dict[int, socket.socket]]
                         ) -> None:
    """Accept ``n_workers × nlanes`` HELLO-stamped sockets and register one
    channel per worker as its lane set completes (bootstrap and elastic
    join share this).  ``half_open`` is caller-owned so a failure can close
    partially-dialed lanes."""
    for _ in range(n_workers * nlanes):
        sock, _addr = listener.accept()
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        sock.settimeout(timeout)  # bounded handshake read
        frame = _pp.read_frame(sock)
        header, _ = _pp.decode_frame(frame)
        if header["t"] != _pp.HELLO:
            raise RuntimeError(f"expected HELLO, got {header['t']!r}")
        if header.get("nl", 1) != nlanes:
            raise RuntimeError(
                f"lane-count mismatch: worker {header['src']} dialed "
                f"{header.get('nl')} lanes, root expects {nlanes}")
        peer, lane = header["src"], header.get("lane", 0)
        sock.settimeout(None)
        lanes = half_open.setdefault(peer, {})
        lanes[lane] = sock
        if len(lanes) == nlanes:
            del half_open[peer]
            net._conns[peer] = net._port.add_channel(
                peer, [lanes[i] for i in range(nlanes)])


def bootstrap(n_localities: int, pools: Optional[Dict[str, int]] = None,
              worker_pools: Optional[Dict[str, int]] = None,
              timeout: float = 120.0,
              config: Optional[_pp.NetConfig] = None) -> NetRuntime:
    """Bring up an ``n_localities``-process runtime; the caller becomes
    locality 0 (AGAS root).  Returns the root :class:`NetRuntime`.

    ``pools`` partitions the *root* scheduler (``core.init`` semantics),
    ``worker_pools`` every worker's; ``config`` tunes the transport tier
    (defaults to :meth:`NetConfig.from_env`) and is shipped to every
    worker so both ends agree on thresholds and lane counts.  Workers are
    spawned (never forked) so no live thread or lock state is duplicated;
    each worker imports the stack fresh, pins its AGAS locality id, and
    dials home with one socket per lane.
    """
    import multiprocessing as _mp

    import repro.core as core

    if n_localities < 1:
        raise ValueError("need at least one locality")
    core.init(pools=pools)
    net = NetRuntime(ROOT, n_localities, config=config)
    if n_localities == 1:  # degenerate but useful: uniform API, no workers
        net._install()
        return net
    cfg = net.config
    nlanes = 1 + max(0, cfg.stripes)

    listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
    listener.bind(("127.0.0.1", 0))
    listener.listen((n_localities - 1) * nlanes)
    listener.settimeout(timeout)
    port = listener.getsockname()[1]

    ctx = _mp.get_context("spawn")
    for lid in range(1, n_localities):
        proc = ctx.Process(target=_worker_main,
                           args=(lid, n_localities, port, worker_pools, cfg),
                           daemon=True, name=f"repro-locality-{lid}")
        proc.start()
        net._procs[lid] = proc

    half_open: Dict[int, Dict[int, socket.socket]] = {}
    try:
        _accept_worker_lanes(net, listener, n_localities - 1, nlanes,
                             timeout, half_open)
    except BaseException as e:
        # ANY handshake failure (timeout, stray client sending garbage,
        # corrupt frame) must reap the already-spawned workers — they would
        # otherwise idle for the parent's lifetime
        for lanes in half_open.values():
            for s in lanes.values():
                try:
                    s.close()
                except OSError:
                    pass
        net.shutdown()
        if isinstance(e, (OSError, socket.timeout)):
            raise RuntimeError(
                f"bootstrap: workers failed to dial home within "
                f"{timeout}s") from e
        raise
    finally:
        listener.close()
    net._install()
    return net


import contextlib


@contextlib.contextmanager
def running(n_localities: int, pools: Optional[Dict[str, int]] = None,
            worker_pools: Optional[Dict[str, int]] = None,
            timeout: float = 120.0,
            config: Optional[_pp.NetConfig] = None):
    """Leak-proof bootstrap: ``with net.running(3) as n: ...`` guarantees
    worker-process teardown even when the body raises — a failing
    multi-locality test cannot strand processes and poison later tests.
    (``bootstrap`` itself already reaps workers on handshake failure; this
    covers everything *after* a successful bootstrap.)"""
    net = bootstrap(n_localities, pools=pools, worker_pools=worker_pools,
                    timeout=timeout, config=config)
    try:
        yield net
    finally:
        net.shutdown()


def _worker_main(locality_id: int, n_localities: int, port: int,
                 pools: Optional[Dict[str, int]],
                 config: Optional[_pp.NetConfig] = None) -> None:
    """Entry point of a worker locality (runs in the spawned process)."""
    from repro.core import agas as agas_mod

    agas_mod.set_default_locality(locality_id)
    import repro.core as core

    core.init(pools=dict(pools) if pools else {"default": 2, "io": 1})
    net = NetRuntime(locality_id, n_localities, config=config)
    nlanes = 1 + max(0, net.config.stripes)
    socks: List[socket.socket] = []
    for lane in range(nlanes):
        sock = socket.create_connection(("127.0.0.1", port), timeout=60)
        sock.settimeout(None)  # connect timeout only — idle wire is healthy
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        # HELLO must be the first frame on each socket: send it raw, before
        # the port owns the socket, so the root's handshake read can't race;
        # it also tells the root which lane slot this socket fills.
        for chunk in _pp.encode_frame({"t": _pp.HELLO, "src": locality_id,
                                       "dst": ROOT, "seq": 0, "lane": lane,
                                       "nl": nlanes}):
            sock.sendall(chunk)
        socks.append(sock)
    net._conns[ROOT] = net._port.add_channel(ROOT, socks)
    net._install()
    net._stop.wait()
    net.shutdown()
    core.finalize()
    os._exit(0)  # skip atexit: daemon threads are already winding down
