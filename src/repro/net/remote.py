"""Remote invocation over the distributed AGAS tier (HPX §2.2–2.3).

Resolution is two-tier, exactly the paper's AGAS split:

- **root table** (locality 0, authoritative): GID → (owner locality,
  generation), plus the symbolic-name index.  Fed by the AGAS hook every
  locality installs at bootstrap — each ``register`` / ``rebind`` /
  ``unregister`` publishes.
- **per-locality resolution cache**: owner placements learned from root
  lookups.  *Generation-based invalidation*: a parcel landing at a
  locality that no longer holds the object comes back as
  :class:`~repro.net.locality.UnknownGid`; the caller drops its cached
  placement, re-resolves through the root (whose entry carries a strictly
  newer generation after any migration) and retries.  Steady-state
  dispatch therefore costs zero extra messages — the HPX+LCI lens — while
  migration pays one extra round trip only on first touch.

``apply_remote(action, gid, *args) -> Future`` is the user surface:
one-sided, asynchronous, locality-transparent — and what
``repro.core.parcel.apply`` delegates to (via the installed route) when a
target does not resolve locally, so existing call sites gain multi-process
reach without a spelling change.

Cross-process migration (:func:`migrate_remote`) moves the *object*:
host-snapshot at the owner, ``AGAS.adopt`` under the same GID at the
destination with a bumped generation (publishing the new owner), then
unregister at the source (a conditional unpublish that cannot erase the
new owner's entry).  ``repro.core.migration`` keeps working unchanged for
intra-process placement moves; this is the inter-process tier above it.
"""

from __future__ import annotations

import threading
import time
from typing import Any, Callable, Dict, Optional, Tuple, Union

from repro.core import agas as _agas
from repro.core import counters as _counters
from repro.core import parcel as _parcel
from repro.core.future import Future, Promise
from repro.net.locality import (
    ROOT,
    Locality,
    NetRuntime,
    UnknownGid,
    _gid_key,
    current,
    require,
)

_MAX_ATTEMPTS = 6
_RETRY_DELAY = 0.08  # backoff base once staleness repeats (mid-migration)

_Target = Union[_agas.GID, str]


def _locality_id(loc: Union[int, Locality]) -> int:
    return loc.id if isinstance(loc, Locality) else int(loc)


def _action_name(fn: Union[str, Callable[..., Any]]) -> str:
    if isinstance(fn, str):
        return fn
    name = getattr(fn, "_action_name", None)
    return name or _parcel._registry.register(fn)


# ------------------------------------------------------- root-table actions
@_parcel.action
def _root_publish(rt: NetRuntime, key, owner: int, generation: int,
                  name: Optional[str]) -> int:
    return rt.publish_local(tuple(key), owner, generation, name)


@_parcel.action
def _root_unpublish(rt: NetRuntime, key, owner: int) -> bool:
    return rt.unpublish_local(tuple(key), owner)


@_parcel.action
def _root_lookup(rt: NetRuntime, key) -> Tuple[int, int]:
    return rt.lookup_local(tuple(key))


@_parcel.action
def _root_lookup_name(rt: NetRuntime, name: str):
    return list(rt.lookup_name_local(name))


@_parcel.action
def _counters_query(rt: NetRuntime, pattern: str):
    return _counters.default().query(pattern)


@_parcel.action
def _counters_stats(rt: NetRuntime, pattern: str):
    return _counters.default().snapshot_stats(pattern)


@_parcel.action
def _counters_export(rt: NetRuntime, pattern: str):
    return _counters.default().snapshot_export(pattern)


@_parcel.action
def _echo(rt: NetRuntime, value: Any) -> Any:
    """Round-trip probe (latency benchmarks, liveness checks)."""
    return value


@_parcel.action
def _slow_sink(rt: NetRuntime, value: Any, delay_s: float = 0.0) -> int:
    """Deliberately slow consumer: holds its executed parcel for
    ``delay_s`` before acking.  Because CREDIT is returned only after
    execution, flooding this action keeps the sender's budget pinned —
    the probe the backpressure tests and the flood benchmark drive."""
    if delay_s > 0:
        time.sleep(delay_s)
    return len(value) if hasattr(value, "__len__") else 0


@_parcel.action
def _record_meta(rt: NetRuntime, key) -> Dict[str, Any]:
    a = _agas.default()
    gid = _agas.GID(*key)
    if not a.contains(gid):
        raise UnknownGid(tuple(key), rt.locality)
    rec = a.record(gid)
    return {"gid": list(key), "name": rec.name, "generation": rec.generation}


@_parcel.action
def _host_snapshot(obj: Any) -> Any:
    """Object-targeted: ship a host copy of the resolved object's state."""
    import sys

    jax = sys.modules.get("jax")
    return jax.device_get(obj) if jax is not None else obj


@_parcel.action
def _install_state(rt: NetRuntime, name: Optional[str], state: Any):
    """Register (or rebind) ``state`` at this locality; returns the GID key.

    The restore half of by-GID checkpointing: a fresh locality adopts a
    saved object's state under its old symbolic name."""
    a = _agas.default()
    if name is not None and a.contains(name):
        gid = a.gid_of(name)
        a.rebind(gid, state)
        return list(_gid_key(gid))
    return list(_gid_key(a.register(state, name=name)))


@_parcel.action
def _migrate_in(rt: NetRuntime, key, state: Any, name: Optional[str],
                generation: int) -> int:
    rec = _agas.default().adopt(_agas.GID(*key), state, name=name,
                                generation=generation)
    return rec.generation


@_parcel.action
def _migrate_out(rt: NetRuntime, key, dest: int) -> int:
    """Runs at the owner: push the object to ``dest``, then drop it here.

    Ordering is the correctness story: (1) dest holds the object under the
    same GID with generation+1, (2) dest's adopt published the new owner
    to the root, (3) only then does the source unregister (its conditional
    unpublish is a no-op — the root already points at dest).  A resolve
    racing this lands either at the old owner while the object is still
    there, or misses and re-resolves to dest; never in a gap."""
    a = _agas.default()
    gid = _agas.GID(*key)
    if not a.contains(gid):
        raise UnknownGid(tuple(key), rt.locality)
    rec = a.record(gid)
    state = _host_snapshot(rec.obj)
    gen = rt.send_parcel(dest, _MIGRATE_IN_NAME, None,
                         (list(key), state, rec.name, rec.generation + 1)
                         ).get(timeout=120)
    a.unregister(gid)
    rt.cache_invalidate(tuple(key))
    return gen


# Wire names the locality layer references without importing the functions.
ROOT_PUBLISH = _root_publish._action_name
ROOT_UNPUBLISH = _root_unpublish._action_name
_MIGRATE_IN_NAME = _migrate_in._action_name


# -------------------------------------------------------------- resolution
def _resolve_owner(net: NetRuntime, target: _Target,
                   refresh: bool = False) -> Tuple[int, Tuple[int, int]]:
    """Target → (owner locality, GID key); local AGAS wins, then the cache,
    then the root (``refresh=True`` skips the cache — the retry path)."""
    a = _agas.default()
    if isinstance(target, str):
        if a.contains(target):
            return net.locality, _gid_key(a.gid_of(target))
        key = None if refresh else net.name_cache_get(target)
        if key is None:
            if net.is_root():
                key = tuple(net.lookup_name_local(target))
            else:
                key = tuple(net.send_parcel(
                    ROOT, _root_lookup_name._action_name, None,
                    (target,)).get(timeout=60))
            net.name_cache_put(target, key)
    else:
        key = _gid_key(target)
        if a.contains(target):
            return net.locality, key
    if not refresh:
        hit = net.cache_get(key)
        if hit is not None:
            return hit[0], key
    if net.is_root():
        owner, gen = net.lookup_local(key)
    else:
        owner, gen = net.send_parcel(
            ROOT, _root_lookup._action_name, None,
            (list(key),)).get(timeout=60)
    net.c_root_lookups.increment()
    net.cache_put(key, owner, gen)
    return owner, key


# ------------------------------------------------------------ apply_remote
def apply_remote(fn: Union[str, Callable[..., Any]], target: _Target,
                 *args: Any, **kwargs: Any) -> Future:
    """``hpx::async(action, gid, args...)`` across localities.

    Resolves ``target`` (GID or symbolic name) through the distributed
    AGAS tier, ships the invocation to the owning locality, and returns a
    Future completed by the result frame.  Stale cached placements
    (object migrated since the last resolve) self-heal: up to
    ``_MAX_ATTEMPTS`` re-resolve-and-retry rounds through the root.
    ``fn`` must be a module-level function (workers resolve it by dotted
    name, importing the defining module on first use)."""
    net = require()
    return _apply_remote_named(net, _action_name(fn), target, args, kwargs)


def _apply_remote_named(net: NetRuntime, action_name: str, target: _Target,
                        args: Tuple[Any, ...],
                        kwargs: Dict[str, Any]) -> Future:
    promise: Promise = Promise()

    def attempt(n: int) -> None:
        try:
            owner, key = _resolve_owner(net, target, refresh=n > 0)
            fut = net.send_parcel(owner, action_name, key, args, kwargs)
        except BaseException as e:  # noqa: BLE001 — resolution failed
            promise.set_exception(e)
            return

        def done(f: Future) -> None:
            exc = f.exception()
            if isinstance(exc, UnknownGid) and n + 1 < _MAX_ATTEMPTS:
                net.cache_invalidate(key)
                net.c_stale.increment()
                if n == 0:  # ordinary stale cache: re-resolve immediately
                    net._exec.post(attempt, n + 1)
                else:
                    # repeated misses mean the object is mid-cutover (live
                    # migration closed the source before the destination
                    # adopted): exponential backoff stretches the retry
                    # budget across the whole transfer window
                    timer = threading.Timer(_RETRY_DELAY * (2 ** (n - 1)),
                                            net._exec.post, (attempt, n + 1))
                    timer.daemon = True
                    timer.start()
            else:
                promise.set_from(f)

        fut.on_ready(done)

    net._exec.post(attempt, 0)
    return promise.future()


def route_parcel(net: NetRuntime, p: _parcel.Parcel) -> Optional[Future]:
    """The hook :mod:`repro.core.parcel` calls for locally-unresolvable
    targets — makes plain ``parcel.apply`` locality-transparent."""
    return _apply_remote_named(net, p.action_name, p.target, p.args,
                               dict(p.kwargs))


def run_on(locality: Union[int, Locality], fn: Union[str, Callable[..., Any]],
           *args: Any, **kwargs: Any) -> Future:
    """Run a module-level function *at* a locality (target = its runtime).

    The remote first argument is the destination's :class:`NetRuntime` —
    the idiom for control-plane work (spawn an engine, probe counters)."""
    net = require()
    return net.send_parcel(_locality_id(locality), _action_name(fn), None,
                           args, kwargs)


# ------------------------------------------------------------ conveniences
def owner_of(target: _Target) -> int:
    """The locality that currently holds ``target`` (root-fresh when the
    local cache is cold; may be one migration stale otherwise — parcel
    dispatch self-heals, this is for placement *reporting*)."""
    net = require()
    owner, _key = _resolve_owner(net, target)
    return owner


def _counter_sweep(localities, action, local_read, pattern: str,
                   timeout: float) -> Dict[int, Any]:
    """Fan a counter read out to many localities at once and survive any of
    them dying mid-sweep: a dead peer contributes ``{"error": "..."}``
    instead of poisoning the whole read.  The fleet controller keeps
    steering through a failure precisely because this never raises."""
    net = require()
    if localities is None:
        ids = net.live_ids()
    else:
        ids = [_locality_id(loc) for loc in localities]
    futures: Dict[int, Any] = {}
    out: Dict[int, Any] = {}
    for lid in ids:
        if lid == net.locality:
            continue
        try:
            futures[lid] = run_on(lid, action, pattern)
        except BaseException as e:  # noqa: BLE001 — no route: mark, move on
            out[lid] = {"error": repr(e)}
    for lid in ids:
        if lid == net.locality:
            try:
                out[lid] = local_read(pattern)
            except BaseException as e:  # noqa: BLE001
                out[lid] = {"error": repr(e)}
        elif lid in futures:
            try:
                out[lid] = futures[lid].get(timeout=timeout)
            except BaseException as e:  # noqa: BLE001 — died mid-sweep
                out[lid] = {"error": repr(e)}
    return out


def query_counters(locality: Union[int, Locality, list, None],
                   pattern: str = "*", timeout: float = 60.0):
    """Read performance counters over the parcelport (paper §2.4: counters
    are readable from any locality *via AGAS*).

    A single locality returns its ``[(name, value), ...]`` pairs (raising
    if it is unreachable — the strict spelling).  ``None`` (every live
    locality) or a list sweeps in parallel and returns
    ``{locality: pairs | {"error": ...}}`` — a peer dying mid-sweep yields
    an error marker, never an exception, so control loops keep working
    through a failure."""
    if locality is None or isinstance(locality, (list, tuple)):
        return _counter_sweep(locality, _counters_query,
                              _counters.default().query, pattern, timeout)
    net = require()
    lid = _locality_id(locality)
    if lid == net.locality:
        return _counters.default().query(pattern)
    return run_on(lid, _counters_query, pattern).get(timeout=timeout)


def query_counter_stats(locality: Union[int, Locality, list, None],
                        pattern: str = "*", timeout: float = 60.0):
    """Full per-counter statistics: timers and histograms keep
    mean/max/p50/p95/p99 instead of collapsing to one scalar — what
    ``--print-counters`` and the fleet sampler report.  Same single-vs-sweep
    contract as :func:`query_counters` (sweeps tolerate dead peers)."""
    if locality is None or isinstance(locality, (list, tuple)):
        return _counter_sweep(locality, _counters_stats,
                              _counters.default().snapshot_stats,
                              pattern, timeout)
    net = require()
    lid = _locality_id(locality)
    if lid == net.locality:
        return _counters.default().snapshot_stats(pattern)
    return run_on(lid, _counters_stats, pattern).get(timeout=timeout)


def query_counter_export(locality: Union[int, Locality, list, None],
                         pattern: str = "*", timeout: float = 60.0):
    """Typed export records (kind + histogram buckets) — the read the
    OpenMetrics ``/metrics`` endpoint fans out on every scrape.  Same
    single-vs-sweep contract as :func:`query_counters` (sweeps tolerate a
    locality dying mid-scrape: it contributes an ``{"error": ...}``
    marker, which the exposition renders as ``repro_up 0``)."""
    if locality is None or isinstance(locality, (list, tuple)):
        return _counter_sweep(locality, _counters_export,
                              _counters.default().snapshot_export,
                              pattern, timeout)
    net = require()
    lid = _locality_id(locality)
    if lid == net.locality:
        return _counters.default().snapshot_export(pattern)
    return run_on(lid, _counters_export, pattern).get(timeout=timeout)


def fetch(target: _Target, timeout: float = 120.0) -> Any:
    """Host-side snapshot of a (possibly remote) AGAS object's state."""
    return apply_remote(_host_snapshot, target).get(timeout=timeout)


def describe(target: _Target, timeout: float = 60.0) -> Dict[str, Any]:
    """The owner's record metadata (``gid`` key, symbolic name,
    generation) for a possibly-remote AGAS object — the public API by-GID
    checkpointing uses to stamp ``agas.json`` so a respawn keeps the
    object's identity.  Resolution is cached, so a following ``fetch``
    goes straight to the owner."""
    net = require()
    owner, key = _resolve_owner(net, target)
    if owner == net.locality:
        rec = _agas.default().record(_agas.GID(*key))
        return {"gid": list(key), "name": rec.name,
                "generation": rec.generation}
    return run_on(owner, _record_meta, list(key)).get(timeout=timeout)


def migrate_remote(target: _Target, dest: Union[int, Locality],
                   timeout: float = 120.0) -> int:
    """Move an AGAS object to another locality; its GID stays valid.

    Returns the new generation.  Concurrent resolvers never observe a gap:
    they either reach the old owner pre-unregister or retry through the
    root to the new one (see :func:`_migrate_out`)."""
    net = require()
    dest_id = _locality_id(dest)
    last: Optional[BaseException] = None
    for attempt in range(_MAX_ATTEMPTS):
        owner, key = _resolve_owner(net, target, refresh=attempt > 0)
        if owner == dest_id:
            if net.is_root():
                return net.lookup_local(key)[1]
            return net.send_parcel(ROOT, _root_lookup._action_name, None,
                                   (list(key),)).get(timeout=60)[1]
        try:
            gen = run_on(owner, _migrate_out, list(key),
                         dest_id).get(timeout=timeout)
        except UnknownGid as e:  # owner moved under us — re-resolve
            net.cache_invalidate(key)
            last = e
            continue
        net.cache_invalidate(key)
        return gen
    raise last if last is not None else RuntimeError("migrate_remote failed")
