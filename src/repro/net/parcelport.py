"""Parcelport — the byte-moving layer of the multi-locality runtime.

HPX's parcelport is the pluggable transport that ships serialized parcels
between localities (the HPX+LCI study in PAPERS.md shows this layer is the
decisive factor for AMT scalability).  Ours moves length-prefixed frames
over stream sockets between OS processes on one host:

    frame := u32 total | u32 header_len | header | body | buffer*      (BE)

- **header** — small msgpack map (pickle fallback when msgpack is absent):
  frame type (``parcel`` / ``result`` / ``hello`` / ``bye``), source and
  destination locality ids, a sequence number correlating results to
  pending promises, the action name + target GID for parcels, and the
  lengths of the out-of-band buffers.
- **body** — pickle protocol 5 of the payload (``(args, kwargs)`` for a
  parcel, the value or exception for a result) with ``buffer_callback``
  extracting every contiguous array buffer *out of band*.
- **buffers** — the raw array bytes, written straight from the source
  buffers (no copy into the pickle stream) and, on receive, reconstructed
  from memoryview slices of the single frame read (no copy out of it).
  This is the zero-copy fast path for host ``numpy`` / ``jax.Array``
  payloads — the C++ runtime's zero-copy serialization [Biddiscombe et
  al. 2017] at the pickle5 level.

Each :class:`Connection` runs a *send pump* (queue + writer thread: action
workers never block on socket writes, frames never interleave) and a
*receive pump* (reader thread that reassembles frames and hands them to
the runtime, which posts parcel execution into the scheduler's "io" pool).

Counters, per connection (HPX ``/parcelport{...}`` naming)::

    /net{locality#L/peer#P}/parcels/sent        cumulative
    /net{locality#L/peer#P}/parcels/received    cumulative
    /net{locality#L/peer#P}/bytes/sent          cumulative
    /net{locality#L/peer#P}/bytes/received      cumulative
"""

from __future__ import annotations

import collections
import io
import pickle
import socket
import struct
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core import counters as _counters
from repro.obs import trace as _trace

try:  # msgpack headers when available (smaller + faster), pickle otherwise
    import msgpack as _msgpack
except ImportError:  # pragma: no cover - container ships msgpack
    _msgpack = None

_U32 = struct.Struct(">I")

# Frame types
PARCEL = "parcel"
RESULT = "result"
HELLO = "hello"
BYE = "bye"

_NO_PAYLOAD = object()


class PortClosed(ConnectionError):
    """The peer went away (EOF / reset) or the port was closed locally."""


# ------------------------------------------------------------------- codec
def _encode_header(header: Dict[str, Any]) -> bytes:
    if _msgpack is not None:
        return _msgpack.packb(header, use_bin_type=True)
    return pickle.dumps(header, protocol=5)


def _decode_header(data: bytes) -> Dict[str, Any]:
    if _msgpack is not None:
        return _msgpack.unpackb(data, raw=False)
    return pickle.loads(data)


def _to_host(obj: Any) -> Any:
    """Swap ``jax.Array`` leaves for host numpy views ahead of pickling.

    ``np.asarray`` on a committed CPU ``jax.Array`` aliases the device
    buffer (no copy); numpy arrays then serialize out-of-band via pickle5.
    Only walks containers when jax is already imported — light processes
    never pay the import.
    """
    import sys

    jax = sys.modules.get("jax")
    if jax is None:
        return obj

    import numpy as np

    def walk(x: Any) -> Any:
        if isinstance(x, jax.Array):
            return np.asarray(x)
        if isinstance(x, tuple) and hasattr(x, "_fields"):  # NamedTuple
            return type(x)(*(walk(v) for v in x))
        if isinstance(x, (list, tuple)):
            return type(x)(walk(v) for v in x)
        if isinstance(x, dict):
            return {k: walk(v) for k, v in x.items()}
        return x

    return walk(obj)


def encode_frame(header: Dict[str, Any], payload: Any = _NO_PAYLOAD) -> List[Any]:
    """Serialize one frame into a chunk list ready for vectored send.

    The returned chunks are ``[prefix+header+body, buffer0, buffer1, ...]``
    where each buffer is a zero-copy view of the original array memory.
    """
    buffers: List[pickle.PickleBuffer] = []
    if payload is _NO_PAYLOAD:
        body = b""
    else:
        body = pickle.dumps(_to_host(payload), protocol=5,
                            buffer_callback=buffers.append)
    views = [b.raw() for b in buffers]
    header = dict(header)
    header["blens"] = [v.nbytes for v in views]
    header["bodylen"] = len(body)
    hdr = _encode_header(header)
    total = 4 + len(hdr) + len(body) + sum(v.nbytes for v in views)
    head = io.BytesIO()
    head.write(_U32.pack(total))
    head.write(_U32.pack(len(hdr)))
    head.write(hdr)
    head.write(body)
    return [head.getvalue(), *views]


def decode_frame(frame: memoryview) -> Tuple[Dict[str, Any], memoryview]:
    """Split a received frame (sans the u32 total prefix) into
    ``(header, rest)`` where ``rest`` covers body+buffers."""
    hlen = _U32.unpack_from(frame, 0)[0]
    header = _decode_header(bytes(frame[4:4 + hlen]))
    return header, frame[4 + hlen:]


def frame_rest(frame: memoryview) -> memoryview:
    """Body+buffers view of a frame whose header was already decoded."""
    hlen = _U32.unpack_from(frame, 0)[0]
    return frame[4 + hlen:]


def forward_chunks(frame: memoryview) -> List[Any]:
    """Re-frame a received frame for forwarding (root → worker switch):
    the payload bytes are never re-encoded, just re-prefixed."""
    return [_U32.pack(frame.nbytes), frame]


def decode_payload(header: Dict[str, Any], rest: memoryview) -> Any:
    """Unpickle the body against in-place buffer views (zero-copy)."""
    bodylen = header.get("bodylen", 0)
    if not bodylen:
        return None
    body = rest[:bodylen]
    bufs, off = [], bodylen
    for n in header.get("blens", ()):
        bufs.append(rest[off:off + n])
        off += n
    return pickle.loads(body, buffers=bufs)


def encode_result_payload(header: Dict[str, Any], value: Any,
                          exc: Optional[BaseException]) -> List[Any]:
    """Encode a result frame, degrading unpicklable values/exceptions to a
    picklable ``RuntimeError`` so the caller always gets *an* outcome."""
    header = dict(header)
    header["ok"] = exc is None
    payload = value if exc is None else exc
    try:
        return encode_frame(header, payload)
    except Exception as e:  # noqa: BLE001 — unpicklable result
        header["ok"] = False
        return encode_frame(header, RuntimeError(
            f"unpicklable {'result' if exc is None else 'exception'} "
            f"from action {header.get('a')!r}: {payload!r} ({e})"))


# -------------------------------------------------------------- connection
def read_exact(sock: socket.socket, n: int) -> bytearray:
    buf = bytearray(n)
    view = memoryview(buf)
    got = 0
    while got < n:
        k = sock.recv_into(view[got:], n - got)
        if k == 0:
            raise PortClosed("peer closed the connection")
        got += k
    return buf


def read_frame(sock: socket.socket) -> memoryview:
    """Blocking read of one length-prefixed frame (without the prefix)."""
    total = _U32.unpack(bytes(read_exact(sock, 4)))[0]
    return memoryview(read_exact(sock, total))


class Connection:
    """One socket to one peer locality: send pump + receive pump.

    ``on_frame(header, frame, conn)`` runs on the receive-pump thread; it
    must stay cheap (the runtime posts parcel execution into the
    scheduler's "io" pool and completes result promises inline).
    """

    def __init__(self, sock: socket.socket, local_id: int, peer_id: int,
                 on_frame: Callable[[Dict[str, Any], memoryview, "Connection"], None],
                 on_close: Optional[Callable[["Connection"], None]] = None):
        self.sock = sock
        self.local_id = local_id
        self.peer_id = peer_id
        self._on_frame = on_frame
        self._on_close = on_close
        self._closed = False
        self._sendq: "collections.deque[List[Any]]" = collections.deque()
        self._send_cv = threading.Condition()

        reg = _counters.default()
        p = f"/net{{locality#{local_id}/peer#{peer_id}}}"
        self.c_parcels_sent = reg.counter(f"{p}/parcels/sent")
        self.c_parcels_recv = reg.counter(f"{p}/parcels/received")
        self.c_bytes_sent = reg.counter(f"{p}/bytes/sent")
        self.c_bytes_recv = reg.counter(f"{p}/bytes/received")

        self._sender = threading.Thread(
            target=self._send_pump, daemon=True,
            name=f"repro-net-{local_id}-send-{peer_id}")
        self._receiver = threading.Thread(
            target=self._recv_pump, daemon=True,
            name=f"repro-net-{local_id}-recv-{peer_id}")
        self._sender.start()
        self._receiver.start()

    # ----------------------------------------------------------------- send
    def send(self, header: Dict[str, Any], payload: Any = _NO_PAYLOAD) -> None:
        self.send_chunks(encode_frame(header, payload))

    def send_chunks(self, chunks: List[Any]) -> None:
        """Enqueue pre-encoded chunks (also the root's forwarding path)."""
        with self._send_cv:
            if self._closed:
                raise PortClosed(f"connection to locality#{self.peer_id} closed")
            self._sendq.append(chunks)
            self._send_cv.notify()

    def _send_pump(self) -> None:
        while True:
            with self._send_cv:
                while not self._sendq and not self._closed:
                    self._send_cv.wait()
                if self._closed and not self._sendq:
                    return
                chunks = self._sendq.popleft()
            try:
                t0 = time.perf_counter() if _trace._enabled else 0.0
                n = 0
                for c in chunks:
                    self.sock.sendall(c)
                    n += len(c) if isinstance(c, (bytes, bytearray)) else c.nbytes
                self.c_parcels_sent.increment()
                self.c_bytes_sent.increment(n)
                if _trace._enabled:
                    _trace.complete("wire/send", "net", t0,
                                    bytes=n, peer=self.peer_id)
            except OSError:
                self._shutdown()
                return

    # -------------------------------------------------------------- receive
    def _recv_pump(self) -> None:
        while True:
            try:
                frame = read_frame(self.sock)
            except (OSError, PortClosed):
                self._shutdown()
                return
            self.c_parcels_recv.increment()
            self.c_bytes_recv.increment(4 + frame.nbytes)
            if _trace._enabled:
                _trace.instant("wire/recv", "net",
                               bytes=4 + frame.nbytes, peer=self.peer_id)
            try:
                header, _rest = decode_frame(frame)
                self._on_frame(header, frame, self)
            except Exception:  # noqa: BLE001 — a bad frame must not kill the pump
                import traceback

                traceback.print_exc()

    # ----------------------------------------------------------------- close
    def _shutdown(self) -> None:
        with self._send_cv:
            already = self._closed
            self._closed = True
            self._send_cv.notify_all()
        if already:
            return
        try:
            self.sock.close()
        except OSError:
            pass
        if self._on_close is not None:
            self._on_close(self)

    def close(self) -> None:
        self._shutdown()

    @property
    def closed(self) -> bool:
        return self._closed
