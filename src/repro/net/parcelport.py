"""Parcelport — the tiered byte-moving layer of the multi-locality runtime.

HPX's parcelport is the pluggable transport that ships serialized parcels
between localities; the HPX+LCI study (Yan et al., PAPERS.md) identifies
what AMT traffic needs from this layer — small-message aggregation,
protocol separation by payload size, dedicated progress resources, and
explicit flow control.  This module implements all four over stream
sockets between OS processes on one host:

- **eager protocol** (payloads under ``NetConfig.eager_threshold``) —
  the whole frame ships inline on the peer's *priority lane* and is
  **coalesced**: sub-threshold frames queued while the short adaptive
  window is open are packed into one multi-parcel container frame,
  flushed by size, parcel count, or deadline.  The first frame after a
  quiet period always goes out immediately, so coalescing adds no
  latency at low rates and amortizes syscalls at high rates.
- **rendezvous protocol** (large payloads) — a small RTS (request to
  send) control frame travels the priority lane; the receiver allocates
  an assembly buffer and grants a CTS; the sender then **stripes** the
  body+buffer byte stream across the N parallel *bulk lanes* in
  ``stripe_chunk``-sized DATA frames.  Bulk bytes never touch the
  priority lane, so one big ``fetch``/``migrate_remote`` cannot
  head-of-line-block latency-sensitive parcels.  The receiver bounds
  concurrent assemblies per sender (``max_rendezvous``) — rendezvous is
  its own flow control.
- **explicit backpressure** (eager parcels) — a per-destination ledger
  of parcel bytes in flight, replenished by CREDIT frames the receiver
  returns *after executing* each parcel.  Once ``send_budget`` is
  exhausted, producer threads block in ``send`` (never the scheduler
  pools or the progress thread, which defer to a FIFO instead), so a
  flooded peer degrades its senders instead of growing queues without
  bound.
- **one progress thread per port** — every socket is non-blocking and
  multiplexed through one readiness loop (``selectors``) per
  :class:`Port`, replacing the previous 2-threads-per-connection pump
  design.  Producers attempt a lock-guarded direct write when a lane is
  idle (no wakeup latency on the common path); the progress thread
  finishes partial writes, runs the receive state machines, the
  coalesce timers, and the rendezvous handshakes.

Wire format (unchanged framing, new frame types)::

    frame := u32 total | u32 header_len | header | rest          (BE)

- ``parcel`` / ``result`` — rest is pickle-5 body + out-of-band buffers
  (the zero-copy path [Biddiscombe et al. 2017]: contiguous array bytes
  never enter the pickle stream on either side).
- ``multi``  — rest is a concatenation of complete sub-frames (each with
  its own u32 prefix); src/dst are uniform, so the root's frame switch
  forwards whole containers without unpacking them.
- ``rts`` / ``cts`` / ``data`` — the rendezvous handshake; a DATA frame's
  rest is a raw window of the payload stream (``o``/``n`` offsets), read
  on the receive side *directly into* the preallocated assembly buffer.
- ``credit`` — returns ``n`` budget bytes to the original sender
  (end-to-end: forwarded through the root for worker↔worker traffic).
- ``hello`` / ``bye`` / ``down`` — lifecycle: per-lane handshake,
  shutdown, and the root's peer-death broadcast.

Counters, per channel (HPX ``/parcelport{...}`` naming)::

    /net{locality#L/peer#P}/parcels/sent|received    logical messages
    /net{locality#L/peer#P}/frames/sent|received     wire frames
    /net{locality#L/peer#P}/bytes/sent|received      wire bytes
    /net{locality#L/peer#P}/coalesce/flushes         multi containers sent
    /net{locality#L/peer#P}/coalesce/parcels         frames packed into them
    /net{locality#L/peer#P}/rendezvous/sent|received completed transfers
    /net{locality#L/peer#P}/credit/blocked|deferred  backpressure events
    /net{locality#L/peer#P}/credit/inflight_bytes    gauge, unacked bytes
"""

from __future__ import annotations

import collections
import os
import pickle
import selectors
import socket
import struct
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core import counters as _counters
from repro.obs import trace as _trace

try:  # msgpack headers when available (smaller + faster), pickle otherwise
    import msgpack as _msgpack
except ImportError:  # pragma: no cover - container ships msgpack
    _msgpack = None

_U32 = struct.Struct(">I")

# Frame types
PARCEL = "parcel"
RESULT = "result"
HELLO = "hello"
BYE = "bye"
MULTI = "multi"     # coalesced container of complete sub-frames
RTS = "rts"         # rendezvous: request to send (carries the real header)
CTS = "cts"         # rendezvous: clear to send (assembly allocated)
DATA = "data"       # rendezvous: one striped window of the payload stream
CREDIT = "credit"   # flow control: return budget bytes to the sender
DOWN = "down"       # root broadcast: a peer locality died
TOPO = "topo"       # root broadcast: the locality id space grew (elastic)

_NO_PAYLOAD = object()

# Frames the root's switch forwards by dst; HELLO/BYE/DOWN are hop-local.
_FORWARDABLE = frozenset((PARCEL, RESULT, MULTI, RTS, CTS, DATA, CREDIT))


class PortClosed(ConnectionError):
    """The peer went away (EOF / reset) or the port was closed locally."""


# ------------------------------------------------------------------- config
@dataclass(frozen=True)
class NetConfig:
    """Tuning knobs of the tiered transport (see README "NetConfig").

    Every field can be overridden through ``REPRO_NET_<FIELD>`` (upper
    case) environment variables, which also reach spawned worker
    localities (the root passes its resolved config to them verbatim).
    """

    eager_threshold: int = 64 * 1024    # payload bytes: eager vs rendezvous
    coalesce_max_bytes: int = 56 * 1024  # flush a container at this size
    coalesce_max_parcels: int = 128      # ... or at this many sub-frames
    coalesce_window_us: float = 300.0    # max added delay (adaptive upper)
    coalesce_min_window_us: float = 50.0
    stripes: int = 2                     # bulk lanes per peer (0 = share)
    stripe_chunk: int = 1 << 20          # bytes per DATA frame
    max_rendezvous: int = 4              # concurrent assemblies per sender
    send_budget: int = 1 << 20           # unacked eager parcel bytes / dst
    block_timeout: float = 120.0         # producer backpressure block cap

    @classmethod
    def from_env(cls) -> "NetConfig":
        kw: Dict[str, Any] = {}
        for name, f in cls.__dataclass_fields__.items():
            raw = os.environ.get(f"REPRO_NET_{name.upper()}")
            if raw is not None:
                kw[name] = (float(raw) if isinstance(f.default, float)
                            else int(float(raw)))
        return cls(**kw)


# ------------------------------------------------------------------- codec
def _encode_header(header: Dict[str, Any]) -> bytes:
    if _msgpack is not None:
        return _msgpack.packb(header, use_bin_type=True)
    return pickle.dumps(header, protocol=5)


def _decode_header(data: bytes) -> Dict[str, Any]:
    if _msgpack is not None:
        return _msgpack.unpackb(data, raw=False)
    return pickle.loads(data)


def _to_host(obj: Any) -> Any:
    """Swap ``jax.Array`` leaves for host numpy views ahead of pickling.

    ``np.asarray`` on a committed CPU ``jax.Array`` aliases the device
    buffer (no copy); numpy arrays then serialize out-of-band via pickle5.
    Only walks containers when jax is already imported — light processes
    never pay the import — and the walk is **identity-preserving**: when
    no ``jax.Array`` leaf is found, every container comes back ``is`` the
    original (nothing is rebuilt or deep-copied for array-free payloads).
    """
    import sys

    jax = sys.modules.get("jax")
    if jax is None:
        return obj

    import numpy as np

    def walk(x: Any) -> Any:
        if isinstance(x, jax.Array):
            return np.asarray(x)
        if isinstance(x, tuple) and hasattr(x, "_fields"):  # NamedTuple
            new = [walk(v) for v in x]
            if all(a is b for a, b in zip(new, x)):
                return x
            return type(x)(*new)
        if isinstance(x, (list, tuple)):
            new = [walk(v) for v in x]
            if all(a is b for a, b in zip(new, x)):
                return x
            return type(x)(new)
        if isinstance(x, dict):
            new = {k: walk(v) for k, v in x.items()}
            if all(a is b for a, b in zip(new.values(), x.values())):
                return x
            return new
        return x

    return walk(obj)


def _encode_body(payload: Any) -> Tuple[bytes, List[memoryview]]:
    """Pickle a payload with every contiguous array buffer out of band."""
    if payload is _NO_PAYLOAD:
        return b"", []
    buffers: List[pickle.PickleBuffer] = []
    body = pickle.dumps(_to_host(payload), protocol=5,
                        buffer_callback=buffers.append)
    return body, [b.raw() for b in buffers]


def _assemble(header: Dict[str, Any], body: bytes,
              views: List[memoryview]) -> List[Any]:
    """Build the chunk list of one complete frame (prefix included).

    The head chunk is one ``b"".join`` pass over preallocated pieces —
    no ``io.BytesIO`` copies — and each buffer view rides zero-copy.
    """
    header = dict(header)
    header["blens"] = [v.nbytes for v in views]
    header["bodylen"] = len(body)
    hdr = _encode_header(header)
    total = 4 + len(hdr) + len(body) + sum(v.nbytes for v in views)
    prefix = bytearray(8)
    _U32.pack_into(prefix, 0, total)
    _U32.pack_into(prefix, 4, len(hdr))
    return [b"".join((prefix, hdr, body)), *views]


def encode_frame(header: Dict[str, Any], payload: Any = _NO_PAYLOAD) -> List[Any]:
    """Serialize one eager frame into a chunk list ready for vectored
    send: ``[prefix+header+body, buffer0, buffer1, ...]`` where each
    buffer is a zero-copy view of the original array memory."""
    body, views = _encode_body(payload)
    return _assemble(header, body, views)


def _chunks_nbytes(chunks: List[Any]) -> int:
    return sum(len(c) if isinstance(c, (bytes, bytearray)) else c.nbytes
               for c in chunks)


def decode_frame(frame: memoryview) -> Tuple[Dict[str, Any], memoryview]:
    """Split a received frame (sans the u32 total prefix) into
    ``(header, rest)`` where ``rest`` covers body+buffers."""
    hlen = _U32.unpack_from(frame, 0)[0]
    header = _decode_header(bytes(frame[4:4 + hlen]))
    return header, frame[4 + hlen:]


def frame_rest(frame: memoryview) -> memoryview:
    """Body+buffers view of a frame whose header was already decoded."""
    hlen = _U32.unpack_from(frame, 0)[0]
    return frame[4 + hlen:]


def forward_chunks(frame: memoryview) -> List[Any]:
    """Re-frame a received frame for forwarding: the payload bytes are
    never re-encoded, just re-prefixed."""
    return [_U32.pack(frame.nbytes), frame]


def reframe(hbytes: bytes, rest: memoryview) -> List[Any]:
    """Forwarding path: rebuild the wire chunks of a parsed frame without
    re-encoding header or payload."""
    total = 4 + len(hbytes) + rest.nbytes
    return [b"".join((_U32.pack(total), _U32.pack(len(hbytes)), hbytes)), rest]


def iter_multi(header: Dict[str, Any], rest: memoryview):
    """Walk a MULTI container's rest: yields ``(sub_header, sub_hbytes,
    sub_rest, sub_wire_bytes)`` per packed sub-frame."""
    p = 0
    for _ in range(header.get("n", 0)):
        sublen = _U32.unpack_from(rest, p)[0]
        sub = rest[p + 4:p + 4 + sublen]
        hlen = _U32.unpack_from(sub, 0)[0]
        hbytes = bytes(sub[4:4 + hlen])
        yield _decode_header(hbytes), hbytes, sub[4 + hlen:], 4 + sublen
        p += 4 + sublen


def failed_parcel_headers(fr: "Frame"):
    """Every parcel header carried by a frame that could not be forwarded
    (the frame itself, a rendezvous announcement's inner header, or each
    sub-frame of a coalesced container)."""
    h = fr.header
    t = h.get("t")
    if t == PARCEL:
        yield h
    elif t == RTS:
        inner = h.get("h") or {}
        if inner.get("t") == PARCEL:
            yield inner
    elif t == MULTI:
        for shdr, _hb, _rest, _wire in iter_multi(h, fr.rest):
            if shdr.get("t") == PARCEL:
                yield shdr


def decode_payload(header: Dict[str, Any], rest: memoryview) -> Any:
    """Unpickle the body against in-place buffer views (zero-copy)."""
    bodylen = header.get("bodylen", 0)
    if not bodylen:
        return None
    body = rest[:bodylen]
    bufs, off = [], bodylen
    for n in header.get("blens", ()):
        bufs.append(rest[off:off + n])
        off += n
    return pickle.loads(body, buffers=bufs)


def encode_result_payload(header: Dict[str, Any], value: Any,
                          exc: Optional[BaseException]) -> List[Any]:
    """Encode a result frame, degrading unpicklable values/exceptions to a
    picklable ``RuntimeError`` so the caller always gets *an* outcome."""
    header = dict(header)
    header["ok"] = exc is None
    payload = value if exc is None else exc
    try:
        return encode_frame(header, payload)
    except Exception as e:  # noqa: BLE001 — unpicklable result
        header["ok"] = False
        return encode_frame(header, RuntimeError(
            f"unpicklable {'result' if exc is None else 'exception'} "
            f"from action {header.get('a')!r}: {payload!r} ({e})"))


def _degrade_result(header: Dict[str, Any], payload: Any,
                    e: Exception) -> Tuple[Dict[str, Any], bytes, list]:
    kind = "result" if header.get("ok") else "exception"
    header = dict(header)
    header["ok"] = False
    body, views = _encode_body(RuntimeError(
        f"unpicklable {kind} from action {header.get('a')!r}: "
        f"{payload!r} ({e})"))
    return header, body, views


# ----------------------------------------------------- blocking-read helpers
def read_exact(sock: socket.socket, n: int) -> bytearray:
    buf = bytearray(n)
    view = memoryview(buf)
    got = 0
    while got < n:
        k = sock.recv_into(view[got:], n - got)
        if k == 0:
            raise PortClosed("peer closed the connection")
        got += k
    return buf


def read_frame(sock: socket.socket) -> memoryview:
    """Blocking read of one length-prefixed frame (without the prefix) —
    used only for the bootstrap HELLO handshake, before a socket joins a
    port's readiness loop."""
    total = _U32.unpack(bytes(read_exact(sock, 4)))[0]
    return memoryview(read_exact(sock, total))


def _is_runtime_thread() -> bool:
    """True on scheduler pool workers and transport threads — the threads
    that must never block on backpressure (they are the drain)."""
    return threading.current_thread().name.startswith("repro-")


# ------------------------------------------------------------- wire structs
class Frame:
    """One parsed wire frame: decoded header + raw pieces for zero-copy
    forwarding (``hbytes``) and payload decode (``rest``)."""

    __slots__ = ("header", "hbytes", "rest", "wire_bytes", "credit_bytes")

    def __init__(self, header: Dict[str, Any], hbytes: bytes,
                 rest: memoryview, wire_bytes: int, credit_bytes: int):
        self.header = header
        self.hbytes = hbytes
        self.rest = rest
        self.wire_bytes = wire_bytes
        # bytes of send-budget this frame consumed at its sender; the
        # receiver returns exactly this as CREDIT after execution
        # (0 for rendezvous-assembled parcels — they never took credit)
        self.credit_bytes = credit_bytes


class _Ledger:
    """Per-destination eager-parcel flow control state (sender side)."""

    __slots__ = ("inflight", "deferred", "cv")

    def __init__(self, lock: threading.RLock):
        self.inflight = 0
        self.deferred: "collections.deque[Tuple[List[Any], int]]" = \
            collections.deque()
        self.cv = threading.Condition(lock)


class _Coalesce:
    """One open aggregation buffer (per destination locality)."""

    __slots__ = ("parts", "nbytes", "count", "deadline")

    def __init__(self, deadline: float):
        self.parts: List[List[Any]] = []
        self.nbytes = 0
        self.count = 0
        self.deadline = deadline


class _OutXfer:
    """Sender-side pending rendezvous: encoded stream parked until CTS."""

    __slots__ = ("xid", "dst", "stream", "size", "t0")

    def __init__(self, xid: int, dst: int, stream: List[memoryview],
                 size: int):
        self.xid = xid
        self.dst = dst
        self.stream = stream
        self.size = size
        self.t0 = 0.0  # RTS send time when tracing — the CTS-wait clock


class _InXfer:
    """Receiver-side assembly of one striped rendezvous transfer."""

    __slots__ = ("src", "xid", "header", "buf", "got", "size")

    def __init__(self, src: int, xid: int, header: Dict[str, Any],
                 size: int):
        self.src = src
        self.xid = xid
        self.header = header
        self.buf = bytearray(size)
        self.got = 0
        self.size = size


class _Lane:
    """One non-blocking socket of a channel: write queue + read machine."""

    __slots__ = ("sock", "idx", "channel", "wq", "wlock", "woff",
                 "want_write", "bytes_written", "bytes_read", "wstart",
                 "rscratch", "rlo", "rhi", "rphase", "rpre", "rpre_got",
                 "rhdr", "rhdr_got", "rheader", "rrest", "rrest_got",
                 "rrest_len", "rassembly", "rtotal")

    def __init__(self, sock: socket.socket, idx: int, channel: "Channel"):
        sock.setblocking(False)
        self.sock = sock
        self.idx = idx
        self.channel = channel
        self.wq: "collections.deque[List[Any]]" = collections.deque()
        self.wlock = threading.Lock()
        self.woff = 0            # byte offset into the head message
        self.want_write = False
        self.wstart = 0.0
        self.bytes_written = 0   # test-inspectable per-lane totals
        self.bytes_read = 0
        # read state machine
        self.rscratch = bytearray(1 << 17)
        self.rlo = self.rhi = 0
        self.rphase = 0          # 0 = prefix, 1 = header, 2 = rest
        self.rpre = bytearray(8)
        self.rpre_got = 0
        self.rhdr = b""
        self.rhdr_got = 0
        self.rheader: Optional[Dict[str, Any]] = None
        self.rrest: Optional[memoryview] = None
        self.rrest_got = 0
        self.rrest_len = 0
        self.rassembly: Optional[_InXfer] = None
        self.rtotal = 0


# --------------------------------------------------------------- the channel
class Channel:
    """All lanes to one peer: priority lane 0 + ``stripes`` bulk lanes.

    Holds the per-destination coalesce buffers and credit ledgers for
    every destination *routed through* this peer (a worker's single
    channel to the root carries traffic for all localities)."""

    def __init__(self, port: "Port", peer_id: int,
                 socks: List[socket.socket]):
        self.port = port
        self.peer_id = peer_id
        self.local_id = port.local_id
        self._closed = False
        self._lock = threading.RLock()
        self.lanes = [_Lane(s, i, self) for i, s in enumerate(socks)]
        self._bulk_rr = 0
        self._ledgers: Dict[int, _Ledger] = {}
        self._cbufs: Dict[int, _Coalesce] = {}
        self._last_flush: Dict[int, float] = {}
        self._window = port.config.coalesce_window_us * 1e-6

        reg = _counters.default()
        p = f"/net{{locality#{self.local_id}/peer#{peer_id}}}"
        self.c_parcels_sent = reg.counter(f"{p}/parcels/sent")
        self.c_parcels_recv = reg.counter(f"{p}/parcels/received")
        self.c_frames_sent = reg.counter(f"{p}/frames/sent")
        self.c_frames_recv = reg.counter(f"{p}/frames/received")
        self.c_bytes_sent = reg.counter(f"{p}/bytes/sent")
        self.c_bytes_recv = reg.counter(f"{p}/bytes/received")
        self.c_co_flushes = reg.counter(f"{p}/coalesce/flushes")
        self.c_co_parcels = reg.counter(f"{p}/coalesce/parcels")
        self.c_rdv_sent = reg.counter(f"{p}/rendezvous/sent")
        self.c_rdv_recv = reg.counter(f"{p}/rendezvous/received")
        self.c_blocked = reg.counter(f"{p}/credit/blocked")
        self.c_deferred = reg.counter(f"{p}/credit/deferred")
        self.g_inflight = reg.gauge(f"{p}/credit/inflight_bytes")

    # ------------------------------------------------------------ public api
    @property
    def closed(self) -> bool:
        return self._closed

    def close(self) -> None:
        self.port._close_channel(self)

    def send(self, header: Dict[str, Any], payload: Any = _NO_PAYLOAD,
             can_block: Optional[bool] = None) -> None:
        """Ship one logical frame, choosing the protocol tier.

        Small payloads go eager (coalescable; parcels consume credit and
        may block the calling thread under backpressure).  Large payloads
        go rendezvous: only a tiny RTS leaves here, the stream follows on
        the bulk lanes after the CTS."""
        if self._closed:
            raise PortClosed(f"connection to locality#{self.peer_id} closed")
        t = header.get("t")
        try:
            body, views = _encode_body(payload)
        except Exception as e:  # noqa: BLE001 — degrade results, raise else
            if t != RESULT:
                raise
            header, body, views = _degrade_result(header, payload, e)
        if t in (PARCEL, RESULT):
            self.c_parcels_sent.increment()
        size = len(body) + sum(v.nbytes for v in views)
        cfg = self.port.config
        if size >= cfg.eager_threshold and t in (PARCEL, RESULT):
            self._send_rendezvous(header, body, views, size)
            return
        chunks = _assemble(header, body, views)
        if can_block is None:
            can_block = not _is_runtime_thread()
        if t == PARCEL:
            if self._admit(header.get("dst", self.peer_id), chunks,
                           can_block):
                self._coalesce_or_send(header.get("dst", self.peer_id),
                                       chunks)
        elif t in (HELLO, BYE, DOWN):
            # lifecycle frames bypass coalescing (BYE flushes first so no
            # queued frame is stranded behind the goodbye)
            if t == BYE:
                with self._lock:
                    for dst in list(self._cbufs):
                        self._flush_locked(dst)
            self.enqueue(0, chunks)
        else:
            self._coalesce_or_send(header.get("dst", self.peer_id), chunks)

    def send_control(self, header: Dict[str, Any]) -> None:
        """Payload-free control frame (CREDIT/CTS/...): eager, coalescable,
        credit-exempt, never blocks."""
        self._coalesce_or_send(header.get("dst", self.peer_id),
                               _assemble(header, b"", []))

    # --------------------------------------------------------- backpressure
    def _ledger(self, dst: int) -> _Ledger:
        led = self._ledgers.get(dst)
        if led is None:
            led = self._ledgers.setdefault(dst, _Ledger(self._lock))
        return led

    def _admit(self, dst: int, chunks: List[Any], can_block: bool) -> bool:
        """Charge one eager parcel against the destination's send budget.

        Returns True when the frame may be sent now; False when it was
        parked on the deferred FIFO (drained by incoming CREDIT)."""
        nbytes = _chunks_nbytes(chunks)
        budget = self.port.config.send_budget
        led = self._ledger(dst)

        def over() -> bool:
            # a parcel bigger than the whole budget still goes — alone —
            # once the wire is quiet (otherwise it would block forever)
            return bool(led.deferred) or (
                led.inflight > 0 and led.inflight + nbytes > budget)

        with self._lock:
            if over() and not can_block:
                led.deferred.append((chunks, nbytes))
                self.c_deferred.increment()
                if _trace._enabled:
                    # Waiting (W): parcel parked on the deferred FIFO until
                    # CREDIT returns — visible contention, not lost time
                    _trace.instant("credit/defer", "net", dst=dst,
                                   bytes=nbytes)
                return False
            if over():
                self.c_blocked.increment()
                t_blk = time.perf_counter() if _trace._enabled else 0.0
                deadline = time.monotonic() + self.port.config.block_timeout
                while not self._closed and over():
                    remaining = deadline - time.monotonic()
                    if remaining <= 0:
                        raise PortClosed(
                            f"send to locality#{dst} blocked longer than "
                            f"{self.port.config.block_timeout}s by "
                            f"backpressure ({led.inflight} bytes unacked)")
                    led.cv.wait(timeout=min(remaining, 1.0))
                if t_blk:
                    # Waiting (W): the sender thread sat in cv.wait until
                    # enough CREDIT flowed back
                    _trace.complete("credit/block", "net", t_blk, dst=dst,
                                    bytes=nbytes)
            if self._closed:
                raise PortClosed(
                    f"connection to locality#{self.peer_id} closed")
            led.inflight += nbytes
            self.g_inflight.set(sum(l.inflight
                                    for l in self._ledgers.values()))
        return True

    def _on_credit(self, src: int, n: int) -> None:
        """CREDIT from ``src`` arrived: release budget, drain deferred."""
        budget = self.port.config.send_budget
        ready: List[List[Any]] = []
        with self._lock:
            led = self._ledger(src)
            led.inflight = max(0, led.inflight - n)
            while led.deferred and (
                    led.inflight == 0
                    or led.inflight + led.deferred[0][1] <= budget):
                chunks, nb = led.deferred.popleft()
                led.inflight += nb
                ready.append(chunks)
            self.g_inflight.set(sum(l.inflight
                                    for l in self._ledgers.values()))
            led.cv.notify_all()
        for chunks in ready:
            self._coalesce_or_send(src, chunks)

    def inflight_bytes(self, dst: Optional[int] = None) -> int:
        with self._lock:
            if dst is not None:
                return self._ledger(dst).inflight
            return sum(l.inflight for l in self._ledgers.values())

    # ----------------------------------------------------------- coalescing
    def _coalesce_or_send(self, dst: int, chunks: List[Any]) -> None:
        """Aggregation policy: first frame after a quiet period goes out
        immediately; frames inside the window pile into a container."""
        now = time.monotonic()
        created = False
        with self._lock:
            if self._closed:
                raise PortClosed(
                    f"connection to locality#{self.peer_id} closed")
            buf = self._cbufs.get(dst)
            if buf is None:
                if now - self._last_flush.get(dst, 0.0) >= self._window:
                    self._last_flush[dst] = now
                    self.enqueue(0, chunks)
                    return
                buf = self._cbufs[dst] = _Coalesce(now + self._window)
                created = True
            buf.parts.append(chunks)
            buf.count += 1
            buf.nbytes += _chunks_nbytes(chunks)
            cfg = self.port.config
            if (buf.nbytes >= cfg.coalesce_max_bytes
                    or buf.count >= cfg.coalesce_max_parcels):
                self._flush_locked(dst)
                return
        if created:
            self.port.wake()  # (re)arm the progress thread's flush timer

    def _flush_locked(self, dst: int, reason: str = "size") -> None:
        buf = self._cbufs.pop(dst, None)
        if buf is None:
            return
        if reason == "deadline" and _trace._enabled:
            # Overhead (O): these parcels sat out the aggregation window
            # without filling the container — latency traded for bandwidth
            _trace.instant("coalesce/deadline_flush", "net", dst=dst,
                           parcels=buf.count, bytes=buf.nbytes)
        self._last_flush[dst] = time.monotonic()
        self._adapt_window(buf)
        if buf.count == 1:
            self.enqueue(0, buf.parts[0])
            return
        header = {"t": MULTI, "src": self.local_id, "dst": dst,
                  "n": buf.count}
        hdr = _encode_header(header)
        inner = sum(_chunks_nbytes(p) for p in buf.parts)
        prefix = bytearray(8)
        _U32.pack_into(prefix, 0, 4 + len(hdr) + inner)
        _U32.pack_into(prefix, 4, len(hdr))
        chunks: List[Any] = [b"".join((prefix, hdr))]
        for part in buf.parts:
            chunks.extend(part)
        self.c_co_flushes.increment()
        self.c_co_parcels.increment(buf.count)
        self.enqueue(0, chunks)

    def _adapt_window(self, buf: _Coalesce) -> None:
        """Short adaptive timer: grow toward the cap while containers fill
        up, shrink toward the floor while they stay near-empty."""
        cfg = self.port.config
        if buf.nbytes >= cfg.coalesce_max_bytes or \
                buf.count >= cfg.coalesce_max_parcels:
            self._window = min(self._window * 1.5,
                               cfg.coalesce_window_us * 1e-6)
        elif buf.count <= 1:
            self._window = max(self._window * 0.5,
                               cfg.coalesce_min_window_us * 1e-6)

    def _flush_expired(self, now: float) -> Optional[float]:
        """Progress-thread tick: flush overdue buffers, return the next
        deadline (or None when nothing is buffered)."""
        nxt: Optional[float] = None
        with self._lock:
            for dst in list(self._cbufs):
                dl = self._cbufs[dst].deadline
                if dl <= now:
                    self._flush_locked(dst, reason="deadline")
                elif nxt is None or dl < nxt:
                    nxt = dl
        return nxt

    # ----------------------------------------------------------- rendezvous
    def _send_rendezvous(self, header: Dict[str, Any], body: bytes,
                         views: List[memoryview], size: int) -> None:
        header = dict(header)
        header["blens"] = [v.nbytes for v in views]
        header["bodylen"] = len(body)
        stream: List[memoryview] = [memoryview(body), *views]
        xfer = _OutXfer(0, header.get("dst", self.peer_id), stream, size)
        if _trace._enabled:
            xfer.t0 = time.perf_counter()
        xid = self.port._register_out(xfer)
        rts = {"t": RTS, "src": self.local_id,
               "dst": header.get("dst", self.peer_id), "x": xid,
               "size": size, "h": header}
        self.send_control(rts)

    def _stream_data(self, xfer: _OutXfer) -> None:
        """CTS granted: stripe the stream across the bulk lanes (progress
        thread; slicing views only — no payload copies)."""
        chunk = self.port.config.stripe_chunk
        off = 0
        seg_i, seg_off = 0, 0
        while off < xfer.size:
            n = min(chunk, xfer.size - off)
            pieces: List[Any] = []
            need = n
            while need > 0:
                seg = xfer.stream[seg_i]
                take = min(need, seg.nbytes - seg_off)
                if take:
                    pieces.append(seg[seg_off:seg_off + take])
                seg_off += take
                need -= take
                if seg_off >= seg.nbytes:
                    seg_i += 1
                    seg_off = 0
            hdr = _encode_header({"t": DATA, "src": self.local_id,
                                  "dst": xfer.dst, "x": xfer.xid,
                                  "o": off, "n": n})
            prefix = bytearray(8)
            _U32.pack_into(prefix, 0, 4 + len(hdr) + n)
            _U32.pack_into(prefix, 4, len(hdr))
            self.enqueue_bulk([b"".join((prefix, hdr)), *pieces])
            off += n
        self.c_rdv_sent.increment()

    # ------------------------------------------------------------- enqueue
    def enqueue(self, lane_idx: int, chunks: List[Any]) -> None:
        """Queue one frame on a lane, trying a direct non-blocking write
        when the lane is idle (no progress-thread wakeup on the fast
        path)."""
        lane = self.lanes[lane_idx]
        with lane.wlock:
            if self._closed:
                raise PortClosed(
                    f"connection to locality#{self.peer_id} closed")
            idle = not lane.wq
            lane.wq.append(chunks)
            if idle:
                lane.wstart = time.perf_counter() if _trace._enabled else 0.0
                done = self.port._write_lane_locked(lane)
                if done:
                    return
            lane.want_write = True
        self.port.wake()

    def enqueue_bulk(self, chunks: List[Any]) -> None:
        """Round-robin a DATA frame onto the bulk lanes (lane 0 carries
        bulk only in the degenerate ``stripes == 0`` configuration)."""
        if len(self.lanes) == 1:
            self.enqueue(0, chunks)
            return
        self._bulk_rr = self._bulk_rr % (len(self.lanes) - 1) + 1
        self.enqueue(self._bulk_rr, chunks)

    def forward(self, fr: Frame) -> None:
        """Root frame switch: re-prefix a parsed frame toward its dst
        without re-encoding header or payload bytes."""
        chunks = reframe(fr.hbytes, fr.rest)
        if fr.header.get("t") == DATA:
            self.enqueue_bulk(chunks)
        else:
            self.enqueue(0, chunks)

    # ---------------------------------------------------------------- close
    def _mark_closed(self) -> None:
        with self._lock:
            self._closed = True
            for led in self._ledgers.values():
                led.cv.notify_all()


# ------------------------------------------------------------------ the port
class PortHooks:
    """Callbacks a :class:`Port` needs from the runtime above it.

    ``deliver(fr, channel)`` — an application frame (parcel/result/bye/
    down) addressed to this locality; runs on the progress thread, must
    stay cheap.  ``route(dst)`` — the channel toward ``dst`` (the root's
    switch table).  ``forward_failed(fr)`` — a frame could not be
    forwarded (dest down).  ``on_forwarded()`` — switch accounting.
    ``on_close(channel)`` — a channel died.
    """

    def deliver(self, fr: Frame, channel: Channel) -> None:  # pragma: no cover
        raise NotImplementedError

    def route(self, dst: int) -> Channel:  # pragma: no cover
        raise NotImplementedError

    def forward_failed(self, fr: Frame) -> None:
        pass

    def on_forwarded(self) -> None:
        pass

    def on_close(self, channel: Channel) -> None:
        pass


class Port:
    """One per locality: the dedicated progress thread and every channel.

    All sockets are non-blocking and multiplexed through one
    ``selectors`` readiness loop — the LCI study's dedicated progress
    resource — which also runs the coalesce flush timers and the
    rendezvous handshake state machines."""

    def __init__(self, local_id: int, hooks: PortHooks,
                 config: Optional[NetConfig] = None):
        self.local_id = local_id
        self.hooks = hooks
        self.config = config or NetConfig()
        self._sel = selectors.DefaultSelector()
        self._channels: List[Channel] = []
        self._lock = threading.Lock()
        self._stopping = False
        self._started = False
        self._xid = 0
        self._outx: Dict[int, _OutXfer] = {}
        self._inx: Dict[Tuple[int, int], _InXfer] = {}
        self._pending_rts: Dict[int, "collections.deque[Dict[str, Any]]"] = {}
        self._reap: List[Channel] = []
        self._waker_r, self._waker_w = socket.socketpair()
        self._waker_r.setblocking(False)
        self._waker_w.setblocking(False)
        self._sel.register(self._waker_r, selectors.EVENT_READ, None)
        self._thread = threading.Thread(
            target=self._run, daemon=True,
            name=f"repro-net-progress-{local_id}")

    # ------------------------------------------------------------- lifecycle
    def add_channel(self, peer_id: int,
                    socks: List[socket.socket]) -> Channel:
        ch = Channel(self, peer_id, socks)
        with self._lock:
            self._channels.append(ch)
            for lane in ch.lanes:
                self._sel.register(lane.sock, selectors.EVENT_READ, lane)
            if not self._started:
                self._started = True
                self._thread.start()
        self.wake()
        return ch

    def wake(self) -> None:
        try:
            self._waker_w.send(b"\x00")
        except (BlockingIOError, OSError):
            pass  # wake pipe full → the loop is already waking up

    def flush(self, timeout: float = 5.0) -> bool:
        """Wait until every lane's write queue drains (BYE delivery)."""
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            busy = any(lane.wq for ch in list(self._channels)
                       for lane in ch.lanes if not ch.closed)
            with self._lock:
                busy = busy or any(ch._cbufs for ch in self._channels
                                   if not ch.closed)
            if not busy:
                return True
            self.wake()
            time.sleep(0.002)
        return False

    def _close_channel(self, ch: Channel) -> None:
        if ch._closed:
            return
        ch._mark_closed()
        with self._lock:
            self._reap.append(ch)
        if self._thread.is_alive():
            self.wake()
        else:
            self._reap_closed()

    def close(self) -> None:
        self._stopping = True
        self.wake()
        if self._started and self._thread.is_alive() and \
                threading.current_thread() is not self._thread:
            self._thread.join(timeout=10.0)
        for ch in list(self._channels):
            ch._mark_closed()
            with self._lock:
                if ch not in self._reap:
                    self._reap.append(ch)
        self._reap_closed(notify=False)
        try:
            self._sel.close()
        except Exception:  # noqa: BLE001
            pass
        for s in (self._waker_r, self._waker_w):
            try:
                s.close()
            except OSError:
                pass

    def _reap_closed(self, notify: bool = True) -> None:
        with self._lock:
            doomed, self._reap = self._reap, []
        for ch in doomed:
            ch._mark_closed()  # idempotent; covers the deferred-close path
            for lane in ch.lanes:
                try:
                    self._sel.unregister(lane.sock)
                except (KeyError, ValueError):
                    pass
                try:
                    lane.sock.close()
                except OSError:
                    pass
            with self._lock:
                if ch in self._channels:
                    self._channels.remove(ch)
            # drop transfer state that can never complete
            for xid in [x for x, xf in self._outx.items()
                        if self._safe_route(xf.dst) is None]:
                self._outx.pop(xid, None)
            self._inx = {k: v for k, v in self._inx.items()
                         if k[0] != ch.peer_id}
            self._pending_rts.pop(ch.peer_id, None)
            if notify:
                try:
                    self.hooks.on_close(ch)
                except Exception:  # noqa: BLE001 — must not kill the loop
                    import traceback

                    traceback.print_exc()

    def drop_transfers(self, peer: int) -> None:
        """Abandon every rendezvous involving ``peer`` (it died): parked
        out-streams, half-built assemblies, queued RTS grants."""
        with self._lock:
            for xid in [x for x, xf in self._outx.items() if xf.dst == peer]:
                self._outx.pop(xid, None)
            self._inx = {k: v for k, v in self._inx.items() if k[0] != peer}
            self._pending_rts.pop(peer, None)

    def _register_out(self, xfer: _OutXfer) -> int:
        with self._lock:
            self._xid += 1
            xfer.xid = self._xid
            self._outx[xfer.xid] = xfer
            return xfer.xid

    def _safe_route(self, dst: int) -> Optional[Channel]:
        try:
            return self.hooks.route(dst)
        except PortClosed:
            return None

    # ---------------------------------------------------------- progress loop
    def _run(self) -> None:
        while not self._stopping:
            now = time.monotonic()
            nxt: Optional[float] = None
            for ch in list(self._channels):
                if ch.closed:
                    continue
                dl = ch._flush_expired(now)
                if dl is not None and (nxt is None or dl < nxt):
                    nxt = dl
            timeout = 0.1 if nxt is None else max(0.0, nxt - now)
            try:
                events = self._sel.select(min(timeout, 0.1))
            except OSError:
                if self._stopping:
                    return
                continue
            for key, mask in events:
                lane = key.data
                if lane is None:  # waker
                    try:
                        while self._waker_r.recv(4096):
                            pass
                    except (BlockingIOError, OSError):
                        pass
                    continue
                if lane.channel.closed:
                    continue
                if mask & selectors.EVENT_READ:
                    self._on_readable(lane)
                if mask & selectors.EVENT_WRITE and not lane.channel.closed:
                    self._service_write(lane)
            self._apply_write_interest()
            self._reap_closed()

    def _apply_write_interest(self) -> None:
        for ch in list(self._channels):
            if ch.closed:
                continue
            for lane in ch.lanes:
                with lane.wlock:
                    want = bool(lane.wq)
                    lane.want_write = want
                try:
                    self._sel.modify(
                        lane.sock,
                        selectors.EVENT_READ |
                        (selectors.EVENT_WRITE if want else 0), lane)
                except (KeyError, ValueError, OSError):
                    pass

    # -------------------------------------------------------------- writing
    def _service_write(self, lane: _Lane) -> None:
        with lane.wlock:
            self._write_lane_locked(lane)

    def _write_lane_locked(self, lane: _Lane) -> bool:
        """Write as much of the lane's queue as the kernel accepts.
        Returns True when the queue fully drained.  Caller holds wlock."""
        ch = lane.channel
        try:
            while lane.wq:
                chunks = lane.wq[0]
                views: List[memoryview] = []
                skip = lane.woff
                total = 0
                for c in chunks:
                    m = memoryview(c)
                    if m.ndim != 1 or m.format != "B":
                        m = m.cast("B")
                    if skip >= m.nbytes:
                        skip -= m.nbytes
                        continue
                    if skip:
                        m = m[skip:]
                        skip = 0
                    views.append(m)
                    total += m.nbytes
                    if len(views) >= 64:
                        break
                sent = lane.sock.sendmsg(views)
                lane.woff += sent
                lane.bytes_written += sent
                ch.c_bytes_sent.increment(sent)
                if sent < total:
                    return False  # kernel buffer full mid-frame
                if len(views) >= 64 and lane.woff < _chunks_nbytes(chunks):
                    continue  # >64-chunk frame: keep feeding the kernel
                # frame fully written
                lane.wq.popleft()
                lane.woff = 0
                ch.c_frames_sent.increment()
                if _trace._enabled:
                    _trace.complete("wire/send", "net", lane.wstart or
                                    time.perf_counter(),
                                    bytes=_chunks_nbytes(chunks),
                                    peer=ch.peer_id, lane=lane.idx)
                    lane.wstart = time.perf_counter()
            return True
        except (BlockingIOError, InterruptedError):
            return False
        except OSError:
            # can't take ch._lock here (caller holds lane.wlock; the lock
            # order is channel → lane) — park the channel for the progress
            # thread to reap instead of closing inline
            lane.wq.clear()
            with self._lock:
                if ch not in self._reap:
                    self._reap.append(ch)
            self.wake()
            return True

    # -------------------------------------------------------------- reading
    def _on_readable(self, lane: _Lane) -> None:
        ch = lane.channel
        try:
            while True:
                if lane.rlo == lane.rhi:
                    # big rest remaining → read straight into the sink
                    if (lane.rphase == 2 and lane.rrest is not None
                            and lane.rrest_len - lane.rrest_got >= 4096):
                        n = lane.sock.recv_into(
                            lane.rrest[lane.rrest_got:])
                        if n == 0:
                            raise PortClosed("peer closed the connection")
                        lane.bytes_read += n
                        ch.c_bytes_recv.increment(n)
                        lane.rrest_got += n
                        if lane.rrest_got >= lane.rrest_len:
                            self._frame_complete(lane)
                        continue
                    lane.rlo = lane.rhi = 0
                    n = lane.sock.recv_into(lane.rscratch)
                    if n == 0:
                        raise PortClosed("peer closed the connection")
                    lane.bytes_read += n
                    ch.c_bytes_recv.increment(n)
                    lane.rhi = n
                self._feed(lane)
        except (BlockingIOError, InterruptedError):
            return
        except (OSError, PortClosed):
            ch.close()
        except Exception:  # noqa: BLE001 — a bad frame must not kill the loop
            import traceback

            traceback.print_exc()
            ch.close()

    def _feed(self, lane: _Lane) -> None:
        """Advance the lane's frame state machine over buffered bytes."""
        scratch = memoryview(lane.rscratch)
        while lane.rlo < lane.rhi:
            avail = lane.rhi - lane.rlo
            if lane.rphase == 0:
                take = min(avail, 8 - lane.rpre_got)
                lane.rpre[lane.rpre_got:lane.rpre_got + take] = \
                    scratch[lane.rlo:lane.rlo + take]
                lane.rpre_got += take
                lane.rlo += take
                if lane.rpre_got < 8:
                    return
                lane.rtotal = _U32.unpack_from(lane.rpre, 0)[0]
                hlen = _U32.unpack_from(lane.rpre, 4)[0]
                lane.rhdr = bytearray(hlen)
                lane.rhdr_got = 0
                lane.rrest_len = lane.rtotal - 4 - hlen
                lane.rphase = 1
            elif lane.rphase == 1:
                hlen = len(lane.rhdr)
                take = min(avail, hlen - lane.rhdr_got)
                lane.rhdr[lane.rhdr_got:lane.rhdr_got + take] = \
                    scratch[lane.rlo:lane.rlo + take]
                lane.rhdr_got += take
                lane.rlo += take
                if lane.rhdr_got < hlen:
                    return
                lane.rheader = _decode_header(bytes(lane.rhdr))
                lane.rassembly = None
                if lane.rrest_len == 0:
                    lane.rrest = memoryview(b"")
                    lane.rrest_got = 0
                    self._frame_complete(lane)
                    continue
                h = lane.rheader
                if (h.get("t") == DATA
                        and h.get("dst", self.local_id) == self.local_id):
                    xf = self._inx.get((h.get("src"), h.get("x")))
                    if xf is not None:
                        lane.rassembly = xf
                        o = h.get("o", 0)
                        lane.rrest = memoryview(xf.buf)[o:o + lane.rrest_len]
                        lane.rrest_got = 0
                        lane.rphase = 2
                        continue
                lane.rrest = memoryview(bytearray(lane.rrest_len))
                lane.rrest_got = 0
                lane.rphase = 2
            else:  # rest
                take = min(avail, lane.rrest_len - lane.rrest_got)
                lane.rrest[lane.rrest_got:lane.rrest_got + take] = \
                    scratch[lane.rlo:lane.rlo + take]
                lane.rrest_got += take
                lane.rlo += take
                if lane.rrest_got < lane.rrest_len:
                    return
                self._frame_complete(lane)

    def _frame_complete(self, lane: _Lane) -> None:
        header, rest = lane.rheader, lane.rrest
        assembly = lane.rassembly
        hbytes = bytes(lane.rhdr)
        wire = 4 + lane.rtotal
        lane.rphase = 0
        lane.rpre_got = 0
        lane.rheader = None
        lane.rrest = None
        lane.rassembly = None
        ch = lane.channel
        ch.c_frames_recv.increment()
        if _trace._enabled:
            _trace.instant("wire/recv", "net", bytes=wire,
                           peer=ch.peer_id, lane=lane.idx)
        if assembly is not None:
            self._data_written(ch, assembly, header)
            return
        self._dispatch(ch, Frame(header, hbytes, rest, wire, wire))

    # ------------------------------------------------------------- dispatch
    def _dispatch(self, ch: Channel, fr: Frame) -> None:
        header = fr.header
        t = header.get("t")
        dst = header.get("dst", self.local_id)
        if dst != self.local_id and t in _FORWARDABLE:
            out = self._safe_route(dst)
            if out is None or out.closed:
                self.hooks.forward_failed(fr)
                return
            self.hooks.on_forwarded()
            try:
                out.forward(fr)
            except PortClosed:
                self.hooks.forward_failed(fr)
            return
        if t == MULTI:
            for shdr, hb, srest, wire in iter_multi(header, fr.rest):
                self._dispatch(ch, Frame(shdr, hb, srest, wire, wire))
        elif t == CREDIT:
            ch._on_credit(header.get("src"), header.get("n", 0))
        elif t == RTS:
            self._on_rts(ch, header)
        elif t == CTS:
            xf = self._outx.pop(header.get("x"), None)
            if xf is not None:
                if xf.t0 and _trace._enabled:
                    # Waiting (W): payload parked sender-side from RTS send
                    # until the receiver granted CTS
                    _trace.complete("rendezvous/cts_wait", "net", xf.t0,
                                    dst=xf.dst, bytes=xf.size)
                out = self._safe_route(xf.dst)
                if out is not None and not out.closed:
                    out._stream_data(xf)
        elif t == DATA:
            # DATA for an unknown assembly (sender raced a close): drop.
            pass
        else:
            if t in (PARCEL, RESULT):
                ch.c_parcels_recv.increment()
            self.hooks.deliver(fr, ch)

    def _on_rts(self, ch: Channel, header: Dict[str, Any]) -> None:
        src = header.get("src")
        active = sum(1 for k in self._inx if k[0] == src)
        if active >= self.config.max_rendezvous:
            self._pending_rts.setdefault(
                src, collections.deque()).append(header)
            return
        self._grant_rts(header)

    def _grant_rts(self, header: Dict[str, Any]) -> None:
        src = header.get("src")
        xid = header.get("x")
        xf = _InXfer(src, xid, header.get("h") or {}, header.get("size", 0))
        self._inx[(src, xid)] = xf
        out = self._safe_route(src)
        if out is None or out.closed:
            self._inx.pop((src, xid), None)
            return
        out.send_control({"t": CTS, "src": self.local_id, "dst": src,
                          "x": xid})
        if xf.size == 0:  # degenerate empty payload: complete immediately
            self._complete_assembly(out, xf)

    def _data_written(self, ch: Channel, xf: _InXfer,
                      header: Dict[str, Any]) -> None:
        xf.got += header.get("n", 0)
        if xf.got >= xf.size:
            self._complete_assembly(ch, xf)

    def _complete_assembly(self, ch: Channel, xf: _InXfer) -> None:
        self._inx.pop((xf.src, xf.xid), None)
        ch.c_rdv_recv.increment()
        inner = dict(xf.header)
        if inner.get("t") in (PARCEL, RESULT):
            ch.c_parcels_recv.increment()
        # rendezvous parcels never consumed eager credit: credit_bytes=0
        self.hooks.deliver(Frame(inner, b"", memoryview(xf.buf),
                                 xf.size, 0), ch)
        q = self._pending_rts.get(xf.src)
        if q:
            self._grant_rts(q.popleft())
            if not q:
                self._pending_rts.pop(xf.src, None)
