"""repro.net.httpd — minimal HTTP exposition listener for the net tier.

A scrape endpoint is a *wire*, so it lives here: ``repro/net`` is the only
package allowed to open listening sockets (``tests/test_api_guard.py``).
The observability layer (:mod:`repro.obs.metrics`) supplies only the
*rendering* — it hands this module a ``handler(path) -> (status,
content_type, body)`` callable and never touches a socket itself.

    ep = HttpEndpoint(handler, port=0)   # port=0 → ephemeral
    ep.start()
    ... scrape http://127.0.0.1:{ep.port}/metrics ...
    ep.close()

The server is a ``ThreadingHTTPServer`` with daemon worker threads: a
scrape must never block runtime shutdown, and a stuck scraper must never
wedge the fleet.  ``http_get`` is the matching client-side helper so
tests and ``repro.obs.top --metrics`` don't need their own transport.
"""

from __future__ import annotations

import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Callable, Optional, Tuple
from urllib.error import HTTPError
from urllib.request import urlopen

# (status, content-type, body) — what a handler returns for one GET
Response = Tuple[int, str, bytes]


class HttpEndpoint:
    """A tiny GET-only HTTP server bound to one handler callable.

    The handler receives the request path (query string stripped) and
    returns a :data:`Response`.  A raising handler maps to a 500 with the
    repr in the body — an exposition endpoint should degrade loudly, not
    take the process down.
    """

    def __init__(self, handler: Callable[[str], Response],
                 host: str = "127.0.0.1", port: int = 0):
        self._handler = handler
        endpoint = self

        class _Handler(BaseHTTPRequestHandler):
            protocol_version = "HTTP/1.1"

            def do_GET(self) -> None:  # noqa: N802 (http.server API)
                path = self.path.split("?", 1)[0]
                try:
                    status, ctype, body = endpoint._handler(path)
                except Exception as e:  # pragma: no cover - defensive
                    status, ctype = 500, "text/plain; charset=utf-8"
                    body = f"scrape handler failed: {e!r}\n".encode()
                self.send_response(status)
                self.send_header("Content-Type", ctype)
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def log_message(self, fmt, *args):  # silence per-request spam
                pass

        self._server = ThreadingHTTPServer((host, port), _Handler)
        self._server.daemon_threads = True
        self._thread: Optional[threading.Thread] = None

    @property
    def host(self) -> str:
        return self._server.server_address[0]

    @property
    def port(self) -> int:
        return self._server.server_address[1]

    def start(self) -> "HttpEndpoint":
        if self._thread is None:
            self._thread = threading.Thread(
                target=self._server.serve_forever, name="repro-httpd",
                daemon=True)
            self._thread.start()
        return self

    def close(self) -> None:
        if self._thread is not None:
            self._server.shutdown()
            self._thread.join(timeout=10)
            self._thread = None
        self._server.server_close()

    def __enter__(self) -> "HttpEndpoint":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()


def http_get(url: str, timeout: float = 10.0) -> Tuple[int, str]:
    """GET ``url`` → ``(status, body_text)``.  Client twin of
    :class:`HttpEndpoint`, kept here so nothing outside ``repro/net``
    grows its own transport."""
    try:
        with urlopen(url, timeout=timeout) as resp:  # noqa: S310 (http only)
            return resp.status, resp.read().decode("utf-8", "replace")
    except HTTPError as e:  # non-2xx is still an answer, not a transport error
        return e.code, e.read().decode("utf-8", "replace")
