"""Data pipeline: synthetic token stream with AMT-scheduler prefetch.

Production shape without a dataset dependency: a deterministic PRNG token
stream (seeded per step — restart-reproducible), host-side batch assembly
on the resource partitioner's "io" pool (P2), and a double-buffered
prefetch queue so batch
(i+1) is built and transferred while the device runs step i — the paper's
"overlapping communication and computation" on the host plane.  The
trainer consumes ``Future[batch]``s (futurization, P1).
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import counters as _counters
from repro.core import executor as _executor
from repro.core.future import Future


@dataclass
class DataConfig:
    batch_size: int = 8
    seq_len: int = 128
    seed: int = 0
    prefetch: int = 2


def synth_batch(cfg: ModelConfig, dcfg: DataConfig, step: int,
                shardings: Optional[Dict[str, Any]] = None) -> Dict[str, jax.Array]:
    """Deterministic synthetic batch for ``step`` (restart-reproducible).

    Token stream has learnable structure (a noisy cyclic grammar) so train
    loss demonstrably falls below the uniform entropy floor.
    """
    rng = np.random.default_rng(dcfg.seed * 1_000_003 + step)
    B, S = dcfg.batch_size, dcfg.seq_len + 1
    V = cfg.vocab_size
    period = max(2, min(64, V // 4))
    phase = rng.integers(0, period, size=(B, 1))
    base = (np.arange(S)[None, :] + phase) % period
    noise = rng.integers(0, V, size=(B, S))
    keep = rng.random((B, S)) < 0.85  # 85% grammar, 15% noise
    tokens = np.where(keep, base, noise).astype(np.int32)
    batch: Dict[str, Any] = {"tokens": tokens}
    if cfg.family == "vlm":
        batch["patches"] = rng.standard_normal(
            (B, cfg.n_patches, cfg.d_model)).astype(np.float32)
    if cfg.family == "encdec":
        batch["enc"] = rng.standard_normal(
            (B, dcfg.seq_len, cfg.d_model)).astype(np.float32)
    out = {}
    for k, v in batch.items():
        arr = jnp.asarray(v, jnp.bfloat16 if v.dtype == np.float32 else None)
        if shardings and k in shardings:
            arr = jax.device_put(arr, shardings[k])
        out[k] = arr
    return out


class Prefetcher:
    """AMT-driven double buffering: ``get(step)`` returns a Future[batch];
    the batch for step+prefetch is already being assembled by pool tasks."""

    def __init__(self, cfg: ModelConfig, dcfg: DataConfig,
                 shardings: Optional[Dict[str, Any]] = None):
        self.cfg = cfg
        self.dcfg = dcfg
        self.shardings = shardings
        self._pending: Dict[int, Future] = {}
        self._lock = threading.Lock()
        # Batch assembly is host I/O-plane work: it runs on the resource
        # partitioner's "io" pool so prefetch never steals compute slots
        # (fallback: the default pool on unpartitioned runtimes).
        self._exec = _executor.get_executor("io", fallback="default")
        self.c_built = _counters.counter("/data{pipeline#0}/batches/built")
        self.t_build = _counters.timer("/data{pipeline#0}/build/duration")

    def _schedule(self, step: int) -> Future:
        def build():
            with self.t_build.time():
                b = synth_batch(self.cfg, self.dcfg, step, self.shardings)
            self.c_built.increment()
            return b

        return self._exec.async_execute(build)

    def get(self, step: int) -> Future:
        with self._lock:
            fut = self._pending.pop(step, None)
            if fut is None:
                fut = self._schedule(step)
            # keep the window full
            for s in range(step + 1, step + 1 + self.dcfg.prefetch):
                if s not in self._pending:
                    self._pending[s] = self._schedule(s)
        return fut
