"""Data pipeline: synthetic token stream with AMT-scheduler prefetch.

Production shape without a dataset dependency: a deterministic PRNG token
stream (seeded per step — restart-reproducible), host-side batch assembly
on the resource partitioner's "io" pool (P2), and a double-buffered
prefetch queue so batch
(i+1) is built and transferred while the device runs step i — the paper's
"overlapping communication and computation" on the host plane.  The
trainer consumes ``Future[batch]``s (futurization, P1).

Locality-sharded mode (work-to-data, ``repro.container``): a
:class:`ShardedTokenDataset` is a :class:`PartitionedVector` of token
rows, block-distributed over the localities and *synthesized in place at
each owner* (``fill_with`` ships the generator function, never the token
bytes).  Its :class:`LocalShardFeeder` is Prefetcher-compatible
(``get(step) → Future[batch]``) but assembles batches exclusively from
the segments this locality owns — a trainer per locality feeds from local
data, and the dataset as a whole never transits the wire.
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import counters as _counters
from repro.core import executor as _executor
from repro.core.future import Future


@dataclass
class DataConfig:
    batch_size: int = 8
    seq_len: int = 128
    seed: int = 0
    prefetch: int = 2


def synth_batch(cfg: ModelConfig, dcfg: DataConfig, step: int,
                shardings: Optional[Dict[str, Any]] = None) -> Dict[str, jax.Array]:
    """Deterministic synthetic batch for ``step`` (restart-reproducible).

    Token stream has learnable structure (a noisy cyclic grammar) so train
    loss demonstrably falls below the uniform entropy floor.
    """
    rng = np.random.default_rng(dcfg.seed * 1_000_003 + step)
    B, S = dcfg.batch_size, dcfg.seq_len + 1
    V = cfg.vocab_size
    period = max(2, min(64, V // 4))
    phase = rng.integers(0, period, size=(B, 1))
    base = (np.arange(S)[None, :] + phase) % period
    noise = rng.integers(0, V, size=(B, S))
    keep = rng.random((B, S)) < 0.85  # 85% grammar, 15% noise
    tokens = np.where(keep, base, noise).astype(np.int32)
    batch: Dict[str, Any] = {"tokens": tokens}
    if cfg.family == "vlm":
        batch["patches"] = rng.standard_normal(
            (B, cfg.n_patches, cfg.d_model)).astype(np.float32)
    if cfg.family == "encdec":
        batch["enc"] = rng.standard_normal(
            (B, dcfg.seq_len, cfg.d_model)).astype(np.float32)
    out = {}
    for k, v in batch.items():
        arr = jnp.asarray(v, jnp.bfloat16 if v.dtype == np.float32 else None)
        if shardings and k in shardings:
            arr = jax.device_put(arr, shardings[k])
        out[k] = arr
    return out


class _WindowedFeeder:
    """AMT-driven double buffering: ``get(step)`` returns a Future[batch];
    the batch for step+prefetch is already being assembled by pool tasks.
    Subclasses provide ``_build(step) → batch``."""

    def __init__(self, dcfg: DataConfig, counter_tag: str):
        self.dcfg = dcfg
        self._pending: Dict[int, Future] = {}
        self._lock = threading.Lock()
        # Batch assembly is host I/O-plane work: it runs on the resource
        # partitioner's "io" pool so prefetch never steals compute slots
        # (fallback: the default pool on unpartitioned runtimes).
        self._exec = _executor.get_executor("io", fallback="default")
        self.c_built = _counters.counter(f"/data{{{counter_tag}}}/batches/built")
        self.t_build = _counters.timer(f"/data{{{counter_tag}}}/build/duration")

    def _build(self, step: int) -> Dict[str, Any]:
        raise NotImplementedError

    def _schedule(self, step: int) -> Future:
        def build():
            with self.t_build.time():
                b = self._build(step)
            self.c_built.increment()
            return b

        return self._exec.async_execute(build)

    def get(self, step: int) -> Future:
        with self._lock:
            fut = self._pending.pop(step, None)
            if fut is None:
                fut = self._schedule(step)
            # keep the window full
            for s in range(step + 1, step + 1 + self.dcfg.prefetch):
                if s not in self._pending:
                    self._pending[s] = self._schedule(s)
        return fut


class Prefetcher(_WindowedFeeder):
    """Single-locality feeder: every batch synthesized here."""

    def __init__(self, cfg: ModelConfig, dcfg: DataConfig,
                 shardings: Optional[Dict[str, Any]] = None):
        super().__init__(dcfg, "pipeline#0")
        self.cfg = cfg
        self.shardings = shardings

    def _build(self, step: int) -> Dict[str, Any]:
        return synth_batch(self.cfg, self.dcfg, step, self.shardings)


# ------------------------------------------------------ locality-sharded mode
def synth_token_rows(global_idx: Any, cfg: ModelConfig,
                     dcfg: DataConfig) -> np.ndarray:
    """Deterministic token rows of the *global* stream: row ``r`` depends
    only on ``(dcfg.seed, r)``, so any locality synthesizing its own
    segment produces exactly the rows a single process would have (the
    ``fill_with`` generator — module-level, pickled by reference)."""
    S, V = dcfg.seq_len + 1, cfg.vocab_size
    period = max(2, min(64, V // 4))
    idx = np.asarray(global_idx, dtype=np.int64)
    out = np.empty((idx.shape[0], S), dtype=np.int32)
    for k, r in enumerate(idx):
        rng = np.random.default_rng(dcfg.seed * 1_000_003 + 7919 * int(r))
        base = (np.arange(S) + rng.integers(0, period)) % period
        noise = rng.integers(0, V, size=S)
        keep = rng.random(S) < 0.85  # 85% grammar, 15% noise
        out[k] = np.where(keep, base, noise)
    return out


class ShardedTokenDataset:
    """Token rows as a PartitionedVector: each locality holds — and
    synthesized, in place — only its own segments."""

    def __init__(self, pv: Any, cfg: ModelConfig, dcfg: DataConfig):
        self.pv = pv
        self.cfg = cfg
        self.dcfg = dcfg

    @classmethod
    def create(cls, name: str, cfg: ModelConfig, dcfg: DataConfig,
               rows: int, distribution: Any = "block") -> "ShardedTokenDataset":
        from repro.container import PartitionedVector

        if cfg.family in ("vlm", "encdec"):
            raise ValueError(
                f"locality-sharded datasets synthesize token rows only; "
                f"the {cfg.family!r} family needs extra batch fields "
                f"(patches/enc) — use Prefetcher for it")
        pv = PartitionedVector.create(name, rows, dtype=np.int32,
                                      element_shape=(dcfg.seq_len + 1,),
                                      distribution=distribution)
        pv.fill_with(synth_token_rows, cfg, dcfg)
        return cls(pv, cfg, dcfg)

    @classmethod
    def attach(cls, name: str, cfg: ModelConfig,
               dcfg: DataConfig) -> "ShardedTokenDataset":
        from repro.container import PartitionedVector

        return cls(PartitionedVector.attach(name), cfg, dcfg)

    def __len__(self) -> int:
        return len(self.pv)

    def feeder(self) -> "LocalShardFeeder":
        return LocalShardFeeder(self.pv, self.dcfg)


class LocalShardFeeder(_WindowedFeeder):
    """Prefetcher-compatible feeder over the *locally-owned* segments of a
    sharded dataset: batch assembly reads a construction-time snapshot of
    the local segments (an in-memory copy — still no token ever crosses
    the wire), so later mutation or migration of the dataset never races
    in-flight batch builds."""

    def __init__(self, pv: Any, dcfg: DataConfig):
        super().__init__(dcfg, f"feeder:{pv.name}")
        local = pv.local_segments()
        if not local:
            raise RuntimeError(
                f"no segment of {pv.name!r} lives on this locality — "
                f"rebalance() it here or use Prefetcher")
        self._rows = np.concatenate([seg for _j, seg in local], axis=0)
        self.global_rows = np.concatenate(
            [pv.dist.global_indices(j) for j, _seg in local])
        self.pv = pv

    def _build(self, step: int) -> Dict[str, Any]:
        rng = np.random.default_rng(self.dcfg.seed * 9_176_081 + step)
        pick = rng.integers(0, self._rows.shape[0],
                            size=self.dcfg.batch_size)
        return {"tokens": jnp.asarray(self._rows[pick])}
