"""AdamW with sharded (ZeRO) state.

Optimizer moments inherit the parameter sharding — under the futurized
plan's FSDP rules that is ZeRO-3: each data shard owns 1/N of every moment
tensor and the update is purely local (no optimizer collectives).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup → cosine decay (the production default)."""
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def init(params: Dict[str, jax.Array]) -> Dict[str, Any]:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return {
        "m": zeros,
        "v": jax.tree.map(jnp.zeros_like, zeros),
        "step": jnp.zeros((), jnp.int32),
    }


def abstract_state(param_specs) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins matching :func:`init` (dry-run)."""
    sds = {p: jax.ShapeDtypeStruct(s.shape, jnp.float32) for p, s in param_specs.items()}
    return {"m": sds, "v": dict(sds), "step": jax.ShapeDtypeStruct((), jnp.int32)}


def state_axes(param_specs) -> Dict[str, Any]:
    """Logical axes for the optimizer state (same as params; ZeRO)."""
    ax = {p: s.axes for p, s in param_specs.items()}
    return {"m": ax, "v": dict(ax), "step": ()}


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def update(cfg: AdamWConfig, params: Dict[str, jax.Array], grads: Dict[str, jax.Array],
           state: Dict[str, Any]) -> Tuple[Dict[str, jax.Array], Dict[str, Any], Dict[str, jax.Array]]:
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) if cfg.grad_clip > 0 else 1.0
    lr = schedule(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat = {k: upd(params[k], grads[k], state["m"][k], state["v"][k]) for k in params}
    new_params = {k: t[0] for k, t in flat.items()}
    new_state = {
        "m": {k: t[1] for k, t in flat.items()},
        "v": {k: t[2] for k, t in flat.items()},
        "step": step,
    }
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
