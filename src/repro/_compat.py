"""Version compatibility backfills for older JAX installs.

The codebase is written against the modern JAX sharding surface
(``jax.make_mesh(..., axis_types=...)``, ``jax.sharding.AxisType``,
``jax.set_mesh``).  The pinned container ships jax 0.4.37, which predates
all three, so this module backfills them *once*, at ``import repro`` time.
Every patch is gated on a ``hasattr`` check: on a current JAX none of this
runs and the native implementations are used untouched.

What is provided on old JAX:

- ``jax.sharding.AxisType`` — the Auto/Explicit/Manual enum (metadata only
  here; 0.4.37 meshes are always fully automatic, which is what every
  caller in this repo asks for).
- ``jax.make_mesh(..., axis_types=...)`` — wrapper that accepts and drops
  the keyword.
- ``jax.set_mesh(mesh)`` — context manager that (a) pushes ``mesh`` onto
  the active-mesh stack consumed by :func:`repro.dist.plan._active_mesh`
  and (b) enters the legacy ``with mesh:`` resource environment so that
  pjit-era machinery sees the same ambient mesh.
- ``jax.experimental.pallas.tpu.CompilerParams`` — alias of the pre-rename
  ``TPUCompilerParams`` (kernels are written against the new name).

The active-mesh stack lives here (not in ``repro.dist.plan``) because it
must exist even when ``jax.set_mesh`` is native; on modern JAX
:func:`active_mesh` reads the native ``get_abstract_mesh`` state instead
of the shim stack.
"""

from __future__ import annotations

import contextlib
import enum
import threading
from typing import Any, List, Optional

import jax

_local = threading.local()


def _mesh_stack() -> List[Any]:
    if not hasattr(_local, "stack"):
        _local.stack = []
    return _local.stack


def active_mesh() -> Optional[Any]:
    """The innermost mesh set via ``jax.set_mesh`` (shimmed or recorded),
    falling back to the legacy ``with mesh:`` resource env; None if no
    mesh is active."""
    stack = _mesh_stack()
    if stack:
        return stack[-1]
    try:  # modern JAX: native jax.set_mesh records the abstract mesh
        import jax.sharding as jshard

        get_am = getattr(jshard, "get_abstract_mesh", None)
        if get_am is not None:
            m = get_am()
            if m is not None and not getattr(m, "empty", True):
                return m
    except Exception:  # noqa: BLE001
        pass
    try:  # legacy ambient mesh (``with mesh:``)
        from jax.interpreters import pxla

        phys = pxla.thread_resources.env.physical_mesh
        if phys is not None and not phys.empty:
            return phys
    except Exception:  # noqa: BLE001 — resource env gone in future JAX
        pass
    return None


def _install() -> None:
    import jax.sharding as jshard

    if not hasattr(jshard, "AxisType"):
        class AxisType(enum.Enum):
            Auto = "auto"
            Explicit = "explicit"
            Manual = "manual"

        jshard.AxisType = AxisType

    # make_mesh(..., axis_types=...) — 0.4.37 lacks the kwarg
    try:
        import inspect

        if "axis_types" not in inspect.signature(jax.make_mesh).parameters:
            _native_make_mesh = jax.make_mesh

            def make_mesh(axis_shapes, axis_names, *, devices=None,
                          axis_types=None):
                del axis_types  # metadata only on this JAX
                return _native_make_mesh(axis_shapes, axis_names,
                                         devices=devices)

            jax.make_mesh = make_mesh
    except Exception:  # noqa: BLE001
        pass

    if not hasattr(jax, "set_mesh"):
        @contextlib.contextmanager
        def set_mesh(mesh):
            _mesh_stack().append(mesh)
            try:
                with mesh:  # legacy resource env (Mesh is a context manager)
                    yield mesh
            finally:
                _mesh_stack().pop()

        jax.set_mesh = set_mesh

    try:  # pallas: CompilerParams was named TPUCompilerParams pre-0.5
        import jax.experimental.pallas.tpu as pltpu

        if not hasattr(pltpu, "CompilerParams") and hasattr(pltpu, "TPUCompilerParams"):
            pltpu.CompilerParams = pltpu.TPUCompilerParams
        if not hasattr(pltpu, "MemorySpace") and hasattr(pltpu, "TPUMemorySpace"):
            pltpu.MemorySpace = pltpu.TPUMemorySpace
    except Exception:  # noqa: BLE001 — pallas optional on some backends
        pass


_install()
