"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not module-level state) so importing
this module never touches jax device initialization — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first jax
use; smoke tests and benches see the real single CPU device.
"""

from __future__ import annotations

from typing import Optional, Sequence

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single-pod (256 chips) or 2×16×16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (CPU) devices exist — tests/examples."""
    n = len(jax.devices())
    data = min(data, n)
    model = max(1, min(model, n // data))
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(AxisType.Auto, AxisType.Auto))


def make_mesh_shape(shape: Sequence[int], axes: Sequence[str]):
    return jax.make_mesh(tuple(shape), tuple(axes),
                         axis_types=(AxisType.Auto,) * len(axes))


# v5e hardware constants for the roofline (EXPERIMENTS.md §Roofline)
POD_SIZE = 256  # chips per pod (16×16) — dist/hlo_analysis classifies
                # ICI vs DCI traffic by this boundary
PEAK_FLOPS_BF16 = 197e12  # per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link (within pod)
DCI_BW = 25e9  # bytes/s per chip across pods (assumption, documented)
