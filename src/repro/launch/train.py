"""Training launcher.

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch qwen25_3b --smoke \
      --steps 100 --batch 8 --seq 128 --plan futurized
  PYTHONPATH=src python -m repro.launch.train --arch mamba2_780m --smoke \
      --steps 50 --ckpt-every 20 --ckpt-dir /tmp/ck

Full (non ``--smoke``) configs are for real accelerator fleets; on this CPU
container use ``--smoke`` (reduced same-family config) or the dry-run
(``repro.launch.dryrun``) for the production shapes.
"""

from __future__ import annotations

import argparse
import json

import jax


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--plan", default="futurized")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="checkpoints")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--scheduler", default="local",
                    choices=("static", "local", "hierarchical"))
    ap.add_argument("--localities", type=int, default=1,
                    help="multi-locality runtime: N OS processes")
    ap.add_argument("--sharded-rows", type=int, default=0,
                    help="locality-sharded dataset of this many token rows "
                         "(synthesized in place at each owning locality); "
                         "the trainer feeds from locality 0's segments")
    # observability
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="record a fleet-wide task/parcel trace and write "
                         "one merged Chrome trace JSON (Perfetto-loadable)")
    ap.add_argument("--print-counters", metavar="PATTERN", default=None,
                    help="end-of-run fleet counter report (HPX "
                         "--hpx:print-counter parity), e.g. '/train*'")
    ap.add_argument("--metrics-port", type=int, default=None, metavar="PORT",
                    help="serve an OpenMetrics /metrics endpoint from "
                         "locality 0 (0 = ephemeral port)")
    ap.add_argument("--timeline", metavar="PATH", default=None,
                    help="persist a JSONL counter timeline; summarize with "
                         "python -m repro.obs.analyze --timeline")
    args = ap.parse_args()

    import contextlib

    import repro.core as core
    from repro.configs import get_config
    from repro.data.pipeline import DataConfig, ShardedTokenDataset
    from repro.dist.plan import get_plan
    from repro.models.model import build_model
    from repro.optim.adamw import AdamWConfig
    from repro.train.trainer import TrainConfig, Trainer

    # Resource partition: compute-plane tasks on "default", prefetch
    # assembly + checkpoint writes on the single-worker "io" pool.  A
    # sharded dataset needs the net runtime even at one locality.
    pools = {"default": args.workers, "io": 1}
    if args.localities > 1 or args.sharded_rows > 0:
        if args.scheduler != "local":
            ap.error("--scheduler is not supported together with "
                     "--localities/--sharded-rows (the multi-locality "
                     "bootstrap brings up the default scheduler)")
        from repro import net as rnet

        ctx = rnet.running(max(args.localities, 1), pools=pools)
    else:
        core.init(policy=args.scheduler, pools=pools)
        ctx = contextlib.nullcontext()
    with ctx as net:
        if args.trace:
            from repro.obs import export as obs_export

            obs_export.enable_fleet(net)
        exporter = None
        if args.metrics_port is not None:
            from repro.obs.metrics import MetricsExporter

            exporter = MetricsExporter(net=net,
                                       port=args.metrics_port).start()
            print(f"metrics: {exporter.url}", flush=True)
        timeline = tl_sampler = None
        if args.timeline:
            from repro.obs.sampler import FleetSampler
            from repro.obs.timeseries import TimelineWriter

            timeline = TimelineWriter(args.timeline, pattern="*",
                                      interval=0.25,
                                      meta={"launcher": "train",
                                            "arch": args.arch})
            tl_sampler = FleetSampler(pattern="*", interval=0.25, net=net,
                                      timeline=timeline)
            tl_sampler.sample_once()  # t=0 baseline record
            tl_sampler.start()
        cfg = get_config(args.arch, smoke=args.smoke)
        plan = get_plan(args.plan, **({"microbatches": args.microbatches}
                                      if args.plan != "bsp" and args.microbatches > 1 else {}))
        model = build_model(cfg, plan)
        dcfg = DataConfig(batch_size=args.batch, seq_len=args.seq)
        prefetcher = None
        if args.sharded_rows > 0:
            ds = ShardedTokenDataset.create("/data/train-shard", cfg, dcfg,
                                            rows=args.sharded_rows)
            prefetcher = ds.feeder()
            print(json.dumps({"sharded_rows": len(ds),
                              "local_rows": int(prefetcher.global_rows.shape[0]),
                              "segments": ds.pv.nsegments}))
        trainer = Trainer(
            model,
            AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 1),
                        total_steps=args.steps),
            dcfg,
            TrainConfig(steps=args.steps, log_every=args.log_every,
                        ckpt_every=args.ckpt_every, ckpt_dir=args.ckpt_dir),
            prefetcher=prefetcher,
        )
        if args.resume:
            print(f"resumed at step {trainer.resume()}")
        history = trainer.fit()
        for h in history:
            print(json.dumps(h))
        print(json.dumps({"counters": dict(core.counters.query("/train*"))}))
        if args.trace:
            tr = obs_export.export_chrome_trace(args.trace, net=net)
            print(json.dumps({"trace": args.trace,
                              "events": len(tr["traceEvents"])}))
        if args.print_counters:
            from repro.obs import sampler as obs_sampler

            obs_sampler.print_counter_report(args.print_counters, net=net)
        if timeline is not None:
            tl_sampler.stop()
            tl_sampler.sample_once()  # end-of-run record (≥2 guaranteed)
            timeline.close()
            print(json.dumps({"timeline": args.timeline,
                              "records": timeline.records_written,
                              "stride": timeline.stride}))
        if exporter is not None:
            exporter.close()
    core.finalize()


if __name__ == "__main__":
    main()
