"""Serving launcher: batched requests through the paged continuous-batching
serving stack (engine replicas behind the least-loaded router), optionally
spread over multiple OS-process localities.

Examples:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen25_3b --smoke \
      --requests 8 --max-new 16 --engines 2 --temperature 0.8 --top-k 40
  PYTHONPATH=src python -m repro.launch.serve --arch qwen25_3b --smoke \
      --requests 12 --max-new 16 --localities 2
  PYTHONPATH=src python -m repro.launch.serve --arch qwen25_3b --smoke \
      --requests 24 --max-new 16 --localities 3 --fleet --slo --stream
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--plan", default="serve")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=256)
    ap.add_argument("--workers", type=int, default=4)
    # routing layer
    ap.add_argument("--engines", type=int, default=1,
                    help="engine replicas behind the least-loaded router "
                         "(single-locality mode)")
    ap.add_argument("--localities", type=int, default=1,
                    help="OS-process localities; >1 bootstraps repro.net "
                         "and runs one engine per locality")
    # cache layer
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--no-paged", action="store_true",
                    help="dense per-slot cache instead of the block pool")
    ap.add_argument("--no-pipeline", action="store_true",
                    help="seed-style inline prefill (the barrier baseline)")
    # sampling / streaming
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--top-p", type=float, default=1.0)
    ap.add_argument("--stream", action="store_true",
                    help="consume tokens via per-request channels (crosses "
                         "localities through the token relay)")
    # fleet control plane
    ap.add_argument("--fleet", action="store_true",
                    help="run the adaptive control plane on locality 0: "
                         "counter sweeps -> policies -> actuators, plus "
                         "gated-batch release each tick (needs "
                         "--localities > 1)")
    ap.add_argument("--slo", action="store_true",
                    help="SLO tiers: first remote engine pinned interactive,"
                         " the rest batch; batch admission gated on gossiped"
                         " KV-page occupancy (hysteresis 0.85/0.60)")
    ap.add_argument("--slo-mix", type=float, default=0.25, metavar="FRAC",
                    help="fraction of requests submitted interactive when "
                         "--slo is on (default 0.25)")
    # observability
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="record a fleet-wide task/parcel trace and write "
                         "one merged Chrome trace JSON (Perfetto-loadable)")
    ap.add_argument("--print-counters", metavar="PATTERN", default=None,
                    help="end-of-run fleet counter report (HPX "
                         "--hpx:print-counter parity), e.g. '/serve*'")
    ap.add_argument("--slow-report", action="store_true",
                    help="after --trace export, run the critical-path "
                         "analyzer and print the per-tier SLOW blame "
                         "report (python -m repro.obs.analyze parity)")
    ap.add_argument("--metrics-port", type=int, default=None, metavar="PORT",
                    help="serve an OpenMetrics /metrics endpoint from "
                         "locality 0 (0 = ephemeral port); every scrape "
                         "sweeps the fleet's counters live")
    ap.add_argument("--timeline", metavar="PATH", default=None,
                    help="persist a JSONL counter timeline (bounded by "
                         "stride-doubling downsample); summarize later "
                         "with python -m repro.obs.analyze --timeline")
    ap.add_argument("--flight-recorder", metavar="PREFIX", default=None,
                    help="arm the anomaly flight recorder on the fleet "
                         "controller: always-on rings + dump_trace trigger "
                         "rules, anomaly traces written to "
                         "results/PREFIX-N.json (needs --fleet)")
    args = ap.parse_args()
    if args.slow_report and not args.trace:
        ap.error("--slow-report needs --trace PATH (it analyzes the "
                 "exported merged trace)")
    if args.flight_recorder and not args.fleet:
        ap.error("--flight-recorder needs --fleet (the controller's tick "
                 "evaluates the trigger rules)")
    if (args.fleet or args.slo) and args.localities < 2:
        ap.error("--fleet/--slo need --localities > 1 (the control plane "
                 "manages remote engines)")
    if args.slo:
        args.fleet = True  # the gate needs the controller's release tick
    if args.localities > 1 and args.engines != 1:
        ap.error("--engines is single-locality replication; with "
                 "--localities N the topology is one engine per locality")

    import repro.core as core
    from repro.configs import get_config
    from repro.dist.plan import get_plan
    from repro.models.model import build_model
    from repro.serve.engine import SamplingParams, ServeConfig
    from repro.serve.router import Router, default_extra_inputs

    # Resource partition: decode continuations on "default", prefill on its
    # own pool, host I/O (logging/ckpt/parcel pumps) on "io" — capacity goes
    # where the work is, and I/O can never stall a decode step.
    pools = {"default": args.workers, "prefill": 2, "io": 1}
    core.init(pools=pools)
    cfg = get_config(args.arch, smoke=args.smoke)

    scfg = ServeConfig(max_batch=args.max_batch, cache_len=args.cache_len,
                       max_new_tokens=args.max_new, page_size=args.page_size,
                       paged=not args.no_paged,
                       pipeline_admission=not args.no_pipeline)
    net = None
    if args.localities > 1:
        from repro import net as rnet

        net = rnet.bootstrap(args.localities, pools=pools, worker_pools=pools)
        if args.trace:
            from repro.obs import export as obs_export

            obs_export.enable_fleet(net)
        router = Router.over_localities(net, args.arch, scfg,
                                        smoke=args.smoke, plan=args.plan)
    else:
        if args.trace:
            from repro.obs import trace as obs_trace

            obs_trace.enable()
        model = build_model(cfg, get_plan(args.plan))
        params = model.init(jax.random.PRNGKey(0))
        router = Router.replicate(model, params, scfg, args.engines,
                                  extra_inputs=default_extra_inputs(cfg))
    controller = None
    if args.slo:
        from repro.fleet import BATCH, INTERACTIVE, AdmissionController

        from repro.serve.router import RemoteEngine

        # first remote engine serves the latency tier, the rest take batch;
        # batch admission rides the occupancy gossip on completion parcels
        remote = [e for e in router.engines if isinstance(e, RemoteEngine)]
        for i, e in enumerate(remote):
            router.set_tier(e.name, INTERACTIVE if i == 0 else BATCH)
        AdmissionController.for_router(router, high=0.85, low=0.60)
    recorder = None
    if args.fleet:
        from repro.fleet import FleetController

        controller = FleetController(net, router, interval=0.25)
        if args.flight_recorder:
            from repro.obs.recorder import FlightRecorder

            recorder = FlightRecorder(net, prefix=args.flight_recorder)
            recorder.start()  # always-on rings, fleet-wide
            recorder.install(controller, p99_high=5.0)
        controller.start()
    exporter = None
    if args.metrics_port is not None:
        from repro.obs.metrics import MetricsExporter

        exporter = MetricsExporter(net=net, port=args.metrics_port).start()
        print(f"metrics: {exporter.url}", flush=True)
    timeline = None
    tl_sampler = None
    if args.timeline:
        from repro.obs.sampler import FleetSampler
        from repro.obs.timeseries import TimelineWriter

        timeline = TimelineWriter(args.timeline, pattern="*", interval=0.25,
                                  meta={"launcher": "serve",
                                        "arch": args.arch})
        if controller is not None:
            # ride the control plane's sweep — one sampler, two consumers
            controller.sampler.timeline = timeline
        else:
            tl_sampler = FleetSampler(pattern="*", interval=0.25, net=net,
                                      timeline=timeline)
            tl_sampler.sample_once()  # t=0 baseline record
            tl_sampler.start()
    sampling = SamplingParams(temperature=args.temperature,
                              top_k=args.top_k, top_p=args.top_p)
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    def _slo_for(i: int):
        if not args.slo:
            return None
        from repro.fleet import BATCH, INTERACTIVE

        return INTERACTIVE if (i % max(round(1 / max(args.slo_mix, 1e-9)), 1)
                               == 0) else BATCH

    if args.stream:
        streams = []
        for i in range(args.requests):
            prompt = rng.integers(1, cfg.vocab_size, size=rng.integers(4, 32)).tolist()
            streams.append(router.submit_stream(prompt, sampling=sampling,
                                                slo=_slo_for(i)))
        outs = []
        for ch, fut in streams:
            toks = list(ch)  # arrives token-by-token as slots advance
            outs.append(fut.get(timeout=600))
            assert toks == outs[-1]
    else:
        futures = []
        for i in range(args.requests):
            prompt = rng.integers(1, cfg.vocab_size, size=rng.integers(4, 32)).tolist()
            futures.append(router.submit(prompt, sampling=sampling,
                                         slo=_slo_for(i)))
        outs = [f.get(timeout=600) for f in futures]
    if controller is not None:
        controller.tick()  # final release sweep before measuring
        controller.stop()
    dt = time.perf_counter() - t0
    total_tokens = sum(len(o) for o in outs)
    report = {
        "requests": len(outs),
        "engines": len(router.engines),
        "localities": args.localities,
        "generated_tokens": total_tokens,
        "wall_s": round(dt, 3),
        "tokens_per_s": round(total_tokens / dt, 2),
        "counters": dict(core.counters.query("/serve*")),
    }
    if net is not None:
        from repro import net as rnet

        # per-locality serving counters, read across the parcelport
        report["per_locality_tokens"] = {
            f"locality#{loc}": dict(rnet.query_counters(
                loc, "/serve{engine*}/tokens/generated"))
            for loc in range(args.localities)
        }
    if args.trace:
        from repro.obs import export as obs_export

        tr = obs_export.export_chrome_trace(args.trace, net=net)
        report["trace"] = {"path": args.trace,
                           "events": len(tr["traceEvents"])}
        if args.slow_report:
            from repro.obs import attribution as obs_attr

            rep = obs_attr.slow_report(tr)
            print(obs_attr.format_report(rep))
            report["slow_report"] = {"requests": rep["requests"],
                                     "tiers": sorted(rep["tiers"])}
    if recorder is not None:
        report["flight_recorder"] = {
            "dumps": int(recorder.c_dumps.get_value()),
            "last": recorder.last_path,
        }
        recorder.stop()
    if args.print_counters:
        from repro.obs import sampler as obs_sampler

        obs_sampler.print_counter_report(args.print_counters, net=net)
    if timeline is not None:
        if tl_sampler is not None:
            tl_sampler.stop()
            tl_sampler.sample_once()  # end-of-run record (≥2 guaranteed)
        timeline.close()
        report["timeline"] = {"path": args.timeline,
                              "records": timeline.records_written,
                              "stride": timeline.stride}
    if exporter is not None:
        report["metrics_url"] = exporter.url
        exporter.close()
    if net is not None:
        net.shutdown()
    print(json.dumps(report, indent=1))
    core.finalize()


if __name__ == "__main__":
    main()
