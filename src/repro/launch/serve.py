"""Serving launcher: batched requests through the continuous-batching engine.

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen25_3b --smoke \
      --requests 8 --max-new 16
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--plan", default="serve")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=256)
    ap.add_argument("--workers", type=int, default=4)
    args = ap.parse_args()

    import repro.core as core
    from repro.configs import get_config
    from repro.dist.plan import get_plan
    from repro.models.model import build_model
    from repro.serve.engine import Engine, ServeConfig

    core.init(num_workers=args.workers)
    cfg = get_config(args.arch, smoke=args.smoke)
    model = build_model(cfg, get_plan(args.plan))
    params = model.init(jax.random.PRNGKey(0))

    extra = {}
    if cfg.family == "vlm":
        extra["patches"] = jax.numpy.zeros((1, cfg.n_patches, cfg.d_model),
                                           jax.numpy.bfloat16)
    if cfg.family == "encdec":
        extra["enc"] = jax.numpy.zeros((1, 64, cfg.d_model), jax.numpy.bfloat16)
        extra["enc_len"] = 64

    engine = Engine(model, params,
                    ServeConfig(max_batch=args.max_batch, cache_len=args.cache_len,
                                max_new_tokens=args.max_new), extra_inputs=extra)
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    futures = []
    for i in range(args.requests):
        prompt = rng.integers(1, cfg.vocab_size, size=rng.integers(4, 32)).tolist()
        futures.append(engine.submit(prompt))
    outs = [f.get(timeout=600) for f in futures]
    dt = time.perf_counter() - t0
    total_tokens = sum(len(o) for o in outs)
    print(json.dumps({
        "requests": len(outs),
        "generated_tokens": total_tokens,
        "wall_s": round(dt, 3),
        "tokens_per_s": round(total_tokens / dt, 2),
        "counters": dict(core.counters.query("/serve*")),
    }, indent=1))
    core.finalize()


if __name__ == "__main__":
    main()
