import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first init).  This module is the ONLY place that forces 512
# placeholder devices — smoke tests and benches see the real CPU device.

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this lowers the real step function (train_step for train
shapes, prefill/serve steps for inference shapes) against ShapeDtypeStruct
stand-ins with the production shardings, compiles it, and records:

- ``memory_analysis()``   bytes per device (proves the cell fits HBM),
- ``cost_analysis()``     HLO FLOPs / bytes (roofline numerator),
- post-SPMD collective inventory (``dist.hlo_analysis``) with while-loop
  trip counts — collective_bytes is NOT in cost_analysis,
- compile wall time.

Results go to ``results/dryrun/<arch>__<shape>__<mesh>__<plan>.json`` —
EXPERIMENTS.md §Dry-run / §Roofline read from there.

Usage:
  python -m repro.launch.dryrun --arch qwen25_3b --shape train_4k --mesh pod
  python -m repro.launch.dryrun --all [--mesh both] [--plan futurized]
"""

import argparse
import json
import subprocess
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"


def _serve_params_sds(specs):
    """Serving uses bf16 weights (no fp32 master copy at inference)."""
    return {p: jax.ShapeDtypeStruct(s.shape, jnp.bfloat16) for p, s in specs.items()}


def run_cell(arch: str, shape: str, mesh_name: str, plan_name: str,
             out_dir: Path = RESULTS, force: bool = False,
             microbatches: int = 1, variant: str = "") -> dict:
    from repro.configs import SHAPES, get_config
    from repro.dist.plan import get_plan
    from repro.launch import mesh as mesh_mod
    from repro.models.model import build_model
    from repro.models.params import param_bytes
    from repro.optim import adamw
    from repro.train import step as step_mod

    out_dir.mkdir(parents=True, exist_ok=True)
    tag = plan_name if microbatches == 1 else f"{plan_name}-mb{microbatches}"
    if variant:
        tag = f"{tag}-{variant}"
    out_path = out_dir / f"{arch}__{shape}__{mesh_name}__{tag}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())

    cfg = get_config(arch)
    cell = SHAPES[shape]
    plan = get_plan(plan_name, **({"microbatches": microbatches}
                                  if microbatches > 1 else {}))
    if variant:  # perf-iteration ablations on the optimized plan
        from dataclasses import replace as _replace

        rules = dict(plan.rules)
        if variant in ("bf16only", "nomods"):
            rules["seq_sp"] = None
        kw = {"rules": rules}
        if variant in ("sponly", "nomods", "spupfront"):
            kw["bf16_boundaries"] = False
        if variant == "spupfront":  # gather weights once per step, reuse
            kw["gather_upfront"] = True  # across all microbatches
        if variant in ("tponly", "tponly-kvseq"):  # == the `serve` plan ablations
            rules["embed"] = None
            kw["fsdp"] = False
            kw["gather_upfront"] = True  # params already whole per TP shard
            if variant == "tponly":
                rules["kv_seq"] = None
        plan = _replace(plan, **kw)
    mesh = mesh_mod.make_production_mesh(multi_pod=(mesh_name == "multipod"))
    n_dev = int(np.prod(list(mesh.shape.values())))
    model = build_model(cfg, plan)
    specs = model.param_specs()

    t0 = time.time()
    with jax.set_mesh(mesh):
        p_sh, o_sh = step_mod.train_state_shardings(model, mesh)

        if cell.kind == "train":
            opt_cfg = adamw.AdamWConfig()
            fn = step_mod.make_train_step(model, opt_cfg, mesh)
            b_specs = model.batch_specs(cell)
            b_sh = step_mod.batch_shardings(model, mesh, b_specs)
            jitted = jax.jit(fn, in_shardings=(p_sh, o_sh, b_sh),
                             out_shardings=(p_sh, o_sh, None),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(model.abstract_params(),
                                   adamw.abstract_state(specs), b_specs)
        elif cell.kind == "prefill":
            fn = step_mod.make_prefill_step(model)
            in_specs = model.prefill_specs(cell)
            in_sh = step_mod.batch_shardings(model, mesh, in_specs)
            c_specs = model.cache_specs(cell.global_batch, cell.seq_len,
                                        enc_len=cell.seq_len)
            c_sh = step_mod.cache_shardings(model, mesh, c_specs)
            jitted = jax.jit(fn, in_shardings=(p_sh, in_sh),
                             out_shardings=(None, c_sh))
            lowered = jitted.lower(_serve_params_sds(specs), in_specs)
        else:  # decode
            fn = step_mod.make_decode_step(model)
            c_specs, tok_spec = model.decode_specs(cell)
            c_sh = step_mod.cache_shardings(model, mesh, c_specs)
            t_sh = plan.sharding(("batch", None), tok_spec.shape, mesh)
            jitted = jax.jit(fn, in_shardings=(p_sh, c_sh, t_sh),
                             out_shardings=(t_sh, c_sh), donate_argnums=(1,))
            lowered = jitted.lower(_serve_params_sds(specs), c_specs, tok_spec)

        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    # ---------------- analyses -------------------------------------------
    mem = {}
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes",
                      "alias_size_in_bytes"):
                if hasattr(ma, k):
                    mem[k] = int(getattr(ma, k))
    except Exception as e:  # noqa: BLE001
        mem["error"] = str(e)

    cost = {}
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        cost = {k: float(ca[k]) for k in ("flops", "bytes accessed") if k in ca}
    except Exception as e:  # noqa: BLE001
        cost["error"] = str(e)

    # static HLO profile: exact matmul FLOPs & collective bytes with
    # while-loop trip counts (cost_analysis counts loop bodies once)
    from repro.dist.hlo_analysis import parse_module

    hlo = compiled.as_text()
    mod = parse_module(hlo, n_dev)
    coll = mod.collectives()
    flops_dev = mod.dot_flops()
    traffic_dev = mod.memory_traffic()

    rec = {
        "arch": arch, "shape": shape, "mesh": mesh_name, "plan": tag,
        "n_devices": n_dev, "kind": cell.kind,
        "seq_len": cell.seq_len, "global_batch": cell.global_batch,
        "param_bytes_fp32": param_bytes(specs),
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": mem,
        "hlo_flops_per_device": float(flops_dev),
        "hlo_flops_total": float(flops_dev) * n_dev,
        "hbm_traffic_per_device": float(traffic_dev),
        "cost_analysis_raw": cost,  # loop bodies counted once; see hlo_*
        "collectives": {
            "count": coll.count(),
            "wire_bytes_total": int(coll.total_wire()),
            "wire_bytes_ici": int(coll.total_wire(crosses_pod=False)),
            "wire_bytes_dci": int(coll.total_wire(crosses_pod=True)),
            "operand_bytes_total": int(coll.total_operand()),
            "by_kind": {k: int(v) for k, v in coll.by_kind().items()},
        },
        "hlo_bytes": len(hlo),
    }
    out_path.write_text(json.dumps(rec, indent=1))
    # keep the optimized HLO (gzipped) so analyses can be refined without
    # recompiling — the perf loop reads these
    import gzip

    with gzip.open(out_path.with_suffix(".hlo.gz"), "wt") as f:
        f.write(hlo)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="pod", choices=("pod", "multipod", "both"))
    ap.add_argument("--plan", default="futurized")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--variant", default="",
                    choices=("", "bf16only", "sponly", "nomods", "spupfront",
                             "tponly", "tponly-kvseq"))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default=str(RESULTS))
    args = ap.parse_args()
    out_dir = Path(args.out)

    if args.all:
        # subprocess per cell: isolation + bounded memory per compile
        from repro.configs import all_cells

        meshes = ("pod", "multipod") if args.mesh == "both" else (args.mesh,)
        cells = all_cells()
        done = failed = 0
        for mesh_name in meshes:
            for arch, shape in cells:
                tag = f"{arch}__{shape}__{mesh_name}__{args.plan}"
                if (out_dir / f"{tag}.json").exists() and not args.force:
                    done += 1
                    continue
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape, "--mesh", mesh_name,
                       "--plan", args.plan, "--out", str(out_dir)]
                if args.force:
                    cmd.append("--force")
                t0 = time.time()
                r = subprocess.run(cmd, capture_output=True, text=True)
                ok = r.returncode == 0
                done += ok
                failed += not ok
                print(f"[{'OK' if ok else 'FAIL'}] {tag} ({time.time()-t0:.0f}s)",
                      flush=True)
                if not ok:
                    (out_dir / f"{tag}.err").write_text(r.stdout[-4000:] + "\n" + r.stderr[-8000:])
        print(f"dryrun --all: {done} ok, {failed} failed")
        sys.exit(1 if failed else 0)

    rec = run_cell(args.arch, args.shape, args.mesh, args.plan,
                   out_dir=out_dir, force=args.force,
                   microbatches=args.microbatches, variant=args.variant)
    print(json.dumps(rec, indent=1))


if __name__ == "__main__":
    main()
