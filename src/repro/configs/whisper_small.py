"""whisper-small [arXiv:2212.04356] — enc-dec audio transformer backbone.

12L encoder + 12L decoder, d_model=768, 12 heads (MHA), d_ff=3072,
vocab=51865. Conv frontend is a STUB: ``input_specs`` provides precomputed
frame embeddings (B, frames, d_model). Whisper uses pre-LN LayerNorm, GELU,
non-gated MLP, learned positions in the decoder (no RoPE).
"""
from repro.configs.base import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="whisper_small", family="encdec",
        num_layers=24, enc_layers=12, dec_layers=12,
        d_model=768, num_heads=12, num_kv_heads=12, head_dim=64,
        d_ff=3072, vocab_size=51865,
        norm="layernorm", act="gelu", glu=False, rope=False,
        learned_pos=True, qkv_bias=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="whisper_small_smoke", family="encdec",
        num_layers=4, enc_layers=2, dec_layers=2,
        d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=512,
        norm="layernorm", act="gelu", glu=False, rope=False,
        learned_pos=True, qkv_bias=True,
    )
