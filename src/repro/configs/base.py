"""Config system: architecture configs, input-shape cells, and the registry.

Each assigned architecture lives in ``src/repro/configs/<id>.py`` exposing
``full_config()`` (the exact published dims) and ``smoke_config()`` (a
reduced same-family config for CPU smoke tests).  The registry maps
``--arch <id>`` to those.

The four assigned input-shape cells are global (``SHAPES``); per-arch
applicability (e.g. ``long_500k`` only for sub-quadratic families) is
resolved by :func:`cells_for`.
"""

from __future__ import annotations

import importlib
from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    # attention / embedding flags
    qkv_bias: bool = False
    rope: bool = True
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    act: str = "silu"  # silu | gelu
    glu: bool = True  # gated MLP (SwiGLU/GeGLU) vs plain 2-layer
    causal: bool = True
    window: int = 0  # >0: sliding-window (local) attention
    learned_pos: bool = False  # learned absolute positions (whisper decoder)
    max_position: int = 0  # size of learned position table (0 = max seq)
    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    first_dense: int = 0  # leading dense FFN layers (DeepSeekMoE)
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    dense_d_ff: int = 0  # d_ff for the leading dense layers / shared experts base
    # SSM (Mamba-2 SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_chunk: int = 256
    ssm_ngroups: int = 1
    ssm_conv: int = 4
    # Hybrid (RecurrentGemma / Griffin)
    block_pattern: Tuple[str, ...] = ()  # e.g. ("rec", "rec", "attn")
    lru_width: int = 0
    # Enc-dec (Whisper)
    enc_layers: int = 0
    dec_layers: int = 0
    # VLM (InternVL2)
    n_patches: int = 0
    # numerics / kernels
    dtype: str = "bfloat16"  # activation/compute dtype
    param_dtype: str = "float32"
    attn_impl: str = "xla"  # xla | pallas (flash kernel; interpret on CPU)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to 128 so the TP axis always divides it (embedding
        tables and logits shard on every mesh; padded logit columns are
        masked to -inf in ``unembed`` — exact semantics preserved)."""
        return ((self.vocab_size + 127) // 128) * 128

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Can this family decode at 500k context? SSM: O(1) state.
        Hybrid: O(window) local attention + O(1) recurrent state."""
        return self.family in ("ssm", "hybrid")

    @property
    def moe_layer_count(self) -> int:
        return self.num_layers - self.first_dense if self.is_moe else 0


@dataclass(frozen=True)
class ShapeCell:
    """One assigned (input shape) cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524_288, 1, "decode"),
}

ARCH_IDS: List[str] = [
    "whisper_small",
    "mamba2_780m",
    "qwen25_3b",
    "starcoder2_3b",
    "granite_34b",
    "starcoder2_15b",
    "deepseek_moe_16b",
    "granite_moe_3b_a800m",
    "recurrentgemma_2b",
    "internvl2_2b",
]

# accept dashed spellings on the CLI
_ALIASES = {a.replace("_", "-"): a for a in ARCH_IDS}


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    arch = _ALIASES.get(arch, arch)
    if arch not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; choose from {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.smoke_config() if smoke else mod.full_config()


def cells_for(cfg: ModelConfig) -> List[str]:
    """Applicable shape cells for an arch (DESIGN.md §4 skips)."""
    cells = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.sub_quadratic:
        cells.append("long_500k")  # needs sub-quadratic attention
    return cells


def all_cells() -> List[Tuple[str, str]]:
    """Every live (arch, shape) baseline cell."""
    out: List[Tuple[str, str]] = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for cell in cells_for(cfg):
            out.append((arch, cell))
    return out
