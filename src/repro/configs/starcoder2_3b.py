"""starcoder2-3b [arXiv:2402.19173] — dense decoder, GQA kv=2, RoPE.

30L, d_model=3072, 24 q heads / 2 kv heads, head_dim=128, d_ff=12288 (4d,
non-gated GELU MLP), vocab=49152, LayerNorm, attention bias.
"""
from repro.configs.base import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2_3b", family="dense",
        num_layers=30, d_model=3072, num_heads=24, num_kv_heads=2,
        head_dim=128, d_ff=12288, vocab_size=49152,
        norm="layernorm", act="gelu", glu=False, qkv_bias=True,
        rope=True, rope_theta=1e5,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2_3b_smoke", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=512,
        norm="layernorm", act="gelu", glu=False, qkv_bias=True,
        rope=True, rope_theta=1e5,
    )
