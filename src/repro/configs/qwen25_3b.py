"""qwen2.5-3b [hf:Qwen/Qwen2.5 family] — dense decoder, GQA kv=2, QKV bias.

36L, d_model=2048, 16 q heads / 2 kv heads, head_dim=128, d_ff=11008,
vocab=151936, SwiGLU, RMSNorm, RoPE theta=1e6.
"""
from repro.configs.base import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="qwen25_3b", family="dense",
        num_layers=36, d_model=2048, num_heads=16, num_kv_heads=2,
        head_dim=128, d_ff=11008, vocab_size=151936,
        qkv_bias=True, rope=True, rope_theta=1e6,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen25_3b_smoke", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=512,
        qkv_bias=True, rope=True, rope_theta=1e6,
    )
