"""deepseek-moe-16b [arXiv:2401.06066] — fine-grained MoE, 2 shared + 64
routed experts top-6.

28L (first layer dense FFN d_ff=10944), d_model=2048, 16 heads MHA (kv=16),
head_dim=128, per-expert d_ff=1408, vocab=102400, SwiGLU, RMSNorm, RoPE.
The MoE dispatch is the flagship *parcel* user (DESIGN.md P4): tokens are
active messages routed to expert localities.
"""
from repro.configs.base import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek_moe_16b", family="moe",
        num_layers=28, d_model=2048, num_heads=16, num_kv_heads=16,
        head_dim=128, d_ff=1408, vocab_size=102400,
        n_experts=64, n_shared_experts=2, top_k=6, first_dense=1,
        dense_d_ff=10944, rope=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek_moe_16b_smoke", family="moe",
        num_layers=3, d_model=64, num_heads=4, num_kv_heads=4,
        head_dim=16, d_ff=32, vocab_size=512,
        n_experts=8, n_shared_experts=2, top_k=2, first_dense=1,
        dense_d_ff=128, rope=True,
    )
