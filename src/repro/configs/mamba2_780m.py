"""mamba2-780m [arXiv:2405.21060] — attention-free SSD (state-space duality).

48 layers, d_model=1536, d_inner=2*d=3072, headdim=64 (48 SSD heads),
d_state=128, vocab=50280. Pure SSM: runs the long_500k cell (O(1) decode
state).
"""
from repro.configs.base import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2_780m", family="ssm",
        num_layers=48, d_model=1536, num_heads=0, num_kv_heads=0, head_dim=0,
        d_ff=0, vocab_size=50280, rope=False, glu=False,
        ssm_state=128, ssm_expand=2, ssm_headdim=64, ssm_chunk=256,
        ssm_ngroups=1, ssm_conv=4, tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2_780m_smoke", family="ssm",
        num_layers=2, d_model=64, num_heads=0, num_kv_heads=0, head_dim=0,
        d_ff=0, vocab_size=512, rope=False, glu=False,
        ssm_state=16, ssm_expand=2, ssm_headdim=16, ssm_chunk=32,
        ssm_ngroups=1, ssm_conv=4, tie_embeddings=True,
    )
