"""starcoder2-15b [arXiv:2402.19173] — dense decoder, GQA kv=4, RoPE.

40L, d_model=6144, 48 q heads / 4 kv heads, head_dim=128, d_ff=24576 (4d,
non-gated GELU MLP), vocab=49152, LayerNorm, attention bias.
"""
from repro.configs.base import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2_15b", family="dense",
        num_layers=40, d_model=6144, num_heads=48, num_kv_heads=4,
        head_dim=128, d_ff=24576, vocab_size=49152,
        norm="layernorm", act="gelu", glu=False, qkv_bias=True,
        rope=True, rope_theta=1e5,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2_15b_smoke", family="dense",
        num_layers=2, d_model=96, num_heads=6, num_kv_heads=2,
        head_dim=16, d_ff=192, vocab_size=512,
        norm="layernorm", act="gelu", glu=False, qkv_bias=True,
        rope=True, rope_theta=1e5,
    )
