"""recurrentgemma-2b [arXiv:2402.19427 Griffin] — hybrid RG-LRU + local attn.

26 layers in pattern (rec, rec, attn): 8 full groups of 3 + 2 trailing rec
layers. d_model=2560, lru_width=2560, 10 q heads / 1 kv head (MQA),
head_dim=256, d_ff=7680 (GeGLU), vocab=256000, local attention window 2048.
Sub-quadratic: runs the long_500k cell (recurrent state + 2048-window KV).
"""
from repro.configs.base import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma_2b", family="hybrid",
        num_layers=26, d_model=2560, num_heads=10, num_kv_heads=1,
        head_dim=256, d_ff=7680, vocab_size=256000,
        act="gelu", glu=True, rope=True, rope_theta=1e4,
        window=2048, block_pattern=("rec", "rec", "attn"),
        lru_width=2560, ssm_conv=4, tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma_2b_smoke", family="hybrid",
        num_layers=5, d_model=64, num_heads=4, num_kv_heads=1,
        head_dim=16, d_ff=128, vocab_size=512,
        act="gelu", glu=True, rope=True,
        window=32, block_pattern=("rec", "rec", "attn"),
        lru_width=64, ssm_conv=4, tie_embeddings=True,
    )
