"""granite-moe-3b-a800m [hf:ibm-granite granite-3.0 MoE family] — 40 routed
experts, top-8, no shared experts.

32L, d_model=1536, 24 q heads / 8 kv heads, head_dim=64, per-expert
d_ff=512, vocab=49155, SwiGLU, RMSNorm, RoPE.

EP note (DESIGN.md §5): 40 experts do not divide the 16-way model axis, so
this arch uses TP-inside-expert (experts replicated, expert d_ff sharded)
— dispatch-time balance instead of expert-location balance.
"""
from repro.configs.base import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="granite_moe_3b_a800m", family="moe",
        num_layers=32, d_model=1536, num_heads=24, num_kv_heads=8,
        head_dim=64, d_ff=512, vocab_size=49155,
        n_experts=40, n_shared_experts=0, top_k=8,
        rope=True, tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="granite_moe_3b_a800m_smoke", family="moe",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=32, vocab_size=512,
        n_experts=5, n_shared_experts=0, top_k=2,
        rope=True, tie_embeddings=True,
    )
