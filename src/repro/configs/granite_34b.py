"""granite-34b [arXiv:2405.04324] — llama-arch code model, MQA (kv=1), 88L.

d_model=6144, 48 q heads / 1 kv head, head_dim=128, d_ff=24576,
vocab=49152, SwiGLU, RMSNorm, RoPE.
"""
from repro.configs.base import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="granite_34b", family="dense",
        num_layers=88, d_model=6144, num_heads=48, num_kv_heads=1,
        head_dim=128, d_ff=24576, vocab_size=49152,
        rope=True, rope_theta=1e5,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="granite_34b_smoke", family="dense",
        num_layers=3, d_model=64, num_heads=4, num_kv_heads=1,
        head_dim=16, d_ff=128, vocab_size=512,
        rope=True, rope_theta=1e5,
    )
