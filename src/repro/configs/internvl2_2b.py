"""internvl2-2b [arXiv:2404.16821] — VLM: InternViT frontend + InternLM2 LM.

LM backbone only (the assignment): 24L, d_model=2048, 16 q heads / 8 kv
heads, head_dim=128, d_ff=8192, vocab=92553, SwiGLU, RMSNorm, RoPE.  The
InternViT frontend is a STUB: ``input_specs`` provides precomputed patch
embeddings (B, 256, d_model) that replace the first 256 token positions.
"""
from repro.configs.base import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="internvl2_2b", family="vlm",
        num_layers=24, d_model=2048, num_heads=16, num_kv_heads=8,
        head_dim=128, d_ff=8192, vocab_size=92553,
        n_patches=256, rope=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="internvl2_2b_smoke", family="vlm",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=512,
        n_patches=8, rope=True,
    )
