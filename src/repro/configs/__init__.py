"""Architecture configs — one module per assigned architecture."""
from repro.configs.base import (
    ARCH_IDS,
    SHAPES,
    ModelConfig,
    ShapeCell,
    all_cells,
    cells_for,
    get_config,
)

__all__ = [
    "ARCH_IDS", "SHAPES", "ModelConfig", "ShapeCell",
    "all_cells", "cells_for", "get_config",
]
