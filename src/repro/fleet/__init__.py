"""repro.fleet — counter-driven adaptive serving control plane.

The paper's §2.4 closes with the point of performance counters: *adaptivity*
— measured state feeding resource decisions at runtime.  This package is
that loop, one level above the router: on locality 0 a
:class:`~repro.fleet.controller.FleetController` polls the fleet's counters
(:class:`~repro.obs.sampler.FleetSampler` histories + router gossip),
evaluates declarative :class:`~repro.fleet.policy.Policy` rules with
hysteresis, and actuates —

- **SLO tiers** (:mod:`repro.fleet.slo`): interactive vs batch request
  classes routed to different engines; batch admission gated on *gossiped*
  KV-page occupancy, not queue depth.
- **Elasticity** (:mod:`repro.fleet.elastic`): spawn a whole new locality
  (+engine) into the running fleet, or drain and retire one.
- **Live migration** (:mod:`repro.fleet.migrate`): move a *running* engine
  — paged KV and in-flight streams included — to another locality with
  zero dropped or duplicated tokens.
"""

from repro.fleet.controller import FleetController
from repro.fleet.elastic import grow_engine, retire_engine
from repro.fleet.migrate import migrate_engine
from repro.fleet.policy import (
    EngineView,
    FleetView,
    Policy,
    utilization_policy,
)
from repro.fleet.slo import BATCH, INTERACTIVE, AdmissionController

__all__ = [
    "AdmissionController", "BATCH", "EngineView", "FleetController",
    "FleetView", "INTERACTIVE", "Policy", "grow_engine", "migrate_engine",
    "retire_engine", "utilization_policy",
]
