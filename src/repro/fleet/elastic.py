"""Elastic fleet membership: grow / retire engine localities at runtime.

``grow_engine`` is the whole join path in one call: spawn a brand-new OS
process into the *running* fleet (:meth:`NetRuntime.spawn_locality` —
HELLO handshake, AGAS-root registration, TOPO broadcast so every peer
accepts routes to the newcomer), build an engine there by the router's
own construction recipe (``router.spec``), and admit it to dispatch under
an SLO tier.  The new capacity starts taking requests on the next
``pick``.

``retire_engine`` is the inverse, drain-first: the engine leaves dispatch
immediately, the drain loop polls its locality's counters until
``submitted - completed`` reaches zero (nothing in flight to strand),
then the locality is BYEd, reaped, purged from the AGAS root and DOWNed
to peers (:meth:`NetRuntime.retire_locality`).  Anything live-migration
should rescue must be migrated *before* calling this — retirement is for
drained capacity, crash recovery is the router failover's job.

Counters::

    /fleet{elastic}/grown     cumulative
    /fleet{elastic}/retired   cumulative
"""

from __future__ import annotations

import time
from typing import Any, Dict, Optional

from repro.core import agas as _agas
from repro.core import counters as _counters
from repro.serve.router import RemoteEngine, Router, _spawn_engine

__all__ = ["grow_engine", "retire_engine"]

# a fresh serving locality wants the engine's pool layout, not the worker
# default ({"default": 2, "io": 1})
_SERVE_POOLS = {"default": 2, "prefill": 2, "io": 1}


def _c(name: str):
    return _counters.default().counter(f"/fleet{{elastic}}/{name}")


def grow_engine(net, router: Router, tier: Optional[str] = None,
                pools: Optional[Dict[str, int]] = None,
                timeout: float = 600.0) -> RemoteEngine:
    """Spawn locality + engine + router admission, in that order.  Returns
    the new :class:`RemoteEngine` handle (its name is ``engine#<lid>``)."""
    from repro.net import remote as _remote

    spec = router.spec
    if spec is None:
        raise RuntimeError("router has no construction spec "
                           "(grow requires Router.over_localities)")
    lid = net.spawn_locality(pools=dict(pools or _SERVE_POOLS),
                            timeout=min(timeout, 120.0))
    name = f"engine#{lid}"
    key = _remote.run_on(lid, _spawn_engine, spec["arch"], spec["smoke"],
                         spec["plan"],
                         {**spec["scfg_kwargs"], "name": name}
                         ).get(timeout=timeout)
    engine = RemoteEngine(net, lid, _agas.GID(*key), name)
    router.add_engine(engine, tier)
    _c("grown").increment()
    return engine


def retire_engine(net, router: Router, name: str, timeout: float = 120.0,
                  poll: float = 0.05) -> int:
    """Drain-first retirement of a remote engine's whole locality.
    Returns the retired locality id."""
    from repro.net import remote as _remote

    engine = router.engine(name)
    if not isinstance(engine, RemoteEngine):
        raise ValueError(f"{name!r} is not a remote engine; the root "
                         f"locality cannot retire itself")
    tier = router.tier_of(name)
    router.remove_engine(name)  # out of dispatch before the drain starts
    lid = engine.locality
    sub_name = f"/serve{{{name}}}/requests/submitted"
    done_name = f"/serve{{{name}}}/requests/completed"
    deadline = time.monotonic() + timeout
    while True:
        pairs: Dict[str, Any] = dict(_remote.query_counters(
            lid, f"/serve{{{name}}}/requests/*", timeout=30.0))
        inflight = pairs.get(sub_name, 0.0) - pairs.get(done_name, 0.0)
        if inflight <= 0:
            break
        if time.monotonic() > deadline:
            router.add_engine(engine, tier)  # undo: engine is stuck live
            raise TimeoutError(
                f"retire_engine({name}): {inflight:g} requests still in "
                f"flight after {timeout}s")
        time.sleep(poll)
    net.retire_locality(lid, timeout=min(timeout, 30.0))
    _c("retired").increment()
    return lid
