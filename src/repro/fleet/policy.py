"""Declarative fleet policies: counter thresholds → actuator firings.

A :class:`Policy` is a rule the controller evaluates every tick against a
:class:`FleetView` (the tick's consistent snapshot of fleet state): a
``metric`` callable reduces the view to one number, and crossing ``high``
(or falling to ``low``) for ``sustain`` consecutive ticks fires the
``up`` (or ``down``) actuator — subject to a per-policy ``cooldown`` so
one burst cannot fire grow-then-shrink-then-grow in three ticks.

The shape mirrors the paper's adaptivity loop: *measure* (counters →
view), *decide* (threshold + hysteresis-by-sustain), *act* (a named
actuator the controller owns: grow an engine, migrate one, shed load).
Policies never actuate directly — they return the actuator's name, which
keeps them trivially unit-testable with a synthetic view.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

__all__ = ["EngineView", "FleetView", "Policy", "utilization_policy"]


@dataclass
class EngineView:
    """One engine as the controller saw it this tick."""
    name: str
    locality: int
    tier: Optional[str]
    load: float
    occupancy: float


@dataclass
class FleetView:
    """Per-tick snapshot the policies evaluate against.  ``rates`` carries
    the sampler's per-(locality, counter) rates for anything the metric
    wants beyond load/occupancy (token throughput, step p99, …)."""
    now: float
    engines: List[EngineView] = field(default_factory=list)
    occupancy: float = 0.0      # max across live engines (the gate signal)
    gated_depth: int = 0        # batch requests parked at the admission gate
    rates: Dict[Tuple[int, str], float] = field(default_factory=dict)
    latest: Dict[Tuple[int, str], float] = field(default_factory=dict)

    def total_load(self) -> float:
        return sum(e.load for e in self.engines)

    def tier_load(self, tier: Optional[str]) -> float:
        return sum(e.load for e in self.engines if e.tier == tier)

    def rate(self, locality: int, name: str) -> float:
        return self.rates.get((locality, name), 0.0)

    # ------------------------------------------- scheduler health signals
    def pool_utilization(self, locality: int, pool: str = "default") -> float:
        """Windowed utilization of one locality's pool, derived from the
        cumulative ``time/busy`` / ``time/idle`` clock rates the sampler
        retained — the fraction of worker wall-time spent running tasks
        over the sampler's window.  0.0 when the counters were never
        sampled (an unreachable locality reads as idle, not saturated,
        so a grow policy can't be spooked by a dead peer)."""
        busy = self.rate(locality, f"/scheduler{{{pool}}}/time/busy")
        idle = self.rate(locality, f"/scheduler{{{pool}}}/time/idle")
        total = busy + idle
        return busy / total if total > 0.0 else 0.0

    def pool_idle_rate(self, locality: int, pool: str = "default") -> float:
        busy = self.rate(locality, f"/scheduler{{{pool}}}/time/busy")
        idle = self.rate(locality, f"/scheduler{{{pool}}}/time/idle")
        total = busy + idle
        return idle / total if total > 0.0 else 1.0

    def mean_utilization(self, pool: str = "default") -> float:
        """Fleet-wide mean pool utilization across every locality the
        sampler has busy/idle clocks for — the saturation signal a
        grow-on-starvation policy predicates on."""
        suffix = f"/scheduler{{{pool}}}/time/busy"
        locs = sorted({loc for (loc, name) in self.rates if name == suffix})
        if not locs:
            return 0.0
        return sum(self.pool_utilization(loc, pool) for loc in locs) / len(locs)


def utilization_policy(high: float = 0.85, low: float = 0.15,
                       up: Optional[str] = "grow",
                       down: Optional[str] = "shrink",
                       pool: str = "default", sustain: int = 3,
                       cooldown: float = 10.0) -> "Policy":
    """The canonical scale-on-saturation rule: fleet mean utilization of
    ``pool`` sustained ≥ ``high`` fires ``up``; sustained ≤ ``low`` fires
    ``down``.  Starvation (SLOW's S) measured by the scheduler itself —
    the idle-rate counters — rather than inferred from queue proxies."""
    return Policy(f"utilization:{pool}",
                  lambda view: view.mean_utilization(pool),
                  high=high, up=up, low=low, down=down,
                  sustain=sustain, cooldown=cooldown)


class Policy:
    """Threshold rule with sustain + cooldown.

    ``metric(view) -> float`` is evaluated every tick.  After ``sustain``
    consecutive ticks at or above ``high`` the policy proposes ``up``;
    after ``sustain`` consecutive ticks at or below ``low`` it proposes
    ``down``.  A firing starts the ``cooldown`` clock; the policy stays
    silent (and keeps its streak counters frozen at zero) until it
    expires.  ``high``/``up`` or ``low``/``down`` may be omitted for
    one-sided rules."""

    def __init__(self, name: str, metric: Callable[[FleetView], float],
                 high: Optional[float] = None, low: Optional[float] = None,
                 up: Optional[str] = None, down: Optional[str] = None,
                 sustain: int = 2, cooldown: float = 5.0):
        assert (high is None) == (up is None), "high and up come together"
        assert (low is None) == (down is None), "low and down come together"
        self.name = name
        self.metric = metric
        self.high = high
        self.low = low
        self.up = up
        self.down = down
        self.sustain = max(1, sustain)
        self.cooldown = cooldown
        self.last_value: Optional[float] = None
        self._hi_streak = 0
        self._lo_streak = 0
        self._last_fired = -float("inf")

    def evaluate(self, view: FleetView,
                 now: Optional[float] = None) -> Optional[str]:
        """Returns the actuator name to fire this tick, or ``None``."""
        now = time.monotonic() if now is None else now
        value = float(self.metric(view))
        self.last_value = value
        if self.high is not None and value >= self.high:
            self._hi_streak += 1
            self._lo_streak = 0
        elif self.low is not None and value <= self.low:
            self._lo_streak += 1
            self._hi_streak = 0
        else:
            self._hi_streak = 0
            self._lo_streak = 0
        if now - self._last_fired < self.cooldown:
            return None
        if self.up is not None and self._hi_streak >= self.sustain:
            self._fire(now)
            return self.up
        if self.down is not None and self._lo_streak >= self.sustain:
            self._fire(now)
            return self.down
        return None

    def _fire(self, now: float) -> None:
        self._last_fired = now
        self._hi_streak = 0
        self._lo_streak = 0
