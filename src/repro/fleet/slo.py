"""SLO tiers + occupancy-gated batch admission.

Two request classes cross the fleet: **interactive** (a human is waiting —
p99 first-token latency is the SLO) and **batch** (throughput work that
tolerates queueing).  The router steers each class to engines labeled with
its tier (:data:`~repro.serve.router.TIER_INTERACTIVE` /
:data:`~repro.serve.router.TIER_BATCH`), so a batch flood deepens batch
queues without ever sitting in front of an interactive request.

Admission control is the second half: batch requests are *gated on
KV-page occupancy*, not queue depth.  Queue depth says how many requests
wait; occupancy says whether the engines' page pools — the resource that
actually runs out and stalls decode for everyone — are near exhaustion.
The signal costs zero extra messages: every completion parcel already
gossips its engine's ``pages_in_use / capacity`` back to the router
(:meth:`Router.occupancy` is a local read of that gossip).

:class:`AdmissionController` is a hysteresis gate over that signal: it
closes at ``high`` and only reopens at ``low``, so occupancy hovering
around one threshold cannot flap the gate (and with it the parked-request
FIFO) open and shut every tick.

Counters::

    /fleet{admission}/closed_edges   cumulative (open → closed transitions)
    /fleet{admission}/opened_edges   cumulative (closed → open transitions)
    /fleet{admission}/open           gauge (1 = admitting)
"""

from __future__ import annotations

import threading
from typing import Callable, Optional

from repro.core import counters as _counters
from repro.serve.router import TIER_BATCH, TIER_INTERACTIVE

INTERACTIVE = TIER_INTERACTIVE
BATCH = TIER_BATCH

__all__ = ["INTERACTIVE", "BATCH", "AdmissionController"]


class AdmissionController:
    """Hysteresis gate: ``allow()`` is True until the occupancy signal
    reaches ``high``; it stays False until the signal falls back to
    ``low``.  ``occupancy_fn`` is any zero-argument callable returning the
    current signal — usually ``router.occupancy`` (gossiped max KV-page
    occupancy across live engines)."""

    def __init__(self, occupancy_fn: Callable[[], float],
                 high: float = 0.85, low: float = 0.60):
        assert low <= high, (low, high)
        self._fn = occupancy_fn
        self.high = high
        self.low = low
        self._open = True
        self._lock = threading.Lock()
        self.last_signal: Optional[float] = None
        reg = _counters.default()
        self.c_closed = reg.counter("/fleet{admission}/closed_edges")
        self.c_opened = reg.counter("/fleet{admission}/opened_edges")
        self.g_open = reg.gauge("/fleet{admission}/open")
        self.g_open.set(1.0)

    def allow(self) -> bool:
        try:
            occ = float(self._fn())
        except Exception:  # noqa: BLE001 — no signal: fail open
            return True
        with self._lock:
            self.last_signal = occ
            if self._open and occ >= self.high:
                self._open = False
                self.c_closed.increment()
                self.g_open.set(0.0)
            elif not self._open and occ <= self.low:
                self._open = True
                self.c_opened.increment()
                self.g_open.set(1.0)
            return self._open

    def is_open(self) -> bool:
        with self._lock:
            return self._open

    @classmethod
    def for_router(cls, router, high: float = 0.85,
                   low: float = 0.60) -> "AdmissionController":
        """Gate on the router's gossiped occupancy and install the gate on
        the router (``submit(slo=BATCH)`` consults it from then on)."""
        ctl = cls(router.occupancy, high=high, low=low)
        router.admission = ctl
        return ctl
