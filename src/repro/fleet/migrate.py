"""Live engine migration: move a *running* engine — paged KV cache and
in-flight streams included — to another locality, dropping zero tokens.

This composes three mechanisms built elsewhere:

- ``migrate_remote``'s **no-gap ordering** (repro.net.remote): install at
  the destination under the same GID with generation+1 — which publishes
  the new owner to the AGAS root — *before* the source unregisters (whose
  conditional unpublish then no-ops).  A resolver racing the cutover lands
  at the old owner while the object is still answering, or misses and
  re-resolves to the new one; never in a gap.
- The engine's **pause / take / restore** surface (repro.serve.engine):
  quiesce at a decode-step boundary, drain every request — active slots
  with their block-pool pages (:meth:`PagedKVCache.snapshot_slot`: live
  tokens only, never the whole pool), queued ones as prompts — into a
  picklable snapshot, and rebuild them slot-for-slot at the destination
  (``pos``/``last_tok``/sampling mirrors restored, decode continues
  mid-generation).
- The **relay**'s indexed streams (repro.serve.relay): the destination
  re-attaches each migrated request's stream at ``idx=len(generated)``,
  continuing the numbering the source left off; the client sink's per-index
  dedup + done-parcel backfill make delivery exactly-once across the
  cutover regardless of how parcels interleave.

Timeline (coordinator = locality 0, where the router lives)::

    stage      dest:   build identical engine shell (router.spec), paused
    suspend    root:   router stops dispatching to the engine
    quiesce    source: pause → close_for_migration (submits now answer
                       UnknownGid → callers re-resolve) → take_requests
    install    dest:   restore_requests (+relay re-attach) → AGAS adopt
                       (gen+1, publishes new owner) → resume
    release    source: unregister (conditional unpublish no-ops)
    re-home    root:   RemoteEngine.locality ← dest; sinks re-pinned so a
                       later source retirement can't abort live streams
    resume     root:   router dispatches to the engine again

Counters: ``/fleet{migrate}/engines_moved``, ``/fleet{migrate}/requests_moved``
(plus the per-engine ``/serve{...}/requests/migrated_{in,out}`` pair).
"""

from __future__ import annotations

from typing import Any, Dict

from repro.core import agas as _agas
from repro.core import counters as _counters
from repro.core import parcel as _parcel
from repro.serve.router import (
    ENGINE_NAME_PREFIX,
    RemoteEngine,
    Router,
    build_engine,
)

__all__ = ["migrate_engine"]

# destination-side staging area: engines built but not yet AGAS-visible
_staged: Dict[str, Any] = {}


# ------------------------------------------------------------ remote actions
@_parcel.action
def _stage_engine(rt, arch: str, smoke: bool, plan: str,
                  scfg_kwargs: Dict[str, Any]) -> bool:
    """Destination, phase A: build an identical engine shell (same recipe,
    same name → shared counter identity) and park it paused + unpublished.
    All the expensive work (param init, jit warm paths) happens here,
    *outside* the cutover window."""
    engine = build_engine(arch, smoke, plan, scfg_kwargs)
    engine.pause()  # nothing runs until install hands it requests
    _staged[engine.scfg.name] = engine
    return True


@_parcel.action
def _unstage_engine(rt, name: str) -> bool:
    """Destination, abort path: drop a staged shell that will never be
    installed (the quiesce failed)."""
    return _staged.pop(name, None) is not None


@_parcel.action
def _quiesce_engine(engine, key) -> Dict[str, Any]:
    """Source, phase B (object-targeted — resolves while the source still
    owns the GID): stop at a step boundary, flip submits to UnknownGid,
    drain everything into the travel snapshot.  The engine object stays
    registered until the destination has adopted."""
    engine.pause()
    engine.close_for_migration(tuple(key))
    return engine.take_requests()


@_parcel.action
def _install_engine(rt, key, name: str, snap: Dict[str, Any],
                    generation: int) -> int:
    """Destination, phase C: adopt the requests, then the identity.

    Order inside matters: requests are restored and their relays
    re-attached *before* the AGAS adopt publishes this locality as owner —
    once a racing submit can land here, the engine must already be whole.
    ``resume`` comes last; the first decode step continues mid-generation
    requests from their shipped ``pos``/``last_tok``."""
    from repro.serve import relay as _relay

    engine = _staged.pop(name)
    n = engine.restore_requests(snap, reattach=_relay.reattach_for(engine))
    _agas.default().adopt(_agas.GID(*key), engine,
                          name=f"{ENGINE_NAME_PREFIX}{name}",
                          generation=generation)
    engine.resume()
    return n


@_parcel.action
def _release_engine(rt, key) -> bool:
    """Source, phase D: drop the husk.  Its unregister's conditional
    unpublish no-ops at the root (the destination's adopt already
    published a newer generation) — exactly ``_migrate_out``'s ordering."""
    a = _agas.default()
    gid = _agas.GID(*key)
    if not a.contains(gid):
        return False
    a.unregister(gid)
    rt.cache_invalidate(tuple(key))
    return True


# -------------------------------------------------------------- coordinator
def migrate_engine(net, router: Router, name: str, dest: int,
                   timeout: float = 600.0) -> int:
    """Live-migrate the remote engine ``name`` to locality ``dest``.
    Returns the number of in-flight requests that moved with it.

    The engine keeps its GID, symbolic name and counters; its in-flight
    requests resume mid-generation at the destination; its streams keep
    flowing into the same client channels with zero dropped or duplicated
    tokens (counter-verified: ``/serve{relay}/tokens/duplicates`` stays
    flat across a migration)."""
    from repro.net import remote as _remote
    from repro.net.locality import _gid_key
    from repro.serve import relay as _relay

    if not net.is_root():
        raise RuntimeError("migrate_engine coordinates from the root")
    engine = router.engine(name)
    if not isinstance(engine, RemoteEngine):
        raise ValueError(f"{name!r} is not a remote engine handle")
    if not net.is_live(dest):
        raise ValueError(f"destination locality#{dest} is not live")
    src = engine.locality
    if dest == src:
        return 0
    spec = router.spec
    if spec is None:
        raise RuntimeError("router has no construction spec "
                           "(migration requires Router.over_localities)")
    key = _gid_key(engine.gid)

    reg = _counters.default()
    c_moved = reg.counter("/fleet{migrate}/engines_moved")
    c_reqs = reg.counter("/fleet{migrate}/requests_moved")

    # A: stage the shell at the destination (slow; cutover not started)
    _remote.run_on(dest, _stage_engine, spec["arch"], spec["smoke"],
                   spec["plan"], {**spec["scfg_kwargs"], "name": name}
                   ).get(timeout=timeout)

    # cutover starts: router stops feeding the engine
    router.suspend(name)
    try:
        try:
            snap = _remote.apply_remote(_quiesce_engine, engine.gid,
                                        list(key)).get(timeout=timeout)
        except BaseException:
            _remote.run_on(dest, _unstage_engine, name)
            raise
        generation = net.lookup_local(key)[1]
        n = _remote.run_on(dest, _install_engine, list(key), name, snap,
                           generation + 1).get(timeout=timeout)
        # destination owns the GID now (adopt published gen+1); the husk
        # at the source can go — racing resolvers self-heal via UnknownGid
        _remote.run_on(src, _release_engine, list(key)).get(timeout=timeout)
        net.cache_invalidate(key)
        # re-pin client-side stream sinks BEFORE anyone may retire src —
        # the peer-down hook must not abort streams dest is now feeding
        _relay.rehome_streams(src, dest)
        engine.locality = dest
    finally:
        router.resume(name)
    c_moved.increment()
    c_reqs.increment(int(n))
    return int(n)
