"""FleetController — the measure → decide → act loop on locality 0.

Each tick (daemon thread, or :meth:`tick` driven synchronously from
tests):

1. **measure** — one fault-tolerant counter sweep across every live
   locality (:meth:`FleetSampler.sample_once`, which rides the sweep form
   of ``query_counters`` — a dying locality yields an error marker, never
   an exception), plus the router's locally-held load/occupancy gossip,
   folded into one :class:`~repro.fleet.policy.FleetView`;
2. **decide** — every :class:`~repro.fleet.policy.Policy` evaluates
   against the view (sustain + cooldown live in the policy);
3. **act** — fired policies name actuators (callables registered on the
   controller: grow, retire, migrate, or anything else); actuator failures
   are counted and contained — a failed grow must not kill the loop;
4. **release** — if the admission gate is open again, parked batch
   requests drain FIFO back into dispatch (``router.release_gated``).

Counters::

    /fleet{controller}/ticks             cumulative
    /fleet{controller}/actions           cumulative (actuator firings)
    /fleet{controller}/action_errors     cumulative
    /fleet{controller}/occupancy         gauge (the view's gate signal)
    /fleet{controller}/released          cumulative (gated → dispatched)
"""

from __future__ import annotations

import threading
import time
from typing import Any, Callable, Dict, Iterable, Optional

from repro.core import counters as _counters
from repro.fleet.policy import EngineView, FleetView, Policy
from repro.obs import trace as _trace
from repro.obs.sampler import FleetSampler
from repro.serve.router import RemoteEngine, Router, engine_name

__all__ = ["FleetController"]


class FleetController:
    def __init__(self, net, router: Router,
                 sampler: Optional[FleetSampler] = None,
                 policies: Iterable[Policy] = (),
                 actuators: Optional[Dict[str, Callable[..., Any]]] = None,
                 interval: float = 0.5):
        self.net = net
        self.router = router
        # "*" not "/serve*": policies now also predicate on scheduler
        # health (idle-rate / time-busy clocks) — see FleetView.pool_utilization
        self.sampler = sampler or FleetSampler(
            pattern="*", interval=interval, net=net)
        self.policies = list(policies)
        self.actuators: Dict[str, Callable[..., Any]] = dict(actuators or {})
        self.interval = interval
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self.last_view: Optional[FleetView] = None

        reg = _counters.default()
        self.c_ticks = reg.counter("/fleet{controller}/ticks")
        self.c_actions = reg.counter("/fleet{controller}/actions")
        self.c_action_errors = reg.counter("/fleet{controller}/action_errors")
        self.g_occupancy = reg.gauge("/fleet{controller}/occupancy")
        self.c_released = reg.counter("/fleet{controller}/released")

    # ------------------------------------------------------------- plumbing
    def add_policy(self, policy: Policy) -> "FleetController":
        self.policies.append(policy)
        return self

    def register(self, name: str,
                 fn: Callable[..., Any]) -> "FleetController":
        """Register an actuator; policies refer to it by this name."""
        self.actuators[name] = fn
        return self

    # -------------------------------------------------------------- measure
    def view(self, now: Optional[float] = None) -> FleetView:
        """Fold router gossip + sampler history into this tick's view.
        Reads only locally-held state — building a view costs zero
        messages (the sweep already happened in :meth:`tick`)."""
        now = time.monotonic() if now is None else now
        engines = []
        for e in list(self.router.engines):
            name = engine_name(e)
            loc = e.locality if isinstance(e, RemoteEngine) else \
                self.net.locality
            try:
                load = float(e.load())
                occ = float(e.occupancy())
            except Exception:  # noqa: BLE001 — engine mid-teardown
                continue
            engines.append(EngineView(name=name, locality=loc,
                                      tier=self.router.tier_of(name),
                                      load=load, occupancy=occ))
        view = FleetView(
            now=now, engines=engines,
            occupancy=max((e.occupancy for e in engines), default=0.0),
            gated_depth=self.router.gated_depth(),
            rates=self.sampler.rates(),
        )
        view.latest = {key: pts[-1][1] for key in self.sampler.keys()
                       for pts in [self.sampler.series(*key)] if pts}
        return view

    # ------------------------------------------------------------------ act
    def tick(self) -> FleetView:
        if _trace._enabled:
            with _trace.span("controller/tick", "fleet"):
                return self._tick_body()
        return self._tick_body()

    def _tick_body(self) -> FleetView:
        self.sampler.sample_once()
        view = self.view()
        self.last_view = view
        self.g_occupancy.set(view.occupancy)
        for policy in self.policies:
            action = policy.evaluate(view, view.now)
            if action is None:
                continue
            fn = self.actuators.get(action)
            if fn is None:
                self.c_action_errors.increment()
                if _trace._enabled:
                    _trace.instant("controller/action_error", "fleet",
                                   policy=policy.name, action=action,
                                   missing=True)
                continue
            self.c_actions.increment()
            if _trace._enabled:
                _trace.instant("controller/action", "fleet",
                               policy=policy.name, action=action)
            try:
                fn(view)
            except Exception:  # noqa: BLE001 — one failed actuation must
                self.c_action_errors.increment()  # not kill the loop
                if _trace._enabled:
                    _trace.instant("controller/action_error", "fleet",
                                   policy=policy.name, action=action)
        released = self.router.release_gated()
        if released:
            self.c_released.increment(released)
        self.c_ticks.increment()
        return view

    # ----------------------------------------------------------------- loop
    def start(self) -> "FleetController":
        if self._thread is not None:
            return self
        self._stop.clear()
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name="repro-fleet-controller")
        self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        t, self._thread = self._thread, None
        if t is not None:
            t.join(timeout=max(5.0, self.interval * 4))

    def _loop(self) -> None:
        while not self._stop.wait(self.interval):
            try:
                self.tick()
            except Exception:  # noqa: BLE001 — the loop must survive a
                self.c_action_errors.increment()  # mid-retirement race
