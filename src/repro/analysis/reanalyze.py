"""Re-run the static HLO profile over archived .hlo.gz artifacts and update
the dry-run JSONs in place — analysis refinements without recompiling.

    PYTHONPATH=src python -m repro.analysis.reanalyze [results/dryrun]
"""

from __future__ import annotations

import gzip
import json
import sys
from pathlib import Path

from repro.dist.hlo_analysis import parse_module

DEFAULT = Path(__file__).resolve().parents[3] / "results" / "dryrun"


def reanalyze(results_dir: Path = DEFAULT) -> int:
    n = 0
    for jpath in sorted(Path(results_dir).glob("*.json")):
        hpath = jpath.with_suffix(".hlo.gz")
        if not hpath.exists():
            continue
        rec = json.loads(jpath.read_text())
        with gzip.open(hpath, "rt") as f:
            hlo = f.read()
        mod = parse_module(hlo, rec["n_devices"])
        coll = mod.collectives()
        rec["hlo_flops_per_device"] = float(mod.dot_flops())
        rec["hlo_flops_total"] = rec["hlo_flops_per_device"] * rec["n_devices"]
        rec["hbm_traffic_per_device"] = float(mod.memory_traffic())
        rec["collectives"] = {
            "count": coll.count(),
            "wire_bytes_total": int(coll.total_wire()),
            "wire_bytes_ici": int(coll.total_wire(crosses_pod=False)),
            "wire_bytes_dci": int(coll.total_wire(crosses_pod=True)),
            "operand_bytes_total": int(coll.total_operand()),
            "by_kind": {k: int(v) for k, v in coll.by_kind().items()},
        }
        jpath.write_text(json.dumps(rec, indent=1))
        n += 1
    return n


if __name__ == "__main__":
    d = Path(sys.argv[1]) if len(sys.argv) > 1 else DEFAULT
    print(f"reanalyzed {reanalyze(d)} artifacts in {d}")
