"""Roofline analysis over dry-run artifacts (EXPERIMENTS.md §Roofline).

Three terms per (arch × shape × mesh), in seconds per step:

    compute    = HLO_FLOPs_total   / (chips × 197 TF/s bf16)
    memory     = HBM_traffic/chip  /          819 GB/s
    collective = ICI_wire/(chips × 50 GB/s) + DCI_wire/(chips × 25 GB/s)

HLO_FLOPs and HBM traffic come from the static HLO profiler
(``dist.hlo_analysis``, while-loop trip counts applied — XLA's own
cost_analysis counts loop bodies once).  MODEL_FLOPS = 6·N·D (train) /
2·N·D (inference), N_active for MoE — the useful-compute ratio
MODEL_FLOPS / HLO_FLOPs exposes remat & quadratic-attention overheads.
"""

from __future__ import annotations

import json
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, List, Optional

import numpy as np

from repro.launch.mesh import DCI_BW, HBM_BW, ICI_BW, PEAK_FLOPS_BF16

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"


def active_param_count(arch: str) -> int:
    """Activated parameters per token (MoE: shared + top-k routed)."""
    from repro.configs import get_config
    from repro.dist.plan import futurized_plan
    from repro.models.model import build_model

    cfg = get_config(arch)
    specs = build_model(cfg, futurized_plan()).param_specs()
    total = 0
    for path, s in specs.items():
        n = int(np.prod(s.shape))
        if cfg.is_moe and "moe/w_" in path:  # routed experts: top_k of E active
            n = n * cfg.top_k // cfg.n_experts
        total += n
    return total


def model_flops(rec: Dict) -> float:
    """MODEL_FLOPS per the brief: 6·N_active·D train, 2·N_active·D inference."""
    n = active_param_count(rec["arch"])
    if rec["kind"] == "train":
        tokens = rec["global_batch"] * rec["seq_len"]
        return 6.0 * n * tokens
    if rec["kind"] == "prefill":
        tokens = rec["global_batch"] * rec["seq_len"]
        return 2.0 * n * tokens
    return 2.0 * n * rec["global_batch"]  # decode: one token per slot


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    plan: str
    chips: int
    compute_s: float
    memory_s: float
    collective_s: float
    ici_s: float
    dci_s: float
    model_flops: float
    hlo_flops: float
    step_s: float = 0.0
    bottleneck: str = ""
    useful_ratio: float = 0.0
    roofline_fraction: float = 0.0

    def finish(self) -> "Roofline":
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        self.bottleneck = max(terms, key=terms.get)
        # overlapped execution model: perfectly async collectives/DMA ⇒ the
        # step takes the max term; roofline fraction = useful compute time
        # over that bound (1.0 = MODEL_FLOPS at peak with zero exposure)
        self.step_s = max(terms.values())
        ideal = self.model_flops / (self.chips * PEAK_FLOPS_BF16)
        self.useful_ratio = self.model_flops / self.hlo_flops if self.hlo_flops else 0.0
        self.roofline_fraction = ideal / self.step_s if self.step_s else 0.0
        return self


def analyze(rec: Dict) -> Roofline:
    chips = rec["n_devices"]
    coll = rec["collectives"]
    ici = coll["wire_bytes_ici"] / (chips * ICI_BW)
    dci = coll["wire_bytes_dci"] / (chips * DCI_BW)
    return Roofline(
        arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"], plan=rec["plan"],
        chips=chips,
        compute_s=rec["hlo_flops_total"] / (chips * PEAK_FLOPS_BF16),
        memory_s=rec["hbm_traffic_per_device"] / HBM_BW,
        collective_s=ici + dci,
        ici_s=ici, dci_s=dci,
        model_flops=model_flops(rec),
        hlo_flops=rec["hlo_flops_total"],
    ).finish()


def load_records(results_dir: Path = RESULTS, plan: Optional[str] = None,
                 mesh: Optional[str] = None) -> List[Dict]:
    recs = []
    for p in sorted(Path(results_dir).glob("*.json")):
        r = json.loads(p.read_text())
        if plan and r.get("plan") != plan:
            continue
        if mesh and r.get("mesh") != mesh:
            continue
        recs.append(r)
    return recs


def table(results_dir: Path = RESULTS, plan: str = "futurized",
          mesh: str = "pod") -> List[Roofline]:
    return [analyze(r) for r in load_records(results_dir, plan, mesh)]


def format_table(rows: List[Roofline]) -> str:
    hdr = (f"{'arch':22s} {'shape':12s} {'chips':>5s} {'compute':>9s} "
           f"{'memory':>9s} {'coll':>9s} {'bottleneck':>10s} {'MF/HF':>6s} "
           f"{'roofline%':>9s}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        lines.append(
            f"{r.arch:22s} {r.shape:12s} {r.chips:5d} {r.compute_s:9.2e} "
            f"{r.memory_s:9.2e} {r.collective_s:9.2e} {r.bottleneck:>10s} "
            f"{r.useful_ratio:6.2f} {100 * r.roofline_fraction:8.1f}%")
    return "\n".join(lines)


if __name__ == "__main__":
    import sys

    mesh = sys.argv[1] if len(sys.argv) > 1 else "pod"
    print(format_table(table(mesh=mesh)))
