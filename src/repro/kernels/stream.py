"""STREAM triad kernel (TPU Pallas): out = a + α·b.

Reproduces the paper's HPX.Compute claim — "porting STREAM to the
single-source abstraction results in no loss of performance" — at the
Pallas layer: the kernel is pure bandwidth, so parity with the native jnp
expression (one fused multiply-add over HBM) is the pass criterion
(benchmarks/bench_stream.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _triad_kernel(a_ref, b_ref, o_ref, *, alpha: float):
    o_ref[...] = a_ref[...] + alpha * b_ref[...]


def triad(a: jax.Array, b: jax.Array, alpha: float = 3.0, *,
          block: int = 65536, interpret: bool = False) -> jax.Array:
    """a/b: (N,) → a + α·b, blocked through VMEM. N % block == 0."""
    (N,) = a.shape
    assert N % block == 0, (N, block)
    kernel = functools.partial(_triad_kernel, alpha=alpha)
    return pl.pallas_call(
        kernel,
        grid=(N // block,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((N,), a.dtype),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(a, b)
