"""Mamba-2 SSD chunked-scan kernel (TPU Pallas) [arXiv:2405.21060].

The SSD dual form maps beautifully onto the MXU: within a chunk of Q steps
the recurrence is three small matmuls (C·Bᵀ ⊙ decay, scores·x, B^T·x);
across chunks only an (P, N) state carries.  Grid: (B·H, S/Q) with the
chunk dim sequential — the carried state lives in fp32 VMEM scratch, so the
whole recurrence never leaves the core between chunks (the GPU original
round-trips SRAM per chunk; on TPU the state persists across grid steps —
the hardware-adaptation win, DESIGN.md §6).

Layout (from ops.py): per (batch·head) rows —
    x  (BH, S, P)   dt (BH, S)    B/C (BH, S, N)   A (BH,)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(a_ref, x_ref, dt_ref, b_ref, c_ref, y_ref, s_scr,
                *, chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    A = a_ref[pl.program_id(0)]  # this row's decay rate (negative scalar)
    x = x_ref[0].astype(jnp.float32)  # (Q, P)
    dt = dt_ref[0].astype(jnp.float32)  # (Q,)
    Bm = b_ref[0].astype(jnp.float32)  # (Q, N)
    Cm = c_ref[0].astype(jnp.float32)  # (Q, N)

    a = dt * A  # (Q,) per-step log decay
    cum = jnp.cumsum(a)  # inclusive
    # intra-chunk: scores[i,j] = C_i·B_j · exp(cum_i - cum_j) · dt_j, j <= i
    seg = cum[:, None] - cum[None, :]
    Q = x.shape[0]
    tri = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.where(tri, jnp.exp(seg), 0.0)
    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    scores = scores * L * dt[None, :]
    y = jax.lax.dot_general(scores, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (Q, P)

    # inter-chunk: contribution of the carried state
    decay_in = jnp.exp(cum)  # (Q,)
    y = y + decay_in[:, None] * jax.lax.dot_general(
        Cm, s_scr[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)  # C_i · S  (N,P)→(Q,P)

    # state update: S' = S·exp(Σa) + Σ_j exp(cum_end - cum_j)·dt_j·B_j ⊗ x_j
    decay_to_end = jnp.exp(cum[-1] - cum)  # (Q,)
    w = (decay_to_end * dt)[:, None] * Bm  # (Q, N)
    s_new = jax.lax.dot_general(w, x, (((0,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (N, P)
    s_scr[...] = s_scr[...] * jnp.exp(cum[-1]) + s_new

    y_ref[0] = y.astype(y_ref.dtype)


def ssd_scan_fwd(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
                 Cm: jax.Array, *, chunk: int = 256,
                 interpret: bool = False) -> jax.Array:
    """x: (BH, S, P); dt: (BH, S); A: (BH,); Bm/Cm: (BH, S, N) → y (BH, S, P).

    S must be a multiple of ``chunk`` (ops.py pads)."""
    BH, S, P = x.shape
    N = Bm.shape[-1]
    assert S % chunk == 0, (S, chunk)
    grid = (BH, S // chunk)
    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.MemorySpace.SMEM),  # A (BH,)
            pl.BlockSpec((1, chunk, P), lambda r, c: (r, c, 0)),
            pl.BlockSpec((1, chunk), lambda r, c: (r, c)),
            pl.BlockSpec((1, chunk, N), lambda r, c: (r, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda r, c: (r, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, P), lambda r, c: (r, c, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(A, x, dt, Bm, Cm)
