"""Flash attention forward kernel (TPU Pallas): blocked online-softmax.

TPU adaptation of FlashAttention [arXiv:2205.14135] — the HBM→VMEM
hierarchy replaces SRAM tiling: Q blocks of ``block_q`` rows live in VMEM,
the kernel streams K/V blocks of ``block_k`` rows, maintaining the running
(max, sum, acc) online-softmax state in fp32 VMEM scratch.  Block sizes are
multiples of 128 to keep the MXU systolic array full (DESIGN.md §6).

Grid: (batch·kv_head·q_group, S/block_q, S/block_k) with the K dimension
``arbitrary`` (sequential) — the carry lives in scratch across the K steps.
Causal masking skips fully-masked K blocks via ``pl.when`` (halves the work
like the original's block-skipping); sliding-window masking composes.

GQA layout: callers (ops.py) reshape q to (B·KV·G, S, Dh) and k/v to
(B·KV, S, Dh); the kernel maps program id → its kv row.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_fwd_kernel(q_ref, k_ref, v_ref, o_ref,
                      m_scr, l_scr, acc_scr,
                      *, scale: float, block_q: int, block_k: int,
                      causal: bool, window: int, seq_len: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = ki * block_k

    def _body():
        q = q_ref[0].astype(jnp.float32)  # (block_q, Dh)
        k = k_ref[0].astype(jnp.float32)  # (block_k, Dh)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        ok = kpos < seq_len
        if causal:
            ok = jnp.logical_and(ok, kpos <= qpos)
        if window > 0:
            ok = jnp.logical_and(ok, kpos > qpos - window)
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_cur = jnp.max(s, axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_scr[...] = m_new
        l_scr[...] = l_new

    # skip K blocks that are entirely masked out (flash block skipping)
    if causal or window > 0:
        run = k_start <= q_start + block_q - 1 if causal else (k_start >= 0)
        if window > 0:
            run = jnp.logical_and(run, k_start + block_k > q_start - window + 1)
        pl.when(run)(_body)
    else:
        _body()

    @pl.when(ki == nk - 1)
    def _finish():
        l = l_scr[...]
        o_ref[0] = (acc_scr[...] / jnp.maximum(l, 1e-20)[:, None]).astype(o_ref.dtype)


def flash_attention_fwd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: int = 0,
                        block_q: int = 128, block_k: int = 128,
                        valid_len: int = 0,
                        interpret: bool = False) -> jax.Array:
    """q: (R, S, Dh) with R = B·KV·G; k/v: (R, S, Dh) (pre-broadcast KV).

    Returns (R, S, Dh). Sequence length must be a multiple of the blocks
    (ops.py pads); ``valid_len`` masks K positions beyond the true length.
    """
    R, S, Dh = q.shape
    assert S % block_q == 0 and S % block_k == 0, (S, block_q, block_k)
    scale = 1.0 / math.sqrt(Dh)
    grid = (R, S // block_q, S // block_k)

    kernel = functools.partial(
        _flash_fwd_kernel, scale=scale, block_q=block_q, block_k=block_k,
        causal=causal, window=window, seq_len=valid_len or S)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, Dh), lambda r, qi, ki: (r, qi, 0)),
            pl.BlockSpec((1, block_k, Dh), lambda r, qi, ki: (r, ki, 0)),
            pl.BlockSpec((1, block_k, Dh), lambda r, qi, ki: (r, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, Dh), lambda r, qi, ki: (r, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((R, S, Dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, Dh), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
