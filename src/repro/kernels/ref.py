"""Pure-jnp oracles for every Pallas kernel (the ground truth in tests).

Deliberately naive: quadratic attention, O(S) sequential recurrences —
correctness first, no blocking tricks.  Each kernel's test sweeps shapes and
dtypes and asserts allclose against these.
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


# ----------------------------------------------------------------- attention
def mha(q: jax.Array, k: jax.Array, v: jax.Array, causal: bool = True,
        window: int = 0) -> jax.Array:
    """q: (B,S,H,Dh), k/v: (B,S,KV,Dh), GQA via H % KV == 0. fp32 math."""
    B, S, H, Dh = q.shape
    KV = k.shape[2]
    G = H // KV
    qf = q.astype(jnp.float32).reshape(B, S, KV, G, Dh)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bqkgd,btkd->bkgqt", qf, kf) / math.sqrt(Dh)
    if causal:
        qpos = jnp.arange(S)[:, None]
        kpos = jnp.arange(S)[None, :]
        ok = kpos <= qpos
        if window > 0:
            ok &= kpos > qpos - window
        s = jnp.where(ok[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqt,btkd->bqkgd", p, vf)
    return o.reshape(B, S, H, Dh).astype(q.dtype)


def decode_mha(q: jax.Array, k: jax.Array, v: jax.Array,
               length: Optional[jax.Array] = None) -> jax.Array:
    """One-token decode. q: (B,H,Dh), k/v: (B,T,KV,Dh); positions >= length
    masked (length scalar or (B,)). fp32 math."""
    B, H, Dh = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    qf = q.astype(jnp.float32).reshape(B, KV, G, Dh)
    s = jnp.einsum("bkgd,btkd->bkgt", qf, k.astype(jnp.float32)) / math.sqrt(Dh)
    if length is not None:
        mask = jnp.arange(T)[None, :] < jnp.broadcast_to(jnp.asarray(length), (B,))[:, None]
        s = jnp.where(mask[:, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgt,btkd->bkgd", p, v.astype(jnp.float32))
    return o.reshape(B, H, Dh).astype(q.dtype)


# ----------------------------------------------------------------------- ssd
def ssd(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
        Cm: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Sequential SSD recurrence (the O(S) definition).

    x: (B,S,H,P), dt: (B,S,H), A: (H,) negative, Bm/Cm: (B,S,G,N).
    h_t = h_{t-1}·exp(dt_t·A) + dt_t·B_t⊗x_t ;  y_t = C_t·h_t
    Returns (y (B,S,H,P), final state (B,H,P,N)).
    """
    Bsz, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Bf = jnp.repeat(Bm.astype(jnp.float32), rep, axis=2)
    Cf = jnp.repeat(Cm.astype(jnp.float32), rep, axis=2)

    def step(h, t):
        decay = jnp.exp(dtf[:, t] * A[None, :])  # (B,H)
        upd = jnp.einsum("bh,bhn,bhp->bhpn", dtf[:, t], Bf[:, t], xf[:, t])
        h = h * decay[:, :, None, None] + upd
        y = jnp.einsum("bhpn,bhn->bhp", h, Cf[:, t])
        return h, y

    h0 = jnp.zeros((Bsz, H, P, N), jnp.float32)
    h, ys = jax.lax.scan(step, h0, jnp.arange(S))
    y = ys.transpose(1, 0, 2, 3)
    return y.astype(x.dtype), h


# --------------------------------------------------------------------- rglru
def rglru(a: jax.Array, b: jax.Array, h0: Optional[jax.Array] = None) -> jax.Array:
    """Sequential linear recurrence h_t = a_t·h_{t-1} + b_t. a/b: (B,S,W)."""
    B, S, W = a.shape
    h = jnp.zeros((B, W), jnp.float32) if h0 is None else h0.astype(jnp.float32)

    def step(h, t):
        h = a[:, t].astype(jnp.float32) * h + b[:, t].astype(jnp.float32)
        return h, h

    _, hs = jax.lax.scan(step, h, jnp.arange(S))
    return hs.transpose(1, 0, 2).astype(a.dtype)


# --------------------------------------------------------------------- triad
def triad(a: jax.Array, b: jax.Array, alpha: float) -> jax.Array:
    """STREAM triad: a + alpha·b."""
    return a + alpha * b
