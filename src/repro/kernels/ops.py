"""Public jit'd wrappers around the Pallas kernels.

Single-source contract (the HPX.Compute claim, DESIGN.md P7): call sites
use these ops everywhere; on TPU they run the Mosaic-compiled kernels, on
CPU they execute the same kernel bodies under ``interpret=True`` — one
source, two backends, identical semantics (tests assert allclose against
``ref.py`` oracles on both paths).

Wrappers own the ugly parts: GQA head broadcasting, layout flattening to
kernel-friendly (rows, seq, feature) shapes, and padding to block multiples.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention import (decode_attention_fwd,
                                            paged_decode_attention_fwd)
from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.rglru_scan import rglru_scan_fwd
from repro.kernels.ssd_scan import ssd_scan_fwd
from repro.kernels.stream import triad as _triad_kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x: jax.Array, axis: int, mult: int) -> Tuple[jax.Array, int]:
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x, n
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), n


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: Optional[bool] = None) -> jax.Array:
    """q: (B,S,H,Dh), k/v: (B,S,KV,Dh) → (B,S,H,Dh). GQA via H % KV == 0."""
    if interpret is None:
        interpret = not _on_tpu()
    B, S, H, Dh = q.shape
    KV = k.shape[2]
    G = H // KV
    qr = q.reshape(B, S, KV, G, Dh).transpose(0, 2, 3, 1, 4).reshape(B * KV * G, S, Dh)
    kr = jnp.repeat(k.transpose(0, 2, 1, 3), G, axis=1).reshape(B * KV * G, S, Dh)
    vr = jnp.repeat(v.transpose(0, 2, 1, 3), G, axis=1).reshape(B * KV * G, S, Dh)
    qr, S0 = _pad_to(qr, 1, max(block_q, block_k))
    kr, _ = _pad_to(kr, 1, max(block_q, block_k))
    vr, _ = _pad_to(vr, 1, max(block_q, block_k))
    o = flash_attention_fwd(qr, kr, vr, causal=causal, window=window,
                            block_q=block_q, block_k=block_k, valid_len=S0,
                            interpret=interpret)
    o = o[:, :S0]
    return o.reshape(B, KV, G, S0, Dh).transpose(0, 3, 1, 2, 4).reshape(B, S0, H, Dh)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     length: jax.Array, *, block_k: int = 512,
                     interpret: Optional[bool] = None) -> jax.Array:
    """q: (B,H,Dh), k/v: (B,T,KV,Dh) → (B,H,Dh).

    ``length`` is the valid cache prefix: a scalar (uniform fill, the
    non-paged reference fast path) or (B,) per-slot (continuous batching —
    every slot at its own depth)."""
    if interpret is None:
        interpret = not _on_tpu()
    B, H, Dh = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    qr = q.reshape(B * KV * G, Dh)
    kr = jnp.repeat(k.transpose(0, 2, 1, 3), G, axis=1).reshape(B * KV * G, T, Dh)
    vr = jnp.repeat(v.transpose(0, 2, 1, 3), G, axis=1).reshape(B * KV * G, T, Dh)
    block_k = min(block_k, max(128, T))
    kr, _ = _pad_to(kr, 1, block_k)
    vr, _ = _pad_to(vr, 1, block_k)
    length = jnp.minimum(jnp.asarray(length, jnp.int32), T)
    if length.ndim == 1:  # (B,) → one entry per kernel row
        length = jnp.repeat(length, KV * G)
    o = decode_attention_fwd(qr, kr, vr, length,
                             block_k=block_k, interpret=interpret)
    return o.reshape(B, H, Dh)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                           page_table: jax.Array, lengths: jax.Array, *,
                           interpret: Optional[bool] = None) -> jax.Array:
    """Paged flash-decode over a block-pool KV cache.

    q: (B,H,Dh); k_pages/v_pages: (P, page, KV, Dh); page_table: (B, maxp)
    int32 (entries past the fill must be valid pool indices, e.g. 0);
    lengths: (B,) int32 → (B,H,Dh).  No dense gather — each kernel row
    walks its own page list via the scalar-prefetched table.
    """
    if interpret is None:
        interpret = not _on_tpu()
    B, H, Dh = q.shape
    KV = k_pages.shape[2]
    G = H // KV
    qr = q.reshape(B * KV * G, Dh)
    o = paged_decode_attention_fwd(qr, k_pages, v_pages, page_table,
                                   lengths, num_kv_heads=KV,
                                   interpret=interpret)
    return o.reshape(B, H, Dh)


def gather_paged_kv(k_pages: jax.Array, v_pages: jax.Array,
                    page_table: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Materialize per-request dense caches from the block pool.

    k_pages/v_pages: (P, page, KV, Dh), page_table: (B, maxp)
    → (B, maxp·page, KV, Dh).  The XLA (non-Pallas) decode path and the
    test oracles use this; the Pallas path never materializes it.
    """
    P, page, KV, Dh = k_pages.shape
    B, maxp = page_table.shape
    k = jnp.take(k_pages, page_table.reshape(-1), axis=0)
    v = jnp.take(v_pages, page_table.reshape(-1), axis=0)
    return (k.reshape(B, maxp * page, KV, Dh),
            v.reshape(B, maxp * page, KV, Dh))


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
             Cm: jax.Array, *, chunk: int = 256,
             interpret: Optional[bool] = None) -> jax.Array:
    """x: (B,S,H,P), dt: (B,S,H), A: (H,), Bm/Cm: (B,S,G,N) → y (B,S,H,P)."""
    if interpret is None:
        interpret = not _on_tpu()
    B, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    chunk = min(chunk, S)
    xk = x.transpose(0, 2, 1, 3).reshape(B * H, S, P)
    dtk = dt.transpose(0, 2, 1).reshape(B * H, S)
    Ak = jnp.tile(A, B)
    Bk = jnp.repeat(Bm.transpose(0, 2, 1, 3), rep, axis=1).reshape(B * H, S, N)
    Ck = jnp.repeat(Cm.transpose(0, 2, 1, 3), rep, axis=1).reshape(B * H, S, N)
    xk, S0 = _pad_to(xk, 1, chunk)
    dtk, _ = _pad_to(dtk, 1, chunk)
    Bk, _ = _pad_to(Bk, 1, chunk)
    Ck, _ = _pad_to(Ck, 1, chunk)
    y = ssd_scan_fwd(xk, dtk, Ak, Bk, Ck, chunk=chunk, interpret=interpret)
    return y[:, :S0].reshape(B, H, S0, P).transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("block_s", "block_w", "interpret"))
def rglru_scan(a: jax.Array, b: jax.Array, *, block_s: int = 256,
               block_w: int = 128, interpret: Optional[bool] = None) -> jax.Array:
    """h_t = a_t·h_{t-1} + b_t. a/b: (B,S,W) → (B,S,W)."""
    if interpret is None:
        interpret = not _on_tpu()
    B, S, W = a.shape
    block_s = min(block_s, S)
    block_w = min(block_w, W)
    a2, S0 = _pad_to(a, 1, block_s)
    b2, _ = _pad_to(b, 1, block_s)
    a2, W0 = _pad_to(a2, 2, block_w)
    b2, _ = _pad_to(b2, 2, block_w)
    h = rglru_scan_fwd(a2, b2, block_s=block_s, block_w=block_w,
                       interpret=interpret)
    return h[:, :S0, :W0]


@functools.partial(jax.jit, static_argnames=("alpha", "block", "interpret"))
def stream_triad(a: jax.Array, b: jax.Array, alpha: float = 3.0, *,
                 block: int = 65536, interpret: Optional[bool] = None) -> jax.Array:
    if interpret is None:
        interpret = not _on_tpu()
    (N,) = a.shape
    block = min(block, N)
    a2, N0 = _pad_to(a, 0, block)
    b2, _ = _pad_to(b, 0, block)
    return _triad_kernel(a2, b2, alpha, block=block, interpret=interpret)[:N0]


# ------------------------------------------------------------ trainable flash
@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def flash_attention_trainable(q: jax.Array, k: jax.Array, v: jax.Array,
                              causal: bool = True, window: int = 0) -> jax.Array:
    """Training-path flash attention: Pallas forward kernel + exact backward.

    Backward recomputes attention in the pure-jnp oracle and differentiates
    it (flash-style recompute — no score materialization is *saved*, the
    memory win is in the forward; a fused backward kernel is the natural
    next TPU optimization and is noted in EXPERIMENTS.md)."""
    return flash_attention(q, k, v, causal=causal, window=window)


def _fat_fwd(q, k, v, causal, window):
    return flash_attention(q, k, v, causal=causal, window=window), (q, k, v)


def _fat_bwd(causal, window, res, ct):
    from repro.kernels import ref

    q, k, v = res
    _, vjp = jax.vjp(lambda q_, k_, v_: ref.mha(q_, k_, v_, causal=causal,
                                                window=window), q, k, v)
    return vjp(ct)


flash_attention_trainable.defvjp(_fat_fwd, _fat_bwd)
