"""Flash-decoding kernels (TPU Pallas): one-token attention over a long KV
cache, KV-blocked with a running log-sum-exp combine.

Decode attention is memory-bound (the whole cache streams HBM→VMEM once per
token); the kernel's job is to keep that stream dense and the softmax state
in registers/VMEM.  Grid: (rows, T/block_k) with the KV dim sequential —
(m, l, acc) scratch carries the online softmax across KV blocks, exactly the
combine that GSPMD emits across *devices* when the cache is
sequence-sharded (DESIGN.md §5) — same math, one level down.

Two variants share the softmax-combine body:

- :func:`decode_attention_fwd` — dense layout (from ops.py): q (R, Dh) with
  R = B·KV·G; k/v (R, T, Dh).  ``length`` is *per row* — either a scalar
  (broadcast fast path, all rows at the same fill) or an (R,) vector
  (continuous batching: every slot at its own depth).  Masking with one
  scalar across divergent slots was the seed bug — rows at shallower fill
  attended over stale/zero KV.
- :func:`paged_decode_attention_fwd` — paged layout: K/V live in a block
  pool (P, page, KV, Dh) shared by all requests; each row walks *its own*
  page list via an SMEM-prefetched page table (the index map reads the
  table before the DMA is issued, so the gather costs nothing extra — this
  is "sending work to data" at the memory-system level).  GQA needs no
  jnp.repeat of the cache: the index map routes each row to its KV head.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _softmax_accumulate(q, k, v, kpos_base, length, m_scr, l_scr, acc_scr,
                        *, scale: float):
    """One KV-block online-softmax update. q (1,Dh); k/v (bk,Dh)."""
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale  # (1, bk)
    kpos = kpos_base + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(kpos < length, s, NEG_INF)
    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref,
                   m_scr, l_scr, acc_scr, *, scale: float, block_k: int,
                   per_row: bool):
    r = pl.program_id(0)
    ki = pl.program_id(1)
    nk = pl.num_programs(1)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[r] if per_row else len_ref[0]
    k_start = ki * block_k

    @pl.when(k_start < length)
    def _body():
        _softmax_accumulate(q_ref[...].astype(jnp.float32),
                            k_ref[0].astype(jnp.float32),
                            v_ref[0].astype(jnp.float32),
                            k_start, length, m_scr, l_scr, acc_scr, scale=scale)

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[...] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-20)).astype(o_ref.dtype)


def decode_attention_fwd(q: jax.Array, k: jax.Array, v: jax.Array,
                         length: jax.Array, *, block_k: int = 512,
                         interpret: bool = False) -> jax.Array:
    """q: (R, Dh); k/v: (R, T, Dh); length: scalar int32 (uniform fill — the
    fast path: one SMEM word) or (R,) int32 (per-row valid prefix).

    Returns (R, Dh). T must be a multiple of block_k (ops.py pads)."""
    R, T, Dh = k.shape
    assert T % block_k == 0, (T, block_k)
    length = jnp.asarray(length, jnp.int32)
    per_row = length.ndim >= 1 and length.size > 1
    if per_row:
        assert length.shape == (R,), (length.shape, R)
        len_arg = length
    else:
        len_arg = length.reshape(1)
    scale = 1.0 / math.sqrt(Dh)
    grid = (R, T // block_k)
    kernel = functools.partial(_decode_kernel, scale=scale, block_k=block_k,
                               per_row=per_row)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.MemorySpace.SMEM),  # length (prefetch-like)
            pl.BlockSpec((1, Dh), lambda r, ki: (r, 0)),
            pl.BlockSpec((1, block_k, Dh), lambda r, ki: (r, ki, 0)),
            pl.BlockSpec((1, block_k, Dh), lambda r, ki: (r, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, Dh), lambda r, ki: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((R, Dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, Dh), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(len_arg, q, k, v)


# ------------------------------------------------------------------- paged
def _paged_decode_kernel(pt_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                         m_scr, l_scr, acc_scr, *, scale: float,
                         page_size: int, rows_per_batch: int):
    r = pl.program_id(0)
    ki = pl.program_id(1)
    nk = pl.num_programs(1)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[r // rows_per_batch]
    k_start = ki * page_size

    @pl.when(k_start < length)
    def _body():
        _softmax_accumulate(q_ref[...].astype(jnp.float32),
                            k_ref[0, :, 0].astype(jnp.float32),
                            v_ref[0, :, 0].astype(jnp.float32),
                            k_start, length, m_scr, l_scr, acc_scr, scale=scale)

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[...] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-20)).astype(o_ref.dtype)


def paged_decode_attention_fwd(q: jax.Array, k_pages: jax.Array,
                               v_pages: jax.Array, page_table: jax.Array,
                               lengths: jax.Array, *, num_kv_heads: int,
                               interpret: bool = False) -> jax.Array:
    """Paged flash-decode.

    q: (R, Dh) with R = B·KV·G (KV-major head order, as ops.py flattens);
    k_pages/v_pages: (P, page, KV, Dh) block pool shared by all requests;
    page_table: (B, maxp) int32 — page_table[b, j] is the pool page holding
    tokens [j·page, (j+1)·page) of request b (entries past the fill must be
    *valid* indices, e.g. 0 — they are skipped, never read);
    lengths: (B,) int32 valid prefix per request.

    Grid is (R, maxp); the KV walk is sequential per row and the page table
    + lengths are scalar-prefetched so each block's DMA source address is
    known up front.  Returns (R, Dh).
    """
    P, page_size, KV, Dh = k_pages.shape
    R = q.shape[0]
    B, maxp = page_table.shape
    assert KV == num_kv_heads, (KV, num_kv_heads)
    assert R % B == 0, (R, B)
    rows_per_batch = R // B  # KV * G
    G = rows_per_batch // KV
    scale = 1.0 / math.sqrt(Dh)

    def kv_index(r, ki, pt, _ln):
        b = r // rows_per_batch
        kv = (r // G) % KV
        return (pt[b, ki], 0, kv, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # page_table, lengths
        grid=(R, maxp),
        in_specs=[
            pl.BlockSpec((1, Dh), lambda r, ki, pt, ln: (r, 0)),
            pl.BlockSpec((1, page_size, 1, Dh), kv_index),
            pl.BlockSpec((1, page_size, 1, Dh), kv_index),
        ],
        out_specs=pl.BlockSpec((1, Dh), lambda r, ki, pt, ln: (r, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, Dh), jnp.float32),
        ],
    )
    kernel = functools.partial(_paged_decode_kernel, scale=scale,
                               page_size=page_size,
                               rows_per_batch=rows_per_batch)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((R, Dh), q.dtype),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(jnp.asarray(page_table, jnp.int32), jnp.asarray(lengths, jnp.int32),
      q, k_pages, v_pages)
