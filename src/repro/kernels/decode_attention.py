"""Flash-decoding kernel (TPU Pallas): one-token attention over a long KV
cache, KV-blocked with a running log-sum-exp combine.

Decode attention is memory-bound (the whole cache streams HBM→VMEM once per
token); the kernel's job is to keep that stream dense and the softmax state
in registers/VMEM.  Grid: (rows, T/block_k) with the KV dim sequential —
(m, l, acc) scratch carries the online softmax across KV blocks, exactly the
combine that GSPMD emits across *devices* when the cache is
sequence-sharded (DESIGN.md §5) — same math, one level down.

Layout (from ops.py): q (R, Dh) with R = B·KV·G; k/v (R, T, Dh).
``length`` masks positions ≥ the current cache fill (ring buffers pass T).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref,
                   m_scr, l_scr, acc_scr, *, scale: float, block_k: int):
    ki = pl.program_id(1)
    nk = pl.num_programs(1)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[0]
    k_start = ki * block_k

    @pl.when(k_start < length)
    def _body():
        q = q_ref[...].astype(jnp.float32)  # (1, Dh)
        k = k_ref[0].astype(jnp.float32)  # (block_k, Dh)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale  # (1, bk)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kpos < length, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[...] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-20)).astype(o_ref.dtype)


def decode_attention_fwd(q: jax.Array, k: jax.Array, v: jax.Array,
                         length: jax.Array, *, block_k: int = 512,
                         interpret: bool = False) -> jax.Array:
    """q: (R, Dh); k/v: (R, T, Dh); length: scalar int32 (valid prefix).

    Returns (R, Dh). T must be a multiple of block_k (ops.py pads)."""
    R, T, Dh = k.shape
    assert T % block_k == 0, (T, block_k)
    scale = 1.0 / math.sqrt(Dh)
    grid = (R, T // block_k)
    kernel = functools.partial(_decode_kernel, scale=scale, block_k=block_k)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.MemorySpace.SMEM),  # length (prefetch-like)
            pl.BlockSpec((1, Dh), lambda r, ki: (r, 0)),
            pl.BlockSpec((1, block_k, Dh), lambda r, ki: (r, ki, 0)),
            pl.BlockSpec((1, block_k, Dh), lambda r, ki: (r, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, Dh), lambda r, ki: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((R, Dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, Dh), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(jnp.asarray(length, jnp.int32).reshape(1), q, k, v)
