"""RG-LRU linear-recurrence kernel (TPU Pallas) [arXiv:2402.19427].

h_t = a_t ⊙ h_{t-1} + b_t — a diagonal (per-channel) recurrence.  The XLA
path uses a log-depth associative scan which materializes O(S·W·log S)
temporaries in HBM; this kernel runs the recurrence *sequentially in VMEM*:
grid (B, W/block_w, S/block_s) with the sequence dim ``arbitrary``, the
carry h (1, block_w) in fp32 scratch, and an unrolled ``fori_loop`` over
the rows of each (block_s, block_w) tile.  Channels are the vectorized
(lane) dimension — the VPU runs all ``block_w`` recurrences in parallel, so
the sequential loop costs S steps of one VPU op each, with zero HBM
round-trips between steps (the hardware adaptation, DESIGN.md §6).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rglru_kernel(a_ref, b_ref, h_ref, carry_scr, *, block_s: int):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        carry_scr[...] = jnp.zeros_like(carry_scr)

    a = a_ref[0].astype(jnp.float32)  # (block_s, block_w)
    b = b_ref[0].astype(jnp.float32)

    def step(t, h):
        h = a[t] * h + b[t]  # (block_w,) vectorized over channels
        h_ref[0, t, :] = h.astype(h_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, block_s, step, carry_scr[0])
    carry_scr[0, :] = h


def rglru_scan_fwd(a: jax.Array, b: jax.Array, *, block_s: int = 256,
                   block_w: int = 128, interpret: bool = False) -> jax.Array:
    """a/b: (B, S, W) → h: (B, S, W).  S % block_s == 0, W % block_w == 0."""
    B, S, W = a.shape
    assert S % block_s == 0 and W % block_w == 0, (S, W, block_s, block_w)
    grid = (B, W // block_w, S // block_s)
    kernel = functools.partial(_rglru_kernel, block_s=block_s)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_s, block_w), lambda bi, wi, si: (bi, si, wi)),
            pl.BlockSpec((1, block_s, block_w), lambda bi, wi, si: (bi, si, wi)),
        ],
        out_specs=pl.BlockSpec((1, block_s, block_w), lambda bi, wi, si: (bi, si, wi)),
        out_shape=jax.ShapeDtypeStruct((B, S, W), a.dtype),
        scratch_shapes=[pltpu.VMEM((1, block_w), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, b)
