"""Whisper-style encoder-decoder backbone [arXiv:2212.04356].

The conv/audio frontend is a STUB per the assignment: the encoder consumes
precomputed frame embeddings (B, S_enc, d_model).  Encoder = bidirectional
pre-LN blocks with fixed sinusoidal positions; decoder = causal self-attn +
cross-attn + MLP with *learned* positions, tied unembedding.

Decode carries two caches: the growing self-attention KV ring and the fixed
cross-attention KV (computed once from the encoder output at prefill).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist.plan import ShardingPlan
from repro.models import layers as Lx
from repro.models.params import ParamSpec
from repro.models.transformer import (
    _attn_specs,
    _layer_axes,
    _mlp_specs,
    _slice_params,
    gather_constrain,
    stacked_gather_constrain,
)

_MAX_POS = 32_768  # learned decoder position table (covers all non-long cells)


def encdec_param_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    D, V = cfg.d_model, cfg.padded_vocab
    Le, Ld = cfg.enc_layers, cfg.dec_layers
    max_pos = cfg.max_position or _MAX_POS
    specs: Dict[str, ParamSpec] = {
        "tok_embed": ParamSpec((V, D), ("vocab", "embed"), scale=0.02),
        "pos_embed": ParamSpec((max_pos, D), (None, "embed"), scale=0.02),
        "enc/final_ln": ParamSpec((D,), (None,), init="ones"),
        "dec/final_ln": ParamSpec((D,), (None,), init="ones"),
    }
    specs.update(_attn_specs(cfg, Le, "enc/"))
    specs.update(_mlp_specs(cfg, Le, "enc/", cfg.d_ff))
    specs.update(_attn_specs(cfg, Ld, "dec/"))  # self-attention
    specs.update(_mlp_specs(cfg, Ld, "dec/", cfg.d_ff))
    # cross-attention (queries from decoder, K/V from encoder output)
    H, KV, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    specs.update({
        "dec/lnx": ParamSpec((Ld, D), ("layers", None), init="ones"),
        "dec/xwq": ParamSpec((Ld, D, H * Dh), ("layers", "embed", "heads")),
        "dec/xwk": ParamSpec((Ld, D, KV * Dh), ("layers", "embed", "kv_heads")),
        "dec/xwv": ParamSpec((Ld, D, KV * Dh), ("layers", "embed", "kv_heads")),
        "dec/xwo": ParamSpec((Ld, H * Dh, D), ("layers", "heads", "embed")),
    })
    if cfg.qkv_bias:
        specs.update({
            "dec/xbq": ParamSpec((Ld, H * Dh), ("layers", "heads"), init="zeros"),
            "dec/xbk": ParamSpec((Ld, KV * Dh), ("layers", "kv_heads"), init="zeros"),
            "dec/xbv": ParamSpec((Ld, KV * Dh), ("layers", "kv_heads"), init="zeros"),
        })
    return specs


def _cross_attention(cfg: ModelConfig, plan: ShardingPlan, x: jax.Array,
                     lp: Dict[str, jax.Array], y_enc: jax.Array) -> jax.Array:
    """Full-sequence cross-attention: queries x (B,Sd,D), K/V from y_enc."""
    import math

    dt = Lx.cdtype(cfg)
    B, Sd, D = x.shape
    H, KV, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    G = H // KV
    q = x @ lp["xwq"].astype(dt)
    k = y_enc @ lp["xwk"].astype(dt)
    v = y_enc @ lp["xwv"].astype(dt)
    if cfg.qkv_bias:
        q = q + lp["xbq"].astype(dt)
        k = k + lp["xbk"].astype(dt)
        v = v + lp["xbv"].astype(dt)
    q = q.reshape(B, Sd, KV, G, Dh)
    k = k.reshape(B, -1, KV, Dh)
    v = v.reshape(B, -1, KV, Dh)
    o = Lx._sdpa(q, k, v, None, 1.0 / math.sqrt(Dh))
    return o.reshape(B, Sd, H * Dh) @ lp["xwo"].astype(dt)


def _encoder(cfg: ModelConfig, plan: ShardingPlan, params, enc_x: jax.Array) -> jax.Array:
    specs = encdec_param_specs(cfg)
    B, Se, D = enc_x.shape
    pos = jnp.asarray(Lx.sinusoidal_positions(Se, D))
    x = enc_x.astype(Lx.cdtype(cfg)) + pos[None].astype(Lx.cdtype(cfg))
    x = plan.constrain(x, ("batch", "seq", None))
    positions = jnp.arange(Se, dtype=jnp.int32)
    enc = _slice_params(params, "enc/")
    enc.pop("final_ln")
    ax = _layer_axes(specs, "enc/")
    ax.pop("final_ln", None)
    if plan.gather_upfront:
        enc = stacked_gather_constrain(plan, enc, ax)

    def body(x, lp):
        if not plan.gather_upfront:
            lp = gather_constrain(plan, lp, ax)
        h = Lx.norm(cfg, x, lp["ln1"])
        x = x + Lx.attention(cfg, plan, h, lp, "", positions, causal=False)
        h = Lx.norm(cfg, x, lp["ln2"])
        return x + Lx.mlp(cfg, plan, h, lp, ""), None

    body = Lx.remat_wrap(plan, body)
    x, _ = jax.lax.scan(body, x, enc)
    return Lx.norm(cfg, x, params["enc/final_ln"])


def _decoder_stack(cfg: ModelConfig, plan: ShardingPlan, params, x: jax.Array,
                   y_enc: jax.Array, positions: jax.Array, collect_kv: bool):
    specs = encdec_param_specs(cfg)
    dec = _slice_params(params, "dec/")
    dec.pop("final_ln")
    ax = _layer_axes(specs, "dec/")
    ax.pop("final_ln", None)
    if plan.gather_upfront:
        dec = stacked_gather_constrain(plan, dec, ax)

    def body(x, lp):
        if not plan.gather_upfront:
            lp = gather_constrain(plan, lp, ax)
        h = Lx.norm(cfg, x, lp["ln1"])
        attn_out = Lx.attention(cfg, plan, h, lp, "", positions, causal=True,
                                return_kv=collect_kv)
        h, kv = attn_out if collect_kv else (attn_out, None)
        x = x + h
        h = Lx.norm(cfg, x, lp["lnx"])
        x = x + _cross_attention(cfg, plan, h, lp, y_enc)
        h = Lx.norm(cfg, x, lp["ln2"])
        x = x + Lx.mlp(cfg, plan, h, lp, "")
        if collect_kv:  # also emit this layer's cross K/V for the cache
            dt = Lx.cdtype(cfg)
            xk = (y_enc @ lp["xwk"].astype(dt))
            xv = (y_enc @ lp["xwv"].astype(dt))
            if cfg.qkv_bias:
                xk = xk + lp["xbk"].astype(dt)
                xv = xv + lp["xbv"].astype(dt)
            KV, Dh = cfg.num_kv_heads, cfg.head_dim
            B, Se = y_enc.shape[0], y_enc.shape[1]
            kv = kv + (xk.reshape(B, Se, KV, Dh), xv.reshape(B, Se, KV, Dh))
        return x, kv

    body = Lx.remat_wrap(plan, body)
    x, kvs = jax.lax.scan(body, x, dec)
    return Lx.norm(cfg, x, params["dec/final_ln"]), kvs


def forward(cfg: ModelConfig, plan: ShardingPlan, params,
            enc_x: jax.Array, dec_tokens: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """enc_x: (B, S_enc, D) stub embeddings; dec_tokens: (B, S_dec)."""
    y_enc = _encoder(cfg, plan, params, enc_x)
    B, Sd = dec_tokens.shape
    x = Lx.embed(cfg, plan, params["tok_embed"], dec_tokens)
    x = x + params["pos_embed"][:Sd][None].astype(x.dtype)
    positions = jnp.arange(Sd, dtype=jnp.int32)
    x, _ = _decoder_stack(cfg, plan, params, x, y_enc, positions, collect_kv=False)
    logits = Lx.unembed(cfg, plan, x, params["tok_embed"], transpose=True)
    return logits, jnp.zeros((), jnp.float32)


def loss_fn(cfg: ModelConfig, plan: ShardingPlan, params, batch) -> jax.Array:
    logits, _ = forward(cfg, plan, params, batch["enc"], batch["tokens"][:, :-1])
    return Lx.cross_entropy(logits, batch["tokens"][:, 1:])


# --------------------------------------------------------------------- cache
def init_cache_specs(cfg: ModelConfig, batch: int, cache_len: int, enc_len: int):
    KV, Dh, Ld = cfg.num_kv_heads, cfg.head_dim, cfg.dec_layers
    dt = jnp.dtype(cfg.dtype)
    return {
        "k": jax.ShapeDtypeStruct((Ld, batch, cache_len, KV, Dh), dt),
        "v": jax.ShapeDtypeStruct((Ld, batch, cache_len, KV, Dh), dt),
        "xk": jax.ShapeDtypeStruct((Ld, batch, enc_len, KV, Dh), dt),
        "xv": jax.ShapeDtypeStruct((Ld, batch, enc_len, KV, Dh), dt),
        "pos": jax.ShapeDtypeStruct((batch,), jnp.int32),
    }


def cache_axes(cfg: ModelConfig):
    ax = ("layers", "batch", "kv_seq", "kv_heads", None)
    return {"k": ax, "v": ax, "xk": ax, "xv": ax, "pos": ("batch",)}


def prefill(cfg: ModelConfig, plan: ShardingPlan, params, enc_x: jax.Array,
            dec_tokens: jax.Array, cache_len: Optional[int] = None):
    """Encoder pass + decoder prefill. Returns (last logits (B,V), cache)."""
    y_enc = _encoder(cfg, plan, params, enc_x)
    B, Sd = dec_tokens.shape
    T = cache_len or Sd
    x = Lx.embed(cfg, plan, params["tok_embed"], dec_tokens)
    x = x + params["pos_embed"][:Sd][None].astype(x.dtype)
    positions = jnp.arange(Sd, dtype=jnp.int32)
    x, (k, v, xk, xv) = _decoder_stack(cfg, plan, params, x, y_enc, positions,
                                       collect_kv=True)
    specs = init_cache_specs(cfg, B, T, enc_x.shape[1])
    cache = {n: jnp.zeros(s.shape, s.dtype) for n, s in specs.items()}
    cache["k"] = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), 0, axis=2)
    cache["v"] = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), 0, axis=2)
    cache["xk"], cache["xv"] = xk.astype(cache["xk"].dtype), xv.astype(cache["xv"].dtype)
    cache["pos"] = jnp.full((B,), Sd, jnp.int32)
    logits = Lx.unembed(cfg, plan, x[:, -1:, :], params["tok_embed"], transpose=True)
    return logits[:, 0, :], cache


def decode_step(cfg: ModelConfig, plan: ShardingPlan, params, cache, token):
    """One decoder token against self-KV + fixed cross-KV."""
    specs = encdec_param_specs(cfg)
    pos = cache["pos"]
    x = Lx.embed(cfg, plan, params["tok_embed"], token)
    x = x + jnp.take(params["pos_embed"], pos, axis=0)[:, None, :].astype(x.dtype)
    dec = _slice_params(params, "dec/")
    dec.pop("final_ln")
    ax = _layer_axes(specs, "dec/")
    ax.pop("final_ln", None)
    if plan.gather_upfront:
        dec = stacked_gather_constrain(plan, dec, ax)

    def body(x, xs):
        lp, kc, vc, xkc, xvc = xs
        if not plan.gather_upfront:
            lp = gather_constrain(plan, lp, ax)
        h = Lx.norm(cfg, x, lp["ln1"])
        h, kc, vc = Lx.decode_attention(cfg, plan, h, lp, "", kc, vc, pos)
        x = x + h
        h = Lx.norm(cfg, x, lp["lnx"])
        # cross-attention against the fixed encoder cache (uses xwq/xbq/xwo)
        xh, _, _ = Lx.decode_attention(cfg, plan, h, lp, "x", xkc, xvc, pos,
                                       cross=True)
        x = x + xh
        h = Lx.norm(cfg, x, lp["ln2"])
        x = x + Lx.mlp(cfg, plan, h, lp, "")
        return x, (kc, vc)

    x, (nk, nv) = jax.lax.scan(body, x, (dec, cache["k"], cache["v"],
                                         cache["xk"], cache["xv"]))
    new_cache = dict(cache)
    new_cache["k"], new_cache["v"] = nk, nv
    new_cache["pos"] = pos + 1
    x = Lx.norm(cfg, x, params["dec/final_ln"])
    logits = Lx.unembed(cfg, plan, x, params["tok_embed"], transpose=True)
    return logits[:, 0, :], new_cache
