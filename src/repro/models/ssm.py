"""Mamba-2 SSD (state-space duality) blocks [arXiv:2405.21060].

The chunked "dual" algorithm: within a chunk the recurrence is computed in
matmul form (MXU-friendly — the whole point of SSD on TPU), across chunks a
tiny ``lax.scan`` carries the (H, P, N) state.  The same math lives in three
places with one oracle:

- here (`ssd_chunked`): the model's XLA path, jit/GSPMD-sharded;
- ``kernels/ssd_scan.py``: the Pallas TPU kernel (VMEM-blocked);
- ``kernels/ref.py::ssd_reference``: the O(S) sequential oracle both are
  tested against.

Decode is the recurrent form: state ← state·exp(dt·A) + dt·B⊗x, O(1) per
token — which is why this arch runs the 500k cell.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist.plan import ShardingPlan
from repro.models.layers import cdtype
from repro.models.params import ParamSpec


def ssm_dims(cfg: ModelConfig) -> Dict[str, int]:
    d_inner = cfg.ssm_expand * cfg.d_model
    H = d_inner // cfg.ssm_headdim
    return dict(
        d_inner=d_inner,
        H=H,
        P=cfg.ssm_headdim,
        N=cfg.ssm_state,
        G=cfg.ssm_ngroups,
        conv_ch=d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state,
        d_in_proj=2 * d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state + H,
    )


def ssm_param_specs(cfg: ModelConfig, L: int, prefix: str) -> Dict[str, ParamSpec]:
    d = ssm_dims(cfg)
    D = cfg.d_model
    return {
        f"{prefix}ln": ParamSpec((L, D), ("layers", None), init="ones"),
        f"{prefix}in_proj": ParamSpec((L, D, d["d_in_proj"]), ("layers", "embed", "ssm_inner")),
        f"{prefix}conv_w": ParamSpec((L, cfg.ssm_conv, d["conv_ch"]), ("layers", None, "ssm_inner"),
                                     init="scaled", scale=0.5),
        f"{prefix}conv_b": ParamSpec((L, d["conv_ch"]), ("layers", "ssm_inner"), init="zeros"),
        f"{prefix}A_log": ParamSpec((L, d["H"]), ("layers", "ssm_heads"), init="ones"),
        f"{prefix}D": ParamSpec((L, d["H"]), ("layers", "ssm_heads"), init="ones"),
        f"{prefix}dt_bias": ParamSpec((L, d["H"]), ("layers", "ssm_heads"), init="zeros"),
        f"{prefix}gate_ln": ParamSpec((L, d["d_inner"]), ("layers", "ssm_inner"), init="ones"),
        f"{prefix}out_proj": ParamSpec((L, d["d_inner"], D), ("layers", "ssm_inner", "embed")),
    }


def causal_conv1d(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv. x: (B,S,C), w: (K,C), b: (C,)."""
    K = w.shape[0]
    w = w.astype(x.dtype)
    b = b.astype(x.dtype)
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    y = sum(xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(K))
    return jax.nn.silu(y + b[None, None, :])


def _segsum(a: jax.Array) -> jax.Array:
    """a: (..., Q) → lower-triangular pairwise sums L[i,j] = Σ_{j<k<=i} a_k."""
    Q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]  # (..., Q, Q): sum (j, i]
    mask = jnp.tril(jnp.ones((Q, Q), bool), 0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
                Cm: jax.Array, chunk: int,
                init_state: jax.Array | None = None) -> Tuple[jax.Array, jax.Array]:
    """SSD in chunked matmul form.

    x: (B,S,H,P)  dt: (B,S,H)  A: (H,) (negative)  Bm/Cm: (B,S,G,N), G|H.
    Returns (y: (B,S,H,P), final_state: (B,H,P,N)).  fp32 internally.
    """
    Bsz, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    Q = min(chunk, S)
    S_orig = S
    pad = (-S) % Q
    if pad:  # zero-pad the tail: dt=0 ⇒ decay 1, no state update (inert)
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        S = S + pad
    nc = S // Q
    rep = H // G

    xf = x.astype(jnp.float32).reshape(Bsz, nc, Q, H, P)
    dtf = dt.astype(jnp.float32).reshape(Bsz, nc, Q, H)
    Bf = jnp.repeat(Bm.astype(jnp.float32), rep, axis=2).reshape(Bsz, nc, Q, H, N)
    Cf = jnp.repeat(Cm.astype(jnp.float32), rep, axis=2).reshape(Bsz, nc, Q, H, N)

    a = dtf * A[None, None, None, :]  # (B,nc,Q,H) decay log per step
    a_t = a.transpose(0, 1, 3, 2)  # (B,nc,H,Q)
    cum_a = jnp.cumsum(a_t, axis=-1)  # within-chunk inclusive cumsum

    # ---- intra-chunk (quadratic in Q, matmul form) -----------------------
    Lmat = jnp.exp(_segsum(a_t))  # (B,nc,H,Q,Q)
    scores = jnp.einsum("bchqn,bchkn->bchqk",
                        Cf.transpose(0, 1, 3, 2, 4), Bf.transpose(0, 1, 3, 2, 4))
    scores = scores * Lmat * dtf.transpose(0, 1, 3, 2)[:, :, :, None, :]
    y_intra = jnp.einsum("bchqk,bchkp->bchqp", scores, xf.transpose(0, 1, 3, 2, 4))

    # ---- chunk summary states --------------------------------------------
    decay_to_end = jnp.exp(cum_a[..., -1:] - cum_a)  # (B,nc,H,Q)
    st = jnp.einsum("bchq,bchqn,bchqp->bchnp",
                    decay_to_end * dtf.transpose(0, 1, 3, 2),
                    Bf.transpose(0, 1, 3, 2, 4), xf.transpose(0, 1, 3, 2, 4))

    # ---- inter-chunk recurrence (tiny scan over nc) ------------------------
    chunk_decay = jnp.exp(cum_a[..., -1])  # (B,nc,H)
    s0 = (jnp.zeros((Bsz, H, N, P), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32).transpose(0, 1, 3, 2))

    def body(s, args):
        st_c, dec_c = args  # (B,H,N,P), (B,H)
        s_new = s * dec_c[:, :, None, None] + st_c
        return s_new, s  # emit the state *entering* the chunk

    s_final, s_in = jax.lax.scan(
        body, s0, (st.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2))
    )
    s_in = s_in.transpose(1, 0, 2, 3, 4)  # (B,nc,H,N,P): state entering each chunk
    final = s_final  # state after the last chunk

    decay_from_start = jnp.exp(cum_a)  # (B,nc,H,Q)
    y_inter = jnp.einsum("bchq,bchqn,bchnp->bchqp",
                         decay_from_start, Cf.transpose(0, 1, 3, 2, 4), s_in)

    y = (y_intra + y_inter).transpose(0, 1, 3, 2, 4).reshape(Bsz, S, H, P)
    y = y[:, :S_orig]
    return y.astype(x.dtype), final.transpose(0, 1, 3, 2)  # state (B,H,P,N)


def ssd_decode_step(state: jax.Array, x: jax.Array, dt: jax.Array, A: jax.Array,
                    Bm: jax.Array, Cm: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Recurrent single step. state: (B,H,P,N), x: (B,H,P), dt: (B,H),
    Bm/Cm: (B,G,N). Returns (y (B,H,P), new_state)."""
    H, G = x.shape[1], Bm.shape[1]
    rep = H // G
    Bf = jnp.repeat(Bm.astype(jnp.float32), rep, axis=1)  # (B,H,N)
    Cf = jnp.repeat(Cm.astype(jnp.float32), rep, axis=1)
    dtf = dt.astype(jnp.float32)
    decay = jnp.exp(dtf * A[None, :])  # (B,H)
    upd = jnp.einsum("bh,bhn,bhp->bhpn", dtf, Bf, x.astype(jnp.float32))
    new_state = state.astype(jnp.float32) * decay[:, :, None, None] + upd
    y = jnp.einsum("bhpn,bhn->bhp", new_state, Cf)
    return y.astype(x.dtype), new_state.astype(state.dtype)


# ------------------------------------------------------------- full block
def ssm_block(cfg: ModelConfig, plan: ShardingPlan, x: jax.Array,
              p: Dict[str, jax.Array], prefix: str) -> jax.Array:
    """One Mamba-2 block (train/prefill): x (B,S,D) → (B,S,D)."""
    from repro.models.layers import norm  # local import avoids cycle

    d = ssm_dims(cfg)
    dt_ = cdtype(cfg)
    B, S, D = x.shape
    h = norm(cfg, x, p[f"{prefix}ln"])
    zxbcdt = h @ p[f"{prefix}in_proj"].astype(dt_)
    z, xbc, dt = jnp.split(zxbcdt, [d["d_inner"], d["d_inner"] + d["conv_ch"]], axis=-1)
    xbc = causal_conv1d(xbc, p[f"{prefix}conv_w"], p[f"{prefix}conv_b"])
    xs, Bm, Cm = jnp.split(xbc, [d["d_inner"], d["d_inner"] + d["G"] * d["N"]], axis=-1)
    xs = xs.reshape(B, S, d["H"], d["P"])
    Bm = Bm.reshape(B, S, d["G"], d["N"])
    Cm = Cm.reshape(B, S, d["G"], d["N"])
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p[f"{prefix}dt_bias"][None, None, :])
    A = -jnp.exp(p[f"{prefix}A_log"].astype(jnp.float32))
    y, _ = ssd_chunked(xs, dt, A, Bm, Cm, cfg.ssm_chunk)
    y = y + p[f"{prefix}D"].astype(dt_)[None, None, :, None] * xs
    y = y.reshape(B, S, d["d_inner"])
    # gated RMSNorm (Mamba-2: norm(y * silu(z)))
    y = norm(cfg, y * jax.nn.silu(z), p[f"{prefix}gate_ln"])
    return x + y @ p[f"{prefix}out_proj"].astype(dt_)


def ssm_block_decode(cfg: ModelConfig, plan: ShardingPlan, x: jax.Array,
                     p: Dict[str, jax.Array], prefix: str,
                     conv_state: jax.Array, ssm_state: jax.Array):
    """One-token decode. x: (B,1,D). conv_state: (B,K-1,conv_ch),
    ssm_state: (B,H,P,N). Returns (out, new_conv_state, new_ssm_state)."""
    from repro.models.layers import norm

    d = ssm_dims(cfg)
    dt_ = cdtype(cfg)
    B = x.shape[0]
    h = norm(cfg, x, p[f"{prefix}ln"])[:, 0]  # (B,D)
    zxbcdt = h @ p[f"{prefix}in_proj"].astype(dt_)
    z, xbc, dt = jnp.split(zxbcdt, [d["d_inner"], d["d_inner"] + d["conv_ch"]], axis=-1)
    # conv over (state ++ current)
    seq = jnp.concatenate([conv_state.astype(dt_), xbc[:, None, :]], axis=1)  # (B,K,C)
    w = p[f"{prefix}conv_w"].astype(dt_)  # (K,C)
    y = jnp.sum(seq * w[None, :, :], axis=1) + p[f"{prefix}conv_b"].astype(dt_)
    xbc = jax.nn.silu(y)
    new_conv = seq[:, 1:, :]
    xs, Bm, Cm = jnp.split(xbc, [d["d_inner"], d["d_inner"] + d["G"] * d["N"]], axis=-1)
    xs = xs.reshape(B, d["H"], d["P"])
    Bm = Bm.reshape(B, d["G"], d["N"])
    Cm = Cm.reshape(B, d["G"], d["N"])
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p[f"{prefix}dt_bias"][None, :])
    A = -jnp.exp(p[f"{prefix}A_log"].astype(jnp.float32))
    ys, new_state = ssd_decode_step(ssm_state, xs, dt, A, Bm, Cm)
    ys = ys + p[f"{prefix}D"].astype(dt_)[None, :, None] * xs
    ys = ys.reshape(B, d["d_inner"])
    ys = norm(cfg, ys * jax.nn.silu(z), p[f"{prefix}gate_ln"])
    out = x + (ys @ p[f"{prefix}out_proj"].astype(dt_))[:, None, :]
    return out, new_conv.astype(conv_state.dtype), new_state
