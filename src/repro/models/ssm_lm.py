"""Mamba-2 language model: embed → scanned SSD blocks → tied logits."""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist.plan import ShardingPlan
from repro.models import layers as Lx
from repro.models.params import ParamSpec
from repro.models.ssm import (
    causal_conv1d,
    ssd_chunked,
    ssm_block_decode,
    ssm_dims,
    ssm_param_specs,
)
from repro.models.transformer import (
    _layer_axes,
    _slice_params,
    gather_constrain,
    stacked_gather_constrain,
)


def lm_param_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    D, V = cfg.d_model, cfg.padded_vocab
    specs: Dict[str, ParamSpec] = {
        "tok_embed": ParamSpec((V, D), ("vocab", "embed"), scale=0.02),
        "final_ln": ParamSpec((D,), (None,), init="ones"),
    }
    specs.update(ssm_param_specs(cfg, cfg.num_layers, "blk/"))
    return specs


def _block_with_state(cfg: ModelConfig, plan: ShardingPlan, x: jax.Array,
                      p: Dict[str, jax.Array], collect_state: bool):
    """ssm_block, optionally emitting (conv_state, final ssm state)."""
    d = ssm_dims(cfg)
    dt_ = Lx.cdtype(cfg)
    B, S, D = x.shape
    h = Lx.norm(cfg, x, p["ln"])
    zxbcdt = h @ p["in_proj"].astype(dt_)
    z, xbc_raw, dt = jnp.split(zxbcdt, [d["d_inner"], d["d_inner"] + d["conv_ch"]], axis=-1)
    xbc = causal_conv1d(xbc_raw, p["conv_w"], p["conv_b"])
    xs, Bm, Cm = jnp.split(xbc, [d["d_inner"], d["d_inner"] + d["G"] * d["N"]], axis=-1)
    xs = xs.reshape(B, S, d["H"], d["P"])
    Bm = Bm.reshape(B, S, d["G"], d["N"])
    Cm = Cm.reshape(B, S, d["G"], d["N"])
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None, :])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, final_state = ssd_chunked(xs, dt, A, Bm, Cm, cfg.ssm_chunk)
    y = y + p["D"].astype(dt_)[None, None, :, None] * xs
    y = y.reshape(B, S, d["d_inner"])
    y = Lx.norm(cfg, y * jax.nn.silu(z), p["gate_ln"])
    out = x + y @ p["out_proj"].astype(dt_)
    if not collect_state:
        return out, None
    K = cfg.ssm_conv
    pad = jnp.pad(xbc_raw, ((0, 0), (max(K - 1 - S, 0), 0), (0, 0)))
    conv_state = pad[:, -(K - 1):, :]
    return out, (conv_state.astype(dt_), final_state.astype(jnp.float32))


def _run_blocks(cfg: ModelConfig, plan: ShardingPlan, params, x: jax.Array,
                collect_state: bool):
    specs = lm_param_specs(cfg)
    blk = _slice_params(params, "blk/")
    ax = _layer_axes(specs, "blk/")
    if plan.gather_upfront:
        blk = stacked_gather_constrain(plan, blk, ax)

    def body(x, lp):
        if not plan.gather_upfront:
            lp = gather_constrain(plan, lp, ax)
        x = plan.constrain(x, ("batch", "seq", None))
        return _block_with_state(cfg, plan, x, lp, collect_state)

    body = Lx.remat_wrap(plan, body)
    return jax.lax.scan(body, x, blk)


def forward(cfg: ModelConfig, plan: ShardingPlan, params, tokens: jax.Array):
    x = Lx.embed(cfg, plan, params["tok_embed"], tokens)
    x, _ = _run_blocks(cfg, plan, params, x, collect_state=False)
    x = Lx.norm(cfg, x, params["final_ln"])
    logits = Lx.unembed(cfg, plan, x, params["tok_embed"], transpose=True)
    return logits, jnp.zeros((), jnp.float32)


def loss_fn(cfg: ModelConfig, plan: ShardingPlan, params, batch) -> jax.Array:
    logits, _ = forward(cfg, plan, params, batch["tokens"][:, :-1])
    return Lx.cross_entropy(logits, batch["tokens"][:, 1:])


# --------------------------------------------------------------------- cache
def init_cache_specs(cfg: ModelConfig, batch: int, cache_len: int = 0):
    """SSM decode state is O(1) — ``cache_len`` is ignored (kept for API)."""
    d = ssm_dims(cfg)
    L = cfg.num_layers
    dt = jnp.dtype(cfg.dtype)
    return {
        "conv": jax.ShapeDtypeStruct((L, batch, cfg.ssm_conv - 1, d["conv_ch"]), dt),
        "state": jax.ShapeDtypeStruct((L, batch, d["H"], d["P"], d["N"]), jnp.float32),
        "pos": jax.ShapeDtypeStruct((batch,), jnp.int32),
    }


def cache_axes(cfg: ModelConfig):
    return {
        "conv": ("layers", "batch", None, "ssm_inner"),
        "state": ("layers", "batch", "ssm_heads", None, None),
        "pos": ("batch",),
    }


def prefill(cfg: ModelConfig, plan: ShardingPlan, params, tokens: jax.Array,
            cache_len: Optional[int] = None):
    B, S = tokens.shape
    x = Lx.embed(cfg, plan, params["tok_embed"], tokens)
    x, states = _run_blocks(cfg, plan, params, x, collect_state=True)
    conv_s, ssm_s = states
    cache = {"conv": conv_s, "state": ssm_s, "pos": jnp.full((B,), S, jnp.int32)}
    x = Lx.norm(cfg, x[:, -1:, :], params["final_ln"])
    logits = Lx.unembed(cfg, plan, x, params["tok_embed"], transpose=True)
    return logits[:, 0, :], cache


def decode_step(cfg: ModelConfig, plan: ShardingPlan, params, cache, token):
    specs = lm_param_specs(cfg)
    x = Lx.embed(cfg, plan, params["tok_embed"], token)
    blk = _slice_params(params, "blk/")
    ax = _layer_axes(specs, "blk/")
    if plan.gather_upfront:
        blk = stacked_gather_constrain(plan, blk, ax)

    def body(x, xs):
        lp, conv_s, ssm_s = xs
        if not plan.gather_upfront:
            lp = gather_constrain(plan, lp, ax)
        x, new_conv, new_state = ssm_block_decode(cfg, plan, x, lp, "", conv_s, ssm_s)
        return x, (new_conv, new_state)

    x, (nconv, nstate) = jax.lax.scan(body, x, (blk, cache["conv"], cache["state"]))
    new_cache = {"conv": nconv, "state": nstate, "pos": cache["pos"] + 1}
    x = Lx.norm(cfg, x, params["final_ln"])
    logits = Lx.unembed(cfg, plan, x, params["tok_embed"], transpose=True)
    return logits[:, 0, :], new_cache
