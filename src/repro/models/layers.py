"""Shared model primitives: norms, RoPE, GQA attention (full / windowed /
decode), gated MLPs, embeddings, cross-entropy.

All functions are mesh-agnostic: activations are constrained through the
:class:`~repro.dist.plan.ShardingPlan` by *logical* axes, weights carry
their own sharding — GSPMD derives the TP collectives.  Compute dtype is
``cfg.dtype`` (bf16), softmax/logits/loss accumulate in fp32.

Long-context note: attention uses an exact query-chunked formulation
(outer loop over Q blocks via ``lax.scan``) once ``S > _CHUNK_THRESHOLD``,
bounding the live score buffer to (B, H, chunk, T) — the XLA analogue of
the flash-attention outer loop (the inner online-softmax lives in the
Pallas kernel, ``kernels/flash_attention.py``).  Sliding-window attention
is banded: each Q block attends to a static (window + chunk) K/V slice, so
windowed prefill is O(S·w), which is what makes the hybrid arch's 500k
cell sub-quadratic.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.dist.plan import ShardingPlan

_CHUNK_THRESHOLD = 2048  # S above this → Q-chunked attention (bounded scores)
_Q_CHUNK = 1024


def cdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ------------------------------------------------------------------- norms
def norm(cfg: ModelConfig, x: jax.Array, scale: jax.Array,
         bias: Optional[jax.Array] = None) -> jax.Array:
    xf = x.astype(jnp.float32)
    if cfg.norm == "rmsnorm":
        y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + 1e-6)
    else:  # layernorm
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + 1e-6)
    y = y * scale.astype(jnp.float32)
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    return y.astype(x.dtype)


# ------------------------------------------------------- bf16 grad boundary
@jax.custom_vjp
def bf16_cotangent(x: jax.Array) -> jax.Array:
    """Identity forward; backward casts the cotangent to bf16 (and back).

    Placed after the fp32 softmax/score region of attention so the dq/dk/dv
    cotangents — and therefore the per-layer dx all-reduces over the model
    axis — ride the wire at half width (EXPERIMENTS.md §Perf, granite_34b).
    """
    return x


def _bf16_ct_fwd(x):
    return x, None


def _bf16_ct_bwd(_, ct):
    return (ct.astype(jnp.bfloat16).astype(ct.dtype),)


bf16_cotangent.defvjp(_bf16_ct_fwd, _bf16_ct_bwd)


# -------------------------------------------------------------------- rope
def rope_tables(cfg: ModelConfig, positions: jax.Array, head_dim: int) -> Tuple[jax.Array, jax.Array]:
    """positions: (...,) int32 → cos/sin tables (..., head_dim/2) fp32."""
    half = head_dim // 2
    freqs = cfg.rope_theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., half)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (B, S, H, D); cos/sin: (S, D/2) or (B, S, D/2). NeoX rotate-half."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:  # (S, half) → broadcast over batch & heads
        cos = cos[None, :, None, :]
        sin = sin[None, :, None, :]
    else:  # (B, S, half)
        cos = cos[:, :, None, :]
        sin = sin[:, :, None, :]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1
    ).astype(x.dtype)


# -------------------------------------------------------------- activations
def act_fn(cfg: ModelConfig, x: jax.Array) -> jax.Array:
    return jax.nn.gelu(x) if cfg.act == "gelu" else jax.nn.silu(x)


# --------------------------------------------------------------------- mlp
def mlp(cfg: ModelConfig, plan: ShardingPlan, x: jax.Array, p: Dict[str, jax.Array],
        prefix: str) -> jax.Array:
    """Gated (SwiGLU/GeGLU) or plain 2-layer MLP. Weights: w_in/w_gate/w_out."""
    dt = cdtype(cfg)
    h = x @ p[f"{prefix}w_in"].astype(dt)
    if cfg.glu:
        g = x @ p[f"{prefix}w_gate"].astype(dt)
        h = act_fn(cfg, g) * h
    else:
        h = act_fn(cfg, h)
    return h @ p[f"{prefix}w_out"].astype(dt)


# --------------------------------------------------------------- attention
def _qkv(cfg: ModelConfig, x: jax.Array, p: Dict[str, jax.Array], prefix: str):
    dt = cdtype(cfg)
    B, S, _ = x.shape
    H, KV, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = x @ p[f"{prefix}wq"].astype(dt)
    k = x @ p[f"{prefix}wk"].astype(dt)
    v = x @ p[f"{prefix}wv"].astype(dt)
    if cfg.qkv_bias:
        q = q + p[f"{prefix}bq"].astype(dt)
        k = k + p[f"{prefix}bk"].astype(dt)
        v = v + p[f"{prefix}bv"].astype(dt)
    return (
        q.reshape(B, S, H, Dh),
        k.reshape(B, S, KV, Dh),
        v.reshape(B, S, KV, Dh),
    )


def _sdpa(q: jax.Array, k: jax.Array, v: jax.Array, mask: Optional[jax.Array],
          scale: float) -> jax.Array:
    """q: (B,Sq,KV,G,Dh), k/v: (B,T,KV,Dh), mask: (Sq,T) additive fp32."""
    s = jnp.einsum("bqkgd,btkd->bkgqt", q, k, preferred_element_type=jnp.float32)
    s = s * scale
    if mask is not None:
        s = s + mask
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqt,btkd->bqkgd", p.astype(v.dtype), v)
    return o


def _causal_mask(sq: int, t: int, q_start, window: int = 0) -> jax.Array:
    """Additive mask (sq, t): causal, optionally banded to `window`."""
    qpos = q_start + jnp.arange(sq)[:, None]
    kpos = jnp.arange(t)[None, :]
    ok = kpos <= qpos
    if window > 0:
        ok = ok & (kpos > qpos - window)
    return jnp.where(ok, 0.0, -1e30).astype(jnp.float32)


def attention(cfg: ModelConfig, plan: ShardingPlan, x: jax.Array,
              p: Dict[str, jax.Array], prefix: str, positions: jax.Array,
              causal: bool = True, window: int = 0, return_kv: bool = False):
    """Self-attention over full sequences (train / prefill path).

    With ``return_kv=True`` also returns the (post-RoPE) K/V used — the
    prefill path collects them into the cache in the same pass.
    """
    dt = cdtype(cfg)
    B, S, D = x.shape
    H, KV, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    G = H // KV
    q, k, v = _qkv(cfg, x, p, prefix)
    if cfg.rope:
        cos, sin = rope_tables(cfg, positions, Dh)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    if getattr(plan, "bf16_boundaries", False):
        q, k, v = bf16_cotangent(q), bf16_cotangent(k), bf16_cotangent(v)
    q = plan.constrain(q.reshape(B, S, KV, G, Dh), ("batch", "seq", None, None, None))
    k = plan.constrain(k, ("batch", "seq", None, None))
    v = plan.constrain(v, ("batch", "seq", None, None))
    scale = 1.0 / math.sqrt(Dh)

    if cfg.attn_impl == "pallas":  # flash kernel path (single source, P7)
        from repro.kernels import ops as kops

        o = kops.flash_attention_trainable(
            q.reshape(B, S, KV, G, Dh).reshape(B, S, H, Dh), k, v,
            causal, window).reshape(B, S, KV, G, Dh)
    elif S <= _CHUNK_THRESHOLD and window == 0:
        mask = _causal_mask(S, S, 0) if causal else None
        o = _sdpa(q, k, v, mask, scale)
    elif window > 0 and causal:
        o = _banded_attention(q, k, v, scale, window)
    else:
        o = _chunked_attention(q, k, v, scale, causal)
    o = o.reshape(B, S, H * Dh)
    out = o @ p[f"{prefix}wo"].astype(dt)
    if return_kv:
        return out, (k, v)
    return out


def _chunked_attention(q, k, v, scale, causal) -> jax.Array:
    """Exact attention, outer loop over Q chunks (bounds score memory)."""
    B, S, KV, G, Dh = q.shape
    C = _Q_CHUNK
    nc = S // C
    assert S % C == 0, f"seq {S} not divisible by q-chunk {C}"
    qc = q.reshape(B, nc, C, KV, G, Dh).transpose(1, 0, 2, 3, 4, 5)  # (nc,B,C,KV,G,Dh)

    def body(_, args):
        i, qi = args
        mask = _causal_mask(C, S, i * C) if causal else None
        return None, _sdpa(qi, k, v, mask, scale)

    _, oc = jax.lax.scan(body, None, (jnp.arange(nc), qc))
    return oc.transpose(1, 0, 2, 3, 4, 5).reshape(B, S, KV, G, Dh)


def _banded_attention(q, k, v, scale, window) -> jax.Array:
    """Sliding-window attention, O(S·window): each Q chunk sees a static
    (window + chunk) K/V slice."""
    B, S, KV, G, Dh = q.shape
    C = min(_Q_CHUNK, S)
    if S % C != 0:
        C = S  # tiny sequences: single chunk
    nc = S // C
    W = min(window, S)
    span = W + C  # kv slice length per chunk
    # pad kv on the left so the slice window never underflows
    kp = jnp.pad(k, ((0, 0), (W, 0), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (W, 0), (0, 0), (0, 0)))
    qc = q.reshape(B, nc, C, KV, G, Dh).transpose(1, 0, 2, 3, 4, 5)

    def body(_, args):
        i, qi = args
        start = i * C  # in padded coords the usable span starts here
        ks = jax.lax.dynamic_slice_in_dim(kp, start, span, axis=1)
        vs = jax.lax.dynamic_slice_in_dim(vp, start, span, axis=1)
        # positions: q rows are start..start+C-1 (unpadded); kv cols map to
        # unpadded positions start-W..start+C-1
        qpos = jnp.arange(C)[:, None] + start
        kpos = jnp.arange(span)[None, :] + start - W
        ok = (kpos <= qpos) & (kpos > qpos - window) & (kpos >= 0)
        mask = jnp.where(ok, 0.0, -1e30).astype(jnp.float32)
        return None, _sdpa(qi, ks, vs, mask, scale)

    _, oc = jax.lax.scan(body, None, (jnp.arange(nc), qc))
    return oc.transpose(1, 0, 2, 3, 4, 5).reshape(B, S, KV, G, Dh)


# ------------------------------------------------------------ decode attn
def _rope_single(cfg: ModelConfig, x: jax.Array, pos: jax.Array) -> jax.Array:
    """RoPE for one position per batch row. x: (B, h, Dh), pos: (B,)."""
    cos, sin = rope_tables(cfg, pos, x.shape[-1])  # (B, half)
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    c, s = cos[:, None, :], sin[:, None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1).astype(x.dtype)


def decode_attention(cfg: ModelConfig, plan: ShardingPlan, x: jax.Array,
                     p: Dict[str, jax.Array], prefix: str,
                     k_cache: jax.Array, v_cache: jax.Array, pos: jax.Array,
                     window: int = 0,
                     cross: bool = False) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One-token attention against a KV cache, per-slot positions.

    x: (B, 1, D); k_cache/v_cache: (B, T, KV, Dh); pos: (B,) current index
    per batch slot (continuous batching: slots advance independently).
    Returns (out (B,1,D), new_k, new_v).  With the ``optimized`` plan the
    cache is sequence-sharded over the model axis and GSPMD emits the
    flash-decoding partial-softmax combine.  ``cross=True`` skips the cache
    update and attends to the full (encoder) cache.
    """
    dt = cdtype(cfg)
    B, _, D = x.shape
    H, KV, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    G = H // KV
    T = k_cache.shape[1]
    q = x @ p[f"{prefix}wq"].astype(dt)
    if cfg.qkv_bias:
        q = q + p[f"{prefix}bq"].astype(dt)
    q = q.reshape(B, KV * G, Dh)
    if not cross:
        k = x @ p[f"{prefix}wk"].astype(dt)
        v = x @ p[f"{prefix}wv"].astype(dt)
        if cfg.qkv_bias:
            k = k + p[f"{prefix}bk"].astype(dt)
            v = v + p[f"{prefix}bv"].astype(dt)
        k = k.reshape(B, KV, Dh)
        v = v.reshape(B, KV, Dh)
        if cfg.rope:
            q = _rope_single(cfg, q, pos)
            k = _rope_single(cfg, k, pos)
        # ring-buffer slot for windowed caches, plain append otherwise
        slot = jnp.mod(pos, T) if window > 0 else jnp.minimum(pos, T - 1)
        k_cache = k_cache.at[jnp.arange(B), slot].set(k.astype(k_cache.dtype))
        v_cache = v_cache.at[jnp.arange(B), slot].set(v.astype(v_cache.dtype))
    else:
        if cfg.rope:
            q = _rope_single(cfg, q, pos)

    q = q.reshape(B, KV, G, Dh)
    kc = plan.constrain(k_cache, ("batch", "kv_seq", None, None))
    vc = plan.constrain(v_cache, ("batch", "kv_seq", None, None))
    s = jnp.einsum("bkgd,btkd->bkgt", q, kc.astype(dt),
                   preferred_element_type=jnp.float32) / math.sqrt(Dh)
    idx = jnp.arange(T)[None, :]
    if cross:
        valid = jnp.ones((B, T), bool)
    elif window > 0:  # ring buffer: everything valid once wrapped
        valid = (idx <= jnp.mod(pos, T)[:, None]) | (pos >= T)[:, None]
    else:
        valid = idx <= pos[:, None]
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    pr = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgt,btkd->bkgd", pr.astype(dt), vc.astype(dt))
    o = o.reshape(B, 1, H * Dh)
    return o @ p[f"{prefix}wo"].astype(dt), k_cache, v_cache


# ------------------------------------------------------- paged decode attn
def paged_decode_attention(cfg: ModelConfig, plan: ShardingPlan, x: jax.Array,
                           p: Dict[str, jax.Array], prefix: str,
                           k_pages: jax.Array, v_pages: jax.Array,
                           page_table: jax.Array, pos: jax.Array
                           ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One-token attention against a *paged* KV cache.

    x: (B, 1, D); k_pages/v_pages: (P, page, KV, Dh) block pool shared by
    all requests; page_table: (B, maxp) int32 (per-request page lists, 0-
    padded past the fill — page 0 is the pool's reserved scratch page);
    pos: (B,) current fill per slot.  The new token's K/V are scattered
    into page ``page_table[b, pos//page]`` at offset ``pos % page``;
    attention then walks the row's page list with per-row lengths — either
    in the paged Pallas kernel (``attn_impl == "pallas"``) or via a dense
    gather + masked softmax (XLA reference path).

    Pages are per-request, so the scatter destinations are unique across
    live slots; idle slots all target the scratch page and their output is
    discarded by the engine.
    Returns (out (B,1,D), new_k_pages, new_v_pages).
    """
    dt = cdtype(cfg)
    B, _, D = x.shape
    H, KV, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    G = H // KV
    page = k_pages.shape[1]
    q = x @ p[f"{prefix}wq"].astype(dt)
    k = x @ p[f"{prefix}wk"].astype(dt)
    v = x @ p[f"{prefix}wv"].astype(dt)
    if cfg.qkv_bias:
        q = q + p[f"{prefix}bq"].astype(dt)
        k = k + p[f"{prefix}bk"].astype(dt)
        v = v + p[f"{prefix}bv"].astype(dt)
    q = q.reshape(B, KV * G, Dh)
    k = k.reshape(B, KV, Dh)
    v = v.reshape(B, KV, Dh)
    if cfg.rope:
        q = _rope_single(cfg, q, pos)
        k = _rope_single(cfg, k, pos)
    pidx = page_table[jnp.arange(B), pos // page]  # (B,) destination pages
    off = pos % page
    k_pages = k_pages.at[pidx, off].set(k.astype(k_pages.dtype))
    v_pages = v_pages.at[pidx, off].set(v.astype(v_pages.dtype))
    lengths = pos + 1

    from repro.kernels import ops as kops

    if cfg.attn_impl == "pallas":
        o = kops.paged_decode_attention(q.reshape(B, H, Dh), k_pages, v_pages,
                                        page_table, lengths)
        o = o.reshape(B, 1, H * Dh)
    else:
        kc, vc = kops.gather_paged_kv(k_pages, v_pages, page_table)
        T = kc.shape[1]
        qh = q.reshape(B, KV, G, Dh)
        s = jnp.einsum("bkgd,btkd->bkgt", qh, kc.astype(dt),
                       preferred_element_type=jnp.float32) / math.sqrt(Dh)
        valid = jnp.arange(T)[None, :] < lengths[:, None]
        s = jnp.where(valid[:, None, None, :], s, -1e30)
        pr = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bkgt,btkd->bkgd", pr.astype(dt), vc.astype(dt))
        o = o.reshape(B, 1, H * Dh)
    return o @ p[f"{prefix}wo"].astype(dt), k_pages, v_pages


# --------------------------------------------------------------- embedding
def embed(cfg: ModelConfig, plan: ShardingPlan, table: jax.Array,
          tokens: jax.Array) -> jax.Array:
    """Token gather. The table has ``cfg.padded_vocab`` rows (sharding-
    friendly padding); tokens are always < vocab_size so padding is inert."""
    x = jnp.take(table.astype(cdtype(cfg)), tokens, axis=0)
    return plan.constrain(x, ("batch", "seq", None))


def unembed(cfg: ModelConfig, plan: ShardingPlan, x: jax.Array,
            table: jax.Array, transpose: bool) -> jax.Array:
    """x @ W_out → logits fp32, vocab-sharded. Padded vocab columns are
    masked to -inf so softmax/argmax semantics match the unpadded vocab."""
    w = table.astype(cdtype(cfg))
    logits = jnp.einsum("bsd,vd->bsv" if transpose else "bsd,dv->bsv", x, w,
                        preferred_element_type=jnp.float32)
    Vp = logits.shape[-1]
    if Vp != cfg.vocab_size:
        pad_mask = jnp.arange(Vp) >= cfg.vocab_size
        logits = jnp.where(pad_mask[None, None, :], -1e30, logits)
    return plan.constrain(logits, ("batch", "seq", "vocab"))


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean token NLL; logits fp32 (B,S,V), labels (B,S) int32."""
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


# ------------------------------------------------------------------- remat
def remat_wrap(plan: ShardingPlan, fn):
    if plan.remat_policy == "none":
        return fn
    if plan.remat_policy == "dots":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)  # "full": recompute everything


def sinusoidal_positions(n: int, d: int) -> np.ndarray:
    """Whisper-style sinusoidal embeddings (n, d) fp32."""
    half = d // 2
    freqs = np.exp(-np.log(10000.0) * np.arange(half) / max(half - 1, 1))
    ang = np.arange(n)[:, None] * freqs[None, :]
    return np.concatenate([np.sin(ang), np.cos(ang)], axis=1).astype(np.float32)
