"""RecurrentGemma hybrid LM: (rec, rec, attn) pattern groups.

26 layers = 8 scanned groups of (RG-LRU, RG-LRU, local-attn) + 2 trailing
RG-LRU layers (DESIGN.md §4).  Every layer is temporal-mix + MLP with
pre-norm residuals.  Decode caches: per rec layer (conv, h) — O(1); per
attn layer a `window`-slot ring buffer — O(window); total O(1) in sequence
length, which is why this arch runs the 500k cell.
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist.plan import ShardingPlan
from repro.models import layers as Lx
from repro.models.params import ParamSpec
from repro.models.rglru import (
    _gates,
    rec_block_decode,
    rec_param_specs,
    rglru_scan,
)
from repro.models.ssm import causal_conv1d
from repro.models.transformer import (
    _attn_specs,
    _layer_axes,
    _mlp_specs,
    _slice_params,
    gather_constrain,
    stacked_gather_constrain,
)


def _pattern(cfg: ModelConfig) -> Tuple[int, int]:
    plen = len(cfg.block_pattern)  # (rec, rec, attn)
    return cfg.num_layers // plen, cfg.num_layers % plen  # (groups, tail)


def hybrid_param_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    D, V = cfg.d_model, cfg.padded_vocab
    G, tail = _pattern(cfg)
    specs: Dict[str, ParamSpec] = {
        "tok_embed": ParamSpec((V, D), ("vocab", "embed"), scale=0.02),
        "final_ln": ParamSpec((D,), (None,), init="ones"),
    }
    for slot in ("ra/", "rb/"):  # two rec layers per group
        specs.update(rec_param_specs(cfg, G, f"grp/{slot}"))
        specs.update(_mlp_specs(cfg, G, f"grp/{slot}", cfg.d_ff))
    specs.update(_attn_specs(cfg, G, "grp/at/"))
    specs.update(_mlp_specs(cfg, G, "grp/at/", cfg.d_ff))
    if tail:
        assert all(k == "rec" for k in cfg.block_pattern[:tail]), \
            "tail layers must be recurrent for this layout"
        specs.update(rec_param_specs(cfg, tail, "tail/"))
        specs.update(_mlp_specs(cfg, tail, "tail/", cfg.d_ff))
    return specs


def _mlp_res(cfg, plan, x, lp, prefix):
    h = Lx.norm(cfg, x, lp[f"{prefix}ln2"])
    return x + Lx.mlp(cfg, plan, h, lp, prefix)


def _rec_with_state(cfg, plan, x, lp, prefix, collect_state: bool):
    """rec_block + MLP, optionally emitting (conv_state, h_final)."""
    dt = Lx.cdtype(cfg)
    B, S, D = x.shape
    h = Lx.norm(cfg, x, lp[f"{prefix}ln"])
    gate = jax.nn.gelu(h @ lp[f"{prefix}w_gate_branch"].astype(dt))
    xw_raw = h @ lp[f"{prefix}w_x"].astype(dt)
    xw = causal_conv1d(xw_raw, lp[f"{prefix}conv_w"], lp[f"{prefix}conv_b"])
    a, gx = _gates(lp, prefix, xw, dt)
    hseq = rglru_scan(a, gx)
    y = (gate * hseq.astype(dt)) @ lp[f"{prefix}rec_out"].astype(dt)
    x = x + y
    x = _mlp_res(cfg, plan, x, lp, prefix)
    if not collect_state:
        return x, None
    K = cfg.ssm_conv
    pad = jnp.pad(xw_raw, ((0, 0), (max(K - 1 - S, 0), 0), (0, 0)))
    return x, (pad[:, -(K - 1):, :].astype(dt), hseq[:, -1, :].astype(jnp.float32))


def _attn_with_kv(cfg, plan, x, lp, prefix, positions, collect_kv: bool):
    h = Lx.norm(cfg, x, lp[f"{prefix}ln1"])
    out = Lx.attention(cfg, plan, h, lp, prefix, positions, causal=True,
                       window=cfg.window, return_kv=collect_kv)
    h_attn, kv = out if collect_kv else (out, None)
    x = x + h_attn
    x = _mlp_res(cfg, plan, x, lp, prefix)
    if collect_kv:
        k, v = kv
        W = min(cfg.window, k.shape[1])
        S = k.shape[1]
        k_w = jnp.roll(k[:, -W:], shift=S % W if W else 0, axis=1)
        v_w = jnp.roll(v[:, -W:], shift=S % W if W else 0, axis=1)
        kv = (k_w, v_w)  # ring-buffer layout: slot = position mod W
    return x, kv


def _run_groups(cfg: ModelConfig, plan: ShardingPlan, params, x: jax.Array,
                positions, collect: bool):
    specs = hybrid_param_specs(cfg)
    grp = _slice_params(params, "grp/")
    ax = _layer_axes(specs, "grp/")
    if plan.gather_upfront:
        grp = stacked_gather_constrain(plan, grp, ax)

    def body(x, lp):
        if not plan.gather_upfront:
            lp = gather_constrain(plan, lp, ax)
        x = plan.constrain(x, ("batch", "seq", None))
        x, sa = _rec_with_state(cfg, plan, x, lp, "ra/", collect)
        x, sb = _rec_with_state(cfg, plan, x, lp, "rb/", collect)
        x, kv = _attn_with_kv(cfg, plan, x, lp, "at/", positions, collect)
        return x, ((sa, sb, kv) if collect else None)

    body = Lx.remat_wrap(plan, body)
    return jax.lax.scan(body, x, grp)


def _run_tail(cfg, plan, params, x, collect: bool):
    G, tail = _pattern(cfg)
    if not tail:
        return x, None
    specs = hybrid_param_specs(cfg)
    tl = _slice_params(params, "tail/")
    ax = _layer_axes(specs, "tail/")
    if plan.gather_upfront:
        tl = stacked_gather_constrain(plan, tl, ax)

    def body(x, lp):
        if not plan.gather_upfront:
            lp = gather_constrain(plan, lp, ax)
        return _rec_with_state(cfg, plan, x, lp, "", collect)

    body = Lx.remat_wrap(plan, body)
    return jax.lax.scan(body, x, tl)


def forward(cfg: ModelConfig, plan: ShardingPlan, params, tokens: jax.Array):
    x = Lx.embed(cfg, plan, params["tok_embed"], tokens)
    x = x * math.sqrt(cfg.d_model)  # gemma-style embedding scale
    positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)
    x, _ = _run_groups(cfg, plan, params, x, positions, collect=False)
    x, _ = _run_tail(cfg, plan, params, x, collect=False)
    x = Lx.norm(cfg, x, params["final_ln"])
    logits = Lx.unembed(cfg, plan, x, params["tok_embed"], transpose=True)
    return logits, jnp.zeros((), jnp.float32)


def loss_fn(cfg: ModelConfig, plan: ShardingPlan, params, batch) -> jax.Array:
    logits, _ = forward(cfg, plan, params, batch["tokens"][:, :-1])
    return Lx.cross_entropy(logits, batch["tokens"][:, 1:])


# --------------------------------------------------------------------- cache
def init_cache_specs(cfg: ModelConfig, batch: int, cache_len: int = 0):
    """cache_len ignored: attention KV is a fixed `window` ring buffer."""
    G, tail = _pattern(cfg)
    W = cfg.lru_width
    KV, Dh, Win, K = cfg.num_kv_heads, cfg.head_dim, cfg.window, cfg.ssm_conv
    dt = jnp.dtype(cfg.dtype)
    specs = {
        "conv_a": jax.ShapeDtypeStruct((G, batch, K - 1, W), dt),
        "h_a": jax.ShapeDtypeStruct((G, batch, W), jnp.float32),
        "conv_b": jax.ShapeDtypeStruct((G, batch, K - 1, W), dt),
        "h_b": jax.ShapeDtypeStruct((G, batch, W), jnp.float32),
        "k": jax.ShapeDtypeStruct((G, batch, Win, KV, Dh), dt),
        "v": jax.ShapeDtypeStruct((G, batch, Win, KV, Dh), dt),
        "pos": jax.ShapeDtypeStruct((batch,), jnp.int32),
    }
    if tail:
        specs["tail_conv"] = jax.ShapeDtypeStruct((tail, batch, K - 1, W), dt)
        specs["tail_h"] = jax.ShapeDtypeStruct((tail, batch, W), jnp.float32)
    return specs


def cache_axes(cfg: ModelConfig):
    kv = ("layers", "batch", "kv_seq", "kv_heads", None)
    out = {
        "conv_a": ("layers", "batch", None, "lru"),
        "h_a": ("layers", "batch", "lru"),
        "conv_b": ("layers", "batch", None, "lru"),
        "h_b": ("layers", "batch", "lru"),
        "k": kv, "v": kv, "pos": ("batch",),
    }
    G, tail = _pattern(cfg)
    if tail:
        out["tail_conv"] = ("layers", "batch", None, "lru")
        out["tail_h"] = ("layers", "batch", "lru")
    return out


def prefill(cfg: ModelConfig, plan: ShardingPlan, params, tokens: jax.Array,
            cache_len: Optional[int] = None):
    B, S = tokens.shape
    x = Lx.embed(cfg, plan, params["tok_embed"], tokens)
    x = x * math.sqrt(cfg.d_model)
    positions = jnp.arange(S, dtype=jnp.int32)
    x, ys = _run_groups(cfg, plan, params, x, positions, collect=True)
    (conv_a, h_a), (conv_b, h_b), (kw, vw) = ys
    cache = {"conv_a": conv_a, "h_a": h_a, "conv_b": conv_b, "h_b": h_b,
             "pos": jnp.full((B,), S, jnp.int32)}
    # pad the window ring if the prompt was shorter than the window
    spec = init_cache_specs(cfg, B)
    for name, arr in (("k", kw), ("v", vw)):
        buf = jnp.zeros(spec[name].shape, spec[name].dtype)
        cache[name] = jax.lax.dynamic_update_slice_in_dim(
            buf, arr.astype(buf.dtype), 0, axis=2) if arr.shape[2] < cfg.window else arr
    x, tail_ys = _run_tail(cfg, plan, params, x, collect=True)
    if tail_ys is not None:
        cache["tail_conv"], cache["tail_h"] = tail_ys
    x = Lx.norm(cfg, x[:, -1:, :], params["final_ln"])
    logits = Lx.unembed(cfg, plan, x, params["tok_embed"], transpose=True)
    return logits[:, 0, :], cache


def decode_step(cfg: ModelConfig, plan: ShardingPlan, params, cache, token):
    specs = hybrid_param_specs(cfg)
    pos = cache["pos"]
    x = Lx.embed(cfg, plan, params["tok_embed"], token)
    x = x * math.sqrt(cfg.d_model)
    grp = _slice_params(params, "grp/")
    ax = _layer_axes(specs, "grp/")
    if plan.gather_upfront:
        grp = stacked_gather_constrain(plan, grp, ax)

    def body(x, xs):
        lp, ca, ha, cb, hb, kc, vc = xs
        if not plan.gather_upfront:
            lp = gather_constrain(plan, lp, ax)
        x, ca, ha = rec_block_decode(cfg, plan, x, _sub(lp, "ra/"), "", ca, ha)
        x = _mlp_res(cfg, plan, x, lp, "ra/")
        x, cb, hb = rec_block_decode(cfg, plan, x, _sub(lp, "rb/"), "", cb, hb)
        x = _mlp_res(cfg, plan, x, lp, "rb/")
        h = Lx.norm(cfg, x, lp["at/ln1"])
        h, kc, vc = Lx.decode_attention(cfg, plan, h, lp, "at/", kc, vc, pos,
                                        window=cfg.window)
        x = x + h
        x = _mlp_res(cfg, plan, x, lp, "at/")
        return x, (ca, ha, cb, hb, kc, vc)

    x, ys = jax.lax.scan(body, x, (grp, cache["conv_a"], cache["h_a"],
                                   cache["conv_b"], cache["h_b"],
                                   cache["k"], cache["v"]))
    new_cache = dict(cache)
    (new_cache["conv_a"], new_cache["h_a"], new_cache["conv_b"],
     new_cache["h_b"], new_cache["k"], new_cache["v"]) = ys

    G, tail = _pattern(cfg)
    if tail:
        tl = _slice_params(params, "tail/")
        axt = _layer_axes(specs, "tail/")
        if plan.gather_upfront:
            tl = stacked_gather_constrain(plan, tl, axt)

        def tbody(x, xs):
            lp, cs, hs = xs
            if not plan.gather_upfront:
                lp = gather_constrain(plan, lp, axt)
            x, cs, hs = rec_block_decode(cfg, plan, x, lp, "", cs, hs)
            x = _mlp_res(cfg, plan, x, lp, "")
            return x, (cs, hs)

        x, (tc, th) = jax.lax.scan(tbody, x, (tl, cache["tail_conv"], cache["tail_h"]))
        new_cache["tail_conv"], new_cache["tail_h"] = tc, th

    new_cache["pos"] = pos + 1
    x = Lx.norm(cfg, x, params["final_ln"])
    logits = Lx.unembed(cfg, plan, x, params["tok_embed"], transpose=True)
    return logits[:, 0, :], new_cache


def _sub(lp: Dict[str, jax.Array], prefix: str) -> Dict[str, jax.Array]:
    return {k[len(prefix):]: v for k, v in lp.items() if k.startswith(prefix)}
