"""Decoder-only transformer (dense / MoE / VLM families).

Layers are scan-stacked: every per-layer parameter has a leading ``layers``
dim and the forward pass is one ``lax.scan`` over the stack (small HLO, fast
512-device compiles).  The *gather point* implements the BSP vs futurized
distinction (DESIGN.md §2):

- BSP plan: the whole stacked FSDP-sharded parameter tree is constrained to
  its gathered spec **before** the scan — one bulk all-gather, a global
  barrier, peak memory ∝ all layers;
- futurized plan: each layer's slice is constrained **inside** the scan
  body — XLA overlaps the per-layer all-gather with the previous layer's
  compute (async collectives), and the backward pass reduce-scatters
  per-layer.  This is HPX futurization expressed at the XLA level.

MoE layers route through :mod:`repro.models.moe` (the parcel path); the VLM
family splices stub patch embeddings over the first ``n_patches`` positions.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist.plan import ShardingPlan
from repro.models import layers as Lx
from repro.models.moe import moe_ffn, moe_param_specs
from repro.models.params import ParamSpec


# ------------------------------------------------------------------- specs
def _attn_specs(cfg: ModelConfig, L: int, prefix: str) -> Dict[str, ParamSpec]:
    D, H, KV, Dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    specs = {
        f"{prefix}ln1": ParamSpec((L, D), ("layers", None), init="ones"),
        f"{prefix}wq": ParamSpec((L, D, H * Dh), ("layers", "embed", "heads")),
        f"{prefix}wk": ParamSpec((L, D, KV * Dh), ("layers", "embed", "kv_heads")),
        f"{prefix}wv": ParamSpec((L, D, KV * Dh), ("layers", "embed", "kv_heads")),
        f"{prefix}wo": ParamSpec((L, H * Dh, D), ("layers", "heads", "embed")),
    }
    if cfg.qkv_bias:
        specs.update({
            f"{prefix}bq": ParamSpec((L, H * Dh), ("layers", "heads"), init="zeros"),
            f"{prefix}bk": ParamSpec((L, KV * Dh), ("layers", "kv_heads"), init="zeros"),
            f"{prefix}bv": ParamSpec((L, KV * Dh), ("layers", "kv_heads"), init="zeros"),
        })
    return specs


def _mlp_specs(cfg: ModelConfig, L: int, prefix: str, d_ff: int) -> Dict[str, ParamSpec]:
    D = cfg.d_model
    specs = {
        f"{prefix}ln2": ParamSpec((L, D), ("layers", None), init="ones"),
        f"{prefix}w_in": ParamSpec((L, D, d_ff), ("layers", "embed", "mlp")),
        f"{prefix}w_out": ParamSpec((L, d_ff, D), ("layers", "mlp", "embed")),
    }
    if cfg.glu:
        specs[f"{prefix}w_gate"] = ParamSpec((L, D, d_ff), ("layers", "embed", "mlp"))
    return specs


def decoder_param_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    D, V = cfg.d_model, cfg.padded_vocab
    specs: Dict[str, ParamSpec] = {
        "tok_embed": ParamSpec((V, D), ("vocab", "embed"), scale=0.02),
        "final_ln": ParamSpec((D,), (None,), init="ones"),
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = ParamSpec((D, V), ("embed", "vocab"))
    fd = cfg.first_dense
    Lm = cfg.num_layers - fd
    if fd > 0:  # leading dense layers (DeepSeekMoE layer 0)
        d_ff0 = cfg.dense_d_ff or cfg.d_ff
        specs.update(_attn_specs(cfg, fd, "d0/"))
        specs.update(_mlp_specs(cfg, fd, "d0/", d_ff0))
    specs.update(_attn_specs(cfg, Lm, "blk/"))
    if cfg.is_moe:
        specs[f"blk/ln2"] = ParamSpec((Lm, D), ("layers", None), init="ones")
        specs.update(moe_param_specs(cfg, Lm, "blk/moe/"))
    else:
        specs.update(_mlp_specs(cfg, Lm, "blk/", cfg.d_ff))
    return specs


# ------------------------------------------------------------------ helpers
_GATHER_AXIS = "embed"  # the FSDP axis


def _layer_axes(specs: Dict[str, ParamSpec], prefix: str) -> Dict[str, Tuple]:
    """Per-layer logical axes (leading 'layers' dim dropped)."""
    out = {}
    for path, s in specs.items():
        if path.startswith(prefix):
            out[path[len(prefix):]] = tuple(a for a in s.axes if a != "layers")
    return out


def _slice_params(params: Dict[str, jax.Array], prefix: str) -> Dict[str, jax.Array]:
    return {k[len(prefix):]: v for k, v in params.items() if k.startswith(prefix)}


def _gathered(axes: Tuple) -> Tuple:
    return tuple(None if a == _GATHER_AXIS else a for a in axes)


def gather_constrain(plan: ShardingPlan, tree: Dict[str, jax.Array],
                     axes: Dict[str, Tuple]) -> Dict[str, jax.Array]:
    """Constrain every param to its *gathered* (non-FSDP) spec."""
    return {k: plan.constrain(v, _gathered(axes[k])) for k, v in tree.items()}


def stacked_gather_constrain(plan: ShardingPlan, tree: Dict[str, jax.Array],
                             axes: Dict[str, Tuple]) -> Dict[str, jax.Array]:
    """BSP: gather the whole stack up-front (axes still carry 'layers')."""
    return {
        k: plan.constrain(v, ("layers",) + _gathered(axes[k])) for k, v in tree.items()
    }


# ------------------------------------------------------------------ blocks
def _layer_body(cfg: ModelConfig, plan: ShardingPlan, x: jax.Array,
                lp: Dict[str, jax.Array], positions: jax.Array,
                moe_layer: bool, collect_kv: bool = False):
    x = plan.constrain(x, ("batch", "seq_sp", None))
    h = Lx.norm(cfg, x, lp["ln1"])
    attn_out = Lx.attention(cfg, plan, h, lp, "", positions, causal=cfg.causal,
                            window=cfg.window, return_kv=collect_kv)
    if collect_kv:
        h, kv = attn_out
    else:
        h, kv = attn_out, None
    x = x + h
    h = Lx.norm(cfg, x, lp["ln2"])
    if moe_layer:
        ffn, aux = moe_ffn(cfg, plan, h, lp, "moe/")
    else:
        ffn, aux = Lx.mlp(cfg, plan, h, lp, ""), jnp.zeros((), jnp.float32)
    return x + ffn, aux, kv


def _run_stack(cfg: ModelConfig, plan: ShardingPlan, x: jax.Array,
               stacked: Dict[str, jax.Array], axes: Dict[str, Tuple],
               positions: jax.Array, moe_layer: bool, collect_kv: bool = False):
    """lax.scan over a stacked layer dict; returns (x, aux_sum, stacked_kv)."""

    def body(carry, lp):
        x, aux_sum = carry
        if not plan.gather_upfront:  # futurized: per-layer gather point
            lp = gather_constrain(plan, lp, axes)
        x, aux, kv = _layer_body(cfg, plan, x, lp, positions, moe_layer, collect_kv)
        return (x, aux_sum + aux), kv

    body = Lx.remat_wrap(plan, body)
    (x, aux), kvs = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), stacked)
    return x, aux, kvs


# ------------------------------------------------------------------ forward
def forward(cfg: ModelConfig, plan: ShardingPlan, params: Dict[str, jax.Array],
            tokens: jax.Array, patches: Optional[jax.Array] = None) -> Tuple[jax.Array, jax.Array]:
    """tokens: (B, S) → (logits fp32 (B,S,V), aux_loss)."""
    specs = decoder_param_specs(cfg)
    x = Lx.embed(cfg, plan, params["tok_embed"], tokens)
    if cfg.family == "vlm":
        assert patches is not None, "vlm family requires patch embeddings"
        x = jnp.concatenate([patches.astype(x.dtype), x[:, cfg.n_patches:, :]], axis=1)
    S = x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)

    if cfg.first_dense > 0:
        d0 = _slice_params(params, "d0/")
        a0 = _layer_axes(specs, "d0/")
        if plan.gather_upfront:
            d0 = stacked_gather_constrain(plan, d0, a0)
        x, _, _ = _run_stack(cfg, plan, x, d0, a0, positions, moe_layer=False)

    blk = _slice_params(params, "blk/")
    ax = _layer_axes(specs, "blk/")
    if plan.gather_upfront:  # BSP: one bulk all-gather before the loop
        blk = stacked_gather_constrain(plan, blk, ax)
    x, aux, _ = _run_stack(cfg, plan, x, blk, ax, positions, moe_layer=cfg.is_moe)

    x = Lx.norm(cfg, x, params["final_ln"])
    table = params["tok_embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = Lx.unembed(cfg, plan, x, table, transpose=cfg.tie_embeddings)
    return logits, aux


def loss_fn(cfg: ModelConfig, plan: ShardingPlan, params: Dict[str, jax.Array],
            batch: Dict[str, jax.Array]) -> jax.Array:
    tokens = batch["tokens"]
    logits, aux = forward(cfg, plan, params, tokens[:, :-1],
                          patches=batch.get("patches"))
    labels = tokens[:, 1:]
    mask = None
    if cfg.family == "vlm":  # no next-token loss on image positions
        mask = (jnp.arange(labels.shape[1]) >= cfg.n_patches)[None, :].astype(jnp.float32)
        mask = jnp.broadcast_to(mask, labels.shape)
    ce = Lx.cross_entropy(logits, labels, mask)
    return ce + cfg.router_aux_weight * aux


# -------------------------------------------------------------------- cache
def init_cache_specs(cfg: ModelConfig, batch: int, cache_len: int) -> Dict[str, jax.ShapeDtypeStruct]:
    """Abstract KV-cache pytree for the dry-run / serve engine."""
    KV, Dh = cfg.num_kv_heads, cfg.head_dim
    fd, Lm = cfg.first_dense, cfg.num_layers - cfg.first_dense
    dt = jnp.dtype(cfg.dtype)
    specs = {
        "k": jax.ShapeDtypeStruct((Lm, batch, cache_len, KV, Dh), dt),
        "v": jax.ShapeDtypeStruct((Lm, batch, cache_len, KV, Dh), dt),
        "pos": jax.ShapeDtypeStruct((batch,), jnp.int32),
    }
    if fd > 0:
        specs["k0"] = jax.ShapeDtypeStruct((fd, batch, cache_len, KV, Dh), dt)
        specs["v0"] = jax.ShapeDtypeStruct((fd, batch, cache_len, KV, Dh), dt)
    return specs


def cache_axes(cfg: ModelConfig) -> Dict[str, Tuple]:
    ax = ("layers", "batch", "kv_seq", "kv_heads", None)
    out = {"k": ax, "v": ax, "pos": ("batch",)}
    if cfg.first_dense > 0:
        out["k0"] = ax
        out["v0"] = ax
    return out


def init_cache(cfg: ModelConfig, batch: int, cache_len: int) -> Dict[str, jax.Array]:
    return {k: jnp.zeros(s.shape, s.dtype) for k, s in
            init_cache_specs(cfg, batch, cache_len).items()}


def paged_cache_specs(cfg: ModelConfig, num_pages: int, page_size: int,
                      max_batch: int, max_pages_per_req: int
                      ) -> Dict[str, jax.ShapeDtypeStruct]:
    """Abstract *paged* KV-cache pytree: a block pool of ``num_pages`` fixed
    ``page_size`` pages shared by every layer (same page index holds a
    request's tokens in all layers, vLLM-style), plus per-slot page tables
    and fill positions.  Memory scales with live tokens, not
    ``max_batch × cache_len``."""
    KV, Dh = cfg.num_kv_heads, cfg.head_dim
    fd, Lm = cfg.first_dense, cfg.num_layers - cfg.first_dense
    dt = jnp.dtype(cfg.dtype)
    specs = {
        "k": jax.ShapeDtypeStruct((Lm, num_pages, page_size, KV, Dh), dt),
        "v": jax.ShapeDtypeStruct((Lm, num_pages, page_size, KV, Dh), dt),
        "page_table": jax.ShapeDtypeStruct((max_batch, max_pages_per_req), jnp.int32),
        "pos": jax.ShapeDtypeStruct((max_batch,), jnp.int32),
    }
    if fd > 0:
        specs["k0"] = jax.ShapeDtypeStruct((fd, num_pages, page_size, KV, Dh), dt)
        specs["v0"] = jax.ShapeDtypeStruct((fd, num_pages, page_size, KV, Dh), dt)
    return specs


def _paged_decode_layer(cfg: ModelConfig, plan: ShardingPlan, x, lp, kp, vp,
                        page_table, pos, moe_layer: bool):
    h = Lx.norm(cfg, x, lp["ln1"])
    h, kp, vp = Lx.paged_decode_attention(cfg, plan, h, lp, "", kp, vp,
                                          page_table, pos)
    x = x + h
    h = Lx.norm(cfg, x, lp["ln2"])
    if moe_layer:
        ffn, _ = moe_ffn(cfg, plan, h, lp, "moe/")
    else:
        ffn = Lx.mlp(cfg, plan, h, lp, "")
    return x + ffn, kp, vp


def decode_step_paged(cfg: ModelConfig, plan: ShardingPlan,
                      params: Dict[str, jax.Array],
                      cache: Dict[str, jax.Array], token: jax.Array
                      ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One decode step against the paged cache (see paged_cache_specs).
    token: (B, 1) int32 → (logits (B,V) fp32, new cache)."""
    specs = decoder_param_specs(cfg)
    pos = cache["pos"]
    pt = cache["page_table"]
    x = Lx.embed(cfg, plan, params["tok_embed"], token)
    new_cache = dict(cache)

    if cfg.first_dense > 0:
        d0 = _slice_params(params, "d0/")
        a0 = _layer_axes(specs, "d0/")

        def body0(x, xs):
            lp, kp, vp = xs
            if not plan.gather_upfront:
                lp = gather_constrain(plan, lp, a0)
            x, kp, vp = _paged_decode_layer(cfg, plan, x, lp, kp, vp, pt, pos, False)
            return x, (kp, vp)

        x, (nk0, nv0) = jax.lax.scan(body0, x, (d0, cache["k0"], cache["v0"]))
        new_cache["k0"], new_cache["v0"] = nk0, nv0

    blk = _slice_params(params, "blk/")
    ax = _layer_axes(specs, "blk/")
    if plan.gather_upfront:
        blk = stacked_gather_constrain(plan, blk, ax)

    def body(x, xs):
        lp, kp, vp = xs
        if not plan.gather_upfront:
            lp = gather_constrain(plan, lp, ax)
        x, kp, vp = _paged_decode_layer(cfg, plan, x, lp, kp, vp, pt, pos,
                                        cfg.is_moe)
        return x, (kp, vp)

    x, (nk, nv) = jax.lax.scan(body, x, (blk, cache["k"], cache["v"]))
    new_cache["k"], new_cache["v"] = nk, nv
    new_cache["pos"] = pos + 1

    x = Lx.norm(cfg, x, params["final_ln"])
    table = params["tok_embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = Lx.unembed(cfg, plan, x, table, transpose=cfg.tie_embeddings)
    return logits[:, 0, :], new_cache


def _decode_layer(cfg: ModelConfig, plan: ShardingPlan, x, lp, kc, vc, pos,
                  moe_layer: bool):
    h = Lx.norm(cfg, x, lp["ln1"])
    h, kc, vc = Lx.decode_attention(cfg, plan, h, lp, "", kc, vc, pos,
                                    window=cfg.window)
    x = x + h
    h = Lx.norm(cfg, x, lp["ln2"])
    if moe_layer:
        ffn, _ = moe_ffn(cfg, plan, h, lp, "moe/")
    else:
        ffn = Lx.mlp(cfg, plan, h, lp, "")
    return x + ffn, kc, vc


def decode_step(cfg: ModelConfig, plan: ShardingPlan, params: Dict[str, jax.Array],
                cache: Dict[str, jax.Array], token: jax.Array
                ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One decode step. token: (B, 1) int32 → (logits (B,V) fp32, new cache)."""
    specs = decoder_param_specs(cfg)
    pos = cache["pos"]
    x = Lx.embed(cfg, plan, params["tok_embed"], token)
    new_cache = dict(cache)

    if cfg.first_dense > 0:
        d0 = _slice_params(params, "d0/")
        a0 = _layer_axes(specs, "d0/")

        def body0(x, xs):
            lp, kc, vc = xs
            if not plan.gather_upfront:
                lp = gather_constrain(plan, lp, a0)
            x, kc, vc = _decode_layer(cfg, plan, x, lp, kc, vc, pos, False)
            return x, (kc, vc)

        x, (nk0, nv0) = jax.lax.scan(body0, x, (d0, cache["k0"], cache["v0"]))
        new_cache["k0"], new_cache["v0"] = nk0, nv0

    blk = _slice_params(params, "blk/")
    ax = _layer_axes(specs, "blk/")
    if plan.gather_upfront:
        blk = stacked_gather_constrain(plan, blk, ax)

    def body(x, xs):
        lp, kc, vc = xs
        if not plan.gather_upfront:
            lp = gather_constrain(plan, lp, ax)
        x, kc, vc = _decode_layer(cfg, plan, x, lp, kc, vc, pos, cfg.is_moe)
        return x, (kc, vc)

    x, (nk, nv) = jax.lax.scan(body, x, (blk, cache["k"], cache["v"]))
    new_cache["k"], new_cache["v"] = nk, nv
    new_cache["pos"] = pos + 1

    x = Lx.norm(cfg, x, params["final_ln"])
    table = params["tok_embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = Lx.unembed(cfg, plan, x, table, transpose=cfg.tie_embeddings)
    return logits[:, 0, :], new_cache


def prefill(cfg: ModelConfig, plan: ShardingPlan, params: Dict[str, jax.Array],
            tokens: jax.Array, patches: Optional[jax.Array] = None,
            cache_len: Optional[int] = None,
            valid_len: Optional[jax.Array] = None
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Single-pass forward + KV-cache collection.

    Returns (last-position logits (B, V) fp32, cache).  K/V are collected as
    scan outputs of the same stack pass (``collect_kv``) — no second pass.

    ``valid_len`` (scalar or (B,) int32) supports right-padded prompts (the
    serve engine pads to static buckets so admission never recompiles):
    logits are taken at position ``valid_len - 1`` instead of ``S - 1`` and
    the cache ``pos`` starts at ``valid_len``.  Causality makes the pad
    positions inert — no valid token attends to them.
    """
    specs = decoder_param_specs(cfg)
    B, S = tokens.shape
    T = cache_len or S
    x = Lx.embed(cfg, plan, params["tok_embed"], tokens)
    if cfg.family == "vlm" and patches is not None:
        x = jnp.concatenate([patches.astype(x.dtype), x[:, cfg.n_patches:, :]], axis=1)
    positions = jnp.arange(S, dtype=jnp.int32)
    cache = init_cache(cfg, B, T)

    if cfg.first_dense > 0:
        d0 = _slice_params(params, "d0/")
        a0 = _layer_axes(specs, "d0/")
        if plan.gather_upfront:
            d0 = stacked_gather_constrain(plan, d0, a0)
        x, _, (k0, v0) = _run_stack(cfg, plan, x, d0, a0, positions,
                                    moe_layer=False, collect_kv=True)
        cache["k0"] = _place(cache["k0"], k0)
        cache["v0"] = _place(cache["v0"], v0)

    blk = _slice_params(params, "blk/")
    ax = _layer_axes(specs, "blk/")
    if plan.gather_upfront:
        blk = stacked_gather_constrain(plan, blk, ax)
    x, _, (k, v) = _run_stack(cfg, plan, x, blk, ax, positions,
                              moe_layer=cfg.is_moe, collect_kv=True)
    cache["k"] = _place(cache["k"], k)
    cache["v"] = _place(cache["v"], v)
    if valid_len is None:
        cache["pos"] = jnp.full((B,), S, jnp.int32)
        x_last = x[:, -1:, :]
    else:
        vl = jnp.broadcast_to(jnp.asarray(valid_len, jnp.int32), (B,))
        cache["pos"] = vl
        idx = jnp.clip(vl - 1, 0, S - 1)
        x_last = jnp.take_along_axis(
            x, idx[:, None, None].astype(jnp.int32), axis=1)
    x_last = Lx.norm(cfg, x_last, params["final_ln"])
    table = params["tok_embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = Lx.unembed(cfg, plan, x_last, table, transpose=cfg.tie_embeddings)
    return logits[:, 0, :], cache


def _place(buf: jax.Array, kv: jax.Array) -> jax.Array:
    """Write (L,B,S,KV,Dh) prefill K/V into the (L,B,T,KV,Dh) cache buffer."""
    return jax.lax.dynamic_update_slice_in_dim(buf, kv.astype(buf.dtype), 0, axis=2)
