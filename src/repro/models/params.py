"""Parameter specification system: one source of truth for shape, dtype,
init AND logical sharding axes.

Every model declares ``param_specs(cfg) -> {path: ParamSpec}``; from that we
derive initialization, the sharding pytree (via :mod:`repro.dist.plan`),
checkpoint manifests, and the dry-run ``ShapeDtypeStruct`` stand-ins.  A flat
``{path: array}`` dict is the params pytree everywhere (paths are
``"block/attn/wq"`` style).

Logical axis names (resolved to mesh axes by a ``ShardingPlan``):

    layers     scan-stacked layer dim            (never sharded)
    embed      d_model rows                      (FSDP axis)
    vocab      vocabulary                        (TP)
    heads      q-head * head_dim columns         (TP)
    kv_heads   kv-head * head_dim columns        (TP if divisible)
    mlp        ffn hidden                        (TP)
    experts    MoE expert dim                    (EP)
    ssm_inner  SSD d_inner                       (TP)
    lru        RG-LRU width                      (TP)
    null       explicitly replicated
"""

from __future__ import annotations

import math
import zlib
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]  # logical axis per dim (None = replicated)
    init: str = "normal"  # normal | zeros | ones | scaled
    scale: Optional[float] = None  # stddev override; default 1/sqrt(fan_in)
    dtype: Any = jnp.float32

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _fan_in(shape: Tuple[int, ...]) -> int:
    # matmul weights here are (.., in, out); fan-in = second-to-last dim
    return shape[-2] if len(shape) >= 2 else shape[-1]


def init_param(key: jax.Array, spec: ParamSpec) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    std = spec.scale if spec.scale is not None else 1.0 / math.sqrt(_fan_in(spec.shape))
    return (jax.random.normal(key, spec.shape, jnp.float32) * std).astype(spec.dtype)


def init_params(specs: Dict[str, ParamSpec], rng: jax.Array) -> Dict[str, jax.Array]:
    """Deterministic per-path keys: fold the path hash into the root key.

    Uses crc32, not ``hash()`` — Python string hashing is salted per
    process (PYTHONHASHSEED), which would make init draws differ across
    processes and elastic restarts."""
    out: Dict[str, jax.Array] = {}
    for path in sorted(specs):
        spec = specs[path]
        key = jax.random.fold_in(rng, zlib.crc32(path.encode()) % (2**31))
        out[path] = init_param(key, spec)
    return out


def abstract_params(specs: Dict[str, ParamSpec]) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for the dry-run (no allocation)."""
    return {p: jax.ShapeDtypeStruct(s.shape, s.dtype) for p, s in specs.items()}


def param_count(specs: Dict[str, ParamSpec]) -> int:
    return int(sum(np.prod(s.shape) for s in specs.values()))


def param_bytes(specs: Dict[str, ParamSpec]) -> int:
    return int(
        sum(np.prod(s.shape) * jnp.dtype(s.dtype).itemsize for s in specs.values())
    )
