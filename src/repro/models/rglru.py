"""Griffin / RecurrentGemma blocks [arXiv:2402.19427]: RG-LRU recurrent
blocks interleaved with local (sliding-window) MQA attention, pattern
(rec, rec, attn).

RG-LRU recurrence (per channel):

    r_t = σ(W_a x_t + b_a)                   recurrence gate
    i_t = σ(W_x x_t + b_x)                   input gate
    a_t = exp(-c · softplus(Λ) · r_t)        c = 8
    h_t = a_t ⊙ h_{t-1} + sqrt(1 − a_t²) ⊙ (i_t ⊙ x_t)

Prefill runs it as an associative scan (log-depth in XLA; the Pallas kernel
``kernels/rglru_scan.py`` is the sequential-in-VMEM TPU version).  Decode is
the O(1) recurrence — with the 2048-token ring-buffer KV of the local-attn
layers this is what makes the 500k cell sub-quadratic (DESIGN.md §4).
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist.plan import ShardingPlan
from repro.models.layers import cdtype, norm
from repro.models.params import ParamSpec
from repro.models.ssm import causal_conv1d

_RGLRU_C = 8.0


def rec_param_specs(cfg: ModelConfig, L: int, prefix: str) -> Dict[str, ParamSpec]:
    """Recurrent-block params, stacked (L, …)."""
    D, W = cfg.d_model, cfg.lru_width
    return {
        f"{prefix}ln": ParamSpec((L, D), ("layers", None), init="ones"),
        f"{prefix}w_x": ParamSpec((L, D, W), ("layers", "embed", "lru")),
        f"{prefix}w_gate_branch": ParamSpec((L, D, W), ("layers", "embed", "lru")),
        f"{prefix}conv_w": ParamSpec((L, cfg.ssm_conv, W), ("layers", None, "lru"),
                                     init="scaled", scale=0.5),
        f"{prefix}conv_b": ParamSpec((L, W), ("layers", "lru"), init="zeros"),
        f"{prefix}lam": ParamSpec((L, W), ("layers", "lru"), init="ones"),
        f"{prefix}w_a": ParamSpec((L, W, W), ("layers", "lru", None)),
        f"{prefix}b_a": ParamSpec((L, W), ("layers", "lru"), init="zeros"),
        f"{prefix}w_i": ParamSpec((L, W, W), ("layers", "lru", None)),
        f"{prefix}b_i": ParamSpec((L, W), ("layers", "lru"), init="zeros"),
        f"{prefix}rec_out": ParamSpec((L, W, D), ("layers", "lru", "embed")),
    }


def _gates(p: Dict[str, jax.Array], prefix: str, xw: jax.Array, dt):
    """a (log-decay) and gated input for the recurrence. xw: (..., W)."""
    r = jax.nn.sigmoid(xw.astype(jnp.float32) @ p[f"{prefix}w_a"].astype(jnp.float32)
                       + p[f"{prefix}b_a"])
    i = jax.nn.sigmoid(xw.astype(jnp.float32) @ p[f"{prefix}w_i"].astype(jnp.float32)
                       + p[f"{prefix}b_i"])
    log_a = -_RGLRU_C * jax.nn.softplus(p[f"{prefix}lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    gated_x = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * xw.astype(jnp.float32))
    return a, gated_x


def rglru_scan(a: jax.Array, b: jax.Array, h0: jax.Array | None = None) -> jax.Array:
    """h_t = a_t h_{t-1} + b_t via associative scan. a/b: (B,S,W) fp32."""
    if h0 is not None:
        # fold h0 into the first step: b_0 += a_0 * h0
        b = b.at[:, 0, :].add(a[:, 0, :] * h0)

    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


def rec_block(cfg: ModelConfig, plan: ShardingPlan, x: jax.Array,
              p: Dict[str, jax.Array], prefix: str) -> jax.Array:
    """Griffin recurrent block (train/prefill): x (B,S,D) → (B,S,D)."""
    dt = cdtype(cfg)
    h = norm(cfg, x, p[f"{prefix}ln"])
    gate = jax.nn.gelu(h @ p[f"{prefix}w_gate_branch"].astype(dt))
    xw = h @ p[f"{prefix}w_x"].astype(dt)
    xw = causal_conv1d(xw, p[f"{prefix}conv_w"], p[f"{prefix}conv_b"])
    a, gx = _gates(p, prefix, xw, dt)
    hseq = rglru_scan(a, gx).astype(dt)
    y = (gate * hseq) @ p[f"{prefix}rec_out"].astype(dt)
    return x + y


def rec_block_decode(cfg: ModelConfig, plan: ShardingPlan, x: jax.Array,
                     p: Dict[str, jax.Array], prefix: str,
                     conv_state: jax.Array, h_state: jax.Array):
    """One-token decode. x: (B,1,D); conv_state: (B,K-1,W); h_state: (B,W)."""
    dt = cdtype(cfg)
    h = norm(cfg, x, p[f"{prefix}ln"])[:, 0]  # (B,D)
    gate = jax.nn.gelu(h @ p[f"{prefix}w_gate_branch"].astype(dt))
    xw = h @ p[f"{prefix}w_x"].astype(dt)
    seq = jnp.concatenate([conv_state.astype(dt), xw[:, None, :]], axis=1)  # (B,K,W)
    w = p[f"{prefix}conv_w"].astype(dt)
    xw = jax.nn.silu(jnp.sum(seq * w[None, :, :], axis=1) + p[f"{prefix}conv_b"].astype(dt))
    new_conv = seq[:, 1:, :]
    a, gx = _gates(p, prefix, xw, dt)
    new_h = a * h_state.astype(jnp.float32) + gx
    y = (gate * new_h.astype(dt)) @ p[f"{prefix}rec_out"].astype(dt)
    return x + y[:, None, :], new_conv.astype(conv_state.dtype), new_h.astype(h_state.dtype)
