"""Unified model facade: one API across the six families.

``Model(cfg, plan)`` dispatches to the family module and exposes:

    param_specs() / init(rng) / abstract_params()
    loss(params, batch)                  train objective
    prefill(params, inputs)              → (last logits, cache)
    decode(params, cache, token)         → (logits, new cache)
    cache_specs(batch, cache_len, enc_len) / cache_axes()
    batch_specs(cell) / prefill_specs(cell) / decode_specs(cell)
        → ShapeDtypeStruct stand-ins for the dry-run (no allocation)

Modality frontends are stubs per the assignment: ``encdec`` takes
precomputed frame embeddings, ``vlm`` takes precomputed patch embeddings.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeCell
from repro.dist.plan import ShardingPlan
from repro.models import encdec, hybrid, ssm_lm, transformer
from repro.models.params import ParamSpec, abstract_params, init_params


class Model:
    def __init__(self, cfg: ModelConfig, plan: ShardingPlan):
        self.cfg = cfg
        self.plan = plan
        fam = cfg.family
        if fam in ("dense", "moe", "vlm"):
            self._m = transformer
            self._specs = transformer.decoder_param_specs(cfg)
        elif fam == "ssm":
            self._m = ssm_lm
            self._specs = ssm_lm.lm_param_specs(cfg)
        elif fam == "hybrid":
            self._m = hybrid
            self._specs = hybrid.hybrid_param_specs(cfg)
        elif fam == "encdec":
            self._m = encdec
            self._specs = encdec.encdec_param_specs(cfg)
        else:
            raise ValueError(f"unknown family {fam!r}")

    # ---------------------------------------------------------------- params
    def param_specs(self) -> Dict[str, ParamSpec]:
        return self._specs

    def init(self, rng: jax.Array) -> Dict[str, jax.Array]:
        return init_params(self._specs, rng)

    def abstract_params(self) -> Dict[str, jax.ShapeDtypeStruct]:
        return abstract_params(self._specs)

    # ----------------------------------------------------------------- train
    def loss(self, params, batch) -> jax.Array:
        if self.cfg.family == "encdec":
            return encdec.loss_fn(self.cfg, self.plan, params, batch)
        if self.cfg.family in ("ssm",):
            return ssm_lm.loss_fn(self.cfg, self.plan, params, batch)
        if self.cfg.family == "hybrid":
            return hybrid.loss_fn(self.cfg, self.plan, params, batch)
        return transformer.loss_fn(self.cfg, self.plan, params, batch)

    # ----------------------------------------------------------------- serve
    def prefill(self, params, inputs: Dict[str, jax.Array],
                cache_len: Optional[int] = None,
                valid_len: Optional[jax.Array] = None):
        """``cache_len`` is static (jit with static_argnums if passed).
        ``valid_len`` (traced) supports right-padded prompts — transformer
        families only (the serve engine's bucketed admission)."""
        cfg, plan = self.cfg, self.plan
        if cfg.family == "encdec":
            return encdec.prefill(cfg, plan, params, inputs["enc"], inputs["tokens"],
                                  cache_len=cache_len)
        if cfg.family == "ssm":
            return ssm_lm.prefill(cfg, plan, params, inputs["tokens"])
        if cfg.family == "hybrid":
            return hybrid.prefill(cfg, plan, params, inputs["tokens"])
        return transformer.prefill(cfg, plan, params, inputs["tokens"],
                                   patches=inputs.get("patches"),
                                   cache_len=cache_len, valid_len=valid_len)

    def decode(self, params, cache, token):
        cfg, plan = self.cfg, self.plan
        if cfg.family == "encdec":
            return encdec.decode_step(cfg, plan, params, cache, token)
        if cfg.family == "ssm":
            return ssm_lm.decode_step(cfg, plan, params, cache, token)
        if cfg.family == "hybrid":
            return hybrid.decode_step(cfg, plan, params, cache, token)
        return transformer.decode_step(cfg, plan, params, cache, token)

    @property
    def supports_paged(self) -> bool:
        """Paged KV serving applies to families with a dense KV cache; SSM /
        hybrid / encdec carry recurrent or ring-buffer state instead."""
        return self.cfg.family in ("dense", "moe", "vlm")

    def decode_paged(self, params, cache, token):
        """One decode step against a block-pool paged cache
        (:func:`repro.models.transformer.paged_cache_specs` layout)."""
        assert self.supports_paged, self.cfg.family
        return transformer.decode_step_paged(self.cfg, self.plan, params,
                                             cache, token)

    def paged_cache_specs(self, num_pages: int, page_size: int,
                          max_batch: int, max_pages_per_req: int):
        assert self.supports_paged, self.cfg.family
        return transformer.paged_cache_specs(self.cfg, num_pages, page_size,
                                             max_batch, max_pages_per_req)

    def cache_specs(self, batch: int, cache_len: int, enc_len: Optional[int] = None):
        cfg = self.cfg
        if cfg.family == "encdec":
            return encdec.init_cache_specs(cfg, batch, cache_len, enc_len or cache_len)
        if cfg.family == "ssm":
            return ssm_lm.init_cache_specs(cfg, batch, cache_len)
        if cfg.family == "hybrid":
            return hybrid.init_cache_specs(cfg, batch, cache_len)
        return transformer.init_cache_specs(cfg, batch, cache_len)

    def cache_axes(self):
        cfg = self.cfg
        if cfg.family == "encdec":
            return encdec.cache_axes(cfg)
        if cfg.family == "ssm":
            return ssm_lm.cache_axes(cfg)
        if cfg.family == "hybrid":
            return hybrid.cache_axes(cfg)
        return transformer.cache_axes(cfg)

    # ------------------------------------------------------- dry-run inputs
    def batch_specs(self, cell: ShapeCell) -> Dict[str, jax.ShapeDtypeStruct]:
        """Training-batch stand-ins for a shape cell."""
        cfg = self.cfg
        B, S = cell.global_batch, cell.seq_len
        dt = jnp.dtype(cfg.dtype)
        specs = {"tokens": jax.ShapeDtypeStruct((B, S + 1), jnp.int32)}
        if cfg.family == "vlm":
            specs["patches"] = jax.ShapeDtypeStruct((B, cfg.n_patches, cfg.d_model), dt)
        if cfg.family == "encdec":
            specs["enc"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), dt)
        return specs

    def batch_axes(self) -> Dict[str, Tuple]:
        cfg = self.cfg
        ax = {"tokens": ("batch", "seq")}
        if cfg.family == "vlm":
            ax["patches"] = ("batch", "seq", None)
        if cfg.family == "encdec":
            ax["enc"] = ("batch", "seq", None)
        return ax

    def prefill_specs(self, cell: ShapeCell) -> Dict[str, jax.ShapeDtypeStruct]:
        cfg = self.cfg
        B, S = cell.global_batch, cell.seq_len
        dt = jnp.dtype(cfg.dtype)
        specs = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        if cfg.family == "vlm":
            specs["patches"] = jax.ShapeDtypeStruct((B, cfg.n_patches, cfg.d_model), dt)
        if cfg.family == "encdec":
            specs["enc"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), dt)
        return specs

    def decode_specs(self, cell: ShapeCell) -> Tuple[Dict[str, Any], jax.ShapeDtypeStruct]:
        """(cache specs, token spec) for a decode cell: one new token against
        a KV cache of ``cell.seq_len``."""
        B, S = cell.global_batch, cell.seq_len
        cache = self.cache_specs(B, S, enc_len=S)
        token = jax.ShapeDtypeStruct((B, 1), jnp.int32)
        return cache, token


def build_model(cfg: ModelConfig, plan: ShardingPlan) -> Model:
    return Model(cfg, plan)
