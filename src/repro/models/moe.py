"""Mixture-of-Experts FFN: shared + routed experts, top-k, capacity dispatch.

This layer is the flagship *parcel* user (DESIGN.md P4): a token assigned to
an expert is an active message — the token (arguments) travels to the expert
"locality" (its shard on the model axis), compute happens *at the data*, and
the result returns through the combine path.  Dispatch-time load balance
(capacity factor + aux loss) replaces HPX's dynamic work stealing, which has
no on-device analogue (DESIGN.md §8.3).

Dispatch is **grouped-local** (GShard-style groups == data shards): tokens
are viewed as (G, T/G, D) with G = the batch-sharding degree of the active
mesh, routing ranks are computed per group with a one-hot cumsum (no global
sort), and the capacity buffers are (G, E, C, D) built by *batched* scatters
(vmap over G) — the scatter's batch dim aligns with the data axis, so GSPMD
keeps dispatch entirely local to each shard.  The EXPERIMENTS.md §Perf log
records the win: the naive global-scatter formulation forced full-buffer
all-reduces over the data axis (granite-moe train: 559 s collective term).

Capacity is per group (C = cf·T_loc·k/E), the standard per-shard semantics
of production EP systems.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.dist.plan import ShardingPlan, _active_mesh
from repro.models.layers import act_fn, cdtype
from repro.models.params import ParamSpec


def moe_param_specs(cfg: ModelConfig, L: int, prefix: str) -> Dict[str, ParamSpec]:
    """Stacked (L, …) specs for the routed-expert FFN of ``L`` layers."""
    D, F, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    specs: Dict[str, ParamSpec] = {
        f"{prefix}router": ParamSpec((L, D, E), ("layers", "embed", None)),
        f"{prefix}w_in": ParamSpec((L, E, D, F), ("layers", "experts", "embed", "mlp")),
        f"{prefix}w_gate": ParamSpec((L, E, D, F), ("layers", "experts", "embed", "mlp")),
        f"{prefix}w_out": ParamSpec((L, E, F, D), ("layers", "experts", "mlp", "embed")),
    }
    if cfg.n_shared_experts > 0:
        Fs = cfg.n_shared_experts * F
        specs.update({
            f"{prefix}shared_w_in": ParamSpec((L, D, Fs), ("layers", "embed", "mlp")),
            f"{prefix}shared_w_gate": ParamSpec((L, D, Fs), ("layers", "embed", "mlp")),
            f"{prefix}shared_w_out": ParamSpec((L, Fs, D), ("layers", "mlp", "embed")),
        })
    return specs


def _group_count(T: int) -> int:
    """Dispatch groups = batch-sharding degree of the active mesh."""
    mesh = _active_mesh()
    if mesh is None:
        return 1
    g = 1
    for ax in ("pod", "data"):
        if ax in mesh.axis_names:
            g *= mesh.shape[ax]
    return g if g > 1 and T % g == 0 else 1


def moe_ffn(cfg: ModelConfig, plan: ShardingPlan, x: jax.Array,
            p: Dict[str, jax.Array], prefix: str = "") -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D) → (out (B,S,D), aux_loss scalar)."""
    dt = cdtype(cfg)
    B, S, D = x.shape
    E, K, F = cfg.n_experts, cfg.top_k, cfg.d_ff
    T = B * S
    G = _group_count(T)
    TL = T // G  # tokens per group (== per data shard on the production mesh)
    xt = plan.constrain(x.reshape(G, TL, D), ("batch", None, None))

    # ---- routing (fp32, local per group) ----------------------------------
    logits = jnp.einsum("gtd,de->gte", xt, p[f"{prefix}router"].astype(dt),
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, gate_i = jax.lax.top_k(probs, K)  # (G,TL,K)
    gate_w = gate_w / jnp.maximum(jnp.sum(gate_w, axis=-1, keepdims=True), 1e-9)

    # Switch-style load-balance aux loss: E · Σ_e f_e · P_e (global mean)
    f_e = jnp.mean(jax.nn.one_hot(gate_i, E, dtype=jnp.float32), axis=(0, 1, 2))
    P_e = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(f_e * P_e)

    # ---- grouped-local dispatch (parcel routing) ---------------------------
    A = TL * K  # assignments per group
    # capacity floor: small-T (decode) batches must never drop — a dropped
    # parcel at decode time corrupts a live request
    C = max(int(cfg.capacity_factor * A / E), min(A, 16), 1)
    flat_e = gate_i.reshape(G, A)
    tok_of = jnp.broadcast_to(
        jnp.repeat(jnp.arange(TL), K)[None, :], (G, A))
    # rank within (group, expert): one-hot cumsum — local, no global sort
    onehot = (flat_e[:, :, None] == jnp.arange(E)[None, None, :]).astype(jnp.int32)
    pos = jnp.take_along_axis(jnp.cumsum(onehot, axis=1), flat_e[:, :, None],
                              axis=2)[:, :, 0] - 1  # (G, A)
    keep = pos < C
    slot = jnp.where(keep, flat_e * C + pos, E * C)  # trap row for drops

    updates = jnp.take_along_axis(xt, tok_of[:, :, None], axis=1).astype(dt)
    buf = jax.vmap(lambda s, u: jnp.zeros((E * C + 1, D), dt).at[s].add(u))(
        slot, updates)  # batched scatter: group dim == data shard, stays local
    buf = plan.constrain(buf[:, : E * C].reshape(G, E, C, D),
                         ("batch", "experts", "expert_cap", None))

    # ---- expert GEMMs at the data (model-axis shards) ----------------------
    h = jnp.einsum("gecd,edf->gecf", buf, p[f"{prefix}w_in"].astype(dt))
    g = jnp.einsum("gecd,edf->gecf", buf, p[f"{prefix}w_gate"].astype(dt))
    h = act_fn(cfg, g) * h
    out_buf = jnp.einsum("gecf,efd->gecd", h, p[f"{prefix}w_out"].astype(dt))
    out_buf = plan.constrain(out_buf, ("batch", "experts", "expert_cap", None))

    # ---- combine (return parcels, batched gather + scatter) ----------------
    flat_out = jnp.concatenate(
        [out_buf.reshape(G, E * C, D), jnp.zeros((G, 1, D), dt)], axis=1)
    y_assign = jnp.take_along_axis(flat_out, slot[:, :, None], axis=1)
    y_assign = y_assign * gate_w.reshape(G, A)[:, :, None].astype(dt)
    y = jax.vmap(lambda t, ya: jnp.zeros((TL, D), dt).at[t].add(ya))(
        tok_of, y_assign)
    y = plan.constrain(y, ("batch", None, None))

    # ---- shared experts (dense path, always-on) ----------------------------
    if cfg.n_shared_experts > 0:
        hs = jnp.einsum("gtd,df->gtf", xt, p[f"{prefix}shared_w_in"].astype(dt))
        gs = jnp.einsum("gtd,df->gtf", xt, p[f"{prefix}shared_w_gate"].astype(dt))
        y = y + jnp.einsum("gtf,fd->gtd", act_fn(cfg, gs) * hs,
                           p[f"{prefix}shared_w_out"].astype(dt))

    return y.reshape(B, S, D), aux
