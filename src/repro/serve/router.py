"""Multi-engine router: least-loaded dispatch over engine replicas.

Scaling past one engine means scaling past one decode chain: each
:class:`~repro.serve.engine.Engine` replica owns its own page pool, decode
continuation chain and performance counters, and the router is the only
coordination point.  Dispatch follows the message-cost lens of the HPX+LCI
study (PAPERS.md): the decision reads *locally cached* counters
(``submitted - completed`` per replica — the engines already publish them)
so routing a request costs zero extra messages; there is no global queue,
no barrier, and replicas never talk to each other.  This is the paper's
"decentralized control flow" one level up from the scheduler.

Replicas share the (read-only) model parameters — on TPU they would be
distinct meshes or pods; on host they are independent engines interleaving
on the AMT runtime's workers.

Counters::

    /serve{router}/requests/dispatched           cumulative
    /serve{router}/dispatch/<engine-name>        cumulative per replica
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax

from repro.core import counters as _counters
from repro.core.future import Channel, Future
from repro.models.model import Model
from repro.serve.engine import Engine, SamplingParams, ServeConfig


class Router:
    def __init__(self, engines: List[Engine]):
        assert engines, "router needs at least one engine"
        self.engines = engines
        reg = _counters.default()
        self.c_dispatched = reg.counter("/serve{router}/requests/dispatched")
        self._c_per_engine = [
            reg.counter(f"/serve{{router}}/dispatch/{e.scfg.name}")
            for e in engines
        ]

    # ------------------------------------------------------------- factory
    @classmethod
    def replicate(cls, model: Model, params: Dict[str, jax.Array],
                  scfg: ServeConfig, replicas: int,
                  extra_inputs: Optional[Dict[str, Any]] = None) -> "Router":
        """N engine replicas named ``engine#0..N-1`` over shared params."""
        engines = []
        for i in range(replicas):
            cfg_i = ServeConfig(**{**scfg.__dict__, "name": f"engine#{i}"})
            engines.append(Engine(model, params, cfg_i,
                                  extra_inputs=extra_inputs))
        return cls(engines)

    # ------------------------------------------------------------ dispatch
    def loads(self) -> List[float]:
        return [e.load() for e in self.engines]

    def pick(self) -> int:
        """Least-loaded replica (first wins ties — stable under no load)."""
        loads = self.loads()
        return min(range(len(loads)), key=lambda i: loads[i])

    def submit(self, prompt: List[int], max_new: Optional[int] = None,
               sampling: Optional[SamplingParams] = None,
               stream: Optional[Channel] = None) -> Future:
        i = self.pick()
        self.c_dispatched.increment()
        self._c_per_engine[i].increment()
        return self.engines[i].submit(prompt, max_new, sampling, stream)

    def submit_stream(self, prompt: List[int], max_new: Optional[int] = None,
                      sampling: Optional[SamplingParams] = None
                      ) -> Tuple[Channel, Future]:
        ch: Channel = Channel()
        return ch, self.submit(prompt, max_new, sampling, stream=ch)
