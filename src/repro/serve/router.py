"""Multi-engine router: least-loaded dispatch over local *and remote*
engine replicas.

Scaling past one engine means scaling past one decode chain: each
:class:`~repro.serve.engine.Engine` replica owns its own page pool, decode
continuation chain and performance counters, and the router is the only
coordination point.  Dispatch follows the message-cost lens of the HPX+LCI
study (PAPERS.md): the decision reads *locally held* state — local engines
publish ``submitted - completed`` counters, remote engines a load estimate
maintained from (a) this router's own in-flight submissions and (b) the
authoritative load the engine's locality *gossips back over the
parcelport*, piggybacked on every result frame — so routing a request
costs zero extra messages; there is no global queue, no barrier, and
replicas never talk to each other.  This is the paper's "decentralized
control flow" one level up from the scheduler.

With :mod:`repro.net` bootstrapped, :meth:`Router.over_localities` places
one engine per locality (each its own OS process: its own GIL, scheduler,
page pool) and fronts them uniformly: a :class:`RemoteEngine` handle ships
``submit`` as a parcel to the engine's locality and completes the caller's
Future from the result frame.  Replicas build identical parameters from
the same seed — on TPU they would be distinct meshes or pods; on host they
are separate processes, which is what makes CPU-bound serving actually
scale (one GIL per locality).

Counters::

    /serve{router}/requests/dispatched           cumulative
    /serve{router}/dispatch/<engine-name>        cumulative per replica
    /serve{router}/load/<engine-name>            gauge, gossiped (remote)
"""

from __future__ import annotations

import threading
from typing import Any, Dict, List, Optional, Tuple

import jax

from repro.core import agas as _agas
from repro.core import counters as _counters
from repro.core import parcel as _parcel
from repro.core.future import Channel, Future, Promise
from repro.models.model import Model
from repro.serve.engine import Engine, SamplingParams, ServeConfig

ENGINE_NAME_PREFIX = "/engines/"


def engine_name(e: Any) -> str:
    """Display/counter name of a local Engine or RemoteEngine handle."""
    name = getattr(e, "name", None)
    return name if name is not None else e.scfg.name


def default_extra_inputs(cfg) -> Dict[str, Any]:
    """Family-dependent synthetic side inputs (vlm patches, encdec memory)
    — built *where the engine lives*, never shipped over the wire."""
    import jax.numpy as jnp

    extra: Dict[str, Any] = {}
    if cfg.family == "vlm":
        extra["patches"] = jnp.zeros((1, cfg.n_patches, cfg.d_model),
                                     jnp.bfloat16)
    if cfg.family == "encdec":
        extra["enc"] = jnp.zeros((1, 64, cfg.d_model), jnp.bfloat16)
        extra["enc_len"] = 64
    return extra


def build_engine(arch: str, smoke: bool, plan: str,
                 scfg_kwargs: Dict[str, Any]) -> Engine:
    """The one engine-construction recipe every locality uses.

    Params come from the shared init seed, so replicas built here are
    identical on every locality without ever moving weights — the
    greedy-parity guarantee depends on local and remote spawns sharing
    this exact path."""
    from repro.configs import get_config
    from repro.dist.plan import get_plan
    from repro.models.model import build_model

    cfg = get_config(arch, smoke=smoke)
    model = build_model(cfg, get_plan(plan))
    params = model.init(jax.random.PRNGKey(0))
    return Engine(model, params, ServeConfig(**scfg_kwargs),
                  extra_inputs=default_extra_inputs(cfg))


# ----------------------------------------------------------- remote actions
@_parcel.action
def _spawn_engine(rt, arch: str, smoke: bool, plan: str,
                  scfg_kwargs: Dict[str, Any]) -> List[int]:
    """Build a full engine at this locality and register it in AGAS; the
    returned GID key is what the root's :class:`RemoteEngine` targets."""
    from repro.net.locality import _gid_key

    engine = build_engine(arch, smoke, plan, scfg_kwargs)
    gid = _agas.default().register(
        engine, name=f"{ENGINE_NAME_PREFIX}{engine.scfg.name}")
    return list(_gid_key(gid))


@_parcel.action
def _engine_submit(engine: Engine, prompt: List[int], max_new: Optional[int],
                   sampling: Optional[SamplingParams]
                   ) -> Tuple[List[int], float]:
    """Runs at the engine's locality; blocks a pool worker (help-along) and
    returns ``(tokens, load-after-completion)`` — the second element is the
    gossip payload the result frame carries back."""
    tokens = engine.submit(prompt, max_new, sampling).get(timeout=600)
    return tokens, engine.load()


class RemoteEngine:
    """Router-side handle to an engine living on another locality.

    ``load()`` needs no wire traffic: it is the max of this router's own
    in-flight count and the engine-side load gossiped back on the last
    result frame (both local reads — zero-message dispatch)."""

    def __init__(self, net, locality: int, gid: _agas.GID, name: str):
        self.net = net
        self.locality = locality
        self.gid = gid
        self.name = name
        self._inflight = 0
        self._gossip = 0.0
        self._lock = threading.Lock()
        self._c_load = _counters.default().gauge(
            f"/serve{{router}}/load/{name}")

    def submit(self, prompt: List[int], max_new: Optional[int] = None,
               sampling: Optional[SamplingParams] = None,
               stream: Optional[Channel] = None) -> Future:
        if stream is not None:
            raise ValueError(
                "streaming channels are per-process; submit to a local "
                "engine or consume the remote future instead")
        from repro.net import remote as _remote

        inner = _remote.apply_remote(_engine_submit, self.gid, list(prompt),
                                     max_new, sampling)
        # count in-flight only once the submit is actually in motion — a
        # synchronous apply_remote failure must not inflate load() forever
        with self._lock:
            self._inflight += 1
        promise: Promise = Promise()

        def done(f: Future) -> None:
            with self._lock:
                self._inflight -= 1
                exc = f.exception()
                if exc is None:
                    tokens, load = f._value
                    self._gossip = float(load)
                    self._c_load.set(self._gossip)
            if exc is None:
                promise.set_value(tokens)
            else:
                promise.set_exception(exc)

        inner.on_ready(done)
        return promise.future()

    def submit_stream(self, *a: Any, **kw: Any):
        raise ValueError("streaming is local-only; see RemoteEngine.submit")

    def load(self) -> float:
        with self._lock:
            return float(max(self._gossip, self._inflight))


# ------------------------------------------------------------------- router
class Router:
    def __init__(self, engines: List[Any]):
        assert engines, "router needs at least one engine"
        self.engines = engines
        reg = _counters.default()
        self.c_dispatched = reg.counter("/serve{router}/requests/dispatched")
        self._c_per_engine = [
            reg.counter(f"/serve{{router}}/dispatch/{engine_name(e)}")
            for e in engines
        ]

    # ------------------------------------------------------------- factory
    @classmethod
    def replicate(cls, model: Model, params: Dict[str, jax.Array],
                  scfg: ServeConfig, replicas: int,
                  extra_inputs: Optional[Dict[str, Any]] = None) -> "Router":
        """N engine replicas named ``engine#0..N-1`` over shared params."""
        engines = []
        for i in range(replicas):
            cfg_i = ServeConfig(**{**scfg.__dict__, "name": f"engine#{i}"})
            engines.append(Engine(model, params, cfg_i,
                                  extra_inputs=extra_inputs))
        return cls(engines)

    @classmethod
    def over_localities(cls, net, arch: str, scfg: ServeConfig,
                        smoke: bool = True, plan: str = "serve",
                        timeout: float = 600.0) -> "Router":
        """One engine per locality: a local Engine at this locality, a
        :class:`RemoteEngine` handle per worker locality (spawned through
        ``run_on`` — the engine is built *where it runs*, by the same
        :func:`build_engine` recipe)."""
        from repro.net import remote as _remote

        spawns = []
        for loc in range(net.n_localities):
            if loc == net.locality:
                continue
            name = f"engine#{loc}"
            spawns.append((loc, name, _remote.run_on(
                loc, _spawn_engine, arch, smoke, plan,
                {**scfg.__dict__, "name": name})))

        engines: List[Any] = [build_engine(
            arch, smoke, plan,
            {**scfg.__dict__, "name": f"engine#{net.locality}"})]
        for loc, name, fut in spawns:
            key = fut.get(timeout=timeout)
            engines.append(RemoteEngine(net, loc, _agas.GID(*key), name))
        return cls(engines)

    # ------------------------------------------------------------ dispatch
    def loads(self) -> List[float]:
        return [e.load() for e in self.engines]

    def pick(self, local_only: bool = False) -> int:
        """Least-loaded replica (first wins ties — stable under no load).

        ``local_only`` restricts to in-process engines — the streaming
        path: token channels cannot cross a process boundary."""
        loads = self.loads()
        candidates = [i for i, e in enumerate(self.engines)
                      if not (local_only and isinstance(e, RemoteEngine))]
        if not candidates:
            raise ValueError("no local engine available for streaming")
        return min(candidates, key=lambda i: loads[i])

    def submit(self, prompt: List[int], max_new: Optional[int] = None,
               sampling: Optional[SamplingParams] = None,
               stream: Optional[Channel] = None) -> Future:
        i = self.pick(local_only=stream is not None)
        self.c_dispatched.increment()
        self._c_per_engine[i].increment()
        return self.engines[i].submit(prompt, max_new, sampling, stream)

    def submit_stream(self, prompt: List[int], max_new: Optional[int] = None,
                      sampling: Optional[SamplingParams] = None
                      ) -> Tuple[Channel, Future]:
        ch: Channel = Channel()
        return ch, self.submit(prompt, max_new, sampling, stream=ch)
