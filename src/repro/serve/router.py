"""Multi-engine router: SLO-tiered, fault-tolerant least-loaded dispatch
over local *and remote* engine replicas.

Scaling past one engine means scaling past one decode chain: each
:class:`~repro.serve.engine.Engine` replica owns its own page pool, decode
continuation chain and performance counters, and the router is the only
coordination point.  Dispatch follows the message-cost lens of the HPX+LCI
study (PAPERS.md): the decision reads *locally held* state — local engines
publish ``submitted - completed`` counters, remote engines a load estimate
maintained from (a) this router's own in-flight submissions and (b) the
authoritative load **and KV-page occupancy** the engine's locality gossips
back, piggybacked on every completion parcel — so routing a request costs
zero extra messages; there is no global queue, no barrier, and replicas
never talk to each other.  This is the paper's "decentralized control
flow" one level up from the scheduler.

The fleet tier (``repro.fleet``) layers three behaviors on top:

- **SLO tiers** — engines carry a tier label (``interactive`` / ``batch``
  / untiered); ``submit(..., slo=...)`` prefers same-tier engines, so a
  batch flood deepens batch queues without touching interactive p99.
  Batch submits additionally pass an admission gate driven by gossiped
  occupancy; gated requests park in a FIFO until ``release_gated``.
- **Failover** — a dead engine locality surfaces as
  :class:`~repro.net.parcelport.PortClosed`; the router evicts the engine
  and retries the submit on a healthy peer (idempotent: a streamed
  request is retried only when zero tokens were delivered — a broken
  prefix is :class:`~repro.serve.relay.StreamBroken`, never re-run).
- **Elasticity** — ``add_engine`` / ``remove_engine`` / ``suspend`` admit
  and retire replicas on a *running* router (spawn, drain, migrate).

Counters::

    /serve{router}/requests/dispatched           cumulative
    /serve{router}/dispatch/<engine-name>        cumulative per replica
    /serve{router}/load/<engine-name>            gauge, gossiped (remote)
    /serve{router}/failover/{evicted,retried,exhausted}   cumulative
    /serve{router}/admission/{gated,released}    cumulative
    /serve{router}/admission/depth               gauge
"""

from __future__ import annotations

import itertools
import threading
from collections import deque
from typing import Any, Dict, List, Optional, Tuple

import jax

from repro.core import agas as _agas
from repro.core import counters as _counters
from repro.core import parcel as _parcel
from repro.core.future import Channel, Future, Promise
from repro.models.model import Model
from repro.obs import trace as _trace
from repro.serve.engine import Engine, SamplingParams, ServeConfig

ENGINE_NAME_PREFIX = "/engines/"

# SLO tier labels (re-exported by repro.fleet.slo — defined here so the
# serve layer never imports the fleet layer)
TIER_INTERACTIVE = "interactive"
TIER_BATCH = "batch"


def engine_name(e: Any) -> str:
    """Display/counter name of a local Engine or RemoteEngine handle."""
    name = getattr(e, "name", None)
    return name if name is not None else e.scfg.name


def default_extra_inputs(cfg) -> Dict[str, Any]:
    """Family-dependent synthetic side inputs (vlm patches, encdec memory)
    — built *where the engine lives*, never shipped over the wire."""
    import jax.numpy as jnp

    extra: Dict[str, Any] = {}
    if cfg.family == "vlm":
        extra["patches"] = jnp.zeros((1, cfg.n_patches, cfg.d_model),
                                     jnp.bfloat16)
    if cfg.family == "encdec":
        extra["enc"] = jnp.zeros((1, 64, cfg.d_model), jnp.bfloat16)
        extra["enc_len"] = 64
    return extra


def build_engine(arch: str, smoke: bool, plan: str,
                 scfg_kwargs: Dict[str, Any]) -> Engine:
    """The one engine-construction recipe every locality uses.

    Params come from the shared init seed, so replicas built here are
    identical on every locality without ever moving weights — the
    greedy-parity guarantee depends on local and remote spawns sharing
    this exact path.  Live migration depends on it too: the destination
    stages an identical engine shell and only the KV pages + request
    state travel."""
    from repro.configs import get_config
    from repro.dist.plan import get_plan
    from repro.models.model import build_model

    cfg = get_config(arch, smoke=smoke)
    model = build_model(cfg, get_plan(plan))
    params = model.init(jax.random.PRNGKey(0))
    return Engine(model, params, ServeConfig(**scfg_kwargs),
                  extra_inputs=default_extra_inputs(cfg))


# ----------------------------------------------------------- remote actions
@_parcel.action
def _spawn_engine(rt, arch: str, smoke: bool, plan: str,
                  scfg_kwargs: Dict[str, Any]) -> List[int]:
    """Build a full engine at this locality and register it in AGAS; the
    returned GID key is what the root's :class:`RemoteEngine` targets."""
    from repro.net.locality import _gid_key

    engine = build_engine(arch, smoke, plan, scfg_kwargs)
    gid = _agas.default().register(
        engine, name=f"{ENGINE_NAME_PREFIX}{engine.scfg.name}")
    return list(_gid_key(gid))


@_parcel.action
def _engine_submit(engine: Engine, prompt: List[int], max_new: Optional[int],
                   sampling: Optional[SamplingParams]
                   ) -> Tuple[List[int], float]:
    """Blocking submit at the engine's locality (help-along keeps the pool
    live); returns ``(tokens, load)``.  The fleet path uses the
    non-blocking :func:`repro.serve.relay._fleet_submit` instead — this
    remains the minimal one-shot spelling."""
    tokens = engine.submit(prompt, max_new, sampling).get(timeout=600)
    return tokens, engine.load()


class RemoteEngine:
    """Router-side handle to an engine living on another locality.

    ``load()`` needs no wire traffic: it is the max of this router's own
    in-flight count and the engine-side load gossiped back on the last
    completion parcel (both local reads — zero-message dispatch).  The
    same parcel carries the engine's KV-page occupancy, which is what the
    fleet admission controller reads — "gossiped occupancy", not a poll.

    Submits ride the relay (:mod:`repro.serve.relay`): the ack parcel is
    gid-targeted, so after a live migration the UnknownGid retry re-routes
    it to the engine's new home without this handle doing anything —
    ``locality`` is then updated by the migration coordinator."""

    def __init__(self, net, locality: int, gid: _agas.GID, name: str):
        self.net = net
        self.locality = locality
        self.gid = gid
        self.name = name
        self._inflight = 0
        self._gossip = 0.0
        self._occ = 0.0
        self._lock = threading.Lock()
        self._c_load = _counters.default().gauge(
            f"/serve{{router}}/load/{name}")

    def submit(self, prompt: List[int], max_new: Optional[int] = None,
               sampling: Optional[SamplingParams] = None,
               stream: Optional[Channel] = None,
               meta: Optional[Dict[str, Any]] = None) -> Future:
        from repro.net import remote as _remote
        from repro.serve import relay as _relay

        promise: Promise = Promise()

        def on_result(ok: bool, payload: Any,
                      gossip: Optional[Dict[str, float]]) -> None:
            with self._lock:
                self._inflight -= 1
                if gossip:
                    self._gossip = float(gossip.get("load", 0.0))
                    self._occ = float(gossip.get("occ", self._occ))
                if self._inflight == 0:
                    # done-parcels execute on the io pool and can apply out
                    # of order; with nothing outstanding from this (sole)
                    # client, any gossiped load is stale — truth is zero
                    self._gossip = 0.0
                self._c_load.set(self._gossip)
            if ok:
                promise.set_value(payload)
            else:
                promise.set_exception(payload)

        sid = _relay.open_sink(self.net, stream, self.locality, on_result)
        with self._lock:
            self._inflight += 1
        meta = meta or {}
        ack = _remote.apply_remote(_relay._fleet_submit, self.gid,
                                   list(prompt), max_new, sampling,
                                   self.net.locality, sid,
                                   stream is not None,
                                   meta.get("req"), meta.get("slo"))

        def acked(f: Future) -> None:
            exc = f.exception()
            if exc is not None:
                # the engine never accepted the request: fail/abort the
                # sink (idempotent — a no-op if a done-parcel landed first)
                _relay.abort(sid, exc)

        ack.on_ready(acked)
        return promise.future()

    def submit_stream(self, prompt: List[int],
                      max_new: Optional[int] = None,
                      sampling: Optional[SamplingParams] = None
                      ) -> Tuple[Channel, Future]:
        ch: Channel = Channel()
        return ch, self.submit(prompt, max_new, sampling, stream=ch)

    def load(self) -> float:
        with self._lock:
            return float(max(self._gossip, self._inflight))

    def occupancy(self) -> float:
        with self._lock:
            return self._occ


# ------------------------------------------------------------------- router
class Router:
    def __init__(self, engines: List[Any],
                 tiers: Optional[Dict[str, Optional[str]]] = None):
        assert engines, "router needs at least one engine"
        self.engines = list(engines)
        self._tiers: Dict[str, Optional[str]] = {
            engine_name(e): (tiers or {}).get(engine_name(e))
            for e in engines
        }
        self._dead: set = set()       # evicted by failover
        self._suspended: set = set()  # mid-migration: no new dispatch
        self._lock = threading.Lock()
        # construction recipe (over_localities): what migration staging and
        # elastic growth need to build an identical engine elsewhere
        self.spec: Optional[Dict[str, Any]] = None
        # fleet admission gate (AdmissionController-alike with .allow());
        # installed by the fleet layer, absent → batch is never gated
        self.admission: Optional[Any] = None
        self.max_failover = 2
        self._gated: deque = deque()
        # fleet-global request tags ("r<locality>:<seq>") stamped into
        # every span the request touches — the critical-path join key
        self._req_seq = itertools.count(1)

        reg = _counters.default()
        self.c_dispatched = reg.counter("/serve{router}/requests/dispatched")
        self._c_dispatch: Dict[str, Any] = {}
        for e in engines:
            self._dispatch_counter(engine_name(e))
        self.c_evicted = reg.counter("/serve{router}/failover/evicted")
        self.c_retried = reg.counter("/serve{router}/failover/retried")
        self.c_exhausted = reg.counter("/serve{router}/failover/exhausted")
        self.c_gated = reg.counter("/serve{router}/admission/gated")
        self.c_released = reg.counter("/serve{router}/admission/released")
        self.g_gate_depth = reg.gauge("/serve{router}/admission/depth")

    def _dispatch_counter(self, name: str):
        c = self._c_dispatch.get(name)
        if c is None:
            c = _counters.default().counter(
                f"/serve{{router}}/dispatch/{name}")
            self._c_dispatch[name] = c
        return c

    # ------------------------------------------------------------- factory
    @classmethod
    def replicate(cls, model: Model, params: Dict[str, jax.Array],
                  scfg: ServeConfig, replicas: int,
                  extra_inputs: Optional[Dict[str, Any]] = None) -> "Router":
        """N engine replicas named ``engine#0..N-1`` over shared params."""
        engines = []
        for i in range(replicas):
            cfg_i = ServeConfig(**{**scfg.__dict__, "name": f"engine#{i}"})
            engines.append(Engine(model, params, cfg_i,
                                  extra_inputs=extra_inputs))
        return cls(engines)

    @classmethod
    def over_localities(cls, net, arch: str, scfg: ServeConfig,
                        smoke: bool = True, plan: str = "serve",
                        timeout: float = 600.0,
                        tiers: Optional[Dict[str, Optional[str]]] = None
                        ) -> "Router":
        """One engine per locality: a local Engine at this locality, a
        :class:`RemoteEngine` handle per worker locality (spawned through
        ``run_on`` — the engine is built *where it runs*, by the same
        :func:`build_engine` recipe)."""
        from repro.net import remote as _remote

        spawns = []
        for loc in range(net.n_localities):
            if loc == net.locality:
                continue
            name = f"engine#{loc}"
            spawns.append((loc, name, _remote.run_on(
                loc, _spawn_engine, arch, smoke, plan,
                {**scfg.__dict__, "name": name})))

        engines: List[Any] = [build_engine(
            arch, smoke, plan,
            {**scfg.__dict__, "name": f"engine#{net.locality}"})]
        for loc, name, fut in spawns:
            key = fut.get(timeout=timeout)
            engines.append(RemoteEngine(net, loc, _agas.GID(*key), name))
        router = cls(engines, tiers=tiers)
        router.spec = {"arch": arch, "smoke": smoke, "plan": plan,
                       "scfg_kwargs": dict(scfg.__dict__)}
        return router

    # ---------------------------------------------------------- membership
    def engine(self, name: str) -> Any:
        for e in self.engines:
            if engine_name(e) == name:
                return e
        raise KeyError(f"no engine named {name!r}")

    def add_engine(self, e: Any, tier: Optional[str] = None) -> None:
        """Admit a replica into a *running* router (elastic growth)."""
        name = engine_name(e)
        self._dispatch_counter(name)
        with self._lock:
            self.engines = [x for x in self.engines
                            if engine_name(x) != name] + [e]
            self._tiers[name] = tier
            self._dead.discard(name)
            self._suspended.discard(name)

    def remove_engine(self, name: str) -> Optional[Any]:
        """Take a replica out of dispatch (retirement drain starts here)."""
        with self._lock:
            found = next((e for e in self.engines
                          if engine_name(e) == name), None)
            self.engines = [e for e in self.engines
                            if engine_name(e) != name]
            self._tiers.pop(name, None)
            self._dead.discard(name)
            self._suspended.discard(name)
        return found

    def set_tier(self, name: str, tier: Optional[str]) -> None:
        with self._lock:
            self._tiers[name] = tier

    def tier_of(self, name: str) -> Optional[str]:
        with self._lock:
            return self._tiers.get(name)

    def suspend(self, name: str) -> None:
        """Stop dispatching to an engine without removing it (the
        migration cutover window)."""
        with self._lock:
            self._suspended.add(name)

    def resume(self, name: str) -> None:
        with self._lock:
            self._suspended.discard(name)

    def _evict(self, name: str) -> None:
        with self._lock:
            if name in self._dead:
                return
            self._dead.add(name)
        self.c_evicted.increment()

    def revive(self, name: str) -> None:
        with self._lock:
            self._dead.discard(name)

    # ------------------------------------------------------------ dispatch
    def loads(self) -> List[float]:
        return [e.load() for e in self.engines]

    def occupancy(self) -> float:
        """Max live-engine KV occupancy: local engines read directly,
        remote ones report what their locality last gossiped.  This is
        the fleet admission signal — zero extra messages."""
        occs = []
        with self._lock:
            engines = [e for e in self.engines
                       if engine_name(e) not in self._dead]
        for e in engines:
            try:
                occs.append(float(e.occupancy()))
            except Exception:  # noqa: BLE001 — engine mid-teardown
                pass
        return max(occs) if occs else 0.0

    def pick(self, local_only: bool = False,
             slo: Optional[str] = None) -> int:
        """Least-loaded replica (first wins ties — stable under no load).

        ``slo``: prefer engines labeled with that tier; fall back to
        untiered engines, then to anything alive — a tier label steers,
        it never strands a request.  ``local_only`` restricts to
        in-process engines (kept for API compatibility; streaming crosses
        localities through the relay now)."""
        with self._lock:
            dead = set(self._dead) | set(self._suspended)
            tiers = dict(self._tiers)
            engines = list(self.engines)
        candidates = [i for i, e in enumerate(engines)
                      if engine_name(e) not in dead
                      and not (local_only and isinstance(e, RemoteEngine))]
        if not candidates:
            raise ValueError("no engine available for dispatch")
        if slo is not None:
            same = [i for i in candidates
                    if tiers.get(engine_name(engines[i])) == slo]
            neutral = [i for i in candidates
                       if tiers.get(engine_name(engines[i])) is None]
            candidates = same or neutral or candidates
        loads = [engines[i].load() for i in candidates]
        return candidates[loads.index(min(loads))]

    def new_tag(self) -> str:
        """Fleet-global request tag: ``r<locality>:<seq>``.  The one id
        joining every span/async event a request touches anywhere in the
        fleet (DESIGN.md §10.4)."""
        return f"r{_trace._detect_locality()}:{next(self._req_seq)}"

    def submit(self, prompt: List[int], max_new: Optional[int] = None,
               sampling: Optional[SamplingParams] = None,
               stream: Optional[Channel] = None,
               slo: Optional[str] = None) -> Future:
        promise: Promise = Promise()
        tag = self.new_tag()
        if (slo == TIER_BATCH and self.admission is not None
                and not self.admission.allow()):
            # backpressure by occupancy, not queue depth: park until the
            # fleet controller's release tick
            with self._lock:
                self._gated.append((list(prompt), max_new, sampling, stream,
                                    slo, promise, tag))
                depth = len(self._gated)
            self.c_gated.increment()
            self.g_gate_depth.set(float(depth))
            if _trace._enabled:
                # the analyzer reads this instant as the start of the
                # request's Waiting (admission-gate) interval
                _trace.instant("router/gated", "serve", req=tag, slo=slo,
                               depth=depth)
            return promise.future()
        self._dispatch(list(prompt), max_new, sampling, stream, slo,
                       promise, 0, tag=tag)
        return promise.future()

    def release_gated(self, limit: Optional[int] = None) -> int:
        """Dispatch parked batch requests while the admission gate allows;
        called from the fleet controller tick.  Returns how many moved."""
        n = 0
        while limit is None or n < limit:
            if self.admission is not None and not self.admission.allow():
                break
            with self._lock:
                if not self._gated:
                    break
                prompt, max_new, sampling, stream, slo, promise, tag = \
                    self._gated.popleft()
                depth = len(self._gated)
            self.c_released.increment()
            self.g_gate_depth.set(float(depth))
            self._dispatch(prompt, max_new, sampling, stream, slo,
                           promise, 0, tag=tag, gated=True)
            n += 1
        return n

    def gated_depth(self) -> int:
        with self._lock:
            return len(self._gated)

    def _dispatch(self, prompt: List[int], max_new: Optional[int],
                  sampling: Optional[SamplingParams],
                  stream: Optional[Channel], slo: Optional[str],
                  promise: Promise, attempt: int,
                  tag: Optional[str] = None, gated: bool = False) -> None:
        try:
            i = self.pick(slo=slo)
        except ValueError as e:
            self._terminal(stream, promise, e)
            return
        engine = self.engines[i]
        name = engine_name(engine)
        self.c_dispatched.increment()
        self._dispatch_counter(name).increment()
        meta = {"req": tag, "slo": slo} if tag else None
        try:
            if _trace._enabled and tag:
                # span wraps the submit so a remote dispatch's
                # send:_fleet_submit span records this sid as its parent
                with _trace.span("router/submit", "serve", req=tag, slo=slo,
                                 engine=name, gated=gated):
                    fut = engine.submit(prompt, max_new, sampling, stream,
                                        meta=meta)
            else:
                fut = engine.submit(prompt, max_new, sampling, stream,
                                    meta=meta)
        except BaseException as exc:  # noqa: BLE001 — sync submit failure
            self._failover(exc, name, prompt, max_new, sampling, stream,
                           slo, promise, attempt, tag)
            return

        def done(f: Future) -> None:
            exc = f.exception()
            if exc is None:
                promise.set_value(f._value)
            else:
                self._failover(exc, name, prompt, max_new, sampling, stream,
                               slo, promise, attempt, tag)

        fut.on_ready(done)

    def _failover(self, exc: BaseException, name: str, prompt: List[int],
                  max_new: Optional[int],
                  sampling: Optional[SamplingParams],
                  stream: Optional[Channel], slo: Optional[str],
                  promise: Promise, attempt: int,
                  tag: Optional[str] = None) -> None:
        """Dead-engine handling: evict and retry on a healthy replica.

        Retriable ⇔ the request observably did nothing and the failure
        names a replica-level cause: *PortClosed* (locality died — evict
        the engine) or *UnknownGid* (engine mid-migration cutover outlived
        the resolver's retry budget — do NOT evict, it is alive elsewhere).
        A stream that already delivered tokens comes back as StreamBroken
        and is never re-run (the retry would re-deliver a prefix the
        consumer already consumed)."""
        from repro.net import parcelport as _pp
        from repro.net.locality import UnknownGid

        if isinstance(exc, (_pp.PortClosed, UnknownGid)):
            if isinstance(exc, _pp.PortClosed):
                self._evict(name)
            if attempt < self.max_failover:
                self.c_retried.increment()
                self._dispatch(prompt, max_new, sampling, stream, slo,
                               promise, attempt + 1, tag=tag)
                return
            self.c_exhausted.increment()
        self._terminal(stream, promise, exc)

    @staticmethod
    def _terminal(stream: Optional[Channel], promise: Promise,
                  exc: BaseException) -> None:
        if stream is not None and not stream.is_closed():
            stream.close(exc)  # blocked readers see the failure, in order
        try:
            promise.set_exception(exc)
        except Exception:  # noqa: BLE001 — relay already completed it
            pass

    def submit_stream(self, prompt: List[int], max_new: Optional[int] = None,
                      sampling: Optional[SamplingParams] = None,
                      slo: Optional[str] = None) -> Tuple[Channel, Future]:
        ch: Channel = Channel()
        return ch, self.submit(prompt, max_new, sampling, stream=ch, slo=slo)
