"""Serving engine: paged-KV continuous batching on the AMT runtime.

The seed engine ran prefill *inside* the decode loop — a bulk-synchronous
barrier: every admission stalled every in-flight decode.  This version is
task-pipelined, HPX-style:

1. **Admission** — ``submit`` enqueues the request and a prefill task is
   posted through a ``PriorityExecutor`` over the dedicated ``prefill``
   pool of the resource partitioner (falling back to the decode pool at
   ``PRIORITY_HIGH`` on unpartitioned runtimes), so admissions never steal
   decode-continuation slots.  Prompts are right-padded to static *buckets*
   so admission never recompiles; ``valid_len`` keeps logits/cache
   positions exact.  Finished prefills land in a ready queue.
2. **Decode continuation chain** — each step is a scheduler task that
   integrates ready prefills into free slots (paged: scatter the prefill
   KV into block-pool pages; dense fallback: migrate into the slot row),
   runs one jitted decode+sample step for the whole batch, streams each
   new token through the request's :class:`~repro.core.future.Channel`,
   and respawns itself.  No prefill barrier anywhere on the hot path.
3. **Completion** — EOS / length ends a slot: pages return to the free
   list, the future resolves with the token list, the stream closes.

Sampling (temperature / top-k / top-p) runs *inside* the jitted step with
per-slot parameter vectors — admission churn never changes shapes, so after
warmup the decode step never recompiles.  ``temperature=0`` rows reduce to
exact argmax (greedy equivalence).

Cache backends: block-pool paged KV (:mod:`repro.serve.kv_cache`) for
KV-cache families (dense/moe/vlm) — memory ∝ live tokens, per-row lengths
in the kernel — and the seed's dense per-slot cache for recurrent families
(ssm/hybrid/encdec).  ``ServeConfig(paged=False, pipeline_admission=False)``
reproduces the seed engine for A/B benchmarks.

Performance counters: ``/serve{<name>}/requests/{submitted,completed}``,
``/serve{<name>}/tokens/generated``, ``/serve{<name>}/step/duration``,
``/serve{<name>}/request/{latency,first_token}``, plus the page-pool
gauges from :mod:`repro.serve.kv_cache`.
"""

from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import agas as _agas
from repro.core import counters as _counters
from repro.core import executor as _executor
from repro.core.future import Channel, Future, Promise
from repro.core.scheduler import PRIORITY_HIGH, current_runtime
from repro.models.model import Model
from repro.obs import trace as _trace

_NEG = -1e30


@dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling controls. ``temperature=0`` → greedy (exact
    argmax, independent of top_k/top_p)."""
    temperature: float = 0.0
    top_k: int = 0      # 0 = disabled
    top_p: float = 1.0  # 1.0 = disabled


GREEDY = SamplingParams()


@dataclass
class ServeConfig:
    max_batch: int = 4
    cache_len: int = 256
    max_new_tokens: int = 32
    eos_id: int = -1  # -1: never stops early
    # paged cache layer
    paged: bool = True       # block-pool cache (KV families); dense fallback
    page_size: int = 16
    num_pages: int = 0       # 0 → auto: every slot can reach cache_len
    # engine pipeline
    pipeline_admission: bool = True  # False → seed-style inline prefill barrier
    prefill_oversub: int = 2  # prefills in flight beyond free slots
    idle_timeout: float = 0.05  # blocking queue wait when drained (no hot-spin)
    # resource partitioning: the decode continuation chain runs on
    # ``decode_pool``; prefill tasks go to a PriorityExecutor over a
    # dedicated ``prefill_pool`` (auto-partitioned with ``prefill_workers``
    # workers; on a runtime without one they fall back to decode_pool at
    # PRIORITY_HIGH — the pre-partitioner behavior).
    decode_pool: str = "default"
    prefill_pool: str = "prefill"
    prefill_workers: int = 2
    # Counters are get-or-create by name: same-named engines *share* them
    # (the seed's observability contract).  Replicas behind a Router must
    # use distinct names or load() merges — Router.replicate does this.
    name: str = "engine#0"
    seed: int = 0


@dataclass
class _Request:
    rid: int
    prompt: List[int]
    max_new: int
    promise: Promise
    sampling: SamplingParams
    stream: Optional[Channel]
    generated: List[int] = field(default_factory=list)
    submit_t: float = 0.0
    first_token_t: float = 0.0
    # opaque picklable routing info (fleet relay: client locality, stream
    # id) that survives live migration — the destination re-attaches its
    # stream and completion hooks from this
    meta: Optional[Dict[str, Any]] = None
    # fleet-global request tag ("r<loc>:<seq>" from the router, or a local
    # fallback) stamped into every span — the critical-path join key
    tag: str = ""


def _cache_batch_axis(name: str) -> int:
    return 0 if name == "pos" else 1


def sample_logits(logits: jax.Array, key: jax.Array, temp: jax.Array,
                  topk: jax.Array, topp: jax.Array) -> jax.Array:
    """Batched sampling, jit-safe with *per-row dynamic* controls.

    logits: (B, V) fp32; temp/topp: (B,) fp32; topk: (B,) int32 (0 = off).
    Rows with temp <= 0 return exact argmax.  top-k/top-p masks are
    derived in sorted space (kth value / nucleus cutoff), so k and p vary
    per row without shape changes → zero recompiles across admissions.
    """
    B, V = logits.shape
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    def _sampled(_):
        t = jnp.where(temp > 0, temp, 1.0).astype(jnp.float32)
        lg = logits.astype(jnp.float32) / t[:, None]
        srt = jnp.sort(lg, axis=-1)[:, ::-1]  # descending
        k_eff = jnp.where(topk > 0, topk, V).astype(jnp.int32)
        kth = jnp.take_along_axis(srt, jnp.clip(k_eff[:, None] - 1, 0, V - 1),
                                  axis=-1)  # (B, 1) value of the k-th logit
        lg = jnp.where(lg < kth, _NEG, lg)
        # nucleus: smallest sorted prefix with mass ≥ top_p (in the top-k set)
        srt_k = jnp.where(jnp.arange(V)[None, :] < k_eff[:, None], srt, _NEG)
        p_srt = jax.nn.softmax(srt_k, axis=-1)
        excl = jnp.cumsum(p_srt, axis=-1) - p_srt
        ncut = jnp.maximum(jnp.sum((excl < topp[:, None]).astype(jnp.int32),
                                   axis=-1), 1)
        cutoff = jnp.take_along_axis(srt_k, (ncut - 1)[:, None], axis=-1)
        lg = jnp.where(lg < cutoff, _NEG, lg)
        g = jax.random.gumbel(key, lg.shape, jnp.float32)
        samp = jnp.argmax(lg + g, axis=-1).astype(jnp.int32)
        return jnp.where(temp <= 0, greedy, samp)

    # all-greedy batches (the common serving default) skip the sort entirely;
    # lax.cond keeps it one compile either way
    return jax.lax.cond(jnp.any(temp > 0), _sampled, lambda _: greedy, None)


def _sample_host(logits: np.ndarray, sp: SamplingParams,
                 rng: np.random.Generator) -> int:
    """Host-side mirror of :func:`sample_logits` for the B=1 prefill token."""
    if sp.temperature <= 0:
        return int(np.argmax(logits))
    lg = logits.astype(np.float64) / sp.temperature
    srt = np.sort(lg)[::-1]
    if sp.top_k > 0:
        lg = np.where(lg < srt[min(sp.top_k, lg.size) - 1], _NEG, lg)
        srt = np.where(np.arange(srt.size) < sp.top_k, srt, _NEG)
    p = np.exp(srt - srt.max())
    p /= p.sum()
    excl = np.cumsum(p) - p
    ncut = max(int((excl < sp.top_p).sum()), 1)
    lg = np.where(lg < srt[ncut - 1], _NEG, lg)
    return int(np.argmax(lg + rng.gumbel(size=lg.shape)))


# --------------------------------------------------------------- backends
class _DenseSlots:
    """Seed-style dense per-slot cache: (L, max_batch, cache_len, KV, Dh)."""

    def __init__(self, model: Model, scfg: ServeConfig,
                 extra: Dict[str, Any]):
        specs = model.cache_specs(scfg.max_batch, scfg.cache_len,
                                  enc_len=extra.get("enc_len"))
        self.cache = {k: jnp.zeros(s.shape, s.dtype) for k, s in specs.items()}
        self.gid = _agas.default().register(self.cache, name=None,
                                            placement="host-engine")

    def admit(self, slot: int, prefill_cache: Dict[str, jax.Array],
              length: int) -> bool:
        # self.cache is the AGAS-registered dict: update keys in place so
        # the global view stays current (and the zero-init cache is freed)
        self.cache.update({
            k: v.at[(slice(None), slot) if _cache_batch_axis(k) == 1 else slot].set(
                jnp.take(prefill_cache[k], 0, axis=_cache_batch_axis(k)))
            for k, v in self.cache.items()
        })
        return True

    def prepare_step(self, slot: int) -> bool:
        return True

    def release(self, slot: int) -> None:
        pass

    def device_cache(self) -> Dict[str, jax.Array]:
        return self.cache

    def commit(self, new_cache: Dict[str, jax.Array]) -> None:
        self.cache.update(new_cache)

    def step_bookkeeping(self, active: List[int]) -> None:
        pass

    def snapshot_slot(self, slot: int) -> Dict[str, Any]:
        raise NotImplementedError(
            "dense cache backend does not support live migration — "
            "use the paged backend (ServeConfig.paged=True)")

    def restore_slot(self, slot: int, snap: Dict[str, Any]) -> bool:
        raise NotImplementedError(
            "dense cache backend does not support live migration — "
            "use the paged backend (ServeConfig.paged=True)")


class _PagedSlots:
    """Block-pool paged cache backend (see :mod:`repro.serve.kv_cache`)."""

    def __init__(self, model: Model, scfg: ServeConfig):
        from repro.serve.kv_cache import PagedKVCache

        page = scfg.page_size
        assert scfg.cache_len % page == 0, (scfg.cache_len, page)
        maxp = scfg.cache_len // page
        num_pages = scfg.num_pages or (scfg.max_batch * maxp + 1)
        self.kv = PagedKVCache(model, num_pages=num_pages, page_size=page,
                               max_batch=scfg.max_batch,
                               max_pages_per_req=maxp, name=scfg.name)
        self.gid = self.kv.gid

    def admit(self, slot, prefill_cache, length):
        return self.kv.admit(slot, prefill_cache, length)

    def prepare_step(self, slot: int) -> bool:
        return self.kv.ensure_next_token(slot)

    def release(self, slot: int) -> None:
        self.kv.release(slot)

    def device_cache(self) -> Dict[str, jax.Array]:
        return self.kv.device_cache()

    def commit(self, new_cache: Dict[str, jax.Array]) -> None:
        self.kv.update_pools(new_cache)

    def step_bookkeeping(self, active: List[int]) -> None:
        self.kv.pos[active] += 1

    def snapshot_slot(self, slot: int) -> Dict[str, Any]:
        return self.kv.snapshot_slot(slot)

    def restore_slot(self, slot: int, snap: Dict[str, Any]) -> bool:
        return self.kv.restore_slot(slot, snap)


# ----------------------------------------------------------------- engine
class Engine:
    def __init__(self, model: Model, params: Dict[str, jax.Array],
                 scfg: ServeConfig, extra_inputs: Optional[Dict[str, Any]] = None):
        self.model = model
        self.params = params
        self.scfg = scfg
        self.extra = extra_inputs or {}
        B = scfg.max_batch
        self.paged = scfg.paged and model.supports_paged
        self.backend = (_PagedSlots(model, scfg) if self.paged
                        else _DenseSlots(model, scfg, self.extra))
        # bucketed (static-shape) prefill needs valid_len (transformer fams)
        # and belongs to the pipelined stack — the seed-parity baseline keeps
        # the seed's exact-length prefill (and its per-length recompiles)
        self._bucketed = model.supports_paged and scfg.pipeline_admission
        self.slots: List[Optional[_Request]] = [None] * B
        self._tokens = np.zeros((B, 1), np.int32)
        self._temp = np.zeros((B,), np.float32)
        self._topk = np.zeros((B,), np.int32)
        self._topp = np.ones((B,), np.float32)
        self._queue: "queue.Queue[_Request]" = queue.Queue()
        self._ready: List[Tuple[_Request, Dict[str, jax.Array], int, int]] = []
        self._inflight_prefills = 0
        self._work_event = threading.Event()  # prefill completion wakeup
        self._lock = threading.Lock()
        self._running = False
        self._paused = False
        self._migrate_key: Optional[Tuple[int, int]] = None
        self._rid = 0
        self._step_count = 0
        self._key = jax.random.PRNGKey(scfg.seed)

        self._prefill = jax.jit(model.prefill, static_argnames=("cache_len",))
        self._decode = jax.jit(self._decode_fn, donate_argnums=(1,))

        # Execution resources (HPX resource partitioner): executors are the
        # only path to scheduler pools.  Pool names resolve lazily at
        # submission, so engines survive runtime restarts.
        rt = current_runtime()
        if rt is not None and scfg.pipeline_admission:
            rt.add_pool(scfg.prefill_pool, scfg.prefill_workers)
        self._loop_exec = _executor.get_executor(
            scfg.decode_pool, fallback=scfg.decode_pool)  # → runtime default
        self._prefill_exec = _executor.get_executor(
            scfg.prefill_pool, priority=PRIORITY_HIGH, fallback=scfg.decode_pool)

        reg = _counters.default()
        n = scfg.name
        self.c_sub = reg.counter(f"/serve{{{n}}}/requests/submitted")
        self.c_done = reg.counter(f"/serve{{{n}}}/requests/completed")
        self.c_tok = reg.counter(f"/serve{{{n}}}/tokens/generated")
        # percentile timers: p50/p95/p99 straight off the counter API —
        # "why is p99 bad" without needing a trace at all
        self.t_step = reg.timer(f"/serve{{{n}}}/step/duration",
                                percentiles=True)
        self.t_latency = reg.timer(f"/serve{{{n}}}/request/latency",
                                   percentiles=True)
        self.t_first = reg.timer(f"/serve{{{n}}}/request/first_token",
                                 percentiles=True)
        # live-migration accounting: migrated-out counts toward completed so
        # load() stays "requests this engine still has to do"
        self.c_mig_out = reg.counter(f"/serve{{{n}}}/requests/migrated_out")
        self.c_mig_in = reg.counter(f"/serve{{{n}}}/requests/migrated_in")
        # live tail-latency gauges: what the flight-recorder trigger polls
        # through the fleet sampler (seconds, from the timer histograms)
        reg.register_callable(f"/serve{{{n}}}/request/latency/p99",
                              lambda: self.t_latency.quantile(0.99))
        reg.register_callable(f"/serve{{{n}}}/request/first_token/p99",
                              lambda: self.t_first.quantile(0.99))

    # --------------------------------------------------------------- decode
    def _decode_fn(self, params, cache, token, key, temp, topk, topp):
        if self.paged:
            logits, new_cache = self.model.decode_paged(params, cache, token)
        else:
            logits, new_cache = self.model.decode(params, cache, token)
        nxt = sample_logits(logits, key, temp, topk, topp)[:, None]
        return nxt, new_cache

    def decode_compile_count(self) -> int:
        """Distinct decode-step compilations (bench asserts this stays at 1
        after warmup — admission churn must never change step shapes)."""
        return int(self._decode._cache_size())

    # ------------------------------------------------------------------ api
    def submit(self, prompt: List[int], max_new: Optional[int] = None,
               sampling: Optional[SamplingParams] = None,
               stream: Optional[Channel] = None,
               meta: Optional[Dict[str, Any]] = None) -> Future:
        """One-sided request → Future[List[int]] of generated ids.

        ``stream``: optional Channel-alike — every generated token is
        ``set()`` the step it is sampled (first token before the request
        completes) and the channel closes when the request finishes.
        ``meta``: picklable routing info carried through live migration
        (the fleet relay's client locality + stream id).
        """
        if self._migrate_key is not None:
            # engine migrated away: answer with the stale-resolution signal
            # so the caller's apply_remote retry re-resolves to the new home
            from repro.net.locality import UnknownGid, current as _net_current
            net = _net_current()
            raise UnknownGid(self._migrate_key,
                             net.locality if net is not None else -1)
        with self._lock:
            self._rid += 1
            rid = self._rid
        tag = (meta or {}).get("req") or f"{self.scfg.name}/{rid}"
        req = _Request(rid, list(prompt),
                       self.scfg.max_new_tokens if max_new is None else max_new,
                       Promise(), sampling or GREEDY, stream,
                       submit_t=time.perf_counter(), meta=meta, tag=tag)
        self._queue.put(req)
        self.c_sub.increment()
        if _trace._enabled:  # request lifetime as one async span
            _trace.async_begin("request", rid, "serve",
                               prompt_len=len(req.prompt), req=tag,
                               slo=(meta or {}).get("slo"))
        self._ensure_running()
        return req.promise.future()

    def submit_stream(self, prompt: List[int], max_new: Optional[int] = None,
                      sampling: Optional[SamplingParams] = None
                      ) -> Tuple[Channel, Future]:
        ch: Channel = Channel()
        return ch, self.submit(prompt, max_new, sampling, stream=ch)

    def load(self) -> float:
        """In-flight requests (queued + prefilling + decoding) — the
        router's least-loaded dispatch metric."""
        return self.c_sub.get_value() - self.c_done.get_value()

    def occupancy(self) -> float:
        """Fraction of KV capacity in use (paged: block-pool pages; dense:
        occupied slots) — the admission-control signal the fleet gossips."""
        if self.paged:
            kv = self.backend.kv
            return kv.pages_in_use() / max(kv.num_pages - 1, 1)
        return sum(s is not None for s in self.slots) / self.scfg.max_batch

    def _ensure_running(self) -> None:
        with self._lock:
            if not self._running and not self._paused:
                self._running = True
                self._loop_exec.post(self._step)

    # ---------------------------------------------------------- migration
    def pause(self, timeout: float = 30.0) -> None:
        """Quiesce at a step boundary: stop the decode continuation chain
        and wait for in-flight prefills to land.  Queued / ready / active
        requests stay put; ``resume`` restarts the chain."""
        self._paused = True
        deadline = time.perf_counter() + timeout
        while True:
            with self._lock:
                if not self._running and self._inflight_prefills == 0:
                    return
            if time.perf_counter() > deadline:
                raise TimeoutError(f"engine {self.scfg.name}: pause timed out")
            time.sleep(0.002)

    def resume(self) -> None:
        self._paused = False
        self._ensure_running()

    def close_for_migration(self, key: Tuple[int, int]) -> None:
        """Point of no return for live migration: every subsequent
        ``submit`` raises :class:`UnknownGid` for ``key`` (this engine's
        GID), so remote callers' retry loop re-resolves through the AGAS
        root — which, once the destination adopts, names the new home."""
        self._migrate_key = tuple(key)

    def take_requests(self) -> Dict[str, Any]:
        """Drain every in-flight request into a picklable snapshot (the
        ship half of live migration; engine must be paused).

        Active slots travel with their paged KV (``snapshot_slot``) and
        resume mid-generation at the destination; queued / prefill-ready
        requests travel as prompts (prefill work is discarded — nothing
        was emitted for them yet, the destination re-prefills).  Requests
        must carry ``meta``: promises and channels are process-local, so
        only fleet-submitted traffic (whose relay re-attaches from meta)
        can be re-homed — anything else fails loudly rather than hang."""
        if not self._paused or self._running:
            raise RuntimeError("take_requests requires a paused engine")

        def _entry(req: _Request, kv=None, last_tok=None) -> Dict[str, Any]:
            # "client" marks relay meta specifically: router-tagged local
            # submits carry meta={"req","slo"} but no re-homeable sink
            if not req.meta or "client" not in req.meta:
                raise RuntimeError(
                    f"request {req.rid} has no relay meta; only "
                    f"fleet-submitted requests survive migration")
            e: Dict[str, Any] = {
                "prompt": req.prompt, "generated": req.generated,
                "max_new": req.max_new,
                "sampling": (req.sampling.temperature, req.sampling.top_k,
                             req.sampling.top_p),
                "meta": req.meta,
            }
            if kv is not None:
                e["kv"] = kv
                e["last_tok"] = last_tok
            return e

        snap: Dict[str, Any] = {"active": [], "queued": []}
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            snap["active"].append(_entry(req, self.backend.snapshot_slot(i),
                                         int(self._tokens[i, 0])))
            self.slots[i] = None
            self.backend.release(i)
            self._temp[i], self._topk[i], self._topp[i] = 0.0, 0, 1.0
            self.c_done.increment()
            self.c_mig_out.increment()
        with self._lock:
            ready, self._ready = self._ready, []
        queued = [r for r, _c, _l, _t in ready]
        while True:
            try:
                queued.append(self._queue.get_nowait())
            except queue.Empty:
                break
        for req in queued:
            snap["queued"].append(_entry(req))
            self.c_done.increment()
            self.c_mig_out.increment()
        return snap

    def _restored_request(self, e: Dict[str, Any]) -> _Request:
        with self._lock:
            self._rid += 1
            rid = self._rid
        t, k, p = e["sampling"]
        meta = dict(e["meta"])
        req = _Request(rid, list(e["prompt"]), int(e["max_new"]), Promise(),
                       SamplingParams(t, k, p), None,
                       generated=list(e["generated"]),
                       submit_t=time.perf_counter(), meta=meta,
                       tag=meta.get("req") or f"{self.scfg.name}/{rid}")
        if req.generated:  # first token happened at the source
            req.first_token_t = req.submit_t
        return req

    def restore_requests(self, snap: Dict[str, Any],
                         reattach: Optional[Any] = None) -> int:
        """Install a :meth:`take_requests` snapshot into this (paused)
        engine.  ``reattach(req)`` runs for every rebuilt request so the
        caller can wire a stream / completion hook from ``req.meta``
        before any token flows.  Returns the number of requests adopted."""
        if not self._paused or self._running:
            raise RuntimeError("restore_requests requires a paused engine")
        n = 0
        for e in snap["active"]:
            free = next((i for i, s in enumerate(self.slots) if s is None),
                        None)
            if free is None:
                raise RuntimeError("destination engine has no free slot for "
                                   "a migrated request")
            if not self.backend.restore_slot(free, e["kv"]):
                raise RuntimeError("destination page pool cannot hold a "
                                   "migrated request's KV")
            req = self._restored_request(e)
            if reattach is not None:
                reattach(req)
            self.slots[free] = req
            self._tokens[free, 0] = int(e["last_tok"])
            self._temp[free] = req.sampling.temperature
            self._topk[free] = req.sampling.top_k
            self._topp[free] = req.sampling.top_p
            self.c_sub.increment()
            self.c_mig_in.increment()
            n += 1
        for e in snap["queued"]:
            req = self._restored_request(e)
            if reattach is not None:
                reattach(req)
            self._queue.put(req)
            self.c_sub.increment()
            self.c_mig_in.increment()
            n += 1
        return n

    # ------------------------------------------------------------ admission
    def _bucket_for(self, n: int) -> int:
        """Smallest power-of-two bucket (≥ page_size) covering n, clamped to
        cache_len — static prefill shapes, no per-length recompiles."""
        b = max(self.scfg.page_size, 8)
        while b < n:
            b *= 2
        return min(b, self.scfg.cache_len)

    def _run_prefill(self, req: _Request):
        """Compute the request's KV cache + first token (any thread)."""
        if _trace._enabled:
            with _trace.span("prefill", "serve", rid=req.rid, req=req.tag,
                             prompt_len=len(req.prompt)):
                return self._run_prefill_body(req)
        return self._run_prefill_body(req)

    def _run_prefill_body(self, req: _Request):
        prompt = req.prompt
        if self.model.cfg.family == "vlm" and len(prompt) < self.model.cfg.n_patches:
            # patches occupy the first n_patches positions; a shorter prompt
            # would read logits from inside the patch region — fail loudly
            raise ValueError(f"vlm prompt needs ≥ {self.model.cfg.n_patches} "
                             f"tokens, got {len(prompt)}")
        pextra = {k: v for k, v in self.extra.items() if k != "enc_len"}
        if self._bucketed:
            bucket = self._bucket_for(len(prompt))
            assert len(prompt) <= bucket, (len(prompt), self.scfg.cache_len)
            toks = np.zeros((1, bucket), np.int32)
            toks[0, : len(prompt)] = prompt
            pin = {"tokens": jnp.asarray(toks), **pextra}
            cache_len = bucket if self.paged else self.scfg.cache_len
            logits, cache1 = self._prefill(
                self.params, pin, cache_len=cache_len,
                valid_len=jnp.asarray([len(prompt)], jnp.int32))
        else:
            pin = {"tokens": jnp.asarray(prompt, jnp.int32)[None, :], **pextra}
            logits, cache1 = self._prefill(self.params, pin,
                                           cache_len=self.scfg.cache_len)
        rng = np.random.default_rng((self.scfg.seed << 20) ^ req.rid)
        tok0 = _sample_host(np.asarray(logits[0], np.float32), req.sampling, rng)
        return req, cache1, len(prompt), tok0

    def _prefill_task(self, req: _Request) -> None:
        try:
            payload = self._run_prefill(req)
        except BaseException as e:  # noqa: BLE001 — fail the one request
            with self._lock:
                self._inflight_prefills -= 1
            if req.stream is not None:
                req.stream.close()
            self.c_done.increment()  # terminated: keep load() = in-flight
            if _trace._enabled:
                _trace.async_end("request", req.rid, "serve", failed=True,
                                 req=req.tag)
            req.promise.set_exception(e)
            self._work_event.set()
            return
        with self._lock:
            self._ready.append(payload)
            self._inflight_prefills -= 1
        self._work_event.set()
        self._ensure_running()

    def _pump_prefills(self) -> None:
        """Launch PRIORITY_HIGH prefill tasks for queued requests, keeping a
        bounded oversubscription so integration always has work ready."""
        while True:
            with self._lock:
                active = sum(s is not None for s in self.slots)
                budget = (self.scfg.max_batch - active
                          + self.scfg.prefill_oversub
                          - self._inflight_prefills - len(self._ready))
            if budget <= 0:
                return
            try:
                req = self._queue.get_nowait()
            except queue.Empty:
                return
            self._launch_prefill(req)

    def _launch_prefill(self, req: _Request) -> None:
        with self._lock:
            self._inflight_prefills += 1
        self._prefill_exec.post(lambda: self._prefill_task(req))

    # ---------------------------------------------------------- integration
    def _emit(self, req: _Request, tok: int) -> None:
        req.generated.append(tok)
        self.c_tok.increment()
        if _trace._enabled:  # inter-token latency = gaps between these
            _trace.async_instant("token", req.rid, "serve",
                                 n=len(req.generated))
        if not req.first_token_t:
            req.first_token_t = time.perf_counter()
            self.t_first.add(req.first_token_t - req.submit_t)
        if req.stream is not None:
            req.stream.set(tok)

    def _finish(self, i: int) -> None:
        req = self.slots[i]
        self.slots[i] = None
        self.backend.release(i)
        self._temp[i], self._topk[i], self._topp[i] = 0.0, 0, 1.0
        self.c_done.increment()
        self.t_latency.add(time.perf_counter() - req.submit_t)
        if _trace._enabled:
            _trace.async_end("request", req.rid, "serve",
                             tokens=len(req.generated), req=req.tag)
        if req.stream is not None:
            req.stream.close()
        req.promise.set_value(req.generated)

    def _done_after(self, req: _Request, tok: int) -> bool:
        return (len(req.generated) >= req.max_new + 1
                or tok == self.scfg.eos_id)

    def _bind_slot(self, i: int, req: _Request, tok0: int) -> None:
        """Occupy slot ``i`` with an admitted request and emit its prefill
        token (shared by the pipelined and inline admission paths)."""
        self.slots[i] = req
        self._tokens[i, 0] = tok0
        self._temp[i] = req.sampling.temperature
        self._topk[i] = req.sampling.top_k
        self._topp[i] = req.sampling.top_p
        self._emit(req, tok0)
        if self._done_after(req, tok0):
            self._finish(i)

    def _integrate_ready(self) -> None:
        while True:
            free = next((i for i, s in enumerate(self.slots) if s is None), None)
            if free is None:
                return
            with self._lock:
                if not self._ready:
                    return
                payload = self._ready.pop(0)
            req, cache1, length, tok0 = payload
            if not self.backend.admit(free, cache1, length):
                if not any(s is not None for s in self.slots):
                    # nothing active will ever free pages → fail the request
                    # instead of wedging the head of the ready queue
                    if req.stream is not None:
                        req.stream.close()
                    req.promise.set_exception(RuntimeError(
                        f"request {req.rid}: {length} prompt tokens exceed "
                        f"page-pool capacity"))
                    self.c_done.increment()
                    continue
                if _trace._enabled:
                    # Waiting (W): the request has its KV ready but cannot
                    # enter a slot — page-pool contention, not queue wait
                    _trace.instant("admit_stall", "serve", req=req.tag,
                                   rid=req.rid)
                with self._lock:  # pool exhausted — retry after completions
                    self._ready.insert(0, payload)
                return
            self._bind_slot(free, req, tok0)

    def _admit_inline(self) -> None:
        """Seed-style admission: prefill runs inside the decode loop (the
        barrier).  Kept as the A/B baseline (pipeline_admission=False)."""
        self._integrate_ready()  # admit-failure retries parked in _ready
        for i, slot in enumerate(self.slots):
            if slot is not None:
                continue
            try:
                req = self._queue.get_nowait()
            except queue.Empty:
                return
            try:
                req2, cache1, length, tok0 = self._run_prefill(req)
            except BaseException as e:  # noqa: BLE001 — fail the one request
                if req.stream is not None:
                    req.stream.close()
                self.c_done.increment()
                req.promise.set_exception(e)
                continue
            if not self.backend.admit(i, cache1, length):
                with self._lock:
                    self._ready.insert(0, (req2, cache1, length, tok0))
                return
            self._bind_slot(i, req2, tok0)

    # ----------------------------------------------------------------- loop
    def _idle_or_stop(self) -> bool:
        """No active slots: block briefly on the queue (no hot-spin burning a
        worker) and decide whether the continuation chain ends."""
        with self._lock:
            waiting_on_prefill = bool(self._ready) or self._inflight_prefills > 0
        if waiting_on_prefill:  # integration work is coming — nap, don't spin
            self._work_event.wait(0.005)
            self._work_event.clear()
            return False
        try:
            req = self._queue.get(timeout=self.scfg.idle_timeout)
        except queue.Empty:
            with self._lock:
                if (self._queue.empty() and not self._ready
                        and self._inflight_prefills == 0):
                    self._running = False  # chain ends; submit() restarts it
                    return True
            return False
        if self.scfg.pipeline_admission:
            self._launch_prefill(req)
        else:
            self._queue.put(req)  # inline admission pops it next iteration
        return False

    def _step(self) -> None:
        """One link of the decode continuation chain."""
        if self._paused:  # quiesce at the step boundary; resume() restarts
            with self._lock:
                self._running = False
            return
        if self.scfg.pipeline_admission:
            self._pump_prefills()
            self._integrate_ready()
        else:
            self._admit_inline()

        active = [i for i, s in enumerate(self.slots) if s is not None]
        for i in list(active):
            if not self.backend.prepare_step(i):  # can't grow: page capacity
                self._finish(i)
                active.remove(i)

        if not active:
            if self._idle_or_stop():
                return
            self._loop_exec.post(self._step)
            return

        step_args: Dict[str, Any] = {"batch": len(active)}
        if _trace._enabled:
            # which requests this step advanced — the analyzer charges the
            # step's duration to every request decoding in it
            step_args["reqs"] = [self.slots[i].tag for i in active]
        with _trace.span("decode_step", "serve", **step_args), \
                self.t_step.time():
            key = jax.random.fold_in(self._key, self._step_count)
            nxt, new_cache = self._decode(
                self.params, self.backend.device_cache(),
                jnp.asarray(self._tokens), key,
                jnp.asarray(self._temp), jnp.asarray(self._topk),
                jnp.asarray(self._topp))
            self.backend.commit(new_cache)
            toks = np.asarray(nxt[:, 0])
        self._step_count += 1
        self.backend.step_bookkeeping(active)
        self._tokens[:, 0] = toks
        for i in active:
            req = self.slots[i]
            tok = int(toks[i])
            self._emit(req, tok)
            if self._done_after(req, tok):
                self._finish(i)
        self._loop_exec.post(self._step)  # continuation chain
