"""Serving engine: continuous batching driven by the AMT runtime.

Requests arrive as futures (``submit`` returns immediately, HPX-style
one-sided semantics); the engine loop runs as a scheduler task and:

1. admits queued requests into free batch slots — each request is prefilled
   (B=1, exact, its own length) and its cache *migrated into* the batched
   cache at the slot index (per-slot ``pos`` lets slots advance
   independently — true continuous batching, no wave barriers);
2. decodes the whole batch each iteration (one jitted ``decode_step``,
   donated cache);
3. resolves a request's future the moment its slot finishes (EOS/max
   tokens), freeing the slot for the next admission.

The engine's cache is AGAS-registered, so load rebalancing / elastic moves
(DESIGN.md §5) operate on it like any other global object.  Performance
counters: ``/serve{engine#0}/requests/{submitted,completed}``,
``/serve{engine#0}/tokens/generated``, ``/serve{engine#0}/step/duration``.
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import agas as _agas
from repro.core import counters as _counters
from repro.core import scheduler as _sched
from repro.core.future import Future, Promise
from repro.models.model import Model


@dataclass
class ServeConfig:
    max_batch: int = 4
    cache_len: int = 256
    max_new_tokens: int = 32
    eos_id: int = -1  # -1: never stops early


@dataclass
class _Request:
    prompt: List[int]
    max_new: int
    promise: Promise
    generated: List[int] = field(default_factory=list)


def _cache_batch_axis(name: str) -> int:
    return 0 if name == "pos" else 1


class Engine:
    def __init__(self, model: Model, params: Dict[str, jax.Array],
                 scfg: ServeConfig, extra_inputs: Optional[Dict[str, Any]] = None):
        self.model = model
        self.params = params
        self.scfg = scfg
        self.extra = extra_inputs or {}
        B = scfg.max_batch
        cache_specs = model.cache_specs(B, scfg.cache_len,
                                        enc_len=self.extra.get("enc_len"))
        self.cache = {k: jnp.zeros(s.shape, s.dtype) for k, s in cache_specs.items()}
        self.tokens = jnp.zeros((B, 1), jnp.int32)
        self.slots: List[Optional[_Request]] = [None] * B
        self._queue: "queue.Queue[_Request]" = queue.Queue()
        self._lock = threading.Lock()
        self._running = False

        self._prefill = jax.jit(model.prefill, static_argnames=("cache_len",))
        self._decode = jax.jit(self._decode_fn, donate_argnums=(1,))

        reg = _counters.default()
        self.c_sub = reg.counter("/serve{engine#0}/requests/submitted")
        self.c_done = reg.counter("/serve{engine#0}/requests/completed")
        self.c_tok = reg.counter("/serve{engine#0}/tokens/generated")
        self.t_step = reg.timer("/serve{engine#0}/step/duration")
        self.gid = _agas.default().register(self.cache, name=None,
                                            placement="host-engine")

    def _decode_fn(self, params, cache, token):
        logits, new_cache = self.model.decode(params, cache, token)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return nxt, new_cache

    # ------------------------------------------------------------------ api
    def submit(self, prompt: List[int], max_new: Optional[int] = None) -> Future:
        """One-sided request: returns Future[List[int]] of generated ids."""
        req = _Request(list(prompt), max_new or self.scfg.max_new_tokens, Promise())
        self._queue.put(req)
        self.c_sub.increment()
        self._ensure_running()
        return req.promise.future()

    def _ensure_running(self) -> None:
        with self._lock:
            if not self._running:
                self._running = True
                _sched.get_runtime().spawn_raw(self._loop)

    # ----------------------------------------------------------------- loop
    def _admit(self) -> None:
        for i, slot in enumerate(self.slots):
            if slot is not None:
                continue
            try:
                req = self._queue.get_nowait()
            except queue.Empty:
                return
            prompt = jnp.asarray(req.prompt, jnp.int32)[None, :]
            pin = {"tokens": prompt, **{k: v for k, v in self.extra.items()
                                        if k not in ("enc_len",)}}
            logits1, cache1 = self._prefill(self.params, pin,
                                            cache_len=self.scfg.cache_len)
            first = int(jnp.argmax(logits1, axis=-1)[0])
            # migrate the single-request cache into slot i of the batch cache
            self.cache = {
                k: v.at[(slice(None), i) if _cache_batch_axis(k) == 1 else i].set(
                    jnp.take(cache1[k], 0, axis=_cache_batch_axis(k)))
                for k, v in self.cache.items()
            }
            self.tokens = self.tokens.at[i, 0].set(first)
            req.generated.append(first)
            self.c_tok.increment()
            self.slots[i] = req

    def _finish(self, i: int) -> None:
        req = self.slots[i]
        self.slots[i] = None
        self.c_done.increment()
        req.promise.set_value(req.generated)

    def _loop(self) -> None:
        while True:
            self._admit()
            active = [i for i, s in enumerate(self.slots) if s is not None]
            if not active:
                with self._lock:
                    if self._queue.empty():
                        self._running = False
                        return
                continue
            with self.t_step.time():
                self.tokens, self.cache = self._decode(self.params, self.cache,
                                                       self.tokens)
                toks = np.asarray(self.tokens[:, 0])
            for i in active:
                req = self.slots[i]
                tok = int(toks[i])
                req.generated.append(tok)
                self.c_tok.increment()
                done = len(req.generated) >= req.max_new + 1 or tok == self.scfg.eos_id
                if done:
                    self._finish(i)
