"""Cross-locality token streaming + completion relay for the fleet tier.

The in-process engine streams tokens through a :class:`~repro.core.future.
Channel`; channels cannot cross a process boundary.  This module is the
wire form of that contract: the *engine side* holds a :class:`TokenRelay`
— a picklable Channel-alike whose ``set(tok)`` ships an indexed,
fire-and-forget token parcel to the client locality — and the *client
side* keeps a **sink registry** that reassembles each stream in order and
completes the caller's future from an authoritative done-parcel.

Why indices instead of trusting the wire: live engine migration
(`repro.fleet.migrate`) moves a running engine — and its in-flight
streams — to another locality mid-generation.  The destination rebuilds
each request's relay at ``idx = len(generated)``, so the token sequence
the client sees is source ``0..k-1`` then destination ``k..n``.  Per-sid
index dedup makes delivery *exactly-once per index* no matter how parcels
interleave across the cutover (duplicates dropped, out-of-order buffered,
anything a crash swallowed backfilled from the done-parcel's full token
list) — the "zero dropped, zero duplicated tokens" guarantee is enforced
here and *counted* here::

    /serve{relay}/tokens/delivered      cumulative (in-order into channels)
    /serve{relay}/tokens/duplicates     cumulative (index seen twice: dropped)
    /serve{relay}/tokens/out_of_order   cumulative (buffered, then drained)
    /serve{relay}/tokens/backfilled     cumulative (recovered from done list)
    /serve{relay}/tokens/orphaned       cumulative (sid already gone)
    /serve{relay}/streams/{opened,closed,aborted}

Engine death is observed through :meth:`NetRuntime.add_peer_down_hook`:
every sink pinned to the dead locality aborts — with
:class:`StreamBroken` when tokens already flowed (not retriable: a
replacement engine would regenerate indices the client consumed), with
the raw failure when none did (the router may re-dispatch into the same
still-open channel).
"""

from __future__ import annotations

import itertools
import threading
from typing import Any, Callable, Dict, List, Optional

from repro.core import counters as _counters
from repro.core import parcel as _parcel
from repro.core.future import Channel, Future

__all__ = ["TokenRelay", "StreamBroken", "open_sink", "abort",
           "abort_for_peer", "rehome_streams", "attach_done", "reattach_for"]


class StreamBroken(RuntimeError):
    """A stream failed *after* delivering tokens: the prefix the consumer
    read is valid, the tail is gone, and a retry would duplicate it."""


def _reg():
    return _counters.default()


class _RelayCounters:
    _instance: Optional["_RelayCounters"] = None

    def __init__(self) -> None:
        reg = _reg()
        self.delivered = reg.counter("/serve{relay}/tokens/delivered")
        self.duplicates = reg.counter("/serve{relay}/tokens/duplicates")
        self.out_of_order = reg.counter("/serve{relay}/tokens/out_of_order")
        self.backfilled = reg.counter("/serve{relay}/tokens/backfilled")
        self.orphaned = reg.counter("/serve{relay}/tokens/orphaned")
        self.opened = reg.counter("/serve{relay}/streams/opened")
        self.closed = reg.counter("/serve{relay}/streams/closed")
        self.aborted = reg.counter("/serve{relay}/streams/aborted")

    @classmethod
    def get(cls) -> "_RelayCounters":
        # counters are get-or-create by name, so a lost race is harmless
        if cls._instance is None:
            cls._instance = cls()
        return cls._instance


# ---------------------------------------------------------------- engine side
class TokenRelay:
    """Engine-side stream endpoint: quacks like the Channel the engine
    already knows (``set`` / ``close``), ships indexed token parcels.

    ``idx`` is the next global token index of the request — a migrated
    request's rebuilt relay starts at ``len(generated)``, continuing the
    numbering the source locality left off at.  ``close`` is a no-op:
    stream end rides the done-parcel (:func:`attach_done`), which carries
    the authoritative full token list for backfill.
    """

    __slots__ = ("client", "sid", "idx", "stream")

    def __init__(self, client: int, sid: int, idx: int, stream: bool):
        self.client = client
        self.sid = sid
        self.idx = idx
        self.stream = stream

    def set(self, tok: int) -> None:
        idx, self.idx = self.idx, self.idx + 1
        if not self.stream:
            return  # non-streaming caller: the done-parcel carries it all
        from repro.net import locality as _locality

        net = _locality.current()
        if net is None:
            return
        try:
            net.send_parcel(self.client, _DELIVER_TOKEN_NAME, None,
                            (self.sid, idx, int(tok)), want_result=False)
        except Exception:  # noqa: BLE001 — client gone; done/abort settles it
            pass

    def close(self, exc: Optional[BaseException] = None) -> None:
        pass


# ---------------------------------------------------------------- client side
class _Sink:
    __slots__ = ("channel", "locality", "next_idx", "pending", "delivered",
                 "on_result", "finalized", "lock")

    def __init__(self, channel: Optional[Channel], locality: int,
                 on_result: Callable[[bool, Any, Optional[Dict]], None]):
        self.channel = channel
        self.locality = locality  # where the engine currently lives
        self.next_idx = 0
        self.pending: Dict[int, int] = {}  # out-of-order buffer: idx → tok
        self.delivered = 0
        self.on_result = on_result
        self.finalized = False
        # per-sink lock: token parcels execute concurrently on the io pool,
        # and in-channel order must match index order
        self.lock = threading.Lock()


_sinks: Dict[int, _Sink] = {}
_sinks_lock = threading.Lock()
_sid_counter = itertools.count(1)


def _ensure_peer_hook(net) -> None:
    if getattr(net, "_relay_hooked", False):
        return
    net._relay_hooked = True
    net.add_peer_down_hook(abort_for_peer)


def open_sink(net, channel: Optional[Channel], locality: int,
              on_result: Callable[[bool, Any, Optional[Dict]], None]) -> int:
    """Register a stream sink; returns the sid the engine-side relay will
    address.  ``on_result(ok, payload_or_exc, gossip)`` fires exactly once
    — from the done-parcel, or from :func:`abort` when the engine's
    locality dies first."""
    _ensure_peer_hook(net)
    sid = next(_sid_counter)
    with _sinks_lock:
        _sinks[sid] = _Sink(channel, locality, on_result)
    _RelayCounters.get().opened.increment()
    return sid


def _push(sink: _Sink, tok: int, c: _RelayCounters) -> None:
    sink.next_idx += 1
    sink.delivered += 1
    c.delivered.increment()
    if sink.channel is not None:
        try:
            sink.channel.set(tok)
        except Exception:  # noqa: BLE001 — consumer closed its end early
            pass


@_parcel.action
def _deliver_token(rt, sid: int, idx: int, tok: int) -> None:
    """Client-side landing of one streamed token (fire-and-forget parcel).
    Exactly-once per index: duplicates drop, gaps buffer until filled, and
    anything racing the done-parcel (io-pool execution can reorder
    same-channel frames) counts orphaned, never double-delivers."""
    c = _RelayCounters.get()
    with _sinks_lock:
        sink = _sinks.get(sid)
    if sink is None:
        c.orphaned.increment()
        return
    with sink.lock:
        if sink.finalized:
            c.orphaned.increment()  # done/abort won the race; it backfilled
            return
        if idx < sink.next_idx or idx in sink.pending:
            c.duplicates.increment()
            return
        if idx > sink.next_idx:
            sink.pending[idx] = tok
            c.out_of_order.increment()
            return
        _push(sink, tok, c)
        while sink.next_idx in sink.pending:  # drain contiguous run
            _push(sink, sink.pending.pop(sink.next_idx), c)


@_parcel.action
def _deliver_done(rt, sid: int, ok: bool, payload: Any,
                  gossip: Optional[Dict[str, float]]) -> None:
    """Client-side landing of a request's completion.  On success
    ``payload`` is the authoritative full token list: any index the stream
    never delivered (parcel lost to a crash, or still stuck in the io
    pool) is backfilled from it *in order* before the channel closes — the
    consumer cannot tell the difference."""
    c = _RelayCounters.get()
    with _sinks_lock:
        sink = _sinks.pop(sid, None)
    if sink is None:
        return  # aborted already (peer death raced the done-parcel)
    with sink.lock:
        sink.finalized = True
        if ok:
            if sink.channel is not None:
                tokens: List[int] = payload
                for idx in range(sink.next_idx, len(tokens)):
                    was = sink.pending.pop(idx, None)
                    if was is None:
                        c.backfilled.increment()
                    _push(sink, tokens[idx], c)
                sink.channel.close()
            c.closed.increment()
            result = (True, payload, gossip)
        else:
            exc = payload
            if sink.delivered > 0:
                exc = StreamBroken(
                    f"stream {sid} failed after {sink.delivered} tokens: "
                    f"{payload!r}")
                if sink.channel is not None:
                    sink.channel.close(exc)
            c.aborted.increment()
            result = (False, exc, gossip)
    sink.on_result(*result)  # outside the lock: completes user promises


_DELIVER_TOKEN_NAME = _deliver_token._action_name
_DELIVER_DONE_NAME = _deliver_done._action_name


def abort(sid: int, exc: BaseException) -> int:
    """Fail one sink (idempotent).  Returns how many tokens it had already
    delivered.  With zero delivered the channel is left *open* — the
    router may re-dispatch the request into it; with any delivered the
    channel closes with :class:`StreamBroken` (retry would duplicate)."""
    with _sinks_lock:
        sink = _sinks.pop(sid, None)
    if sink is None:
        return 0
    with sink.lock:
        sink.finalized = True
        delivered = sink.delivered
        if delivered > 0:
            exc = StreamBroken(
                f"stream {sid} broke after {delivered} tokens: {exc!r}")
            if sink.channel is not None:
                sink.channel.close(exc)
    _RelayCounters.get().aborted.increment()
    sink.on_result(False, exc, None)
    return delivered


def abort_for_peer(lid: int) -> int:
    """Peer-down hook: abort every sink whose engine lived on ``lid``."""
    from repro.net import parcelport as _pp

    with _sinks_lock:
        doomed = [sid for sid, s in _sinks.items() if s.locality == lid]
    n = 0
    for sid in doomed:
        abort(sid, _pp.PortClosed(f"engine locality#{lid} went away"))
        n += 1
    return n


def rehome_streams(old: int, new: int) -> int:
    """Re-pin every sink from locality ``old`` to ``new`` (live migration:
    must happen before the source locality can be retired, or the
    peer-down hook would abort streams the destination is still feeding)."""
    n = 0
    with _sinks_lock:
        for sink in _sinks.values():
            if sink.locality == old:
                sink.locality = new
                n += 1
    return n


def live_sids() -> List[int]:
    with _sinks_lock:
        return list(_sinks)


# ------------------------------------------------------------- engine hooks
def attach_done(engine, fut: Future, client: int, sid: int,
                tag: Optional[str] = None) -> None:
    """Wire a request future (at the engine's locality) to the client's
    sink: completion ships a done-parcel carrying the outcome plus this
    engine's load/occupancy gossip.  Re-attachable — migration calls this
    again at the destination; the source's pending future died with its
    process, so the sink still sees exactly one done-parcel."""
    def done(f: Future) -> None:
        from repro.net import locality as _locality
        from repro.obs import trace as _trace

        net = _locality.current()
        if net is None:
            return
        exc = f.exception()
        try:
            gossip = {"load": float(engine.load()),
                      "occ": float(engine.occupancy())}
        except Exception:  # noqa: BLE001
            gossip = None
        args = ((sid, True, f._value, gossip) if exc is None
                else (sid, False, exc, gossip))
        try:
            if _trace._enabled and tag:
                # tagged wrapper: the nested send:_deliver_done span's
                # parent is this sid, so the analyzer can attribute the
                # completion leg's wire time to the request
                with _trace.span("relay/done", "serve", req=tag, dst=client):
                    net.send_parcel(client, _DELIVER_DONE_NAME, None, args,
                                    want_result=False)
            else:
                net.send_parcel(client, _DELIVER_DONE_NAME, None, args,
                                want_result=False)
        except Exception:  # noqa: BLE001 — client gone; nothing to tell
            pass

    fut.on_ready(done)


@_parcel.action
def _fleet_submit(engine, prompt: List[int], max_new: Optional[int],
                  sampling, client: int, sid: int, want_stream: bool,
                  tag: Optional[str] = None,
                  slo: Optional[str] = None) -> bool:
    """Non-blocking engine submit (object-targeted, so live migration's
    UnknownGid self-heal re-routes it): builds the request's relay + meta,
    attaches the done hook, acks immediately.  Tokens and completion flow
    back as separate one-sided parcels — no pool worker blocks per
    request, which is what lets one locality hold hundreds of in-flight
    remote requests."""
    tag = tag or f"s{int(client)}:{int(sid)}"
    meta = {"client": int(client), "sid": int(sid),
            "stream": bool(want_stream), "req": tag, "slo": slo}
    relay = TokenRelay(int(client), int(sid), 0, bool(want_stream))
    fut = engine.submit(prompt, max_new, sampling, stream=relay, meta=meta)
    attach_done(engine, fut, int(client), int(sid), tag=tag)
    return True


def reattach_for(engine) -> Callable[[Any], None]:
    """The ``reattach`` callback :meth:`Engine.restore_requests` needs:
    rebuild each migrated request's relay continuing the source's token
    numbering, and re-wire its done hook to the same client sink."""
    def reattach(req) -> None:
        m = req.meta
        req.stream = TokenRelay(m["client"], m["sid"], len(req.generated),
                                m["stream"])
        attach_done(engine, req.promise.future(), m["client"], m["sid"],
                    tag=m.get("req"))

    return reattach
