"""Block-pool paged KV cache (the serving stack's cache layer).

The seed engine allocated a dense ``(L, max_batch, cache_len, KV, Dh)``
cache — memory ∝ ``max_batch × cache_len`` whether slots are full or empty.
This module replaces it with a vLLM-style block pool: KV lives in
``num_pages`` fixed-size pages shared by all requests and all layers (page
``p`` holds a request's tokens in *every* layer array), a free list hands
pages out on demand, and each batch slot owns a page list mirrored into a
``(max_batch, max_pages_per_req)`` page table that the paged decode kernel
walks (``kernels/decode_attention.py::paged_decode_attention_fwd``).
Memory therefore scales with *live tokens*.

Page 0 is reserved as a scratch page: idle slots' page tables point at it,
so the batched decode step can write their (discarded) K/V somewhere
harmless without per-slot branching.

Ownership split with the engine: this class owns *allocation* (host-side
free list, page-table / pos mirrors, prefill scatter) and the device page
pools; the engine drives the jitted decode step, passing
:meth:`device_cache` in and storing the donated-out pools back via
:meth:`update_pools`.  The pool pytree is AGAS-registered, so elastic
rebalancing moves it like any other global object (DESIGN.md §5).

Performance counters::

    /serve{<name>}/pages/in_use        gauge
    /serve{<name>}/pages/capacity      gauge
    /serve{<name>}/pages/allocated     cumulative
    /serve{<name>}/pages/freed         cumulative
    /serve{<name>}/pages/alloc_failures cumulative
"""

from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import agas as _agas
from repro.core import counters as _counters

_POOL_KEYS = ("k", "v", "k0", "v0")


@functools.partial(jax.jit, donate_argnums=(0,))
def _scatter_pages(pool: jax.Array, src: jax.Array,
                   page_ids: jax.Array) -> jax.Array:
    """pool (L,P,page,KV,Dh) ← src (L,npg,page,KV,Dh) at pages ``page_ids``."""
    return pool.at[:, page_ids].set(src.astype(pool.dtype))


class PagedKVCache:
    """Fixed-page block pool + free list + per-slot page tables."""

    def __init__(self, model, *, num_pages: int, page_size: int,
                 max_batch: int, max_pages_per_req: int,
                 name: str = "engine#0"):
        assert num_pages >= 2, "need at least the scratch page plus one"
        self.page_size = page_size
        self.num_pages = num_pages
        self.max_batch = max_batch
        self.max_pages_per_req = max_pages_per_req
        specs = model.paged_cache_specs(num_pages, page_size, max_batch,
                                        max_pages_per_req)
        self.pools: Dict[str, jax.Array] = {
            k: jnp.zeros(s.shape, s.dtype) for k, s in specs.items()
            if k in _POOL_KEYS
        }
        # host-authoritative mirrors (admission mutates them between steps)
        self.page_table = np.zeros((max_batch, max_pages_per_req), np.int32)
        self.pos = np.zeros((max_batch,), np.int32)
        # LIFO free list; page 0 reserved as the idle-slot scratch page
        self._free: List[int] = list(range(num_pages - 1, 0, -1))
        self._owned: Dict[int, List[int]] = {i: [] for i in range(max_batch)}

        reg = _counters.default()
        self.g_in_use = reg.gauge(f"/serve{{{name}}}/pages/in_use")
        self.g_capacity = reg.gauge(f"/serve{{{name}}}/pages/capacity")
        self.g_capacity.set(float(num_pages - 1))
        self.c_alloc = reg.counter(f"/serve{{{name}}}/pages/allocated")
        self.c_freed = reg.counter(f"/serve{{{name}}}/pages/freed")
        self.c_fail = reg.counter(f"/serve{{{name}}}/pages/alloc_failures")
        self.gid = _agas.default().register(self.pools, name=None,
                                            placement="host-engine")

    # ------------------------------------------------------------ free list
    def free_pages(self) -> int:
        return len(self._free)

    def pages_in_use(self) -> int:
        return (self.num_pages - 1) - len(self._free)

    def _take(self, n: int) -> Optional[List[int]]:
        if len(self._free) < n:
            self.c_fail.increment()
            return None
        pages = [self._free.pop() for _ in range(n)]
        self.c_alloc.increment(n)
        self.g_in_use.set(float(self.pages_in_use()))
        return pages

    # ------------------------------------------------------------ slot api
    def admit(self, slot: int, prefill_cache: Dict[str, jax.Array],
              length: int) -> bool:
        """Bind ``slot`` to a freshly prefilled request: allocate pages for
        its ``length`` valid tokens and scatter the (possibly right-padded)
        prefill K/V into them.  Returns False if the pool is exhausted
        (caller retries after the next completion frees pages)."""
        assert not self._owned[slot], f"slot {slot} still owns pages"
        npg = -(-length // self.page_size)  # ceil
        if npg > self.max_pages_per_req:
            return False
        pages = self._take(npg)
        if pages is None:
            return False
        ids = jnp.asarray(pages, jnp.int32)
        for key in self.pools:
            src = prefill_cache[key][:, 0]  # (L, S_bucket, KV, Dh)
            L, S, KV, Dh = src.shape
            pad = npg * self.page_size - S
            if pad > 0:
                src = jnp.pad(src, ((0, 0), (0, pad), (0, 0), (0, 0)))
            src = src[:, : npg * self.page_size]
            src = src.reshape(L, npg, self.page_size, KV, Dh)
            self.pools[key] = _scatter_pages(self.pools[key], src, ids)
        self._owned[slot] = pages
        self.page_table[slot, :] = 0
        self.page_table[slot, :npg] = pages
        self.pos[slot] = length
        return True

    def ensure_next_token(self, slot: int) -> bool:
        """Make sure the page holding token index ``pos[slot]`` exists.
        Returns False when the slot can no longer grow (page-table capacity
        or pool exhaustion) — the engine finishes the request."""
        idx = int(self.pos[slot]) // self.page_size
        owned = self._owned[slot]
        if idx < len(owned):
            return True
        if idx >= self.max_pages_per_req:
            return False
        pages = self._take(1)
        if pages is None:
            return False
        owned.append(pages[0])
        self.page_table[slot, idx] = pages[0]
        return True

    def release(self, slot: int) -> None:
        """Return the slot's pages to the free list (admission churn path)."""
        pages, self._owned[slot] = self._owned[slot], []
        if pages:
            self._free.extend(reversed(pages))
            self.c_freed.increment(len(pages))
            self.g_in_use.set(float(self.pages_in_use()))
        self.page_table[slot, :] = 0
        self.pos[slot] = 0

    # ------------------------------------------------------- migration i/o
    def snapshot_slot(self, slot: int) -> Dict[str, Any]:
        """Host copy of one slot's live KV state: the pages it owns (in
        page-table order) gathered out of every pool, plus its position.
        This is the unit live engine migration ships — pages for *live
        tokens only*, never the whole pool."""
        pages = self._owned[slot]
        ids = np.asarray(pages, np.int32)
        return {
            "pos": int(self.pos[slot]),
            "pages": {k: np.asarray(jax.device_get(pool[:, ids]))
                      for k, pool in self.pools.items()} if pages else {},
            "n_pages": len(pages),
        }

    def restore_slot(self, slot: int, snap: Dict[str, Any]) -> bool:
        """Re-home a snapshotted slot into *this* pool: allocate fresh pages
        (the page ids are locality-local — only the contents travel) and
        scatter the shipped KV into them.  Returns False when this pool
        cannot hold the slot (caller must not have dropped the source
        yet)."""
        assert not self._owned[slot], f"slot {slot} still owns pages"
        npg = int(snap["n_pages"])
        if npg == 0:
            self.pos[slot] = snap["pos"]
            return True
        if npg > self.max_pages_per_req:
            return False
        pages = self._take(npg)
        if pages is None:
            return False
        ids = jnp.asarray(pages, jnp.int32)
        for key in self.pools:
            self.pools[key] = _scatter_pages(self.pools[key],
                                             jnp.asarray(snap["pages"][key]),
                                             ids)
        self._owned[slot] = pages
        self.page_table[slot, :] = 0
        self.page_table[slot, :npg] = pages
        self.pos[slot] = snap["pos"]
        return True

    # ------------------------------------------------------------- step i/o
    def device_cache(self) -> Dict[str, jax.Array]:
        """The pytree the jitted paged decode step consumes (pool arrays are
        donated out by the step; page table / pos re-upload from the
        host-authoritative mirrors each step — a few hundred bytes)."""
        cache = dict(self.pools)
        cache["page_table"] = jnp.asarray(self.page_table)
        cache["pos"] = jnp.asarray(self.pos)
        return cache

    def update_pools(self, new_cache: Dict[str, jax.Array]) -> None:
        # self.pools is the AGAS-registered object; in-place update keeps the
        # global view current without a rebind (which would count a migration)
        for key in self.pools:
            self.pools[key] = new_cache[key]
