"""Train/serve step builders: BSP baseline vs futurized vs optimized.

The *step structure* is where the paper's thesis lives (DESIGN.md §2):

- BSP (``bsp`` plan): one macro-batch, params bulk-gathered before the layer
  loop, gradient reduction at the very end — the global-barrier structure of
  MPI+X that HPX argues against.
- Futurized (``futurized`` plan): FSDP per-layer gathers inside the scan,
  per-layer reduce-scatter in backward, optional microbatch accumulation —
  fine-grained constraint-based synchronization; XLA overlaps the resulting
  async collectives with compute exactly like an HPX dataflow graph.
- Optimized (``optimized`` plan): + bf16-compressed pod-axis gradient
  reduction and selective remat (beyond-paper, EXPERIMENTS.md §Perf).

All steps donate ``(params, opt_state)`` — the XLA analogue of HPX's
zero-copy parcel serialization (buffers are aliased, never copied).
"""

from __future__ import annotations

from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist.collectives import pod_manual_value_and_grad
from repro.dist.plan import ShardingPlan
from repro.models.model import Model
from repro.optim import adamw


def make_loss_fn(model: Model) -> Callable:
    def loss_fn(params, batch):
        return model.loss(params, batch)

    return loss_fn


def _microbatch_grads(loss_fn: Callable, params, batch, n_mb: int):
    """Gradient accumulation over ``n_mb`` microbatches via lax.scan.

    Each microbatch's backward finishes with its own (overlappable)
    reduce-scatter — the futurized pipeline. Batch dim must divide n_mb.
    """

    def split(x):
        return x.reshape((n_mb, x.shape[0] // n_mb) + x.shape[1:])

    mbs = jax.tree.map(split, batch)
    zero_grads = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def body(carry, mb):
        loss_acc, grads_acc = carry
        loss, grads = jax.value_and_grad(loss_fn)(params, mb)
        grads_acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32), grads_acc, grads)
        return (loss_acc + loss, grads_acc), None

    (loss_sum, grads_sum), _ = jax.lax.scan(body, (jnp.zeros((), jnp.float32), zero_grads), mbs)
    inv = 1.0 / n_mb
    return loss_sum * inv, jax.tree.map(lambda g: g * inv, grads_sum)


def make_train_step(model: Model, opt_cfg: adamw.AdamWConfig,
                    mesh=None) -> Callable:
    """Returns ``step(params, opt_state, batch) → (params, opt_state, metrics)``."""
    plan = model.plan
    loss_fn = make_loss_fn(model)

    def step(params, opt_state, batch):
        if plan.compress_pod_grads and mesh is not None and "pod" in mesh.axis_names:
            loss, grads = pod_manual_value_and_grad(loss_fn, mesh)(params, batch)
        elif plan.microbatches > 1:
            loss, grads = _microbatch_grads(loss_fn, params, batch, plan.microbatches)
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        new_params, new_opt, om = adamw.update(opt_cfg, params, grads, opt_state)
        metrics = {"loss": loss, **om}
        return new_params, new_opt, metrics

    return step


def make_prefill_step(model: Model) -> Callable:
    def prefill_step(params, inputs):
        return model.prefill(params, inputs)

    return prefill_step


def make_decode_step(model: Model) -> Callable:
    """Greedy one-token decode (the ``serve_step`` of the decode cells)."""

    def decode_step(params, cache, token):
        logits, new_cache = model.decode(params, cache, token)
        next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return next_token, new_cache

    return decode_step


# ---------------------------------------------------------------- shardings
def train_state_shardings(model: Model, mesh) -> Tuple[Any, Any]:
    """(param shardings, optimizer-state shardings) for jit in/out."""
    plan = model.plan
    specs = model.param_specs()
    p_sh = plan.param_shardings(specs, mesh)
    ax = adamw.state_axes(specs)
    o_sh = {
        "m": {k: plan.sharding(ax["m"][k], specs[k].shape, mesh) for k in specs},
        "v": {k: plan.sharding(ax["v"][k], specs[k].shape, mesh) for k in specs},
        "step": plan.replicated(mesh),
    }
    return p_sh, o_sh


def batch_shardings(model: Model, mesh, batch_specs: Dict[str, jax.ShapeDtypeStruct]):
    plan = model.plan
    axes = model.batch_axes()
    return {
        k: plan.sharding(axes.get(k, ("batch",) + (None,) * (len(s.shape) - 1)),
                         s.shape, mesh)
        for k, s in batch_specs.items()
    }


def cache_shardings(model: Model, mesh, cache_specs: Dict[str, Any]):
    plan = model.plan
    axes = model.cache_axes()
    return {
        k: plan.sharding(axes[k], s.shape, mesh) if s.shape else plan.replicated(mesh)
        for k, s in cache_specs.items()
    }
