"""Task-based pipeline parallelism: 1F1B from dataflow ordering (DESIGN §5).

The paper's claim in miniature: express the pipeline as a dependency DAG of
stage tasks and the schedule *emerges* — no hand-written 1F1B state machine,
no global barrier.  Forward task (s, m) depends on (s−1, m); backward task
(s, m) depends on (s+1, m)'s cotangent and its own forward residuals; the
AMT scheduler (work-stealing pool) runs whatever is ready, so bubbles fill
exactly as in 1F1B the moment resources free up.

Each stage holds its own parameters (= a pipeline rank's weights); the step
returns per-stage gradients averaged over microbatches.  On a TPU fleet each
stage task dispatches to that stage's mesh slice — here every stage is a
jitted function on the local device, which demonstrates ordering and overlap
of the host plane (and is exactly how a multi-controller deployment would
drive per-stage meshes).
"""

from __future__ import annotations

from typing import Any, Callable, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import counters as _counters
from repro.core import scheduler as _sched
from repro.core.dataflow import dataflow
from repro.core.future import Future, when_all


def pipeline_value_and_grad(
    stage_fns: Sequence[Callable],  # stage_fns[s](params_s, x) -> y
    loss_fn: Callable,  # loss_fn(y_last, target_mb) -> scalar
    stage_params: Sequence[Any],
    batches: Sequence[Tuple[Any, Any]],  # [(x_mb, target_mb)] microbatches
) -> Tuple[Future, List[Future]]:
    """Futurized pipeline step.

    Returns (loss future (mean over microbatches),
             per-stage gradient futures (mean over microbatches)).
    """
    S, M = len(stage_fns), len(batches)
    rt = _sched.get_runtime()
    c_tasks = _counters.counter("/pipeline{1f1b}/tasks/cumulative")

    # ---- forward wave: fwd[s][m] = (activation future, vjp closure) -------
    acts: List[List[Future]] = [[None] * M for _ in range(S)]
    vjps: List[List[Future]] = [[None] * M for _ in range(S)]

    def fwd_task(s: int, x: Any) -> Tuple[Any, Callable]:
        c_tasks.increment()
        y, vjp = jax.vjp(lambda p, xx: stage_fns[s](p, xx), stage_params[s], x)
        return y, vjp

    for m, (x_mb, _) in enumerate(batches):
        carry: Any = x_mb
        for s in range(S):
            pair = (dataflow(fwd_task, s, carry) if s == 0 else
                    dataflow(lambda prev, s=s: fwd_task(s, prev[0]), carry))
            acts[s][m] = pair.then_value(lambda p: p[0])
            vjps[s][m] = pair.then_value(lambda p: p[1])
            carry = pair

    # ---- loss + backward wave ---------------------------------------------
    def loss_task(y: Any, target: Any) -> Tuple[Any, Any]:
        c_tasks.increment()
        loss, vjp = jax.vjp(loss_fn, y, target)
        dy, _ = vjp(jnp.ones_like(loss))
        return loss, dy

    losses: List[Future] = []
    grads: List[List[Future]] = [[None] * M for _ in range(S)]
    for m, (_, tgt) in enumerate(batches):
        lt = dataflow(loss_task, acts[S - 1][m], tgt)
        losses.append(lt.then_value(lambda p: p[0]))
        ct = lt.then_value(lambda p: p[1])  # cotangent entering stage S-1
        for s in reversed(range(S)):
            def bwd_task(vjp, dy, s=s):
                c_tasks.increment()
                dp, dx = vjp(dy)
                return dp, dx

            bt = dataflow(bwd_task, vjps[s][m], ct)
            grads[s][m] = bt.then_value(lambda p: p[0])
            ct = bt.then_value(lambda p: p[1])

    # ---- reductions (dataflow, no barrier until the caller looks) ----------
    def mean_tree(*trees: Any) -> Any:
        return jax.tree.map(lambda *xs: sum(xs) / len(xs), *trees)

    loss_fut = dataflow(lambda *ls: sum(ls) / len(ls), *losses)
    grad_futs = [dataflow(mean_tree, *grads[s]) for s in range(S)]
    return loss_fut, grad_futs


def split_stages(layers: Sequence[Any], n_stages: int) -> List[List[Any]]:
    """Even-ish contiguous split of layer params into pipeline stages."""
    k, r = divmod(len(layers), n_stages)
    out, i = [], 0
    for s in range(n_stages):
        n = k + (1 if s < r else 0)
        out.append(list(layers[i: i + n]))
        i += n
    return out
