"""Futurized training driver (the end-to-end AMT loop).

The BSP trainer's step is: build batch → step → wait → maybe checkpoint —
every stage a barrier.  This driver futurizes all of it:

- batches are built by scheduler tasks ``prefetch`` steps ahead
  (``data.Prefetcher`` futures);
- the jitted step is dispatched asynchronously (JAX returns device futures;
  the host thread immediately starts the next iteration's admission);
- checkpoints are snapshotted and written by a scheduler task
  (``checkpoint.save_async``) while the device keeps training;
- the loop only synchronizes on metrics every ``log_every`` steps.

Fault tolerance: train state is AGAS-registered (GID stable across
migrations); ``elastic_restart`` reshards the live state onto a new mesh
(node-failure shrink / expansion), and ``Trainer.resume`` restores the
latest checkpoint onto whatever mesh is active.  Straggler detection: the
step-time EMA counter flags steps > ``straggler_factor``× EMA and counts
them (``/train{loop#0}/stragglers/detected``) — the policy hook
re-dispatches the batch (host-level retry) when enabled.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint import ckpt as ckpt_mod
from repro.core import agas as _agas
from repro.core import counters as _counters
from repro.core import migration
from repro.core import scheduler as _sched
from repro.core.future import Future
from repro.data.pipeline import DataConfig, Prefetcher
from repro.models.model import Model
from repro.obs import trace as _trace
from repro.optim import adamw
from repro.train import step as step_mod


@dataclass
class TrainConfig:
    steps: int = 100
    log_every: int = 10
    ckpt_every: int = 0  # 0 = disabled
    ckpt_dir: str = "checkpoints"
    straggler_factor: float = 3.0
    retry_stragglers: bool = False


class Trainer:
    def __init__(self, model: Model, opt_cfg: adamw.AdamWConfig,
                 data_cfg: DataConfig, tcfg: TrainConfig,
                 mesh=None, rng_seed: int = 0, prefetcher=None):
        self.model = model
        self.opt_cfg = opt_cfg
        self.data_cfg = data_cfg
        self.tcfg = tcfg
        self.mesh = mesh
        # Ensure the AMT runtime is up and the I/O plane is partitioned:
        # prefetch assembly and checkpoint writes run on the "io" pool.
        _sched.get_runtime().add_pool("io", 1)

        self.params = model.init(jax.random.PRNGKey(rng_seed))
        self.opt_state = adamw.init(self.params)
        self.step_num = 0
        self._step_fn = jax.jit(step_mod.make_train_step(model, opt_cfg, mesh),
                                donate_argnums=(0, 1))
        # Any ``get(step) -> Future[batch]`` source plugs in — notably
        # ``data.pipeline.LocalShardFeeder`` (locality-sharded datasets:
        # this trainer then feeds exclusively from segments its own
        # locality holds, the work-to-data training path).
        self.prefetcher = (prefetcher if prefetcher is not None
                           else Prefetcher(model.cfg, data_cfg))
        self.gid = _agas.default().register_name(
            f"/train/state/{model.cfg.name}",
            {"params": self.params, "opt": self.opt_state}, replace=True)

        reg = _counters.default()
        self.t_step = reg.timer("/train{loop#0}/step/duration",
                                percentiles=True)
        self.c_steps = reg.counter("/train{loop#0}/steps/cumulative")
        self.c_straggler = reg.counter("/train{loop#0}/stragglers/detected")
        self.g_loss = reg.gauge("/train{loop#0}/loss/instantaneous")

    # ------------------------------------------------------------------ fit
    def fit(self, steps: Optional[int] = None) -> List[Dict[str, float]]:
        steps = steps or self.tcfg.steps
        history: List[Dict[str, float]] = []
        ckpt_futures: List[Future] = []
        for _ in range(steps):
            i = self.step_num
            batch = self.prefetcher.get(i).get()  # future → host batch
            t0 = time.perf_counter()
            with _trace.span("train/step", "train", step=i):
                self.params, self.opt_state, metrics = self._step_fn(
                    self.params, self.opt_state, batch)
            if (i + 1) % self.tcfg.log_every == 0 or i + 1 == steps:
                loss = float(metrics["loss"])  # sync point (only here)
                dt = time.perf_counter() - t0
                self.t_step.add(dt)
                self._check_straggler(dt, batch)
                self.g_loss.set(loss)
                history.append({"step": i + 1, "loss": loss,
                                "grad_norm": float(metrics["grad_norm"])})
            self.c_steps.increment()
            self.step_num += 1
            if self.tcfg.ckpt_every and self.step_num % self.tcfg.ckpt_every == 0:
                ckpt_futures.append(self.checkpoint_async())
        for f in ckpt_futures:
            f.get()  # join outstanding checkpoint I/O
        _agas.default().rebind(self.gid, {"params": self.params, "opt": self.opt_state})
        return history

    def _check_straggler(self, dt: float, batch) -> None:
        ema = self.t_step.ema
        if ema is not None and dt > self.tcfg.straggler_factor * max(ema, 1e-9):
            self.c_straggler.increment()
            if self.tcfg.retry_stragglers:
                # host-level redundant dispatch: re-run the same batch (the
                # multi-controller analogue re-sends work to a healthy host)
                self.params, self.opt_state, _ = self._step_fn(
                    self.params, self.opt_state, batch)

    # ----------------------------------------------------------- checkpoint
    def checkpoint_async(self) -> Future:
        state = {"params": self.params, "opt": self.opt_state}
        return ckpt_mod.save_async(Path(self.tcfg.ckpt_dir), self.step_num, state)

    def resume(self, shardings: Optional[Any] = None) -> int:
        step, state = ckpt_mod.restore(Path(self.tcfg.ckpt_dir),
                                       shardings=shardings)
        self.params = state["params"]
        self.opt_state = state["opt"]
        self.step_num = step
        _agas.default().rebind(self.gid, state)
        return step

    # -------------------------------------------------------------- elastic
    def elastic_restart(self, new_mesh) -> None:
        """Migrate live state onto a different mesh (failure shrink / regrow)
        and rebuild the step function against it."""
        plan = self.model.plan
        specs = self.model.param_specs()
        p_sh = plan.param_shardings(specs, new_mesh)
        o_ax = adamw.state_axes(specs)
        o_sh = {
            "m": {k: plan.sharding(o_ax["m"][k], specs[k].shape, new_mesh) for k in specs},
            "v": {k: plan.sharding(o_ax["v"][k], specs[k].shape, new_mesh) for k in specs},
            "step": plan.replicated(new_mesh),
        }
        self.params = migration.migrate_tree(self.params, p_sh)
        self.opt_state = migration.migrate_tree(self.opt_state, o_sh)
        self.mesh = new_mesh
        self._step_fn = jax.jit(step_mod.make_train_step(self.model, self.opt_cfg, new_mesh),
                                donate_argnums=(0, 1))
        _agas.default().rebind(self.gid,
                               {"params": self.params, "opt": self.opt_state},
                               placement=new_mesh)
        _counters.counter("/train{loop#0}/elastic_restarts/cumulative").increment()
