"""Fleet counter sampler — scalar counters become time series.

The paper's §2.4 adaptivity story needs *history*: a cumulative counter
read once says "1.2M tasks executed", read on a cadence it says "tasks/s,
and it dipped 40% when locality 2 started migrating".  The sampler runs on
locality 0, snapshots every locality's counters over the parcelport
(``net.query_counters`` — the same AGAS-published names the rest of the
runtime uses), and keeps a fixed-depth ring of ``(t, value)`` points per
``(locality, counter)``:

- ``rate(loc, name)`` — positive-delta rate over the retained window.
  Counter *resets* (process restart, ``reset_all``) appear as negative
  deltas; those samples contribute the post-reset value instead of being
  summed as a huge negative, so rates stay truthful across restarts.
- ``series(loc, name)`` — the raw retained points, for plotting.

The loop is a daemon thread (in-process observer, not a transport — the
parcelport does the remote reads), started with :meth:`FleetSampler.start`
and stopped either explicitly or by garbage collection of the runtime.
``sample_once()`` is public so tests and the ``--print-counters`` report
can drive sampling synchronously without a thread.
"""

from __future__ import annotations

import collections
import fnmatch
import threading
import time
from typing import Any, Deque, Dict, List, Optional, Tuple

from repro.core import counters as _counters


class FleetSampler:
    """Periodic counter snapshots across all localities, bounded history."""

    def __init__(self, pattern: str = "*", interval: float = 1.0,
                 depth: int = 240, net=None,
                 registry: Optional[_counters.CounterRegistry] = None,
                 timeline=None):
        self.pattern = pattern
        self.interval = interval
        self.depth = depth
        self.net = net
        self.registry = registry or _counters.default()
        # optional repro.obs.timeseries.TimelineWriter — every sweep this
        # sampler takes is also offered to the on-disk timeline
        self.timeline = timeline
        # (locality, counter name) → ring of (perf_counter, value)
        self._histories: Dict[Tuple[int, str],
                              Deque[Tuple[float, float]]] = {}
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self.samples_taken = 0
        self.sample_errors = 0

    # ------------------------------------------------------------ sampling
    def _localities(self) -> List[int]:
        if self.net is None:
            return [0]
        return self.net.live_ids()

    def sample_once(self) -> int:
        """One parallel sweep over every *live* locality; returns points
        recorded.  Rides the fault-tolerant sweep form of
        ``net.query_counters``: a locality dying mid-sweep contributes an
        error marker, not an exception — the flight recorder (and the
        fleet controller driving it) outlives individual crashes, and an
        elastic join shows up as a new locality on the next sweep."""
        now = time.perf_counter()
        points = 0
        if self.net is None:
            sweep: Dict[int, Any] = {0: self.registry.query(self.pattern)}
        else:
            from repro.net import remote as _remote

            sweep = _remote.query_counters(
                None, self.pattern, timeout=max(30.0, self.interval * 4))
        for loc, pairs in sweep.items():
            if isinstance(pairs, dict):  # {"error": ...} — peer went away
                self.sample_errors += 1
                continue
            with self._lock:
                for name, value in pairs:
                    ring = self._histories.get((loc, name))
                    if ring is None:
                        ring = collections.deque(maxlen=self.depth)
                        self._histories[(loc, name)] = ring
                    ring.append((now, float(value)))
                    points += 1
        if self.timeline is not None:
            try:
                self.timeline.append(sweep, now=now)
            except ValueError:  # writer closed mid-run — stop offering
                self.timeline = None
        self.samples_taken += 1
        return points

    # ----------------------------------------------------------- the loop
    def start(self) -> "FleetSampler":
        if self._thread is not None:
            return self
        self._stop.clear()
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name="repro-obs-sampler")
        self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        t, self._thread = self._thread, None
        if t is not None:
            t.join(timeout=max(5.0, self.interval * 2))

    def _loop(self) -> None:
        while not self._stop.wait(self.interval):
            self.sample_once()

    # ------------------------------------------------------------- queries
    def series(self, locality: int, name: str) -> List[Tuple[float, float]]:
        with self._lock:
            ring = self._histories.get((locality, name))
            return list(ring) if ring else []

    def keys(self) -> List[Tuple[int, str]]:
        with self._lock:
            return sorted(self._histories)

    def latest(self, locality: int, name: str) -> Optional[float]:
        """Most recent sampled value, or ``None`` if never seen — the
        policy layer's gauge read (occupancy, queue depth)."""
        with self._lock:
            ring = self._histories.get((locality, name))
            return ring[-1][1] if ring else None

    def rate(self, locality: int, name: str) -> float:
        """Per-second rate of a cumulative counter over the retained window.

        Sums positive inter-sample deltas; a negative delta means the
        counter was reset between samples, so that interval contributes the
        post-reset value (everything counted since the reset) rather than
        poisoning the sum."""
        pts = self.series(locality, name)
        if len(pts) < 2:
            return 0.0
        span = pts[-1][0] - pts[0][0]
        if span <= 0.0:
            return 0.0
        total = 0.0
        for (_, v0), (_, v1) in zip(pts, pts[1:]):
            d = v1 - v0
            total += d if d >= 0.0 else v1
        return total / span

    def rates(self, pattern: Optional[str] = None) -> Dict[Tuple[int, str], float]:
        pat = pattern or "*"
        return {(loc, name): self.rate(loc, name)
                for loc, name in self.keys()
                if fnmatch.fnmatch(name, pat)}


# ------------------------------------------------------- end-of-run report
def print_counter_report(pattern: str = "*", net=None,
                         sampler: Optional[FleetSampler] = None,
                         file=None) -> List[str]:
    """HPX ``--hpx:print-counter`` parity: dump every matching counter on
    every locality — value, rate (when a sampler retained history), and
    p50/p95/p99 for timers/histograms.  The SLOW blame histograms
    (``/obs{blame/...}``) ride along regardless of ``pattern`` — once an
    analysis folded them, the report shows p50/p95/p99 *blame* next to
    whatever was asked for.  Output is sorted by locality then counter
    path (stable diffs in CI logs).  Returns the printed lines."""
    blame_pat = "/obs{blame/*"
    if net is None:
        sweep = {0: _counters.default().snapshot_stats(pattern)}
        blame = {0: _counters.default().snapshot_stats(blame_pat)}
    else:
        from repro.net import remote as _remote

        # fault-tolerant sweep form: a dead peer contributes an
        # {"error": ...} marker, not an exception — the report says so
        # explicitly instead of silently shrinking the fleet
        sweep = _remote.query_counter_stats(None, pattern)
        blame = _remote.query_counter_stats(None, blame_pat)

    def _unreachable(result) -> bool:
        # counter names always start with "/" so the shapes can't collide
        return ("error" in result
                and not any(k.startswith("/") for k in result))

    lines = [f"{'counter':<58} {'value':>12} {'rate/s':>10} "
             f"{'p50':>9} {'p95':>9} {'p99':>9}"]
    for loc in sorted(sweep):
        stats = sweep[loc]
        if _unreachable(stats):
            lines.append(f"locality#{loc} UNREACHABLE ({stats['error']})")
            continue
        extra = blame.get(loc, {})
        if not _unreachable(extra):
            stats.update(extra)
        for name, st in sorted(stats.items()):
            value = st.get("value", st.get("count", 0.0))
            rate = sampler.rate(loc, name) if sampler is not None else None
            cells = [f"L{loc} {name:<55.55}"[:58].ljust(58),
                     f"{value:>12.4g}",
                     f"{rate:>10.4g}" if rate is not None else f"{'-':>10}"]
            for q in ("p50", "p95", "p99"):
                cells.append(f"{st[q] * 1e3:>8.3g}m" if q in st
                             else f"{'-':>9}")
            lines.append(" ".join(cells))
    for ln in lines:
        print(ln, file=file)
    return lines
