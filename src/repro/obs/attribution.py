"""Aggregate SLOW-blame reporting over merged traces.

:mod:`repro.obs.critical_path` answers "why was *this* request slow";
this module folds every request's tiled timeline into the fleet view:

- :func:`analyze_requests` — critical paths for every complete request
  in a trace;
- :func:`slow_report` — per-SLO-tier totals, per-class shares and
  latency percentiles, with attribution coverage (min/mean fraction) and
  the trace's lossy flag surfaced — the ``--slow-report`` CLI payload;
- :func:`fold_into_counters` — feed per-request per-class seconds into
  the PR 6 histogram counters (``/obs{blame/<tier>}/<class>``), so
  p50/p95/p99 *blame* is queryable live through ``query_counters`` /
  the fleet sampler exactly like any other counter — no trace file in
  hand required once the fold has run;
- :func:`diff_reports` — A/B two reports (the ``--diff`` CLI): per-tier
  per-class deltas for "did the optimization move waiting into work?".
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional

from repro.obs.critical_path import (CLASS_NAMES, SLOW_CLASSES, CriticalPath,
                                     TraceIndex, critical_path, request_ids)

__all__ = ["analyze_requests", "slow_report", "fold_into_counters",
           "diff_reports", "format_report", "format_critical_path",
           "UNTIERED"]

UNTIERED = "untiered"


def analyze_requests(tr: Dict[str, Any],
                     reqs: Optional[List[str]] = None
                     ) -> Dict[str, CriticalPath]:
    """Critical paths for every (or the given) complete request tags."""
    idx = tr if isinstance(tr, TraceIndex) else TraceIndex(tr)
    out: Dict[str, CriticalPath] = {}
    for tag in (reqs if reqs is not None else request_ids(idx)):
        cp = critical_path(idx, tag)
        if cp is not None:
            out[tag] = cp
    return out


def _percentile(sorted_vals: List[float], q: float) -> float:
    if not sorted_vals:
        return 0.0
    i = min(len(sorted_vals) - 1, max(0, int(q * len(sorted_vals))))
    return sorted_vals[i]


def slow_report(tr: Dict[str, Any],
                cps: Optional[Dict[str, CriticalPath]] = None
                ) -> Dict[str, Any]:
    """Fleet blame report: per SLO tier, where did the wall time go."""
    idx = tr if isinstance(tr, TraceIndex) else TraceIndex(tr)
    cps = analyze_requests(idx) if cps is None else cps
    tiers: Dict[str, List[CriticalPath]] = {}
    for cp in cps.values():
        tiers.setdefault(cp.slo or UNTIERED, []).append(cp)

    # per-locality drop totals ("{pid}/{thread}" keys folded by pid) so a
    # lossy trace's header says *how much* each locality's rings lost
    drops_by_loc: Dict[str, int] = {}
    for key, n in getattr(idx, "ring_drops", {}).items():
        loc = str(key).split("/", 1)[0]
        drops_by_loc[loc] = drops_by_loc.get(loc, 0) + int(n)
    report: Dict[str, Any] = {"requests": len(cps), "lossy": idx.lossy,
                              "ring_drops": drops_by_loc, "tiers": {}}
    for tier, group in sorted(tiers.items()):
        totals = sorted(cp.total_us for cp in group)
        by_class = {CLASS_NAMES[c]: sum(cp.by_class[c] for cp in group)
                    for c in SLOW_CLASSES}
        grand = sum(by_class.values()) or 1.0
        report["tiers"][tier] = {
            "count": len(group),
            "total_us": sum(totals),
            "by_class_us": by_class,
            "shares": {k: v / grand for k, v in by_class.items()},
            "latency_us": {"p50": _percentile(totals, 0.50),
                           "p95": _percentile(totals, 0.95),
                           "p99": _percentile(totals, 0.99)},
            "attributed_fraction": {
                "min": min(cp.fraction for cp in group),
                "mean": sum(cp.fraction for cp in group) / len(group),
            },
            "residual_us": sum(cp.residual_us for cp in group),
            "clamped_count": sum(cp.clamped_count for cp in group),
        }
    return report


def fold_into_counters(cps: Dict[str, CriticalPath], registry=None) -> int:
    """Feed per-request blame into live histogram counters.

    One histogram per (tier, class): ``/obs{blame/<tier>}/<class>`` in
    *seconds*, plus ``.../total`` for end-to-end latency — the same
    log-bucketed histograms the serve timers use, so the fleet sampler
    and ``print_counter_report`` pick up p50/p95/p99 blame with zero new
    plumbing.  Returns how many requests were folded."""
    from repro.core import counters as _counters

    reg = registry if registry is not None else _counters.default()
    for cp in cps.values():
        tier = cp.slo or UNTIERED
        for c in SLOW_CLASSES:
            reg.histogram(f"/obs{{blame/{tier}}}/{CLASS_NAMES[c]}").add(
                cp.by_class[c] * 1e-6)
        reg.histogram(f"/obs{{blame/{tier}}}/total").add(cp.total_us * 1e-6)
    return len(cps)


def diff_reports(a: Dict[str, Any], b: Dict[str, Any]) -> Dict[str, Any]:
    """B minus A, per tier per class (µs and share deltas)."""
    out: Dict[str, Any] = {"tiers": {}}
    for tier in sorted(set(a.get("tiers", {})) | set(b.get("tiers", {}))):
        ta = a.get("tiers", {}).get(tier, {})
        tb = b.get("tiers", {}).get(tier, {})
        classes = sorted(set(ta.get("by_class_us", {}))
                         | set(tb.get("by_class_us", {})))
        out["tiers"][tier] = {
            "count": (tb.get("count", 0) - ta.get("count", 0)),
            "delta_us": {c: (tb.get("by_class_us", {}).get(c, 0.0)
                             - ta.get("by_class_us", {}).get(c, 0.0))
                         for c in classes},
            "delta_share": {c: (tb.get("shares", {}).get(c, 0.0)
                                - ta.get("shares", {}).get(c, 0.0))
                            for c in classes},
            "delta_p99_us": (tb.get("latency_us", {}).get("p99", 0.0)
                             - ta.get("latency_us", {}).get("p99", 0.0)),
        }
    return out


def format_report(report: Dict[str, Any]) -> str:
    """Terminal rendering of :func:`slow_report` output."""
    drops = report.get("ring_drops") or {}
    drop_note = ""
    if report.get("lossy"):
        per_loc = ", ".join(f"L{loc}={n}" for loc, n in sorted(drops.items()))
        drop_note = (f"   [LOSSY TRACE — rings wrapped: dropped {per_loc}]"
                     if per_loc else "   [LOSSY TRACE — rings wrapped]")
    lines = [f"requests analyzed: {report.get('requests', 0)}" + drop_note]
    order = [CLASS_NAMES[c] for c in SLOW_CLASSES]
    for tier, t in sorted(report.get("tiers", {}).items()):
        lat = t.get("latency_us", {})
        frac = t.get("attributed_fraction", {})
        lines.append(
            f"\n[{tier}]  n={t['count']}  "
            f"p50={lat.get('p50', 0.0) / 1e3:.1f}ms  "
            f"p95={lat.get('p95', 0.0) / 1e3:.1f}ms  "
            f"p99={lat.get('p99', 0.0) / 1e3:.1f}ms  "
            f"attributed≥{frac.get('min', 0.0) * 100:.1f}%")
        for cname in order:
            us = t["by_class_us"].get(cname, 0.0)
            share = t["shares"].get(cname, 0.0)
            bar = "#" * int(share * 40)
            lines.append(f"  {cname:<10} {us / 1e3:>10.2f}ms "
                         f"{share * 100:>5.1f}%  {bar}")
        if t.get("clamped_count"):
            lines.append(f"  (clock clamps: {t['clamped_count']}, "
                         f"residual {t['residual_us'] / 1e3:.2f}ms)")
    return "\n".join(lines)


def format_critical_path(cp) -> str:
    """Terminal rendering of one request's tiled timeline."""
    s = cp.summary()
    lines = [f"request {cp.req}  (tier: {cp.slo or UNTIERED})  "
             f"total {cp.total_us / 1e3:.2f}ms  "
             f"attributed {cp.fraction * 100:.1f}%  "
             f"localities {s['localities']}"]
    for iv in cp.intervals:
        dur = iv.t1 - iv.t0
        lines.append(f"  {iv.t0 - cp.t0:>10.0f}us  "
                     f"{CLASS_NAMES[iv.cls]:<10} {dur:>10.0f}us  "
                     f"L{iv.pid}  {iv.what}")
    if cp.clamped_count:
        lines.append(f"  clock clamps: {cp.clamped_count} "
                     f"({cp.clamped_us:.0f}us)")
    by = s["by_class_us"]
    lines.append("  -- " + "  ".join(
        f"{k}={v / 1e3:.2f}ms" for k, v in by.items() if v > 0))
    return "\n".join(lines)
