"""Persisted counter timelines — every run leaves a queryable record.

The sampler's rings answer "what happened in the last four minutes"; this
module answers "what happened during *that run last Tuesday*".  A
:class:`TimelineWriter` attached to a :class:`repro.obs.sampler.
FleetSampler` appends one JSONL record per sweep:

    {"kind": "header", "version": 1, "pattern": "*", ...}     # line 1
    {"t": 12.03, "wall": 1754650000.1, "stride": 1,
     "sweep": {"0": {"/scheduler{default}/idle-rate": 0.12, ...}},
     "errors": []}                                            # per sweep

**Bounded by stride-doubling downsample** — the file can never grow
without limit: when the retained record count would exceed
``max_records`` the writer drops every second retained record, doubles
its sampling stride (record every 2nd sweep, then every 4th, ...), and
atomically rewrites the file.  A week-long serve run converges to ≤
``max_records`` records at coarser-and-coarser resolution instead of an
unbounded log — same trick trace rings use for time, applied to disk.

Readers: :func:`read_timeline` / :func:`series` for plotting,
:func:`summarize` for the ``repro.obs.analyze --timeline`` report
(per-counter stats plus *derived* per-pool utilization from the
``time/busy`` / ``time/idle`` cumulative counters — the windowed form of
idle-rate that survives restarts).
"""

from __future__ import annotations

import json
import os
import re
import time
from typing import Any, Dict, List, Optional, Tuple

VERSION = 1


class TimelineWriter:
    """Append-only JSONL counter timeline with stride-doubling bound.

    ``append(sweep)`` takes the same shape ``FleetSampler.sample_once``
    works from: ``{locality: [(name, value), ...]}`` with dead peers as
    ``{"error": ...}`` markers (recorded in the ``errors`` list — an
    unreachable peer is part of the run's history too).
    """

    def __init__(self, path: str, pattern: str = "*",
                 interval: Optional[float] = None,
                 max_records: int = 4096,
                 meta: Optional[Dict[str, Any]] = None):
        if max_records < 2:
            raise ValueError("max_records must be >= 2")
        self.path = path
        self.max_records = max_records
        self.stride = 1
        self._seen = 0          # sweeps offered
        self.records_written = 0
        self.compactions = 0
        self._records: List[Dict[str, Any]] = []  # retained (== file body)
        self._header = {"kind": "header", "version": VERSION,
                        "pattern": pattern, "interval": interval,
                        "started_wall": time.time(),
                        "max_records": max_records}
        if meta:
            self._header["meta"] = dict(meta)
        parent = os.path.dirname(os.path.abspath(path))
        if parent:
            os.makedirs(parent, exist_ok=True)
        self._fh = open(path, "w", encoding="utf-8")
        self._fh.write(json.dumps(self._header) + "\n")
        self._fh.flush()

    def append(self, sweep: Dict[int, Any],
               now: Optional[float] = None) -> bool:
        """Offer one sweep; returns True if it was recorded (stride may
        skip it)."""
        if self._fh is None:
            raise ValueError("timeline writer is closed")
        self._seen += 1
        if (self._seen - 1) % self.stride != 0:
            return False
        values: Dict[str, Dict[str, float]] = {}
        errors: List[int] = []
        for loc, pairs in sweep.items():
            if isinstance(pairs, dict):      # {"error": ...} marker
                errors.append(int(loc))
                continue
            values[str(loc)] = {name: float(v) for name, v in pairs}
        rec = {"t": now if now is not None else time.perf_counter(),
               "wall": time.time(), "stride": self.stride,
               "sweep": values, "errors": sorted(errors)}
        self._records.append(rec)
        if len(self._records) > self.max_records:
            self._compact()
        else:
            self._fh.write(json.dumps(rec) + "\n")
            self._fh.flush()
        self.records_written += 1
        return True

    def _compact(self) -> None:
        """Halve resolution: keep every 2nd retained record (newest
        kept), double the stride, rewrite the file atomically."""
        self._records = self._records[1::2]
        self.stride *= 2
        self.compactions += 1
        tmp = self.path + ".tmp"
        with open(tmp, "w", encoding="utf-8") as fh:
            fh.write(json.dumps(self._header) + "\n")
            for rec in self._records:
                fh.write(json.dumps(rec) + "\n")
        self._fh.close()
        os.replace(tmp, self.path)
        self._fh = open(self.path, "a", encoding="utf-8")

    def close(self) -> None:
        fh, self._fh = self._fh, None
        if fh is not None:
            fh.close()

    def __enter__(self) -> "TimelineWriter":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


# ------------------------------------------------------------------ readers
def read_timeline(path: str) -> Tuple[Dict[str, Any], List[Dict[str, Any]]]:
    """Load ``(header, records)``; raises on a file that isn't a
    timeline (wrong header) so the analyzer fails loudly, not weirdly."""
    header: Optional[Dict[str, Any]] = None
    records: List[Dict[str, Any]] = []
    with open(path, "r", encoding="utf-8") as fh:
        for line in fh:
            line = line.strip()
            if not line:
                continue
            obj = json.loads(line)
            if header is None:
                if obj.get("kind") != "header":
                    raise ValueError(f"{path}: not a timeline (no header)")
                if obj.get("version") != VERSION:
                    raise ValueError(f"{path}: timeline version "
                                     f"{obj.get('version')} != {VERSION}")
                header = obj
            else:
                records.append(obj)
    if header is None:
        raise ValueError(f"{path}: empty file")
    return header, records


def series(records: List[Dict[str, Any]], locality: int,
           name: str) -> List[Tuple[float, float]]:
    out: List[Tuple[float, float]] = []
    key = str(locality)
    for rec in records:
        vals = rec.get("sweep", {}).get(key)
        if vals is not None and name in vals:
            out.append((rec["t"], vals[name]))
    return out


def _rate(points: List[Tuple[float, float]]) -> float:
    """Positive-delta rate over the whole series (reset-tolerant, same
    contract as ``FleetSampler.rate``)."""
    if len(points) < 2:
        return 0.0
    span = points[-1][0] - points[0][0]
    if span <= 0.0:
        return 0.0
    total = 0.0
    for (_, v0), (_, v1) in zip(points, points[1:]):
        d = v1 - v0
        total += d if d >= 0.0 else v1
    return total / span


_POOL_TIME_RE = re.compile(r"^/scheduler\{(?P<pool>[^}]*)\}/time/(busy|idle)$")


def summarize(path: str) -> Dict[str, Any]:
    """Digest a timeline: per-(locality, counter) stats plus derived
    per-pool utilization/idle-rate from the cumulative busy/idle clocks."""
    header, records = read_timeline(path)
    counters: Dict[Tuple[int, str], Dict[str, float]] = {}
    keys: set = set()
    error_sweeps = 0
    for rec in records:
        if rec.get("errors"):
            error_sweeps += 1
        for loc_s, vals in rec.get("sweep", {}).items():
            for name in vals:
                keys.add((int(loc_s), name))
    for loc, name in sorted(keys):
        pts = series(records, loc, name)
        vs = [v for _, v in pts]
        counters[(loc, name)] = {
            "n": len(pts), "first": vs[0], "last": vs[-1],
            "min": min(vs), "max": max(vs),
            "mean": sum(vs) / len(vs), "rate": _rate(pts),
        }
    # derived windowed utilization per (locality, pool): the ratio of the
    # busy-clock rate to total-clock rate over the recorded span
    derived: Dict[Tuple[int, str], Dict[str, float]] = {}
    for (loc, name), st in counters.items():
        m = _POOL_TIME_RE.match(name)
        if not m or not name.endswith("/busy"):
            continue
        pool = m.group("pool")
        idle = counters.get((loc, f"/scheduler{{{pool}}}/time/idle"))
        if idle is None:
            continue
        busy_d = st["last"] - st["first"]
        idle_d = idle["last"] - idle["first"]
        total = busy_d + idle_d
        if total <= 0.0:
            continue
        derived[(loc, pool)] = {"utilization": busy_d / total,
                                "idle_rate": idle_d / total,
                                "busy_s": busy_d, "idle_s": idle_d}
    span = (records[-1]["t"] - records[0]["t"]) if len(records) > 1 else 0.0
    return {"header": header, "records": len(records), "span_s": span,
            "final_stride": records[-1]["stride"] if records else 1,
            "error_sweeps": error_sweeps,
            "counters": counters, "utilization": derived}


def format_summary(summary: Dict[str, Any]) -> List[str]:
    """Human lines for ``repro.obs.analyze --timeline``."""
    hdr = summary["header"]
    lines = [f"timeline: pattern={hdr.get('pattern')!r} "
             f"records={summary['records']} span={summary['span_s']:.1f}s "
             f"stride={summary['final_stride']} "
             f"error_sweeps={summary['error_sweeps']}"]
    if summary["utilization"]:
        lines.append(f"{'pool utilization':<34} {'util':>8} {'idle':>8} "
                     f"{'busy_s':>10} {'idle_s':>10}")
        for (loc, pool), d in sorted(summary["utilization"].items()):
            lines.append(f"L{loc} scheduler{{{pool}}}"[:34].ljust(34) + " "
                         f"{d['utilization']:>8.1%} {d['idle_rate']:>8.1%} "
                         f"{d['busy_s']:>10.2f} {d['idle_s']:>10.2f}")
    lines.append(f"{'counter':<58} {'n':>5} {'last':>12} {'mean':>12} "
                 f"{'rate/s':>10}")
    for (loc, name), st in sorted(summary["counters"].items()):
        lines.append(f"L{loc} {name:<55.55}"[:58].ljust(58) + " "
                     f"{st['n']:>5d} {st['last']:>12.4g} "
                     f"{st['mean']:>12.4g} {st['rate']:>10.4g}")
    return lines
