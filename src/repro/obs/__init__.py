"""repro.obs — APEX-style observability for the distributed runtime.

Three pillars (see DESIGN.md §10):

- :mod:`repro.obs.trace`   — per-thread ring-buffer task/parcel tracer,
  off by default, near-zero disabled cost;
- :mod:`repro.obs.export`  — fleet trace collection over the parcelport,
  clock-corrected, merged into one Perfetto-loadable Chrome trace;
- :mod:`repro.obs.sampler` — counter time-series (histories, rates) and
  the ``--print-counters`` fleet report.

Only :mod:`trace` is imported eagerly: it is a leaf the core runtime
instruments, so this package must never pull in the net tier at import
time (export/sampler load on first attribute access).
"""

from repro.obs import trace  # noqa: F401 — the leaf recorder

__all__ = ["trace", "export", "sampler"]


def __getattr__(name):
    if name in ("export", "sampler"):
        import importlib

        return importlib.import_module(f"repro.obs.{name}")
    raise AttributeError(f"module 'repro.obs' has no attribute {name!r}")
