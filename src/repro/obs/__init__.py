"""repro.obs — APEX-style observability for the distributed runtime.

Two tiers (see DESIGN.md §10):

**Recording** —

- :mod:`repro.obs.trace`   — per-thread ring-buffer task/parcel tracer,
  off by default, near-zero disabled cost;
- :mod:`repro.obs.export`  — fleet trace collection over the parcelport,
  clock-corrected, merged into one Perfetto-loadable Chrome trace;
- :mod:`repro.obs.sampler` — counter time-series (histories, rates) and
  the ``--print-counters`` fleet report.

**Export** (ISSUE 10) —

- :mod:`repro.obs.metrics`    — OpenMetrics/Prometheus text exposition
  of the fleet counter tree (the listener lives in ``repro.net.httpd``);
- :mod:`repro.obs.timeseries` — append-only JSONL counter timelines,
  bounded by stride-doubling downsample;
- :mod:`repro.obs.top`        — the ``python -m repro.obs.top`` live
  fleet dashboard ("hpx-top").

**Analysis** (ISSUE 9) —

- :mod:`repro.obs.critical_path` — per-request dependency-path
  reconstruction with SLOW-taxonomy interval blame;
- :mod:`repro.obs.attribution`   — aggregate per-tier reports, folded
  into live histogram counters;
- :mod:`repro.obs.recorder`      — anomaly-triggered fleet flight
  recorder (controller-driven ``dump_trace`` actuator);
- :mod:`repro.obs.analyze`       — the ``python -m repro.obs.analyze``
  CLI.

Only :mod:`trace` is imported eagerly: it is a leaf the core runtime
instruments, so this package must never pull in the net tier at import
time (everything else loads on first attribute access).
"""

from repro.obs import trace  # noqa: F401 — the leaf recorder

__all__ = ["trace", "export", "sampler", "critical_path", "attribution",
           "recorder", "analyze", "metrics", "timeseries", "top"]

_LAZY = ("export", "sampler", "critical_path", "attribution", "recorder",
         "analyze", "metrics", "timeseries", "top")


def __getattr__(name):
    if name in _LAZY:
        import importlib

        return importlib.import_module(f"repro.obs.{name}")
    raise AttributeError(f"module 'repro.obs' has no attribute {name!r}")
