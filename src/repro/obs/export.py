"""Fleet-wide trace collection + Chrome trace-event export.

The flight-recorder read-out: locality 0 pulls every locality's per-thread
ring buffers over the parcelport (plain actions — the trace rides the same
wire it instruments), corrects worker clocks onto the root's
``time.perf_counter`` domain via a min-RTT handshake, and merges everything
into one Chrome trace-event JSON that loads directly in Perfetto
(https://ui.perfetto.dev) or ``chrome://tracing``:

- localities render as *processes* (``pid`` = locality id, named via
  ``process_name`` metadata), threads as *tracks*;
- cross-locality parcels render as *flow arrows*: the send span carries a
  flow-start (``ph:"s"``), the remote execute span the matching
  flow-finish (``ph:"f"``, ``bp:"e"``) with the same id — Perfetto draws
  the arrow from sender to receiver;
- serve requests render as *async spans* (``b``/``n``/``e``) spanning
  admission → prefill → decode steps → finish.

Clock correction: ``time.perf_counter`` has a per-process arbitrary epoch,
so worker timestamps are meaningless next to the root's.  For each worker
we run a few RTT probes (read the worker's clock, bracket it with local
reads) and keep the probe with the smallest RTT:
``offset = w - (t0 + t1) / 2`` — the classic Cristian handshake.  Worker
events are shifted by ``-offset`` into the root's domain; the residual
error is bounded by half the best RTT (tens of µs on loopback).
"""

from __future__ import annotations

import json
import time
from typing import Any, Dict, List, Optional

from repro.core import parcel as _parcel
from repro.obs import trace as _trace


# ---------------------------------------------------------- fleet actions
@_parcel.action
def _obs_enable(rt, capacity: int) -> bool:
    _trace.enable(capacity=capacity)
    return True


@_parcel.action
def _obs_disable(rt) -> bool:
    _trace.disable()
    return True


@_parcel.action
def _obs_clear(rt) -> bool:
    _trace.clear()
    return True


@_parcel.action
def _obs_collect(rt) -> List[Dict[str, Any]]:
    """Snapshot this locality's ring buffers (raw event tuples)."""
    return _trace.export_buffers()


# Fault-injection hook for clock-correction tests: a skew added to the
# clock *as reported to probes* emulates the correction error left by
# asymmetric link latency (the Cristian midpoint assumes symmetry).
_probe_skew = 0.0


def set_probe_skew(delta: float) -> None:
    global _probe_skew
    _probe_skew = float(delta)


@_parcel.action
def _obs_set_probe_skew(rt, delta: float) -> bool:
    set_probe_skew(delta)
    return True


@_parcel.action
def _obs_clock(rt) -> float:
    """Read this locality's monotonic clock (the handshake probe)."""
    return time.perf_counter() + _probe_skew


def clock_offset(net, locality: int, probes: int = 5) -> float:
    """``remote_perf_counter - local_perf_counter`` for ``locality``,
    estimated from the minimum-RTT probe of ``probes`` round trips."""
    from repro.net import remote as _remote

    if locality == net.locality:
        return 0.0
    best_rtt, best_off = float("inf"), 0.0
    for _ in range(probes):
        t0 = time.perf_counter()
        w = _remote.run_on(locality, _obs_clock).get(timeout=30)
        t1 = time.perf_counter()
        rtt = t1 - t0
        if rtt < best_rtt:
            best_rtt, best_off = rtt, w - (t0 + t1) / 2.0
    return best_off


def enable_fleet(net=None, capacity: int = _trace.DEFAULT_CAPACITY) -> None:
    """Turn tracing on at every locality (local-only when ``net`` is None)."""
    _trace.enable(capacity=capacity)
    if net is not None:
        from repro.net import remote as _remote

        for loc in range(net.n_localities):
            if loc != net.locality:
                _remote.run_on(loc, _obs_enable, capacity).get(timeout=30)


def disable_fleet(net=None) -> None:
    _trace.disable()
    if net is not None:
        from repro.net import remote as _remote

        for loc in range(net.n_localities):
            if loc != net.locality:
                _remote.run_on(loc, _obs_disable).get(timeout=30)


def clear_fleet(net=None) -> None:
    """Drop every locality's ring buffers — the flight recorder arms from
    an empty window so a dump's evidence has a well-defined start."""
    _trace.clear()
    if net is not None:
        from repro.net import remote as _remote

        for loc in range(net.n_localities):
            if loc != net.locality:
                _remote.run_on(loc, _obs_clear).get(timeout=30)


# ------------------------------------------------------------- conversion
def _chrome_events(buffers: List[Dict[str, Any]], pid: int,
                   offset: float) -> List[Dict[str, Any]]:
    """Raw per-thread event tuples → Chrome trace-event dicts.

    ``offset`` maps this locality's clock into the root's domain
    (subtracted); timestamps convert to microseconds, the Chrome unit.
    """
    out: List[Dict[str, Any]] = []
    for buf in buffers:
        tid = int(buf["tid"]) & 0x7FFFFFFF  # Chrome wants smallish ints
        for ph, name, cat, ts, dur, eid, args in buf["events"]:
            ev: Dict[str, Any] = {
                "name": name, "cat": cat, "ph": ph, "pid": pid, "tid": tid,
                "ts": (ts - offset) * 1e6,
            }
            if ph == "X":
                ev["dur"] = dur * 1e6
                if eid is not None:
                    # the span's own id, in the same "loc:seq" form that
                    # child spans reference via args["parent"] — the
                    # analyzer's parent->child link
                    sid = f"{eid[0]}:{eid[1]}"
                    if args:
                        ev["args"] = dict(args)
                        ev["args"]["sid"] = sid
                    else:
                        ev["args"] = {"sid": sid}
                    out.append(ev)
                    continue
            elif ph == "i":
                ev["s"] = "t"  # instant scoped to its thread
            elif ph in ("s", "f"):
                # flow id: globally unique as "origin_locality:seq"
                ev["id"] = f"{eid[0]}:{eid[1]}"
                if ph == "f":
                    ev["bp"] = "e"  # bind to the enclosing slice
            elif ph in ("b", "n", "e"):
                # async events match on (cat, id); scope ids per locality
                ev["id"] = f"{pid}:{eid}"
            if args:
                ev["args"] = dict(args)
            out.append(ev)
        if buf.get("dropped"):
            out.append({"name": "trace/dropped", "cat": "obs", "ph": "i",
                        "pid": pid, "tid": tid, "ts": 0.0, "s": "t",
                        "args": {"count": buf["dropped"]}})
    return out


def _metadata(buffers: List[Dict[str, Any]], pid: int) -> List[Dict[str, Any]]:
    meta = [{"name": "process_name", "ph": "M", "pid": pid,
             "args": {"name": f"locality#{pid}"}}]
    for buf in buffers:
        meta.append({"name": "thread_name", "ph": "M", "pid": pid,
                     "tid": int(buf["tid"]) & 0x7FFFFFFF,
                     "args": {"name": buf["thread_name"]}})
    return meta


# --------------------------------------------------------------- assembly
def merged_trace(net=None, probes: int = 5) -> Dict[str, Any]:
    """One merged Chrome trace across the fleet (or just this process).

    With ``net`` (a bootstrapped :class:`repro.net.NetRuntime`, normally
    the root), every other locality's buffers are pulled over the
    parcelport and clock-corrected; flow events recorded on both ends of
    each parcel stitch the localities together.
    """
    events: List[Dict[str, Any]] = []
    ring_drops: Dict[str, int] = {}
    local_pid = 0
    if net is not None:
        local_pid = net.locality
    else:
        try:
            from repro.core import agas as _agas

            a = _agas.peek()
            local_pid = a.locality if a is not None else _agas._default_locality
        except Exception:
            local_pid = 0

    def _account_drops(bufs: List[Dict[str, Any]], pid: int) -> None:
        for buf in bufs:
            if buf.get("dropped"):
                key = f"{pid}/{buf.get('thread_name', buf.get('tid'))}"
                ring_drops[key] = ring_drops.get(key, 0) + int(buf["dropped"])

    if net is not None:
        from repro.net import remote as _remote

        for loc in range(net.n_localities):
            if loc == net.locality:
                continue
            off = clock_offset(net, loc, probes=probes)
            bufs = _remote.run_on(loc, _obs_collect).get(timeout=60)
            _account_drops(bufs, loc)
            events.extend(_metadata(bufs, loc))
            events.extend(_chrome_events(bufs, loc, offset=off))

    # snapshot the collector's own buffers LAST: the collection round
    # trips above record send spans here whose execute spans are already
    # in the remote snapshots — collecting locally first would orphan them
    local = _trace.export_buffers()
    _account_drops(local, local_pid)
    events.extend(_metadata(local, local_pid))
    events.extend(_chrome_events(local, local_pid, offset=0.0))

    events.sort(key=lambda e: (e["ph"] != "M", e.get("ts", 0.0)))
    tr: Dict[str, Any] = {"traceEvents": events, "displayTimeUnit": "ms"}
    if ring_drops:
        # any wrapped ring means the trace is a *suffix* of reality —
        # analyses must not claim completeness, so say so in the header
        tr["lossy"] = True
        tr["ring_drops"] = ring_drops
    return tr


def export_chrome_trace(path: str, net=None, probes: int = 5) -> Dict[str, Any]:
    """Write the merged fleet trace to ``path`` (Perfetto-loadable JSON);
    returns the trace dict for immediate inspection."""
    tr = merged_trace(net=net, probes=probes)
    with open(path, "w") as f:
        json.dump(tr, f)
    return tr


def flow_links(tr: Dict[str, Any]) -> Dict[str, Dict[str, Optional[int]]]:
    """Flow id → ``{"src": sender pid, "dst": receiver pid}`` (None when
    one side is missing) — the causal-link audit used by tests and the
    bench harness to prove cross-locality stitching actually happened."""
    links: Dict[str, Dict[str, Optional[int]]] = {}
    for ev in tr["traceEvents"]:
        if ev["ph"] in ("s", "f"):
            slot = links.setdefault(ev["id"], {"src": None, "dst": None})
            slot["src" if ev["ph"] == "s" else "dst"] = ev["pid"]
    return links
