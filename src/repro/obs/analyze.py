"""Trace analysis CLI — ``python -m repro.obs.analyze``.

Makes merged Chrome traces consumable without a browser:

    # where did the fleet's time go, per SLO tier
    python -m repro.obs.analyze results/obs_trace_demo.json --slow-report

    # one request's tiled admission->finish timeline
    python -m repro.obs.analyze trace.json --critical-path r0:3

    # list analyzable request tags
    python -m repro.obs.analyze trace.json --requests

    # A/B two traces (did the fix move waiting into work?)
    python -m repro.obs.analyze --diff before.json after.json

    # digest a persisted counter timeline (--timeline from a launcher)
    python -m repro.obs.analyze --timeline results/serve_timeline.jsonl

``--json`` emits machine-readable output for CI diffing.  Exit status is
non-zero when the requested analysis has nothing to chew on (unknown
request tag, no complete requests) so scripts fail loudly.
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Any, Dict

from repro.obs import attribution as _attribution
from repro.obs import critical_path as _cp

__all__ = ["main"]


def _load(path: str) -> Dict[str, Any]:
    with open(path) as f:
        return json.load(f)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.analyze",
        description="Critical-path / SLOW-blame analysis of merged traces")
    ap.add_argument("trace", nargs="?", help="merged Chrome trace JSON")
    ap.add_argument("--critical-path", metavar="REQ",
                    help="print REQ's tiled SLOW timeline")
    ap.add_argument("--slow-report", action="store_true",
                    help="aggregate per-tier blame report")
    ap.add_argument("--requests", action="store_true",
                    help="list analyzable request tags")
    ap.add_argument("--diff", nargs=2, metavar=("A", "B"),
                    help="diff two traces' slow reports (B minus A)")
    ap.add_argument("--timeline", metavar="JSONL",
                    help="summarize a persisted counter timeline")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="machine-readable output")
    args = ap.parse_args(argv)

    if args.timeline:
        from repro.obs import timeseries as _ts

        try:
            summary = _ts.summarize(args.timeline)
        except (OSError, ValueError) as e:
            print(f"cannot read timeline {args.timeline!r}: {e}",
                  file=sys.stderr)
            return 1
        if args.as_json:
            # tuple keys aren't JSON — flatten to "L{loc} {name}" strings
            out = dict(summary)
            out["counters"] = {f"L{loc} {name}": st for (loc, name), st
                               in summary["counters"].items()}
            out["utilization"] = {f"L{loc} {pool}": d for (loc, pool), d
                                  in summary["utilization"].items()}
            print(json.dumps(out, indent=2))
        else:
            print("\n".join(_ts.format_summary(summary)))
        return 0 if summary["records"] else 1

    if args.diff:
        ra = _attribution.slow_report(_load(args.diff[0]))
        rb = _attribution.slow_report(_load(args.diff[1]))
        d = _attribution.diff_reports(ra, rb)
        if args.as_json:
            print(json.dumps(d, indent=2))
        else:
            for tier, t in sorted(d["tiers"].items()):
                print(f"[{tier}]  Δcount={t['count']:+d}  "
                      f"Δp99={t['delta_p99_us'] / 1e3:+.2f}ms")
                for c, us in sorted(t["delta_us"].items()):
                    share = t["delta_share"].get(c, 0.0)
                    print(f"  {c:<10} {us / 1e3:>+10.2f}ms "
                          f"{share * 100:>+6.1f}%")
        return 0

    if not args.trace:
        ap.error("a trace file is required (or use --diff A B)")
    tr = _load(args.trace)
    idx = _cp.TraceIndex(tr)

    if args.requests:
        tags = _cp.request_ids(idx)
        print(json.dumps(tags) if args.as_json else "\n".join(tags))
        return 0 if tags else 1

    if args.critical_path:
        cp = _cp.critical_path(idx, args.critical_path)
        if cp is None:
            known = _cp.request_ids(idx)
            print(f"request {args.critical_path!r} not found in trace "
                  f"({len(known)} analyzable: {known[:8]}...)",
                  file=sys.stderr)
            return 1
        if args.as_json:
            print(json.dumps(cp.summary(), indent=2))
        else:
            print(_attribution.format_critical_path(cp))
        return 0

    # default (and --slow-report): the aggregate blame report
    report = _attribution.slow_report(idx)
    if args.as_json:
        print(json.dumps(report, indent=2))
    else:
        print(_attribution.format_report(report))
    return 0 if report["requests"] else 1


if __name__ == "__main__":
    try:
        sys.exit(main())
    except BrokenPipeError:  # `... | head` closed stdout — not an error
        import os

        # point stdout at devnull so the interpreter's exit-time flush
        # doesn't raise the same error again as "Exception ignored"
        os.dup2(os.open(os.devnull, os.O_WRONLY), sys.stdout.fileno())
        sys.exit(0)
