"""OpenMetrics exposition — the counter tree as a Prometheus scrape.

HPX publishes ``/threads{locality#0/total}/idle-rate`` and expects an
operator (or Grafana) to be watching; our equivalent is this module.  A
scrape walks the *fleet-wide* counter tree via the fault-tolerant sweep
form of ``net.query_counter_export`` (one parcel round per locality, dead
peers degrade to ``repro_up 0`` instead of failing the scrape) and
renders Prometheus text format 0.0.4:

- counter-path grammar ``/object{instance}/rest`` maps to a metric name
  ``repro_<object>_<rest>`` plus labels mined from the path —
  ``/scheduler{default}/idle-rate`` → ``repro_scheduler_idle_rate{pool=
  "default",locality="0"}``; ``word#N`` segments anywhere (``engine#3``,
  ``victim#0``, ``peer#2``) become ``word="N"`` labels; ``/obs{blame/
  compute}`` becomes ``tier="compute"``.
- monotonic counters get the ``_total`` suffix and ``# TYPE counter``;
  the log-bucketed :class:`repro.core.counters.Histogram` renders as a
  *native* Prometheus histogram (cumulative ``_bucket{le=...}`` series,
  ``+Inf``, ``_sum``/``_count``), adjacent buckets merged down to
  ``BUCKET_CAP`` so a long-running timer can't bloat a scrape.

The HTTP listener itself lives in :mod:`repro.net.httpd` (only
``repro/net`` may open sockets); :class:`MetricsExporter` glues the two:
``MetricsExporter(net=net).start()`` on locality 0 and every scrape of
``/metrics`` sweeps the fleet live.  ``parse_prometheus_text`` is the
strict round-trip parser the tests (and ``obs.top --metrics``) use — it
enforces the format invariants (escaping, declared types, bucket
monotonicity, ``+Inf == _count``) rather than trusting the renderer.
"""

from __future__ import annotations

import math
import re
import threading
from typing import Any, Dict, List, Optional, Tuple

from repro.core import counters as _counters

# Prometheus text format 0.0.4 — what /metrics advertises
CONTENT_TYPE = "text/plain; version=0.0.4; charset=utf-8"

# max rendered buckets per histogram series (adjacent-merge above this)
BUCKET_CAP = 32

_COUNTER_RE = re.compile(r"^/(?P<obj>[^{/]+)\{(?P<inst>[^}]*)\}(?P<rest>(?:/.*)?)$")
_SEG_LABEL_RE = re.compile(r"^([A-Za-z_][\w-]*)#(\d+)$")
_NAME_OK_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")


def _sanitize(part: str) -> str:
    return re.sub(r"[^a-zA-Z0-9_]", "_", part)


def counter_to_metric(name: str) -> Tuple[str, Dict[str, str]]:
    """Map one counter path to ``(metric_base_name, labels)``.

    The base name carries no kind suffix — the renderer appends
    ``_total`` for counters and the histogram suffixes itself.
    """
    m = _COUNTER_RE.match(name)
    if m is None:  # counter outside the /obj{inst}/... grammar
        return "repro_" + _sanitize(name.strip("/")) or "repro_counter", {}
    obj, inst, rest = m.group("obj"), m.group("inst"), m.group("rest")
    labels: Dict[str, str] = {}
    plain_inst: List[str] = []
    if obj == "scheduler":
        labels["pool"] = inst
    elif inst.startswith("blame/"):
        labels["tier"] = inst[len("blame/"):]
    else:
        for seg in inst.split("/"):
            sm = _SEG_LABEL_RE.match(seg)
            if sm:
                labels[_sanitize(sm.group(1))] = sm.group(2)
            elif seg:
                plain_inst.append(seg)
        if plain_inst:
            labels["instance"] = "/".join(plain_inst)
    parts: List[str] = []
    for seg in rest.split("/"):
        if not seg:
            continue
        sm = _SEG_LABEL_RE.match(seg)
        if sm:
            labels[_sanitize(sm.group(1))] = sm.group(2)
        else:
            parts.append(_sanitize(seg))
    base = "repro_" + _sanitize(obj)
    if parts:
        base += "_" + "_".join(parts)
    return base, labels


# --------------------------------------------------------------- rendering
def _escape_label(v: str) -> str:
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _escape_help(v: str) -> str:
    return v.replace("\\", "\\\\").replace("\n", "\\n")


def _fmt(v: float) -> str:
    if v != v:  # NaN
        return "NaN"
    if v in (math.inf, -math.inf):
        return "+Inf" if v > 0 else "-Inf"
    if float(v).is_integer() and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


def _labels_str(labels: Dict[str, str]) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{_escape_label(str(v))}"'
                     for k, v in sorted(labels.items()))
    return "{" + inner + "}"


def _merge_buckets(buckets: List[Tuple[float, int]],
                   cap: int = BUCKET_CAP) -> List[Tuple[float, int]]:
    """Adjacent-merge down to ``cap`` buckets; counts are conserved and
    upper bounds keep their meaning (the survivor keeps the *higher*
    bound of each merged pair)."""
    out = list(buckets)
    while len(out) > cap:
        merged: List[Tuple[float, int]] = []
        it = iter(out)
        for lo in it:
            hi = next(it, None)
            if hi is None:
                merged.append(lo)
            else:
                merged.append((hi[0], lo[1] + hi[1]))
        out = merged
    return out


def _is_error_marker(result: Any) -> bool:
    """A sweep entry for a dead peer is ``{"error": repr}`` — counter
    names always start with ``/`` so the shapes can't collide."""
    return (isinstance(result, dict) and "error" in result
            and not any(str(k).startswith("/") for k in result))


def render_openmetrics(sweep: Dict[int, Any]) -> str:
    """Render one fleet export sweep (``{locality: {name: record}}`` with
    dead peers as ``{"error": ...}``) as Prometheus text format."""
    # family name → (type, help); samples grouped per family for one
    # TYPE/HELP header each, deterministic order for diffable scrapes
    families: Dict[str, Tuple[str, str]] = {}
    scalars: Dict[str, List[Tuple[Dict[str, str], float]]] = {}
    hists: Dict[str, List[Tuple[Dict[str, str], Dict[str, Any]]]] = {}
    up: Dict[int, int] = {}
    errors: Dict[int, int] = {}

    for loc in sorted(sweep):
        result = sweep[loc]
        if _is_error_marker(result):
            up[loc] = 0
            continue
        up[loc] = 1
        for cname in sorted(result):
            rec = result[cname]
            kind = rec.get("kind", "gauge")
            if kind == "error":
                errors[loc] = errors.get(loc, 0) + 1
                continue
            base, labels = counter_to_metric(cname)
            labels["locality"] = str(loc)
            if kind in ("histogram", "timer"):
                families.setdefault(base, ("histogram", cname))
                hists.setdefault(base, []).append((labels, rec))
            elif kind == "counter":
                name = base + "_total"
                families.setdefault(name, ("counter", cname))
                scalars.setdefault(name, []).append(
                    (labels, float(rec.get("value", 0.0))))
            else:
                families.setdefault(base, ("gauge", cname))
                scalars.setdefault(base, []).append(
                    (labels, float(rec.get("value", 0.0))))

    for loc, v in up.items():
        families.setdefault("repro_up", ("gauge", "locality reachable"))
        scalars.setdefault("repro_up", []).append(
            ({"locality": str(loc)}, float(v)))
    for loc, n in errors.items():
        families.setdefault("repro_scrape_counter_errors",
                            ("gauge", "counters that raised during export"))
        scalars.setdefault("repro_scrape_counter_errors", []).append(
            ({"locality": str(loc)}, float(n)))

    lines: List[str] = []
    for fam in sorted(families):
        ftype, fhelp = families[fam]
        lines.append(f"# HELP {fam} {_escape_help(fhelp)}")
        lines.append(f"# TYPE {fam} {ftype}")
        if ftype == "histogram":
            for labels, rec in hists[fam]:
                raw = rec.get("buckets") or []
                merged = _merge_buckets(raw)
                cum = 0
                for ub, cnt in merged:
                    cum += cnt
                    bl = dict(labels)
                    bl["le"] = _fmt(float(ub))
                    lines.append(f"{fam}_bucket{_labels_str(bl)} {cum}")
                bl = dict(labels)
                bl["le"] = "+Inf"
                count = int(rec.get("count", cum))
                lines.append(f"{fam}_bucket{_labels_str(bl)} {count}")
                lines.append(f"{fam}_sum{_labels_str(labels)} "
                             f"{_fmt(float(rec.get('sum', 0.0)))}")
                lines.append(f"{fam}_count{_labels_str(labels)} {count}")
        else:
            for labels, value in scalars[fam]:
                lines.append(f"{fam}{_labels_str(labels)} {_fmt(value)}")
    return "\n".join(lines) + "\n"


# ------------------------------------------------------------ the exporter
class MetricsExporter:
    """Serve ``/metrics`` from this process (locality 0 by convention).

    Each scrape is a *live* fleet sweep — no cache, no staleness window;
    Prometheus's own scrape interval is the sampling cadence.  With no
    net runtime the exporter degrades to single-locality (local registry
    only), which is what the bench harness and unit tests use.
    """

    def __init__(self, pattern: str = "*", host: str = "127.0.0.1",
                 port: int = 0, net=None,
                 registry: Optional[_counters.CounterRegistry] = None):
        self.pattern = pattern
        self.net = net
        self.registry = registry or _counters.default()
        self._host, self._port = host, port
        self._endpoint = None
        self._lock = threading.Lock()
        self.scrapes = 0

    def sweep(self) -> Dict[int, Any]:
        if self.net is None:
            return {0: self.registry.snapshot_export(self.pattern)}
        from repro.net import remote as _remote

        return _remote.query_counter_export(None, self.pattern)

    def scrape(self) -> str:
        with self._lock:
            self.scrapes += 1
        return render_openmetrics(self.sweep())

    # handler given to the net-tier listener
    def _handle(self, path: str):
        if path in ("/metrics", "/"):
            return 200, CONTENT_TYPE, self.scrape().encode("utf-8")
        return 404, "text/plain; charset=utf-8", b"try /metrics\n"

    @property
    def port(self) -> int:
        if self._endpoint is None:
            raise RuntimeError("exporter not started")
        return self._endpoint.port

    @property
    def url(self) -> str:
        return f"http://{self._host}:{self.port}/metrics"

    def start(self) -> "MetricsExporter":
        if self._endpoint is None:
            from repro.net.httpd import HttpEndpoint

            self._endpoint = HttpEndpoint(self._handle, host=self._host,
                                          port=self._port).start()
        return self

    def close(self) -> None:
        ep, self._endpoint = self._endpoint, None
        if ep is not None:
            ep.close()

    def __enter__(self) -> "MetricsExporter":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()


# ------------------------------------------------------- strict re-parser
_SAMPLE_RE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?:\{(?P<labels>.*)\})?"
    r"\s+(?P<value>\S+)(?:\s+\d+)?$")
_LABEL_RE = re.compile(r'([a-zA-Z_][a-zA-Z0-9_]*)="((?:[^"\\]|\\.)*)"')


def _unescape_label(v: str) -> str:
    out: List[str] = []
    i = 0
    while i < len(v):
        c = v[i]
        if c == "\\" and i + 1 < len(v):
            nxt = v[i + 1]
            out.append({"n": "\n", "\\": "\\", '"': '"'}.get(nxt, "\\" + nxt))
            i += 2
        else:
            out.append(c)
            i += 1
    return "".join(out)


def _parse_labels(raw: str) -> Dict[str, str]:
    labels: Dict[str, str] = {}
    pos = 0
    while pos < len(raw):
        m = _LABEL_RE.match(raw, pos)
        if m is None:
            raise ValueError(f"malformed label pair at {raw[pos:]!r}")
        labels[m.group(1)] = _unescape_label(m.group(2))
        pos = m.end()
        if pos < len(raw):
            if raw[pos] != ",":
                raise ValueError(f"expected ',' in labels at {raw[pos:]!r}")
            pos += 1
    return labels


def _parse_value(raw: str) -> float:
    if raw == "+Inf":
        return math.inf
    if raw == "-Inf":
        return -math.inf
    if raw == "NaN":
        return math.nan
    return float(raw)


def _family_of(sample_name: str, declared: Dict[str, str]) -> Optional[str]:
    if sample_name in declared:
        return sample_name
    for suffix in ("_bucket", "_sum", "_count"):
        if sample_name.endswith(suffix):
            base = sample_name[:-len(suffix)]
            if declared.get(base) == "histogram":
                return base
    return None


def parse_prometheus_text(text: str, strict: bool = True) -> Dict[str, Dict[str, Any]]:
    """Parse (and, when ``strict``, *validate*) Prometheus text format.

    Returns ``{family: {"type", "help", "samples": [(name, labels,
    value), ...]}}``.  Strict mode enforces what a real scraper would:
    every sample belongs to a declared ``# TYPE`` family, metric/label
    names are well-formed, histogram ``_bucket`` series are cumulative
    and monotone with a ``+Inf`` bucket equal to ``_count``, and counter
    samples carry the ``_total`` suffix with non-negative values.
    """
    declared: Dict[str, str] = {}
    families: Dict[str, Dict[str, Any]] = {}
    for lineno, line in enumerate(text.splitlines(), 1):
        if not line.strip():
            continue
        if line.startswith("# HELP "):
            _, _, rest = line.partition("# HELP ")
            name, _, help_text = rest.partition(" ")
            families.setdefault(name, {"type": None, "help": None,
                                       "samples": []})["help"] = help_text
            continue
        if line.startswith("# TYPE "):
            _, _, rest = line.partition("# TYPE ")
            name, _, ftype = rest.partition(" ")
            if strict and ftype not in ("counter", "gauge", "histogram",
                                        "summary", "untyped"):
                raise ValueError(f"line {lineno}: unknown type {ftype!r}")
            if strict and declared.get(name) not in (None, ftype):
                raise ValueError(f"line {lineno}: type redeclared for {name}")
            declared[name] = ftype
            families.setdefault(name, {"type": None, "help": None,
                                       "samples": []})["type"] = ftype
            continue
        if line.startswith("#"):
            continue  # plain comment
        m = _SAMPLE_RE.match(line)
        if m is None:
            raise ValueError(f"line {lineno}: malformed sample {line!r}")
        name = m.group("name")
        labels = _parse_labels(m.group("labels") or "")
        value = _parse_value(m.group("value"))
        fam = _family_of(name, declared)
        if fam is None:
            if strict:
                raise ValueError(
                    f"line {lineno}: sample {name!r} has no declared family")
            fam = name
            families.setdefault(fam, {"type": None, "help": None,
                                      "samples": []})
        if strict and not _NAME_OK_RE.match(name):
            raise ValueError(f"line {lineno}: bad metric name {name!r}")
        families[fam]["samples"].append((name, labels, value))

    if strict:
        _validate_families(families)
    return families


def _validate_families(families: Dict[str, Dict[str, Any]]) -> None:
    for fam, info in families.items():
        ftype = info["type"]
        if ftype == "counter":
            for name, _labels, value in info["samples"]:
                if not name.endswith("_total"):
                    raise ValueError(f"{fam}: counter sample {name!r} "
                                     "lacks _total suffix")
                if value < 0:
                    raise ValueError(f"{fam}: negative counter {value}")
        elif ftype == "histogram":
            # group by label-set minus 'le'
            series: Dict[Tuple, Dict[str, Any]] = {}
            for name, labels, value in info["samples"]:
                key = tuple(sorted((k, v) for k, v in labels.items()
                                   if k != "le"))
                s = series.setdefault(key, {"buckets": [], "sum": None,
                                            "count": None})
                if name.endswith("_bucket"):
                    if "le" not in labels:
                        raise ValueError(f"{fam}: _bucket without le label")
                    s["buckets"].append((_parse_value(labels["le"]), value))
                elif name.endswith("_sum"):
                    s["sum"] = value
                elif name.endswith("_count"):
                    s["count"] = value
            for key, s in series.items():
                buckets = sorted(s["buckets"])
                if not buckets or buckets[-1][0] != math.inf:
                    raise ValueError(f"{fam}{dict(key)}: missing +Inf bucket")
                last = -1.0
                for _ub, cum in buckets:
                    if cum < last:
                        raise ValueError(
                            f"{fam}{dict(key)}: non-monotone buckets")
                    last = cum
                if s["count"] is None or buckets[-1][1] != s["count"]:
                    raise ValueError(
                        f"{fam}{dict(key)}: +Inf bucket != _count")
