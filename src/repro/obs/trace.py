"""Task/parcel trace recorder — the APEX introspection tier (paper §2.4).

HPX ships with APEX, whose task timers and OTF2/Chrome exporters are what
the shared-memory task-scheduling study (Diehl et al., arXiv:2302.07191)
and the HPX+LCI parcel study (Yan et al., arXiv:2503.12774) use to answer
"where does the time go".  This module is the recorder half of our
adaptation: a **lock-cheap per-thread ring buffer** of trace events that
the instrumented subsystems append to —

- scheduler worker loop: one complete span per task (pool, steals);
- parcelport: serialize/send/recv/execute spans with wire byte counts,
  and *flow events* stitching a parcel's send span to its remote
  execution span;
- serve engine: per-request async spans (admission → prefill → every
  decode step → finish) so TTFT and inter-token latency fall out of the
  trace with no extra bookkeeping;
- trainer step loop and segmented-algorithm per-segment actions.

Cost model (the observability contract):

- **Disabled** (the default): every recording entry point checks the
  module-level ``_enabled`` flag first and returns immediately — no
  allocation, no clock read, no lock.  Instrumentation call sites on hot
  paths additionally guard with ``if trace._enabled:`` so the disabled
  cost is one attribute load + branch.
- **Enabled**: events append to a *per-thread* ring buffer (single
  writer, no lock on the append path; the global registry lock is taken
  once per thread, at buffer creation).  The ring overwrites the oldest
  events on wraparound and counts drops — tracing never blocks and never
  grows unbounded.

Trace context propagation: every span publishes ``(locality, span_id)``
as the thread's current context; the net tier copies it into the parcel
header (``tc``) so the receiving locality records a causally-linked child
span plus a Chrome flow-event pair (``ph:"s"`` at the sender inside the
send span, ``ph:"f"`` at the receiver inside the execute span) that
Perfetto draws as an arrow across localities.

This module is a leaf: no ``repro`` imports at module scope (the
scheduler imports it, so it must sit below everything).
"""

from __future__ import annotations

import itertools
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

# Module-level flag, checked before ANY event is recorded (the ISSUE's
# near-zero-disabled-cost contract).  Instrumentation sites read it as
# ``trace._enabled`` — one attribute load — before touching anything else.
_enabled = False

DEFAULT_CAPACITY = 65536

_lock = threading.Lock()
_buffers: List["TraceBuffer"] = []
_capacity = DEFAULT_CAPACITY
_epoch = 0          # bumped by clear(): stale thread-local buffers re-register
_locality = 0       # stamped into span/flow ids; refreshed by enable()
_seq = itertools.count(1)  # span / flow id allocator (process-wide)

_tls = threading.local()

# Event tuples: (ph, name, cat, ts, dur, id, args)
#   ph  — Chrome trace-event phase: "X" complete span, "i" instant,
#         "s"/"f" flow start/finish, "b"/"n"/"e" async begin/instant/end
#   id  — flow id (loc, seq) for s/f, async id (int) for b/n/e, else None
#   ts/dur in seconds (perf_counter domain); export converts to µs.


class TraceBuffer:
    """One thread's ring of trace events.  Single writer (the owning
    thread), lock-free append; readers (the exporter) take a snapshot and
    tolerate the benign race of the writer lapping the oldest slots."""

    __slots__ = ("events", "capacity", "idx", "tid", "thread_name", "epoch")

    def __init__(self, capacity: int, tid: int, thread_name: str, epoch: int):
        self.events: List[Optional[tuple]] = [None] * capacity
        self.capacity = capacity
        self.idx = 0  # monotone write cursor; slot = idx % capacity
        self.tid = tid
        self.thread_name = thread_name
        self.epoch = epoch

    def append(self, ev: tuple) -> None:
        i = self.idx
        self.events[i % self.capacity] = ev
        self.idx = i + 1

    def snapshot(self) -> Tuple[List[tuple], int]:
        """(events oldest-first, dropped-count).  Safe from any thread."""
        n = self.idx
        if n <= self.capacity:
            evs = self.events[:n]
        else:
            k = n % self.capacity
            evs = self.events[k:] + self.events[:k]
        return [e for e in evs if e is not None], max(0, n - self.capacity)


def _buf() -> TraceBuffer:
    b = getattr(_tls, "buf", None)
    if b is None or b.epoch != _epoch or b.capacity != _capacity:
        t = threading.current_thread()
        b = TraceBuffer(_capacity, t.ident or 0, t.name, _epoch)
        with _lock:
            _buffers.append(b)
        _tls.buf = b
    return b


def _detect_locality() -> int:
    try:
        from repro.core import agas as _agas

        a = _agas.peek()
        return a.locality if a is not None else _agas._default_locality
    except Exception:  # pragma: no cover - agas import failure
        return 0


# ------------------------------------------------------------------ control
def recorded_events() -> int:
    """Events currently resident across every thread's ring."""
    with _lock:
        bufs = list(_buffers)
    return sum(min(b.idx, b.capacity) for b in bufs)


def dropped_events() -> int:
    """Events overwritten by ring wraparound (lost to the exporter)."""
    with _lock:
        bufs = list(_buffers)
    return sum(max(0, b.idx - b.capacity) for b in bufs)


def _register_counters(locality: int) -> None:
    """Publish ring occupancy/drop gauges so lossiness is visible *live*
    (before any export) — ``/obs{locality#L}/trace/{events,dropped}``."""
    try:
        from repro.core import counters as _counters

        reg = _counters.default()
        prefix = f"/obs{{locality#{locality}}}/trace"
        reg.register_callable(f"{prefix}/events", recorded_events)
        reg.register_callable(f"{prefix}/dropped", dropped_events)
    except Exception:  # pragma: no cover - counters tier not initialised
        pass


def enable(capacity: int = DEFAULT_CAPACITY) -> None:
    """Turn the recorder on (idempotent).  ``capacity`` is per thread."""
    global _enabled, _capacity, _locality
    with _lock:
        _capacity = int(capacity)
    _locality = _detect_locality()
    _register_counters(_locality)
    _enabled = True


def disable() -> None:
    global _enabled
    _enabled = False


def enabled() -> bool:
    return _enabled


def clear() -> None:
    """Drop every recorded event (buffers re-register lazily)."""
    global _epoch
    with _lock:
        _epoch += 1
        _buffers.clear()


def new_id() -> Tuple[int, int]:
    """Allocate a globally-unique span/flow id: (locality, seq)."""
    return (_locality, next(_seq))


def current_context() -> Optional[Tuple[int, int]]:
    """The innermost open span's id on this thread (the trace context a
    parcel carries in its header), or None outside any span."""
    return getattr(_tls, "ctx", None)


class with_context:
    """Install a foreign trace context (the receiver side of propagation):
    spans opened inside become children of the remote parent."""

    __slots__ = ("ctx", "prev")

    def __init__(self, ctx: Optional[Tuple[int, int]]):
        self.ctx = tuple(ctx) if ctx is not None else None

    def __enter__(self) -> "with_context":
        self.prev = getattr(_tls, "ctx", None)
        _tls.ctx = self.ctx
        return self

    def __exit__(self, *exc) -> bool:
        _tls.ctx = self.prev
        return False


# ---------------------------------------------------------------- recording
class _Span:
    __slots__ = ("name", "cat", "args", "flow_in", "flow_out",
                 "t0", "sid", "prev")

    def __init__(self, name, cat, flow_in, flow_out, args):
        self.name = name
        self.cat = cat
        self.flow_in = flow_in
        self.flow_out = flow_out
        self.args = args

    def __enter__(self) -> "_Span":
        self.prev = getattr(_tls, "ctx", None)
        self.sid = new_id()
        _tls.ctx = self.sid
        self.t0 = time.perf_counter()
        # flow markers share the span's start timestamp so they bind to
        # this slice in Perfetto (binding point "enclosing slice")
        if self.flow_in is not None:
            _buf().append(("f", self.name, self.cat, self.t0, 0.0,
                           tuple(self.flow_in), None))
        if self.flow_out is not None:
            _buf().append(("s", self.name, self.cat, self.t0, 0.0,
                           tuple(self.flow_out), None))
        return self

    def __exit__(self, *exc) -> bool:
        t1 = time.perf_counter()
        _tls.ctx = self.prev
        if _enabled:  # disabled mid-span: drop silently
            args = self.args
            if self.prev is not None:
                args = dict(args) if args else {}
                args["parent"] = f"{self.prev[0]}:{self.prev[1]}"
            _buf().append(("X", self.name, self.cat, self.t0, t1 - self.t0,
                           self.sid, args))
        return False


class _NullSpan:
    """Shared no-op returned while disabled: __enter__/__exit__ do nothing."""

    __slots__ = ()
    sid = None

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False


_NULL = _NullSpan()


def span(name: str, cat: str = "task",
         flow_in: Optional[Tuple[int, int]] = None,
         flow_out: Optional[Tuple[int, int]] = None, **args: Any):
    """Context manager recording one complete span (Chrome ``"X"``).

    ``flow_in``/``flow_out`` additionally record a flow finish/start bound
    to this span — the cross-locality arrow.  Disabled → shared no-op."""
    if not _enabled:
        return _NULL
    return _Span(name, cat, flow_in, flow_out, args or None)


def instant(name: str, cat: str = "task", **args: Any) -> None:
    """Zero-duration marker (steals, wire receipts)."""
    if not _enabled:
        return
    _buf().append(("i", name, cat, time.perf_counter(), 0.0, None,
                   args or None))


def complete(name: str, cat: str, t0: float,
             flow_out: Optional[Tuple[int, int]] = None, **args: Any) -> None:
    """Record a span from a caller-held start time (for sites where a
    context manager would obscure control flow, e.g. the send pump)."""
    if not _enabled:
        return
    t1 = time.perf_counter()
    b = _buf()
    if flow_out is not None:
        b.append(("s", name, cat, t0, 0.0, tuple(flow_out), None))
    b.append(("X", name, cat, t0, t1 - t0, None, args or None))


def async_begin(name: str, aid: int, cat: str = "serve", **args: Any) -> None:
    """Open a per-object async span (e.g. one serving request's lifetime:
    admission → ... → finish).  ``aid`` must be unique per (cat, locality)."""
    if not _enabled:
        return
    _buf().append(("b", name, cat, time.perf_counter(), 0.0, int(aid),
                   args or None))


def async_instant(name: str, aid: int, cat: str = "serve", **args: Any) -> None:
    if not _enabled:
        return
    _buf().append(("n", name, cat, time.perf_counter(), 0.0, int(aid),
                   args or None))


def async_end(name: str, aid: int, cat: str = "serve", **args: Any) -> None:
    if not _enabled:
        return
    _buf().append(("e", name, cat, time.perf_counter(), 0.0, int(aid),
                   args or None))


# ------------------------------------------------------------------- drain
def export_buffers() -> List[Dict[str, Any]]:
    """Snapshot every thread's ring: a list of
    ``{"tid", "thread_name", "dropped", "events"}`` dicts (events are the
    raw tuples — :mod:`repro.obs.export` converts to Chrome form).  The
    payload is picklable, so it travels over the parcelport as-is."""
    with _lock:
        bufs = list(_buffers)
    out = []
    for b in bufs:
        events, dropped = b.snapshot()
        out.append({"tid": b.tid, "thread_name": b.thread_name,
                    "dropped": dropped, "events": events})
    return out


def events() -> List[tuple]:
    """Flat, time-ordered view of every recorded event (test helper)."""
    evs: List[tuple] = []
    for b in export_buffers():
        evs.extend(b["events"])
    evs.sort(key=lambda e: e[3])
    return evs
