"""``python -m repro.obs.top`` — live fleet dashboard ("hpx-top").

The terminal answer to "is the fleet healthy *right now*": per-locality
pool utilization bars, queue depths, serve engine p99s, parcelport
credit/inflight, and the admission gate — refreshed off one sampler, no
browser, no Grafana.

Two data paths, one frame renderer:

- **in-process** — a :class:`repro.obs.sampler.FleetSampler` sweeping the
  fleet over the parcelport (the launcher's ``--metrics-port`` sibling);
- **remote scrape** — ``--metrics http://host:port/metrics`` re-parses
  the OpenMetrics exposition (via the strict parser), so an operator can
  point ``obs.top`` at any running fleet from *outside* the process tree.

``--once`` renders a single frame and exits (what CI smoke-tests); the
default loop redraws every ``--interval`` seconds until interrupted.
"""

from __future__ import annotations

import argparse
import re
import sys
import time
from typing import Any, Dict, Optional, Tuple

_POOL_RE = re.compile(r"^/scheduler\{(?P<pool>[^}]*)\}/(?P<rest>.+)$")
_SERVE_P99_RE = re.compile(
    r"^/serve\{engine#(?P<engine>\d+)\}/request/"
    r"(?P<which>latency|first_token)/p99$")
_NET_RE = re.compile(
    r"^/net\{locality#(?P<loc>\d+)/peer#(?P<peer>\d+)\}/credit/"
    r"(?P<which>inflight_bytes|blocked|deferred)$")
_QUEUE_RE = re.compile(r"^queue/worker#(?P<w>\d+)/depth$")


# ------------------------------------------------------------- snapshots
def snapshot_from_flat(flat: Dict[Tuple[int, str], float]) -> Dict[str, Any]:
    """Build one dashboard snapshot from ``{(locality, counter): value}``
    — the common denominator of both data paths."""
    pools: Dict[Tuple[int, str], Dict[str, Any]] = {}
    serve: Dict[Tuple[int, int], Dict[str, float]] = {}
    net: Dict[Tuple[int, int], Dict[str, float]] = {}
    admission: Dict[int, Dict[str, float]] = {}
    for (loc, name), value in flat.items():
        pm = _POOL_RE.match(name)
        if pm:
            pool = pools.setdefault((loc, pm.group("pool")),
                                    {"queue": 0.0, "workers": 0})
            rest = pm.group("rest")
            if rest == "utilization":
                pool["util"] = value
            elif rest == "idle-rate":
                pool["idle"] = value
            elif rest == "queue/high/depth":
                pool["high"] = value
            else:
                qm = _QUEUE_RE.match(rest)
                if qm:
                    pool["queue"] += value
                    pool["workers"] += 1
            continue
        sm = _SERVE_P99_RE.match(name)
        if sm:
            s = serve.setdefault((loc, int(sm.group("engine"))), {})
            s[sm.group("which")] = value
            continue
        nm = _NET_RE.match(name)
        if nm:
            n = net.setdefault((int(nm.group("loc")), int(nm.group("peer"))),
                               {})
            n[nm.group("which")] = value
            continue
        if name == "/serve{router}/admission/depth":
            admission.setdefault(loc, {})["depth"] = value
        elif name == "/serve{router}/admission/gated":
            admission.setdefault(loc, {})["gated"] = value
        elif name == "/fleet{admission}/open":
            admission.setdefault(loc, {})["open"] = value
    localities = sorted({loc for loc, _ in flat})
    return {"localities": localities, "pools": pools, "serve": serve,
            "net": net, "admission": admission}


def snapshot_from_sampler(sampler) -> Dict[str, Any]:
    """Latest sampled value of every retained counter → one snapshot."""
    flat: Dict[Tuple[int, str], float] = {}
    for loc, name in sampler.keys():
        v = sampler.latest(loc, name)
        if v is not None:
            flat[(loc, name)] = v
    return snapshot_from_flat(flat)


# families of interest ← how the exposition spells each dashboard input;
# the inverse of obs.metrics.counter_to_metric for exactly these names
def _flat_from_families(families: Dict[str, Dict[str, Any]]
                        ) -> Dict[Tuple[int, str], float]:
    flat: Dict[Tuple[int, str], float] = {}
    ups: Dict[int, float] = {}
    for fam, info in families.items():
        for name, labels, value in info["samples"]:
            loc = int(labels.get("locality", 0))
            if fam == "repro_up":
                ups[loc] = value
            elif fam in ("repro_scheduler_utilization",
                         "repro_scheduler_idle_rate"):
                leaf = ("utilization" if fam.endswith("utilization")
                        else "idle-rate")
                flat[(loc, f"/scheduler{{{labels.get('pool', '')}}}/"
                           f"{leaf}")] = value
            elif fam == "repro_scheduler_queue_depth" and "worker" in labels:
                flat[(loc, f"/scheduler{{{labels.get('pool', '')}}}/queue/"
                           f"worker#{labels['worker']}/depth")] = value
            elif fam == "repro_scheduler_queue_high_depth":
                flat[(loc, f"/scheduler{{{labels.get('pool', '')}}}/queue/"
                           "high/depth")] = value
            elif (fam in ("repro_serve_request_latency_p99",
                          "repro_serve_request_first_token_p99")
                  and "engine" in labels):
                which = ("latency" if "latency" in fam else "first_token")
                flat[(loc, f"/serve{{engine#{labels['engine']}}}/request/"
                           f"{which}/p99")] = value
            elif fam == "repro_net_credit_inflight_bytes" and "peer" in labels:
                flat[(loc, f"/net{{locality#{loc}/peer#{labels['peer']}}}/"
                           "credit/inflight_bytes")] = value
            elif fam == "repro_net_credit_blocked_total" and "peer" in labels:
                flat[(loc, f"/net{{locality#{loc}/peer#{labels['peer']}}}/"
                           "credit/blocked")] = value
            elif fam == "repro_serve_admission_depth":
                flat[(loc, "/serve{router}/admission/depth")] = value
            elif fam == "repro_serve_admission_gated_total":
                flat[(loc, "/serve{router}/admission/gated")] = value
            elif fam == "repro_fleet_open":
                flat[(loc, "/fleet{admission}/open")] = value
    snap_extra = {loc for loc, up in ups.items() if up}
    for loc in snap_extra:  # a reachable-but-quiet locality still shows up
        flat.setdefault((loc, "/fleet{_up}/marker"), 1.0)
    return flat


def snapshot_from_metrics(text: str) -> Dict[str, Any]:
    from repro.obs import metrics as _metrics

    return snapshot_from_flat(
        _flat_from_families(_metrics.parse_prometheus_text(text)))


# -------------------------------------------------------------- rendering
def _bar(frac: Optional[float], width: int = 20) -> str:
    if frac is None:
        return "-" * width
    frac = min(1.0, max(0.0, frac))
    full = int(round(frac * width))
    return "#" * full + "." * (width - full)


def render_frame(snapshot: Dict[str, Any],
                 now: Optional[float] = None) -> str:
    lines = []
    locs = snapshot["localities"]
    stamp = time.strftime("%H:%M:%S") if now is None else f"t={now:.1f}s"
    lines.append(f"repro fleet-top — {len(locs)} localit"
                 f"{'y' if len(locs) == 1 else 'ies'} — {stamp}")
    if snapshot["pools"]:
        lines.append("")
        lines.append(f"{'POOL':<26} {'utilization':<27} {'idle':>6} "
                     f"{'queued':>7} {'hi-q':>5}")
        for (loc, pool), st in sorted(snapshot["pools"].items()):
            util = st.get("util")
            lines.append(
                f"L{loc} scheduler{{{pool}}}"[:26].ljust(26) + " "
                f"[{_bar(util)}] "
                + (f"{util:>4.0%}" if util is not None else "   -") + " "
                + (f"{st['idle']:>6.0%}" if "idle" in st else f"{'-':>6}")
                + f" {st.get('queue', 0):>7.0f}"
                + (f" {st['high']:>5.0f}" if "high" in st else f" {'-':>5}"))
    if snapshot["serve"]:
        lines.append("")
        lines.append(f"{'SERVE ENGINE':<26} {'p99 latency':>12} "
                     f"{'p99 first-token':>16}")
        for (loc, eng), st in sorted(snapshot["serve"].items()):
            lat = st.get("latency")
            ftk = st.get("first_token")
            lines.append(
                f"L{loc} engine#{eng}"[:26].ljust(26)
                + (f" {lat * 1e3:>10.1f}ms" if lat is not None
                   else f" {'-':>12}")
                + (f" {ftk * 1e3:>14.1f}ms" if ftk is not None
                   else f" {'-':>16}"))
    if snapshot["net"]:
        lines.append("")
        lines.append(f"{'NET loc→peer':<26} {'inflight':>10} {'blocked':>9}")
        for (loc, peer), st in sorted(snapshot["net"].items()):
            lines.append(
                f"L{loc} → L{peer}"[:26].ljust(26)
                + f" {st.get('inflight_bytes', 0):>10.0f}"
                + f" {st.get('blocked', 0):>9.0f}")
    if snapshot["admission"]:
        lines.append("")
        for loc, st in sorted(snapshot["admission"].items()):
            gate = st.get("open")
            state = ("open" if gate else "CLOSED") if gate is not None else "?"
            lines.append(f"L{loc} admission: {state}  "
                         f"depth={st.get('depth', 0):.0f}  "
                         f"gated={st.get('gated', 0):.0f}")
    return "\n".join(lines)


# -------------------------------------------------------------------- CLI
def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.top",
        description="live fleet dashboard off the counter tree")
    ap.add_argument("--metrics", metavar="URL",
                    help="scrape an OpenMetrics endpoint instead of "
                         "sampling in-process")
    ap.add_argument("--pattern", default="*",
                    help="counter pattern for in-process sampling")
    ap.add_argument("--interval", type=float, default=2.0,
                    help="refresh period in seconds")
    ap.add_argument("--frames", type=int, default=0,
                    help="stop after N frames (0 = until interrupted)")
    ap.add_argument("--once", action="store_true",
                    help="render a single frame and exit (no clearing)")
    args = ap.parse_args(argv)

    frames = 1 if args.once else args.frames
    sampler = None
    if args.metrics is None:
        from repro import net as rnet
        from repro.obs.sampler import FleetSampler

        sampler = FleetSampler(pattern=args.pattern,
                               interval=args.interval, net=rnet.current())

    n = 0
    try:
        while True:
            if args.metrics is not None:
                from repro.net.httpd import http_get

                status, body = http_get(args.metrics)
                if status != 200:
                    print(f"scrape failed: HTTP {status}", file=sys.stderr)
                    return 1
                snap = snapshot_from_metrics(body)
            else:
                sampler.sample_once()
                snap = snapshot_from_sampler(sampler)
            frame = render_frame(snap)
            if not args.once and n > 0:
                sys.stdout.write("\x1b[2J\x1b[H")  # clear + home
            print(frame, flush=True)
            n += 1
            if frames and n >= frames:
                return 0
            time.sleep(args.interval)
    except KeyboardInterrupt:
        return 0


if __name__ == "__main__":
    sys.exit(main())
