"""Anomaly-triggered fleet flight recorder.

PR 6 built the trace plane (always-on per-thread rings, fleet-merged
Chrome export); PR 8 built the control plane (FleetController's measure →
decide → act tick).  This module wires them together: the rings run
continuously at low cost, and when the controller's trigger rules fire —
a p99 SLO breach, the admission gate slamming shut, actuator errors —
the ``dump_trace`` actuator *freezes* recording fleet-wide, collects and
clock-corrects every locality's rings, finds the worst offending request,
marks its SLOW-classified critical path into the trace, and writes one
Perfetto-loadable anomaly file.  Recording re-arms afterwards.

The freeze-first ordering matters: the collection round itself sends
parcels, which would overwrite the very ring slots holding the anomaly —
``disable`` is one flag write on each locality, so the window between
trigger and freeze is a single parcel RTT.

Counters::

    /obs{recorder}/dumps        cumulative anomaly dumps written
    /obs{recorder}/suppressed   trigger fired inside the re-arm window
"""

from __future__ import annotations

import json
import os
import threading
import time
from typing import Any, Dict, Optional

from repro.core import counters as _counters
from repro.obs import attribution as _attribution
from repro.obs import critical_path as _cp
from repro.obs import export as _export
from repro.obs import trace as _trace

__all__ = ["FlightRecorder"]


class FlightRecorder:
    """Always-on rings + anomaly dump-on-trigger.

    ``capacity`` is deliberately small (the "low-cost" contract: a 16k
    ring per thread holds the last few seconds of serving at full tilt);
    ``rearm_s`` rate-limits dumps so a sustained breach produces one
    trace, not one per controller tick."""

    def __init__(self, net=None, out_dir: str = "results",
                 prefix: str = "anomaly", capacity: int = 16384,
                 rearm_s: float = 30.0, probes: int = 3):
        self.net = net
        self.out_dir = out_dir
        self.prefix = prefix
        self.capacity = capacity
        self.rearm_s = rearm_s
        self.probes = probes
        self._seq = 0
        self._last_dump = -float("inf")
        self._lock = threading.Lock()
        self.last_path: Optional[str] = None
        self.last_trace: Optional[Dict[str, Any]] = None
        self.last_offender: Optional[str] = None
        reg = _counters.default()
        self.c_dumps = reg.counter("/obs{recorder}/dumps")
        self.c_suppressed = reg.counter("/obs{recorder}/suppressed")

    # ---------------------------------------------------------------- rings
    def start(self) -> "FlightRecorder":
        """Arm the always-on rings fleet-wide, from an empty window."""
        _export.clear_fleet(self.net)
        _export.enable_fleet(self.net, capacity=self.capacity)
        return self

    def stop(self) -> None:
        _export.disable_fleet(self.net)

    # ----------------------------------------------------------------- dump
    def dump(self, reason: str = "manual",
             detail: Optional[Dict[str, Any]] = None) -> Optional[str]:
        """Freeze → collect → blame → write → re-arm.  Returns the path of
        the anomaly trace, or None when suppressed by the re-arm window."""
        now = time.monotonic()
        with self._lock:
            if now - self._last_dump < self.rearm_s:
                self.c_suppressed.increment()
                return None
            self._last_dump = now
            self._seq += 1
            seq = self._seq

        was_enabled = _trace.enabled()
        _export.disable_fleet(self.net)  # freeze the evidence
        try:
            tr = _export.merged_trace(self.net, probes=self.probes)
            cps = _attribution.analyze_requests(tr)
            offender = None
            if cps:
                offender = max(cps.values(), key=lambda c: c.total_us)
                _cp.mark_critical_path(tr, offender)
            tr["anomaly"] = {
                "reason": reason,
                "detail": detail or {},
                "offender": offender.summary() if offender else None,
                "requests_analyzed": len(cps),
            }
            if cps:  # live blame histograms update with the dump
                _attribution.fold_into_counters(cps)

            os.makedirs(self.out_dir, exist_ok=True)
            path = os.path.join(self.out_dir, f"{self.prefix}-{seq}.json")
            with open(path, "w") as f:
                json.dump(tr, f)
            self.last_path = path
            self.last_trace = tr
            self.last_offender = offender.req if offender else None
            self.c_dumps.increment()
            return path
        finally:
            if was_enabled:  # re-arm for the next anomaly
                _export.enable_fleet(self.net, capacity=self.capacity)

    # ------------------------------------------------------------- triggers
    def install(self, controller, p99_high: Optional[float] = None,
                gate_trigger: bool = True, error_trigger: bool = True,
                sustain: int = 1) -> "FlightRecorder":
        """Register the ``dump_trace`` actuator plus the ISSUE 9 trigger
        rules on a :class:`~repro.fleet.controller.FleetController`:

        - ``p99_high`` (seconds): any engine's live request-latency p99
          gauge (``/serve{...}/request/latency/p99``, swept by the fleet
          sampler) at or above this fires;
        - ``gate_trigger``: the admission gate closed (parked batch
          requests appeared);
        - ``error_trigger``: actuator errors since the last tick.

        Policy cooldowns mirror ``rearm_s`` so triggers and dumps
        rate-limit coherently."""
        from repro.fleet.policy import Policy

        def dump_trace(view) -> None:
            self.dump(reason="controller",
                      detail={"occupancy": getattr(view, "occupancy", 0.0),
                              "gated_depth": getattr(view, "gated_depth", 0)})

        controller.register("dump_trace", dump_trace)

        if p99_high is not None:
            def worst_p99(view) -> float:
                worst = 0.0
                for (_loc, name), val in (view.latest or {}).items():
                    if name.endswith("/request/latency/p99"):
                        worst = max(worst, float(val))
                return worst

            controller.add_policy(Policy(
                "recorder/p99_breach", worst_p99, high=p99_high,
                up="dump_trace", sustain=sustain, cooldown=self.rearm_s))

        if gate_trigger:
            controller.add_policy(Policy(
                "recorder/gate_closed",
                lambda view: float(view.gated_depth), high=1.0,
                up="dump_trace", sustain=sustain, cooldown=self.rearm_s))

        if error_trigger:
            err = _counters.default().counter(
                "/fleet{controller}/action_errors")
            seen = {"n": err.get_value()}

            def error_delta(view) -> float:
                now_n = err.get_value()
                delta = now_n - seen["n"]
                seen["n"] = now_n
                return float(delta)

            controller.add_policy(Policy(
                "recorder/actuator_errors", error_delta, high=1.0,
                up="dump_trace", sustain=sustain, cooldown=self.rearm_s))
        return self
