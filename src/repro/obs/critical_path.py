"""Per-request critical-path reconstruction + SLOW-taxonomy blame.

The analysis half of the observability tier: :mod:`repro.obs.trace`
records spans/flows/async events, :mod:`repro.obs.export` merges them
fleet-wide onto one clock — this module answers *"why was this request
slow?"* with the SLOW vocabulary of the ParalleX performance model
(Anderson et al., arXiv:1109.5201):

- **S**tarvation — the request had nothing running on its behalf because
  no execution resource picked it up yet (prefill-pool queue wait,
  ready-queue wait for slot integration);
- **L**atency — clock-corrected parcel transit: the gap between a send
  span ending on one locality and the matching execute span starting on
  another (submit leg, completion leg);
- **O**verhead — machinery that is neither user work nor waiting on a
  resource: router dispatch, serialization/send, engine-loop bookkeeping
  between decode steps, completion plumbing;
- **W**aiting — contention on a held resource: the admission gate
  (``router/gated``), KV page-pool exhaustion (``admit_stall``), credit
  blocks / rendezvous CTS waits on the wire.

Everything else on the path — prefill and decode-step spans — is
**work**.  The request's admission→finish wall time is *tiled*: every
microsecond lands in exactly one classified interval, so attribution
sums to the total by construction and any residual (end-clamps from
clock-correction error) is reported explicitly, never silently dropped.

The join key is the fleet-global request tag (``args["req"]``) the
router stamps into every span the request touches, on every locality
(DESIGN.md §10.4).  Parent→child links ride ``args["parent"]`` (span
sids) and flow ids; both come from the same ``(locality, seq)``
allocator, so an id names exactly one edge.

Cross-locality edges use *clock-corrected* timestamps (export's min-RTT
Cristian handshake).  The residual correction error is bounded by half
the best probe RTT but can still run an edge backwards — such negative
intervals are clamped to zero and **counted** (``clamped_count`` /
``clamped_us``), the satellite contract of ISSUE 9.
"""

from __future__ import annotations

from collections import defaultdict
from typing import Any, Dict, List, NamedTuple, Optional, Set, Tuple

__all__ = ["SLOW_CLASSES", "CLASS_NAMES", "Interval", "CriticalPath",
           "TraceIndex", "request_ids", "critical_path", "flow_edges",
           "mark_critical_path", "CP_TID"]

# classification keys: work + the four SLOW categories
SLOW_CLASSES = ("work", "S", "L", "O", "W")
CLASS_NAMES = {"work": "work", "S": "starvation", "L": "latency",
               "O": "overhead", "W": "waiting"}

# synthetic track the marked critical path renders on (one per locality)
CP_TID = 0x7FFFFFFE


class Interval(NamedTuple):
    t0: float        # µs, merged-clock domain
    t1: float
    cls: str         # one of SLOW_CLASSES
    what: str        # human label ("prefill", "wire", "admission gate", …)
    pid: int         # locality the interval is charged to


class CriticalPath:
    """One request's tiled admission→finish timeline."""

    def __init__(self, req: str, slo: Optional[str], t0: float, t1: float,
                 intervals: List[Interval], clamped_count: int,
                 clamped_us: float):
        self.req = req
        self.slo = slo
        self.t0 = t0
        self.t1 = t1
        self.intervals = intervals
        self.clamped_count = clamped_count
        self.clamped_us = clamped_us
        self.total_us = max(0.0, t1 - t0)
        self.by_class: Dict[str, float] = {c: 0.0 for c in SLOW_CLASSES}
        for iv in intervals:
            self.by_class[iv.cls] += iv.t1 - iv.t0
        self.attributed_us = sum(self.by_class.values())
        self.residual_us = max(0.0, self.total_us - self.attributed_us)
        self.fraction = (self.attributed_us / self.total_us
                         if self.total_us > 0 else 1.0)

    def localities(self) -> Set[int]:
        return {iv.pid for iv in self.intervals}

    def summary(self) -> Dict[str, Any]:
        return {
            "req": self.req, "slo": self.slo,
            "total_us": self.total_us,
            "attributed_us": self.attributed_us,
            "residual_us": self.residual_us,
            "fraction": self.fraction,
            "clamped_count": self.clamped_count,
            "clamped_us": self.clamped_us,
            "localities": sorted(self.localities()),
            "by_class_us": {CLASS_NAMES[c]: v
                            for c, v in self.by_class.items()},
        }


# ------------------------------------------------------------------ indexing
class TraceIndex:
    """One-pass index over a merged Chrome trace (timestamps in µs)."""

    def __init__(self, tr: Dict[str, Any]):
        self.events: List[Dict[str, Any]] = tr.get("traceEvents", [])
        self.lossy = bool(tr.get("lossy"))
        # "{pid}/{thread}" → events the ring overwrote (export header);
        # kept so reports can *quantify* the loss, not just flag it
        self.ring_drops: Dict[str, int] = dict(tr.get("ring_drops") or {})
        self.spans_by_name: Dict[str, List[dict]] = defaultdict(list)
        self.span_by_sid: Dict[str, dict] = {}
        self.children: Dict[str, List[dict]] = defaultdict(list)
        self.instants_by_name: Dict[str, List[dict]] = defaultdict(list)
        # flow "s" events keyed by (pid, tid, ts): a span records its
        # flow-start at its own start timestamp on its own thread, so this
        # triple joins an X span to the flow id it emitted
        self.flow_start_at: Dict[Tuple[int, int, float], str] = {}
        self.flow_events: Dict[str, Dict[str, dict]] = defaultdict(dict)
        # request async lifetimes: tag -> {"b": ev, "e": ev}
        self.requests: Dict[str, Dict[str, dict]] = defaultdict(dict)

        for ev in self.events:
            ph = ev.get("ph")
            args = ev.get("args") or {}
            if ph == "X":
                self.spans_by_name[ev["name"]].append(ev)
                sid = args.get("sid")
                if sid:
                    self.span_by_sid[sid] = ev
                parent = args.get("parent")
                if parent:
                    self.children[parent].append(ev)
            elif ph == "i":
                self.instants_by_name[ev["name"]].append(ev)
            elif ph in ("s", "f"):
                self.flow_events[ev["id"]][ph] = ev
                if ph == "s":
                    self.flow_start_at[(ev["pid"], ev["tid"],
                                        ev["ts"])] = ev["id"]
            elif ph in ("b", "e") and ev.get("name") == "request":
                tag = args.get("req")
                if tag:
                    self.requests[tag][ph] = ev

    # -------------------------------------------------------- link walking
    def spans_for_req(self, name: str, req: str) -> List[dict]:
        return sorted((s for s in self.spans_by_name.get(name, [])
                       if (s.get("args") or {}).get("req") == req),
                      key=lambda s: s["ts"])

    def instants_for_req(self, name: str, req: str) -> List[dict]:
        return sorted((i for i in self.instants_by_name.get(name, [])
                       if (i.get("args") or {}).get("req") == req),
                      key=lambda i: i["ts"])

    def child_send(self, span: dict, prefix: str = "send:") -> Optional[dict]:
        """The send:* span recorded inside ``span`` (parent = its sid)."""
        sid = (span.get("args") or {}).get("sid")
        if not sid:
            return None
        for c in self.children.get(sid, []):
            if c["name"].startswith(prefix):
                return c
        return None

    def remote_execute(self, send_span: dict) -> Optional[dict]:
        """Follow a send span's flow arrow to the remote execute span."""
        fid = self.flow_start_at.get((send_span["pid"], send_span["tid"],
                                      send_span["ts"]))
        if fid is None:
            return None
        for c in self.children.get(fid, []):
            if c["name"].startswith("execute:"):
                return c
        return None


def request_ids(tr: Dict[str, Any]) -> List[str]:
    """Every request tag with a complete (begin AND end) lifetime in the
    trace — the population :func:`critical_path` can analyze."""
    idx = tr if isinstance(tr, TraceIndex) else TraceIndex(tr)
    return sorted(tag for tag, be in idx.requests.items()
                  if "b" in be and "e" in be)


# --------------------------------------------------------------- flow edges
def flow_edges(tr: Dict[str, Any]) -> List[Dict[str, Any]]:
    """Every cross-locality flow arrow with its clock-corrected transit.

    Negative transits (clock-correction residual ran the edge backwards)
    are clamped to zero and flagged ``clamped`` — the audit the 3-locality
    skew test asserts on: edges never go backwards, and clamping is
    counted, not silent."""
    idx = tr if isinstance(tr, TraceIndex) else TraceIndex(tr)
    edges: List[Dict[str, Any]] = []
    for fid, sides in sorted(idx.flow_events.items()):
        s, f = sides.get("s"), sides.get("f")
        if s is None or f is None:
            continue
        raw = f["ts"] - s["ts"]
        edges.append({
            "id": fid, "src": s["pid"], "dst": f["pid"],
            "transit_us": max(0.0, raw), "raw_us": raw,
            "clamped": raw < 0.0,
        })
    return edges


# ------------------------------------------------------------ path building
def _seg(span: dict, cls: str, what: Optional[str] = None) -> Interval:
    return Interval(span["ts"], span["ts"] + span.get("dur", 0.0), cls,
                    what or span["name"], span["pid"])


def critical_path(tr: Dict[str, Any], req: str) -> Optional[CriticalPath]:
    """Reconstruct ``req``'s admission→finish path and tile it into
    classified intervals.  Returns None when the trace lacks the
    request's begin/end anchors (ring wrapped, or tag unknown)."""
    idx = tr if isinstance(tr, TraceIndex) else TraceIndex(tr)
    be = idx.requests.get(req) or {}
    begin, end = be.get("b"), be.get("e")
    if begin is None or end is None:
        return None

    router_spans = idx.spans_for_req("router/submit", req)
    gated = idx.instants_for_req("router/gated", req)
    stalls = idx.instants_for_req("admit_stall", req)
    prefills = idx.spans_for_req("prefill", req)
    relay_dones = idx.spans_for_req("relay/done", req)
    steps = sorted((s for s in idx.spans_by_name.get("decode_step", [])
                    if req in ((s.get("args") or {}).get("reqs") or [])),
                   key=lambda s: s["ts"])
    slo = ((begin.get("args") or {}).get("slo")
           or next(((r.get("args") or {}).get("slo")
                    for r in router_spans), None))

    segments: List[Interval] = []
    for rs in router_spans:
        segments.append(_seg(rs, "O", "router dispatch"))
        send = idx.child_send(rs)
        if send is not None:
            ex = idx.remote_execute(send)
            if ex is not None:
                segments.append(_seg(ex, "O", "submit execute"))
    for p in prefills:
        segments.append(_seg(p, "work", "prefill"))
    for s in steps:
        segments.append(_seg(s, "work", "decode_step"))

    t_end = end["ts"]
    for rd in relay_dones:
        segments.append(_seg(rd, "O", "completion send"))
        send = idx.child_send(rd)
        if send is not None:
            ex = idx.remote_execute(send)
            if ex is not None:
                segments.append(_seg(ex, "O", "completion execute"))
                t_end = max(t_end, ex["ts"] + ex.get("dur", 0.0))

    t_start = min([begin["ts"]]
                  + [r["ts"] for r in router_spans]
                  + [g["ts"] for g in gated])
    segments.sort(key=lambda iv: (iv.t0, iv.t1))

    gate_ts = [g["ts"] for g in gated]
    stall_ts = [s["ts"] for s in stalls]

    def gap_cls(prev: Optional[Interval], nxt: Optional[Interval],
                g0: float, g1: float) -> Tuple[str, str]:
        if any(g0 <= t <= g1 for t in gate_ts):
            return "W", "admission gate"
        if any(g0 <= t <= g1 for t in stall_ts):
            return "W", "kv-pool stall"
        if prev is not None and nxt is not None and prev.pid != nxt.pid:
            return "L", "wire"
        if nxt is not None and nxt.what == "prefill":
            return "S", "prefill queue"
        if (nxt is not None and nxt.what == "decode_step"
                and (prev is None or prev.what == "prefill")):
            return "S", "ready queue"
        return "O", "engine loop"

    intervals: List[Interval] = []
    clamped_count, clamped_us = 0, 0.0
    cursor = t_start
    prev: Optional[Interval] = None
    for seg in segments:
        if seg.t1 <= cursor:  # fully inside something already tiled
            continue
        raw_gap = seg.t0 - cursor
        if raw_gap < 0.0:
            # overlap (nested span / clock residual): clip, count the loss
            clamped_count += 1
            clamped_us += -raw_gap
        elif raw_gap > 0.0:
            cls, what = gap_cls(prev, seg, cursor, seg.t0)
            pid = seg.pid if cls in ("S", "O", "W") else \
                (prev.pid if prev is not None else seg.pid)
            intervals.append(Interval(cursor, seg.t0, cls, what, pid))
        s0 = max(cursor, seg.t0)
        intervals.append(Interval(s0, seg.t1, seg.cls, seg.what, seg.pid))
        cursor = seg.t1
        prev = seg
    if t_end > cursor:
        pid = prev.pid if prev is not None else begin["pid"]
        intervals.append(Interval(cursor, t_end, "O", "finish", pid))
    elif t_end < cursor:
        clamped_count += 1
        clamped_us += cursor - t_end
    t_end = max(t_end, cursor)

    return CriticalPath(req, slo, t_start, t_end, intervals,
                        clamped_count, clamped_us)


# ----------------------------------------------------------------- marking
def mark_critical_path(tr: Dict[str, Any], cp: CriticalPath) -> Dict[str, Any]:
    """Inject the critical path into the trace as ``cat:"anomaly"`` spans
    on a dedicated per-locality track, so Perfetto shows the blame
    timeline right under the real slices.  Mutates and returns ``tr``."""
    events = tr.setdefault("traceEvents", [])
    for pid in sorted(cp.localities()):
        events.append({"name": "thread_name", "ph": "M", "pid": pid,
                       "tid": CP_TID,
                       "args": {"name": f"critical path [{cp.req}]"}})
    for iv in cp.intervals:
        events.append({
            "name": f"{CLASS_NAMES[iv.cls]}:{iv.what}", "cat": "anomaly",
            "ph": "X", "pid": iv.pid, "tid": CP_TID,
            "ts": iv.t0, "dur": max(iv.t1 - iv.t0, 0.0),
            "args": {"req": cp.req, "class": CLASS_NAMES[iv.cls]},
        })
    tr["critical_path"] = cp.summary()
    return tr
