# Tier-1 verification: the one command CI and humans both run.
# Collection errors fail loudly here — a missing module kills the whole
# suite at collect time, which is exactly what we want to see first.

PY ?= python

.PHONY: verify test bench bench-compare bench-serve bench-algorithms \
	bench-net bench-net-check bench-container bench-obs bench-obs-check \
	bench-fleet bench-fleet-check smoke

verify:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} $(PY) -m pytest -x -q

test:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} $(PY) -m pytest -q

bench:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} $(PY) -m benchmarks.run

# Regression gate: re-run the fast suites and band-check their headline
# metrics against the committed results/ baselines (benchmarks/run.py GATES).
bench-compare:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} $(PY) -m benchmarks.run \
		--suites algorithms,obs --compare results/

bench-serve:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} $(PY) -m benchmarks.bench_serve

bench-algorithms:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} $(PY) -m benchmarks.bench_algorithms

bench-net:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} $(PY) -m benchmarks.bench_net

bench-net-check:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} $(PY) -m benchmarks.bench_net --check

bench-container:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} $(PY) -m benchmarks.bench_container

bench-obs:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} $(PY) -m benchmarks.bench_obs

bench-obs-check:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} $(PY) -m benchmarks.bench_obs --check

bench-fleet:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} $(PY) -m benchmarks.bench_fleet

bench-fleet-check:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} $(PY) -m benchmarks.bench_fleet --check

smoke:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} $(PY) -m repro.launch.train \
		--arch qwen25_3b --smoke --steps 10 --batch 4 --seq 64
