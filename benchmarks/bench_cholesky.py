"""Paper claim: HPX linear-algebra building blocks (tiled Cholesky dataflow)
perform on par with leading libraries.  Futurized tiled right-looking
Cholesky on the AMT runtime vs jnp.linalg.cholesky."""
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.core as core
from repro.core.dataflow import dataflow


def tiled_cholesky(A: np.ndarray, tile: int):
    """Right-looking blocked Cholesky as a dataflow DAG of jitted tile ops."""
    n = A.shape[0] // tile
    potrf = jax.jit(jnp.linalg.cholesky)
    trsm = jax.jit(lambda L, B: jax.scipy.linalg.solve_triangular(
        L, B.T, lower=True).T)
    syrk = jax.jit(lambda C, L: C - L @ L.T)
    gemm = jax.jit(lambda C, A_, B_: C - A_ @ B_.T)

    tiles = {(i, j): core.make_ready_future(
        jnp.asarray(A[i * tile:(i + 1) * tile, j * tile:(j + 1) * tile]))
        for i in range(n) for j in range(n) if j <= i}

    for k in range(n):
        tiles[(k, k)] = dataflow(potrf, tiles[(k, k)])
        for i in range(k + 1, n):
            tiles[(i, k)] = dataflow(trsm, tiles[(k, k)], tiles[(i, k)])
        for i in range(k + 1, n):
            tiles[(i, i)] = dataflow(syrk, tiles[(i, i)], tiles[(i, k)])
            for j in range(k + 1, i):
                tiles[(i, j)] = dataflow(gemm, tiles[(i, j)], tiles[(i, k)],
                                         tiles[(j, k)])
    out = np.zeros_like(A)
    for (i, j), fut in tiles.items():
        out[i * tile:(i + 1) * tile, j * tile:(j + 1) * tile] = np.asarray(fut.get())
    return np.tril(out)


def run():
    rows = []
    rng = np.random.default_rng(0)
    N, tile = 1024, 256
    X = rng.standard_normal((N, N)).astype(np.float32)
    A = X @ X.T + N * np.eye(N, dtype=np.float32)

    ref_fn = jax.jit(jnp.linalg.cholesky)
    Lref = np.asarray(ref_fn(jnp.asarray(A)))
    t0 = time.perf_counter()
    ref_fn(jnp.asarray(A)).block_until_ready()
    t_ref = time.perf_counter() - t0

    core.get_runtime()
    tiled_cholesky(A, tile)  # warm the tile jits
    t0 = time.perf_counter()
    L = tiled_cholesky(A, tile)
    t_tiled = time.perf_counter() - t0
    err = float(np.max(np.abs(L - Lref)) / np.max(np.abs(Lref)))

    rows.append(("cholesky/jnp_native", t_ref * 1e6, f"N={N}"))
    rows.append(("cholesky/dataflow_tiled", t_tiled * 1e6,
                 f"rel_err={err:.1e} ratio={t_tiled / t_ref:.2f}x"))
    return rows
