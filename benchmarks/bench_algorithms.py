"""Paper claim: standards-conforming parallel algorithms (C++17 par).
seq vs par (AMT pool) vs vec on reduce / sort / transform_reduce."""
import time

import repro.core as core
from repro.core import algorithms as alg
from repro.core.executor import par, seq, vec


def _timeit(fn, reps=3):
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run():
    core.get_runtime()
    rows = []
    data = list(range(400_000))
    f = lambda x: x * x + 1

    t_seq = _timeit(lambda: alg.transform_reduce(seq, data, f))
    t_par = _timeit(lambda: alg.transform_reduce(par.with_chunk_size(25_000), data, f))
    rows.append(("algorithms/transform_reduce_seq", t_seq * 1e6, ""))
    rows.append(("algorithms/transform_reduce_par", t_par * 1e6,
                 f"speedup={t_seq / t_par:.2f}x"))

    import random

    random.seed(0)
    xs = [random.random() for _ in range(400_000)]
    t_seq = _timeit(lambda: alg.sort(seq, xs))
    t_par = _timeit(lambda: alg.sort(par.with_chunk_size(50_000), xs))
    rows.append(("algorithms/sort_seq", t_seq * 1e6, ""))
    rows.append(("algorithms/sort_par", t_par * 1e6,
                 f"speedup={t_seq / t_par:.2f}x"))

    t_vec = _timeit(lambda: alg.reduce(vec, xs))
    rows.append(("algorithms/reduce_vec", t_vec * 1e6, "jnp backend"))
    return rows
