"""Paper claim: standards-conforming parallel algorithms (C++17 par) over
the executor hierarchy + resource partitioner.

Measures, and records into ``results/BENCH_algorithms.json``:

- ``transform`` seq vs par vs vec throughput.  The par workload is
  numpy-kernel rows (BLAS releases the GIL), so the 4-worker host pool
  shows real scaling; a pure-Python body is also measured honestly
  (GIL-bound, ~1x) as the contrast row.
- ``sort`` / ``transform_reduce`` seq vs par.
- pool-isolation tail latency: p50/p99 of PRIORITY_HIGH no-op tasks on the
  compute pool while (a) a 1-worker "io" pool is saturated (partitioned —
  the latency should not move) vs (b) the same saturation lands on the
  compute pool itself (unpartitioned baseline — the tail blows up).

Run directly (``make bench-algorithms``) for the JSON artifact, or through
``benchmarks.run`` for the CSV rows.
"""

import json
import time
from pathlib import Path

import numpy as np

from repro.core import algorithms as alg
from repro.core.executor import par, seq, vec
from repro.core.scheduler import PRIORITY_HIGH, Runtime

WORKERS = 4


def _timeit(fn, reps=3):
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _percentile(xs, q):
    return float(np.percentile(np.asarray(xs), q))


def bench_transform(rt) -> dict:
    """seq vs par vs vec transform across three body classes.

    The headline row is *latency-bound* bodies (each element stalls ~1ms on
    a blocking wait — the stand-in for storage/RPC — plus a small numpy
    reduction): this is the paper's "oversubscribing execution resources"
    claim, and the par speedup tracks the worker count, not the core count.
    CPU-bound numpy bodies are bounded by physical cores; pure-Python
    bodies are GIL-bound.  All three are recorded honestly.
    """
    import os
    import jax.numpy as jnp

    rng = np.random.default_rng(0)

    # -- headline: latency-bound bodies (oversubscription hides the stall)
    n_rows, row = 64, 16_384
    data = [rng.standard_normal(row) for _ in range(n_rows)]

    def io_fn(v):
        time.sleep(0.001)  # blocking stall: GIL released, no CPU
        return float(np.dot(v, v))

    t_seq = _timeit(lambda: alg.transform(seq, data, io_fn))
    t_par = _timeit(lambda: alg.transform(
        par.with_(chunk_size=max(1, n_rows // (4 * WORKERS))), data, io_fn))
    io_bound = {"rows": n_rows, "stall_ms": 1.0,
                "seq_s": t_seq, "par_s": t_par, "par_speedup": t_seq / t_par}

    # -- CPU-bound numpy bodies (BLAS releases the GIL; core-count bound)
    cdata = [rng.standard_normal(65_536) for _ in range(192)]
    cfn = lambda v: float(np.dot(v, v))
    tc_seq = _timeit(lambda: alg.transform(seq, cdata, cfn))
    tc_par = _timeit(lambda: alg.transform(par.with_(chunk_size=48), cdata, cfn))
    arr = jnp.asarray(np.stack(cdata), jnp.float32)
    vfn = lambda v: jnp.dot(v, v)
    _ = alg.transform(vec, arr, vfn)  # compile/warm
    tc_vec = _timeit(lambda: alg.transform(vec, arr, vfn))
    cpu_bound = {"rows": 192, "row_len": 65_536,
                 "seq_s": tc_seq, "par_s": tc_par, "vec_s": tc_vec,
                 "par_speedup": tc_seq / tc_par, "vec_speedup": tc_seq / tc_vec,
                 "physical_cores": os.cpu_count()}

    # -- pure-Python bodies (GIL-bound contrast row)
    pydata = list(range(200_000))
    pyfn = lambda x: x * x + 1
    tp_seq = _timeit(lambda: alg.transform(seq, pydata, pyfn))
    tp_par = _timeit(lambda: alg.transform(par, pydata, pyfn))
    python_body = {"seq_s": tp_seq, "par_s": tp_par,
                   "par_speedup": tp_seq / tp_par}

    return {
        "par_speedup": io_bound["par_speedup"],  # headline (latency-bound)
        "io_bound": io_bound,
        "cpu_bound": cpu_bound,
        "python_body": python_body,
        "note": "headline par_speedup is the latency-bound row (AMT "
                "oversubscription); cpu_bound is core-limited, python_body "
                "is GIL-limited — recorded for honesty",
    }


def bench_sort_reduce(rt) -> dict:
    rng = np.random.default_rng(1)
    xs = rng.standard_normal(400_000).tolist()
    t_seq_sort = _timeit(lambda: alg.sort(seq, xs))
    t_par_sort = _timeit(lambda: alg.sort(par.with_(chunk_size=50_000), xs))

    rows = [rng.standard_normal(32_768) for _ in range(128)]
    tr_fn = lambda v: float(np.sum(v * v))
    t_seq_tr = _timeit(lambda: alg.transform_reduce(seq, rows, tr_fn))
    t_par_tr = _timeit(lambda: alg.transform_reduce(
        par.with_(chunk_size=len(rows) // (2 * WORKERS)), rows, tr_fn))
    return {
        "sort_seq_s": t_seq_sort, "sort_par_s": t_par_sort,
        "sort_par_speedup": t_seq_sort / t_par_sort,
        "transform_reduce_seq_s": t_seq_tr, "transform_reduce_par_s": t_par_tr,
        "transform_reduce_par_speedup": t_seq_tr / t_par_tr,
    }


def bench_pool_isolation() -> dict:
    """Tail latency of PRIORITY_HIGH compute-pool tasks under I/O pressure:
    partitioned (io pool saturated) vs unpartitioned (same load on the
    compute pool)."""
    def _measure(rt, saturate_pool: str) -> dict:
        hog = rt.get_executor(saturate_pool)
        # a backlog of short blocking I/O-like tasks (outlives the probe loop)
        hogs = [hog.async_execute(time.sleep, 0.002) for _ in range(2000)]
        hi = rt.get_executor("default", priority=PRIORITY_HIGH)
        lat = []
        for _ in range(200):
            t0 = time.perf_counter()
            hi.async_execute(lambda: None).get(timeout=30.0)
            lat.append((time.perf_counter() - t0) * 1e3)
        [f.get(timeout=120.0) for f in hogs]
        return {"p50_ms": _percentile(lat, 50), "p99_ms": _percentile(lat, 99),
                "max_ms": _percentile(lat, 100)}

    # standalone runtimes (not entered as context managers, so the driver's
    # global runtime is left untouched)
    rt = Runtime(pools={"default": WORKERS, "io": 1})
    try:
        isolated = _measure(rt, "io")
    finally:
        rt.shutdown()
    rt = Runtime(pools={"default": WORKERS})
    try:
        shared = _measure(rt, "default")
    finally:
        rt.shutdown()
    return {
        "isolated_io_saturated": isolated,
        "unpartitioned_baseline": shared,
        "p99_improvement": shared["p99_ms"] / max(isolated["p99_ms"], 1e-6),
        "note": "PRIORITY_HIGH task latency on the compute pool while a "
                "backlog of 2000 blocking 2ms I/O tasks runs on 'io' "
                "(partitioned) vs on the compute pool itself (baseline)",
    }


def _acct_punch_cost_ns(iters: int = 200_000) -> float:
    """Measured ns/task of the accounting the worker loop adds: the exact
    idle→busy and busy→idle clock-punch sequences (two perf_counter reads
    plus the list writes), timed in isolation."""
    perf = time.perf_counter
    idle, busy = [0.0], [0.0]
    mark, state = [perf()], [0]
    t0 = perf()
    for _ in range(iters):
        now = perf()                      # idle -> busy
        idle[0] += now - mark[0]
        mark[0] = now
        state[0] = 1
        now = perf()                      # busy -> idle
        busy[0] += now - mark[0]
        mark[0] = now
        state[0] = 0
    dt = perf() - t0
    return dt / iters * 1e9


def bench_sched_accounting() -> dict:
    """The scheduler's utilization-accounting cost (ISSUE 10: the
    busy/idle clock punches at every worker state transition must cost
    ≤ 2% on the algorithms-bench task shape).

    The gated metric is *derived* the same way bench_obs derives disabled
    tracing cost: the measured per-task price of the exact punch sequence
    × the task rate the accounting-on pool actually sustains, stated as a
    fraction of wall time with every punch serialized (worst case — in
    reality they spread across WORKERS).  A wall-clock A/B of the same
    workload is recorded alongside for honesty, but an A/A control puts
    that comparison's noise floor at ±4% on this task shape (256 × ~25µs
    tasks), so it cannot resolve a 2% bound and is not gated.
    """
    rng = np.random.default_rng(2)
    rows = [rng.standard_normal(16_384) for _ in range(256)]
    fn = lambda v: float(np.dot(v, v))
    punch_ns = _acct_punch_cost_ns()

    def _pass_pair(work: bool, reps: int = 25):
        """Interleaved A/B: both runtimes live at once, timed reps
        alternate between them, median per arm — OS jitter, CPU
        frequency drift and cache state hit both arms equally."""
        rt_on = Runtime(pools={"default": WORKERS}, accounting=True)
        rt_off = Runtime(pools={"default": WORKERS}, accounting=False)
        try:
            def _body(rt):
                ex = rt.get_executor("default")
                if work:
                    return lambda: [f.get() for f in
                                    [ex.async_execute(fn, r) for r in rows]]
                return lambda: [f.get() for f in
                                [ex.async_execute(lambda: None)
                                 for _ in range(2000)]]
            body_on, body_off = _body(rt_on), _body(rt_off)
            body_on(), body_off()  # warm both pools (thread start, allocator)
            ons, offs = [], []
            for _ in range(reps):
                offs.append(_timeit(body_off, reps=1))
                ons.append(_timeit(body_on, reps=1))
            return float(np.median(ons)), float(np.median(offs))
        finally:
            rt_on.shutdown()
            rt_off.shutdown()

    on, off = _pass_pair(work=True)
    churn_on, churn_off = _pass_pair(work=False, reps=5)
    # worst case: every punch serialized onto the critical path
    overhead = len(rows) * punch_ns * 1e-9 / on
    ab_overhead = on / off - 1.0
    churn_overhead = churn_on / churn_off - 1.0
    return {
        "tasks": len(rows), "row_len": 16_384,
        "acct_punch_ns_per_task": round(punch_ns, 1),
        "accounting_on_s": on, "accounting_off_s": off,
        "overhead": round(overhead, 6),
        "ab_wall_overhead": round(ab_overhead, 4),
        "noop_churn_on_s": churn_on, "noop_churn_off_s": churn_off,
        "noop_churn_ab_overhead": round(churn_overhead, 4),
        "within_2pct": overhead <= 0.02,
        "note": "gated 'overhead' = punch cost x task rate, serialized "
                "worst case; ab_wall_overhead is the raw wall-clock A/B "
                "(noise floor ~±4% on this shape, informational only)",
    }


def bench() -> dict:
    import repro.core as core

    rt = core.init(num_workers=WORKERS)
    out = {
        "workers": WORKERS,
        "transform": bench_transform(rt),
        "sort_reduce": bench_sort_reduce(rt),
        "pool_isolation": bench_pool_isolation(),
        "sched_accounting": bench_sched_accounting(),
    }
    return out


def run():
    """CSV rows for the benchmarks.run driver; also refreshes the JSON
    artifact so ``--compare`` gates (sched_accounting.overhead) see the
    fresh values, not the committed baseline."""
    res = bench()
    out = Path(__file__).resolve().parent.parent / "results" / "BENCH_algorithms.json"
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(res, indent=1))
    tr, sr, iso = res["transform"], res["sort_reduce"], res["pool_isolation"]
    acct = res["sched_accounting"]
    return [
        ("algorithms/transform_io_seq", tr["io_bound"]["seq_s"] * 1e6, ""),
        ("algorithms/transform_io_par", tr["io_bound"]["par_s"] * 1e6,
         f"speedup={tr['io_bound']['par_speedup']:.2f}x"),
        ("algorithms/transform_cpu_par", tr["cpu_bound"]["par_s"] * 1e6,
         f"speedup={tr['cpu_bound']['par_speedup']:.2f}x"),
        ("algorithms/transform_vec", tr["cpu_bound"]["vec_s"] * 1e6,
         f"speedup={tr['cpu_bound']['vec_speedup']:.2f}x"),
        ("algorithms/sort_par", sr["sort_par_s"] * 1e6,
         f"speedup={sr['sort_par_speedup']:.2f}x"),
        ("algorithms/transform_reduce_par", sr["transform_reduce_par_s"] * 1e6,
         f"speedup={sr['transform_reduce_par_speedup']:.2f}x"),
        ("algorithms/pool_isolation_p99", iso["isolated_io_saturated"]["p99_ms"] * 1e3,
         f"baseline_p99={iso['unpartitioned_baseline']['p99_ms']:.2f}ms"),
        ("algorithms/sched_accounting", acct["accounting_on_s"] * 1e6,
         f"overhead={acct['overhead'] * 100:.3f}% (<=2% "
         f"{'OK' if acct['within_2pct'] else 'FAIL'}), "
         f"punch={acct['acct_punch_ns_per_task']:.0f}ns/task, "
         f"ab_wall={acct['ab_wall_overhead'] * 100:+.1f}%"),
    ]


def main() -> None:
    res = bench()
    out = Path(__file__).resolve().parent.parent / "results" / "BENCH_algorithms.json"
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(res, indent=1))
    tr, iso = res["transform"], res["pool_isolation"]
    print(json.dumps(res, indent=1))
    print(f"\npar-over-seq transform speedup: {tr['par_speedup']:.2f}x "
          f"(target >= 2x on {WORKERS} workers)")
    print(f"pool-isolation p99: {iso['isolated_io_saturated']['p99_ms']:.2f}ms "
          f"vs unpartitioned {iso['unpartitioned_baseline']['p99_ms']:.2f}ms")
    acct = res["sched_accounting"]
    print(f"scheduler accounting overhead: {acct['overhead'] * 100:.3f}% "
          f"(target <= 2%; {acct['acct_punch_ns_per_task']:.0f}ns/task, "
          f"raw A/B {acct['ab_wall_overhead'] * 100:+.1f}%)")


if __name__ == "__main__":
    main()
