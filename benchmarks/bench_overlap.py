"""Paper claim: the LibGeoDecomp N-body HPX backend beat MPI by 1.4× through
overlap of communication and computation.  Our analogue, from the compiled
dry-run: the BSP step exposes its collectives serially (step = compute +
comm), the futurized step overlaps them (step = max(compute, comm)).  We
lower BOTH plans for a representative cell and report the modeled speedup
plus the structural evidence (collective placement inside vs outside the
layer loop, peak memory)."""
import json
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]
OUT = REPO / "results" / "dryrun"
CELL = ("qwen25_3b", "train_4k", "pod")


def _ensure(plan: str) -> dict:
    tag = f"{CELL[0]}__{CELL[1]}__{CELL[2]}__{plan}.json"
    path = OUT / tag
    if not path.exists():
        subprocess.run([sys.executable, "-m", "repro.launch.dryrun",
                        "--arch", CELL[0], "--shape", CELL[1], "--mesh", CELL[2],
                        "--plan", plan], check=True, capture_output=True,
                       cwd=REPO, env={**__import__("os").environ,
                                      "PYTHONPATH": str(REPO / "src")})
    return json.loads(path.read_text())


def run():
    from repro.analysis.roofline import analyze

    rows = []
    recs = {plan: _ensure(plan) for plan in ("bsp", "futurized")}
    models = {}
    for plan, rec in recs.items():
        r = analyze(rec)
        serial = r.compute_s + r.memory_s + r.collective_s  # BSP: no overlap
        overlapped = max(r.compute_s, r.memory_s, r.collective_s)
        models[plan] = (serial, overlapped, r, rec)
        rows.append((f"overlap/{plan}_serial_model_s", serial * 1e6,
                     f"coll={r.collective_s:.3f}s mem={r.memory_s:.3f}s"))
    bsp_time = models["bsp"][0]          # BSP executes serially
    fut_time = models["futurized"][1]    # futurized overlaps
    rows.append(("overlap/modeled_speedup", 0.0,
                 f"{bsp_time / fut_time:.2f}x (paper: 1.4x over MPI)"))
    mem_bsp = models["bsp"][3]["memory"].get("temp_size_in_bytes", 0)
    mem_fut = models["futurized"][3]["memory"].get("temp_size_in_bytes", 0)
    rows.append(("overlap/peak_temp_bytes_ratio", 0.0,
                 f"bsp/futurized={mem_bsp / max(mem_fut, 1):.2f}x"))
    return rows
