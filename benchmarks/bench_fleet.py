"""Adaptive fleet benchmark — the control-plane acceptance gates (ISSUE 8).

Two experiments over one 3-locality fleet (smoke model, one engine per
locality):

- **SLO A/B** — a batch flood plus sparse interactive requests, run twice.
  *Static*: no tiers, no admission gate — every request joins the same
  least-loaded scramble, so interactive work queues behind the flood.
  *Adaptive*: interactive requests pin to a reserved interactive-tier
  engine, batch spreads over the batch tier, and batch admission is gated
  on gossiped KV-page occupancy with the fleet controller releasing parked
  requests as pressure drains.  Gate: adaptive interactive p99 latency at
  least ``GATE_SLO_P99``x better than static.
- **Live migration under load** — grow a brand-new locality into the
  running fleet (elastic join), then migrate the interactive engine onto
  it mid-stream with 8 requests in flight.  Gate: every stream's channel
  tokens exactly equal its future's authoritative result AND the relay's
  duplicate counter does not move — zero dropped, zero duplicated.

``--check`` re-reads ``results/BENCH_fleet.json`` and exits non-zero if a
gate failed (the CI assertion step).
"""
import json
import sys
import time
from pathlib import Path

import numpy as np

REPO = Path(__file__).resolve().parents[1]
OUT = REPO / "results" / "BENCH_fleet.json"

LOCALITIES = 3
ARCH = "qwen25_3b"
BATCH_FLOOD = 18          # batch requests fired as one burst
INTERACTIVE_N = 8         # sparse latency-sensitive requests
BATCH_MAX_NEW = 24
INTERACTIVE_MAX_NEW = 4
MIGRATE_STREAMS = 8
GATE_SLO_P99 = 2.5


def _percentile(xs, q):
    return float(np.percentile(np.asarray(xs, dtype=np.float64), q))


def _prompts(n, seed):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, 512, size=rng.integers(4, 16)).tolist()
            for _ in range(n)]


def _drain(router, timeout=120):
    """Wait for everything in flight to finish before the next phase."""
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if router.gated_depth() == 0 and all(
                e.load() == 0 for e in router.engines):
            return
        time.sleep(0.05)
    raise TimeoutError("fleet did not drain")


def _slo_round(router, slo_interactive, slo_batch):
    """One flood+probe round; returns interactive latencies (s) and the
    batch futures (caller drains them)."""
    batch_futs = [router.submit(p, max_new=BATCH_MAX_NEW, slo=slo_batch)
                  for p in _prompts(BATCH_FLOOD, seed=11)]
    lat = []
    inter_futs = []
    for p in _prompts(INTERACTIVE_N, seed=13):
        t0 = time.perf_counter()
        f = router.submit(p, max_new=INTERACTIVE_MAX_NEW, slo=slo_interactive)
        f.get(timeout=600)
        lat.append(time.perf_counter() - t0)
        inter_futs.append(f)
        time.sleep(0.02)  # sparse arrivals, the interactive traffic shape
    return lat, batch_futs


def _slo_ab(net, router):
    from repro.fleet import BATCH, INTERACTIVE, AdmissionController
    from repro.fleet.controller import FleetController

    # warm every engine's jit paths so the A/B measures queueing, not
    # compilation
    for f in [e.submit(list(range(1, 9))) for e in router.engines]:
        f.get(timeout=600)

    # -- static: one undifferentiated pool
    static_lat, batch_futs = _slo_round(router, None, None)
    for f in batch_futs:
        f.get(timeout=600)
    _drain(router)

    # -- adaptive: tiers + occupancy-gated admission + controller ticks
    names = [getattr(e, "name", None) or e.scfg.name for e in router.engines]
    router.set_tier(names[1], INTERACTIVE)       # reserved latency engine
    for n in (names[0], *names[2:]):
        router.set_tier(n, BATCH)
    gate = AdmissionController.for_router(router, high=0.70, low=0.40)
    controller = FleetController(net, router, interval=0.05).start()
    try:
        adaptive_lat, batch_futs = _slo_round(router, INTERACTIVE, BATCH)
        for f in batch_futs:
            f.get(timeout=600)
        _drain(router)
    finally:
        controller.stop()
        router.admission = None
        for n in names:
            router.set_tier(n, None)

    from repro.core import counters as _counters
    reg = _counters.default()
    sp99, ap99 = _percentile(static_lat, 99), _percentile(adaptive_lat, 99)
    return {
        "batch_flood": BATCH_FLOOD,
        "interactive_requests": INTERACTIVE_N,
        "static_p50_ms": round(_percentile(static_lat, 50) * 1e3, 1),
        "static_p99_ms": round(sp99 * 1e3, 1),
        "adaptive_p50_ms": round(_percentile(adaptive_lat, 50) * 1e3, 1),
        "adaptive_p99_ms": round(ap99 * 1e3, 1),
        "p99_improvement": round(sp99 / ap99, 2),
        "gate_2p5x_met": bool(sp99 >= GATE_SLO_P99 * ap99),
        "admission": {
            "gated": int(reg.get_value("/serve{router}/admission/gated")),
            "released": int(
                reg.get_value("/serve{router}/admission/released")),
            "closed_edges": int(
                reg.get_value("/fleet{admission}/closed_edges")),
            "controller_ticks": int(
                reg.get_value("/fleet{controller}/ticks")),
        },
    }


def _migration_under_load(net, router):
    import repro.core as core
    from repro.core.future import Channel
    from repro.fleet import grow_engine, migrate_engine

    def relay_total(name):
        return sum(v for _n, v in
                   core.counters.query(f"/serve{{relay}}/tokens/{name}"))

    victim = router.engines[1]  # remote engine on locality 1
    # elastic join: the migration destination is a locality that did not
    # exist when the fleet booted
    t0 = time.perf_counter()
    newcomer = grow_engine(net, router)
    grow_wall = time.perf_counter() - t0
    dest = newcomer.locality

    dups_before = relay_total("duplicates")
    delivered_before = relay_total("delivered")
    pairs = []
    for p in _prompts(MIGRATE_STREAMS, seed=17):
        ch = Channel()
        pairs.append((ch, victim.submit(p, max_new=BATCH_MAX_NEW, stream=ch)))
    t0 = time.perf_counter()
    moved = migrate_engine(net, router, victim.name, dest)
    cutover = time.perf_counter() - t0

    exact = 0
    for ch, fut in pairs:
        out = fut.get(timeout=600)
        if list(ch) == out and len(out) == BATCH_MAX_NEW + 1:
            exact += 1
    dup_delta = relay_total("duplicates") - dups_before
    _drain(router)
    return {
        "streams": MIGRATE_STREAMS,
        "grow_wall_s": round(grow_wall, 2),
        "requests_moved": int(moved),
        "cutover_s": round(cutover, 3),
        "streams_token_exact": exact,
        "tokens_streamed": int(relay_total("delivered") - delivered_before),
        "duplicate_tokens": int(dup_delta),
        "engine_now_on": victim.locality,
        "gate_zero_drop_met": bool(
            exact == MIGRATE_STREAMS and dup_delta == 0 and moved >= 0),
    }


def _bench():
    from repro import net as rnet
    from repro.serve.engine import ServeConfig
    from repro.serve.router import Router

    pools = {"default": 4, "prefill": 2, "io": 1}
    net = rnet.bootstrap(LOCALITIES, pools=pools, worker_pools=pools)
    try:
        scfg = ServeConfig(max_batch=2, cache_len=96,
                           max_new_tokens=BATCH_MAX_NEW + 1)
        router = Router.over_localities(net, ARCH, scfg, smoke=True,
                                        plan="serve")
        slo = _slo_ab(net, router)
        migration = _migration_under_load(net, router)
        return {
            "localities": LOCALITIES,
            "arch": ARCH,
            "slo": slo,
            "migration": migration,
            # headline keys (CI gates + cross-PR comparisons read these)
            "interactive_p99_improvement": slo["p99_improvement"],
            "migration_cutover_s": migration["cutover_s"],
            "migration_duplicate_tokens": migration["duplicate_tokens"],
        }
    finally:
        net.shutdown()


def check(res=None) -> int:
    """CI gate: exit 0 iff the fleet met the ISSUE 8 acceptance bars."""
    res = res or json.loads(OUT.read_text())
    failures = []
    if not res["slo"]["gate_2p5x_met"]:
        failures.append(
            f"SLO gate: adaptive p99 {res['slo']['adaptive_p99_ms']}ms is "
            f"only {res['slo']['p99_improvement']}x better than static "
            f"{res['slo']['static_p99_ms']}ms (need {GATE_SLO_P99}x)")
    if not res["migration"]["gate_zero_drop_met"]:
        m = res["migration"]
        failures.append(
            f"migration gate: {m['streams_token_exact']}/{m['streams']} "
            f"streams token-exact, {m['duplicate_tokens']} duplicates")
    for f in failures:
        print(f"GATE FAILED — {f}")
    if not failures:
        print("all fleet gates met")
    return 1 if failures else 0


def run():
    res = _bench()
    OUT.parent.mkdir(parents=True, exist_ok=True)
    OUT.write_text(json.dumps(res, indent=1))
    s, m = res["slo"], res["migration"]
    return [
        ("fleet/slo_interactive_p99", s["adaptive_p99_ms"] * 1e3,
         f"{s['p99_improvement']}x better than static "
         f"{s['static_p99_ms']}ms under a {s['batch_flood']}-request "
         f"batch flood; {s['admission']['gated']} gated / "
         f"{s['admission']['released']} released"),
        ("fleet/live_migration", m["cutover_s"] * 1e6,
         f"{m['requests_moved']} in-flight requests moved in "
         f"{m['cutover_s']}s, {m['streams_token_exact']}/{m['streams']} "
         f"streams token-exact, {m['duplicate_tokens']} dup tokens "
         f"(grow {m['grow_wall_s']}s)"),
    ]


def main() -> None:
    import repro.core as core

    if "--check" in sys.argv:
        sys.exit(check())
    # run through the canonically-imported module, not __main__: worker
    # localities resolve actions by dotted module name
    from benchmarks import bench_fleet as canonical

    core.init(num_workers=4)
    try:
        for name, us, derived in canonical.run():
            print(f"{name},{us:.2f},{derived}")
        print(json.dumps(json.loads(OUT.read_text()), indent=1))
    finally:
        core.finalize()
    sys.exit(canonical.check())


if __name__ == "__main__":
    main()
