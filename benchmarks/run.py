"""Benchmark driver — one benchmark per paper table/figure (DESIGN.md §8).
Prints ``name,us_per_call,derived`` CSV."""
import sys
import traceback


def main() -> None:
    import repro.core as core

    core.init(num_workers=4)
    from benchmarks import (bench_algorithms, bench_cholesky, bench_container,
                            bench_dist, bench_efficiency, bench_fleet,
                            bench_net, bench_obs, bench_overlap, bench_serve,
                            bench_stream, bench_tasks)

    suites = [
        ("tasks", bench_tasks),
        ("stream", bench_stream),
        ("cholesky", bench_cholesky),
        ("algorithms", bench_algorithms),
        ("overlap", bench_overlap),
        ("efficiency", bench_efficiency),
        ("dist", bench_dist),
        ("serve", bench_serve),
        ("net", bench_net),
        ("container", bench_container),
        ("obs", bench_obs),
        ("fleet", bench_fleet),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for name, mod in suites:
        try:
            for row_name, us, derived in mod.run():
                print(f"{row_name},{us:.2f},{derived}")
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{name}/ERROR,0,{type(e).__name__}: {e}", flush=True)
            traceback.print_exc(file=sys.stderr)
    core.finalize()
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
