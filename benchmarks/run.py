"""Benchmark driver — one benchmark per paper table/figure (DESIGN.md §8).
Prints ``name,us_per_call,derived`` CSV.

``--suites a,b`` runs a subset.  ``--compare DIR`` turns the run into a
regression gate: the headline metrics in DIR's committed BENCH_*.json
baselines are snapshotted *before* the suites overwrite them, then the
fresh values are checked against tolerance bands (generous for
throughput-type metrics — CI containers are noisy — tight for the
absolute contracts like tracing overhead).  Any band violation makes the
exit status non-zero, so CI fails loudly with the fresh artifacts
uploaded for diffing.
"""
import argparse
import json
import sys
import traceback
from pathlib import Path

# (file, dotted.path, kind, bound) — the regression contract.
#  abs_max : fresh <= bound                      (absolute ceiling)
#  abs_min : fresh >= bound                      (absolute floor)
#  rel_min : fresh >= baseline * (1 - bound)     (throughput-type)
#  rel_max : fresh <= baseline * (1 + bound)     (latency-type)
# Relative bands are generous (50-100%): they catch order-of-magnitude
# regressions, not scheduler jitter.  Missing baselines or metrics warn
# and are skipped — a new metric must not fail the first CI run that
# introduces it.
GATES = [
    # absolute contracts (ISSUE 6/9 acceptance: tracing cost, attribution)
    ("BENCH_obs.json", "overhead.tracing_disabled_overhead", "abs_max", 0.02),
    ("BENCH_obs.json", "overhead.tracing_enabled_overhead", "abs_max", 0.10),
    ("BENCH_obs.json", "fleet_demo.attributed_fraction_min", "abs_min", 0.95),
    ("BENCH_fleet.json", "migration.duplicate_tokens", "abs_max", 0.0),
    # ISSUE 10 acceptance: idle-rate accounting overhead and the export tier
    ("BENCH_algorithms.json", "sched_accounting.overhead", "abs_max", 0.02),
    ("BENCH_obs.json", "export_tier.scrape_strict_parse_ok", "abs_min", 1.0),
    ("BENCH_obs.json", "export_tier.scrape_localities", "abs_min", 2.0),
    ("BENCH_obs.json", "export_tier.timeline_records", "abs_min", 2.0),
    # relative bands against the committed baseline
    ("BENCH_obs.json", "fleet_demo.flow_links_cross_locality",
     "rel_min", 0.5),
    ("BENCH_algorithms.json", "transform.par_speedup", "rel_min", 0.5),
    ("BENCH_algorithms.json", "pool_isolation.p99_improvement",
     "rel_min", 0.6),
    ("BENCH_serve.json", "speedup_tokens_per_s", "rel_min", 0.5),
    ("BENCH_net.json", "throughput.speedup_vs_baseline", "rel_min", 0.5),
    ("BENCH_net.json", "latency.parcel_round_trip_us", "rel_max", 1.0),
    ("BENCH_fleet.json", "slo.p99_improvement", "rel_min", 0.6),
    ("BENCH_dist.json", "bsp_over_futurized", "rel_min", 0.3),
]


def _lookup(obj, dotted: str):
    for part in dotted.split("."):
        if not isinstance(obj, dict) or part not in obj:
            return None
        obj = obj[part]
    return obj if isinstance(obj, (int, float)) else None


def snapshot_baselines(compare_dir: str):
    """Read every gated metric out of DIR before the suites overwrite the
    files in place (DIR is usually results/ itself)."""
    base = {}
    for fname, path, _kind, _bound in GATES:
        p = Path(compare_dir) / fname
        if not p.exists():
            continue
        try:
            base[(fname, path)] = _lookup(json.loads(p.read_text()), path)
        except (json.JSONDecodeError, OSError):
            base[(fname, path)] = None
    return base


def compare(baselines, results_dir: str, only_files=None) -> int:
    """Check fresh results against the snapshotted baselines; prints one
    line per gate, returns the number of violations."""
    violations = 0
    fresh_cache = {}
    for fname, path, kind, bound in GATES:
        if only_files is not None and fname not in only_files:
            continue
        p = Path(results_dir) / fname
        if fname not in fresh_cache:
            try:
                fresh_cache[fname] = json.loads(p.read_text())
            except (json.JSONDecodeError, OSError):
                fresh_cache[fname] = None
        doc = fresh_cache[fname]
        fresh = _lookup(doc, path) if doc is not None else None
        if fresh is None:
            print(f"COMPARE skip {fname}:{path} (no fresh value)")
            continue
        if kind == "abs_max":
            ok, want = fresh <= bound, f"<= {bound}"
        elif kind == "abs_min":
            ok, want = fresh >= bound, f">= {bound}"
        else:
            basev = baselines.get((fname, path))
            if basev is None:
                print(f"COMPARE skip {fname}:{path} (no baseline)")
                continue
            if kind == "rel_min":
                lim = basev * (1.0 - bound)
                ok, want = fresh >= lim, f">= {lim:.4g} ({basev:.4g} -{bound:.0%})"
            else:  # rel_max
                lim = basev * (1.0 + bound)
                ok, want = fresh <= lim, f"<= {lim:.4g} ({basev:.4g} +{bound:.0%})"
        tag = "ok " if ok else "REGRESSION"
        print(f"COMPARE {tag} {fname}:{path} = {fresh:.6g} (want {want})")
        violations += 0 if ok else 1
    return violations


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        description="repro benchmark driver (DESIGN.md §8)")
    ap.add_argument("--suites", metavar="a,b",
                    help="comma-separated subset of suites to run")
    ap.add_argument("--compare", metavar="DIR",
                    help="regression-gate fresh results against the "
                         "baselines committed in DIR (exit non-zero on a "
                         "band violation)")
    args = ap.parse_args(argv)

    baselines = snapshot_baselines(args.compare) if args.compare else None

    import repro.core as core

    core.init(num_workers=4)
    from benchmarks import (bench_algorithms, bench_cholesky, bench_container,
                            bench_dist, bench_efficiency, bench_fleet,
                            bench_net, bench_obs, bench_overlap, bench_serve,
                            bench_stream, bench_tasks)

    suites = [
        ("tasks", bench_tasks),
        ("stream", bench_stream),
        ("cholesky", bench_cholesky),
        ("algorithms", bench_algorithms),
        ("overlap", bench_overlap),
        ("efficiency", bench_efficiency),
        ("dist", bench_dist),
        ("serve", bench_serve),
        ("net", bench_net),
        ("container", bench_container),
        ("obs", bench_obs),
        ("fleet", bench_fleet),
    ]
    if args.suites:
        wanted = {s.strip() for s in args.suites.split(",") if s.strip()}
        unknown = wanted - {name for name, _ in suites}
        if unknown:
            ap.error(f"unknown suites: {sorted(unknown)}")
        suites = [(n, m) for n, m in suites if n in wanted]

    print("name,us_per_call,derived")
    failures = 0
    ran_files = set()
    for name, mod in suites:
        try:
            for row_name, us, derived in mod.run():
                print(f"{row_name},{us:.2f},{derived}")
            ran_files.add(f"BENCH_{name}.json")
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{name}/ERROR,0,{type(e).__name__}: {e}", flush=True)
            traceback.print_exc(file=sys.stderr)
    core.finalize()

    regressions = 0
    if baselines is not None:
        # only gate on metrics the selected suites actually refreshed
        regressions = compare(baselines, args.compare, only_files=ran_files)
        print(f"COMPARE {'PASS' if regressions == 0 else 'FAIL'} "
              f"({regressions} regression(s))")
    sys.exit(1 if failures or regressions else 0)


if __name__ == "__main__":
    main()
