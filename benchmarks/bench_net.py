"""Multi-locality transport sweep: the parcelport performance tier.

Measures the tiered transport (coalescing, eager/rendezvous protocols,
bulk-lane striping, credit backpressure — `net/parcelport.py`) in its two
regimes separately, the way the HPX+LCI study frames it:

- **latency-bound** — sequential round-trip time per payload size, across
  the eager→rendezvous boundary.  Coalescing must NOT tax this regime
  (the first frame after a quiet period ships immediately).
- **bandwidth-bound** — bulk array round trips per size over the striped
  rendezvous path, plus overlapped small-parcel throughput where
  coalescing amortizes syscalls into multi-parcel containers.
- **flood** — fire-and-forget parcels at a deliberately slow consumer:
  proves the credit scheme bounds sender-side in-flight bytes at
  ``NetConfig.send_budget`` (the producer blocks; queues never grow
  without bound) and that the budget fully drains afterwards.
- **codec** — `encode_frame` microbenchmark against the previous
  `io.BytesIO`-based implementation (kept inline as the reference).

Gates (ISSUE 7, against the pre-tier baseline committed in PR 4):
``remote_actions_per_s >= 5x 590.6`` and
``array_round_trip_MB_per_s >= 2x 219.0``.  ``--check`` re-reads
``results/BENCH_net.json`` and exits non-zero if a gate failed (the CI
assertion step).  The 2-locality router comparison that used to live
here moved with PR 4's acceptance into the net test suite; this file is
about the wire itself.
"""
import io
import json
import pickle
import struct
import sys
import threading
import time
from pathlib import Path

import numpy as np

REPO = Path(__file__).resolve().parents[1]
OUT = REPO / "results" / "BENCH_net.json"

LOCALITIES = 2
# Committed pre-tier baseline (results/BENCH_net.json @ PR 4) — the gate
# denominators.  Do not update these when re-running on faster hardware;
# they pin what "5x" means.
BASELINE_ACTIONS_PER_S = 590.6
BASELINE_BULK_MB_S = 219.0
GATE_ACTIONS = 5.0
GATE_BULK = 2.0

RTT_SIZES = [0, 1 << 10, 16 << 10, 256 << 10]  # last one crosses into rdv
RTT_REPS = 120
THROUGHPUT_ACTIONS = 3000
BULK_MB = [1, 8, 32]
CODEC_REPS = 2000
FLOOD_PARCELS = 400
FLOOD_PAYLOAD = 8 << 10
FLOOD_DELAY_S = 0.001


def _echo_bytes(rt, arr):
    return arr


# ------------------------------------------------------------ codec micro
def _encode_frame_bytesio(header, payload):
    """The pre-tier `encode_frame`: header+body staged through io.BytesIO.
    Kept verbatim as the reference the satellite task benches against."""
    from repro.net import parcelport as pp

    buffers = []
    body = b""
    if payload is not pp._NO_PAYLOAD:
        body = pickle.dumps(pp._to_host(payload), protocol=5,
                            buffer_callback=buffers.append)
    views = [b.raw() for b in buffers]
    header = dict(header)
    header["blens"] = [v.nbytes for v in views]
    header["bodylen"] = len(body)
    hdr = pp._encode_header(header)
    total = 4 + len(hdr) + len(body) + sum(v.nbytes for v in views)
    out = io.BytesIO()
    out.write(struct.pack(">I", total))
    out.write(struct.pack(">I", len(hdr)))
    out.write(hdr)
    out.write(body)
    return [out.getvalue(), *views]


def _codec_bench():
    from repro.net import parcelport as pp

    header = {"t": pp.PARCEL, "src": 0, "dst": 1, "seq": 7,
              "a": "benchmarks.bench_net._echo_bytes", "g": None}
    small = ((b"x" * 64,), {})
    arr = ((np.arange(1024, dtype=np.float64),), {})
    out = {}
    for name, payload in (("small", small), ("array_8k", arr)):
        for label, fn in (("bytesio_us", _encode_frame_bytesio),
                          ("encode_us", pp.encode_frame)):
            fn(header, payload)  # warm
            t0 = time.perf_counter()
            for _ in range(CODEC_REPS):
                fn(header, payload)
            out.setdefault(name, {})[label] = round(
                (time.perf_counter() - t0) / CODEC_REPS * 1e6, 3)
        s = out[name]
        s["speedup"] = round(s["bytesio_us"] / s["encode_us"], 2)
    return out


# ----------------------------------------------------------- wire regimes
def _latency_sweep(rnet):
    """Sequential RTT per payload size — the latency-bound regime."""
    rows = {}
    for size in RTT_SIZES:
        payload = b"" if size == 0 else bytes(size)
        rnet.run_on(1, _echo_bytes, payload).get(timeout=60)  # warm
        t0 = time.perf_counter()
        for _ in range(RTT_REPS):
            rnet.run_on(1, _echo_bytes, payload).get(timeout=60)
        rows[str(size)] = round(
            (time.perf_counter() - t0) / RTT_REPS * 1e6, 1)
    return rows


def _throughput(rnet):
    """Overlapped small-parcel actions/s — where coalescing earns its
    keep: thousands of sub-threshold frames collapse into containers."""
    futs = [rnet.run_on(1, _echo_bytes, i) for i in range(64)]  # warm
    for f in futs:
        f.get(timeout=60)
    t0 = time.perf_counter()
    futs = [rnet.run_on(1, _echo_bytes, i) for i in range(THROUGHPUT_ACTIONS)]
    got = sorted(f.get(timeout=300) for f in futs)
    wall = time.perf_counter() - t0
    assert got == list(range(THROUGHPUT_ACTIONS))
    return THROUGHPUT_ACTIONS / wall


def _bulk_sweep(rnet):
    """Round-trip MB/s per array size — the bandwidth-bound regime over
    the rendezvous handshake and the striped bulk lanes."""
    rng = np.random.default_rng(0)
    rows = {}
    for mb in BULK_MB:
        arr = rng.integers(0, 255, size=mb << 20, dtype=np.uint8)
        rnet.run_on(1, _echo_bytes, arr[:1024]).get(timeout=60)  # warm
        best = 0.0
        for _ in range(3):
            t0 = time.perf_counter()
            back = rnet.run_on(1, _echo_bytes, arr).get(timeout=300)
            wall = time.perf_counter() - t0
            best = max(best, 2 * mb / wall)  # there and back
        assert back[0] == arr[0] and back[-1] == arr[-1]
        rows[str(mb)] = round(best, 1)
    return rows


def _flood(net):
    """Fire-and-forget flood at a slow consumer: in-flight bytes must stay
    bounded by the send budget (producer blocks — explicit backpressure,
    not queue growth) and fully drain once the consumer catches up."""
    from repro.net import remote as _remote

    ch = net._conns[1]
    budget = net.config.send_budget
    payload = bytes(FLOOD_PAYLOAD)
    samples = []
    stop = threading.Event()

    def sampler():
        while not stop.is_set():
            samples.append(ch.inflight_bytes(1))
            time.sleep(0.0005)

    th = threading.Thread(target=sampler, daemon=True)
    blocked0 = ch.c_blocked.get_value()
    th.start()
    t0 = time.perf_counter()
    for _ in range(FLOOD_PARCELS):
        net.send_parcel(1, _remote._slow_sink._action_name, None,
                        (payload, FLOOD_DELAY_S), want_result=False)
    send_wall = time.perf_counter() - t0
    # drain: every flood parcel must execute and return its CREDIT —
    # in-flight bytes must come back to exactly zero (release-after-drain)
    deadline = time.perf_counter() + 60
    while ch.inflight_bytes(1) and time.perf_counter() < deadline:
        time.sleep(0.005)
    drain_wall = time.perf_counter() - t0
    stop.set()
    th.join(timeout=2)
    max_inflight = max(samples) if samples else 0
    return {
        "parcels": FLOOD_PARCELS,
        "payload_bytes": FLOOD_PAYLOAD,
        "consumer_delay_s": FLOOD_DELAY_S,
        "send_budget": budget,
        "max_inflight_bytes": max_inflight,
        "bounded": bool(max_inflight <= budget),
        "blocked_events": int(ch.c_blocked.get_value() - blocked0),
        "backpressure_engaged": bool(ch.c_blocked.get_value() - blocked0 > 0),
        "inflight_after_drain": ch.inflight_bytes(1),
        "drained": bool(ch.inflight_bytes(1) == 0),
        "send_wall_s": round(send_wall, 3),
        "drain_wall_s": round(drain_wall, 3),
    }


def _coalesce_stats(net):
    from repro.core import counters

    reg = counters.default()
    flushes = sum(v for _n, v in reg.query("/net{*}/coalesce/flushes"))
    parcels = sum(v for _n, v in reg.query("/net{*}/coalesce/parcels"))
    frames = sum(v for _n, v in reg.query("/net{*}/frames/sent"))
    sent = sum(v for _n, v in reg.query("/net{*}/parcels/sent"))
    return {
        "container_flushes": int(flushes),
        "parcels_coalesced": int(parcels),
        "parcels_per_container": round(parcels / flushes, 2) if flushes else 0.0,
        "wire_frames_sent": int(frames),
        "logical_parcels_sent": int(sent),
    }


def _bench():
    from repro import net as rnet

    codec = _codec_bench()
    pools = {"default": 4, "io": 2}
    net = rnet.bootstrap(LOCALITIES, pools=pools, worker_pools=pools)
    try:
        cfg = net.config
        latency = _latency_sweep(rnet)
        actions_per_s = _throughput(rnet)
        bulk = _bulk_sweep(rnet)
        flood = _flood(net)
        coalesce = _coalesce_stats(net)
        bulk_8mb = bulk[str(8)]
        return {
            "localities": LOCALITIES,
            "config": {
                "eager_threshold": cfg.eager_threshold,
                "coalesce_max_bytes": cfg.coalesce_max_bytes,
                "coalesce_max_parcels": cfg.coalesce_max_parcels,
                "coalesce_window_us": cfg.coalesce_window_us,
                "stripes": cfg.stripes,
                "stripe_chunk": cfg.stripe_chunk,
                "send_budget": cfg.send_budget,
            },
            "codec": codec,
            "latency": {
                "rtt_us_by_size": latency,
                "parcel_round_trip_us": latency[str(0)],
            },
            "throughput": {
                "actions": THROUGHPUT_ACTIONS,
                "baseline_actions_per_s": BASELINE_ACTIONS_PER_S,
                "speedup_vs_baseline": round(
                    actions_per_s / BASELINE_ACTIONS_PER_S, 2),
                "gate_5x_met": bool(
                    actions_per_s >= GATE_ACTIONS * BASELINE_ACTIONS_PER_S),
            },
            "bulk": {
                "MB_per_s_by_size": bulk,
                "baseline_MB_per_s": BASELINE_BULK_MB_S,
                "speedup_vs_baseline": round(bulk_8mb / BASELINE_BULK_MB_S, 2),
                "gate_2x_met": bool(
                    bulk_8mb >= GATE_BULK * BASELINE_BULK_MB_S),
            },
            "flood": flood,
            "coalesce": coalesce,
            # headline keys, stable across schema versions (CI gates +
            # cross-PR comparisons read these)
            "parcel_round_trip_us": latency[str(0)],
            "remote_actions_per_s": round(actions_per_s, 1),
            "array_round_trip_MB_per_s": bulk_8mb,
        }
    finally:
        net.shutdown()


def check(res=None) -> int:
    """CI gate: exit 0 iff the sweep met the ISSUE 7 acceptance bars."""
    res = res or json.loads(OUT.read_text())
    failures = []
    if not res["throughput"]["gate_5x_met"]:
        failures.append(
            f"actions/s gate: {res['remote_actions_per_s']} < "
            f"{GATE_ACTIONS}x baseline {BASELINE_ACTIONS_PER_S}")
    if not res["bulk"]["gate_2x_met"]:
        failures.append(
            f"bulk gate: {res['array_round_trip_MB_per_s']} MB/s < "
            f"{GATE_BULK}x baseline {BASELINE_BULK_MB_S}")
    if not res["flood"]["bounded"]:
        failures.append(
            f"flood: inflight {res['flood']['max_inflight_bytes']} "
            f"exceeded budget {res['flood']['send_budget']}")
    if not res["flood"]["backpressure_engaged"]:
        failures.append("flood: backpressure never engaged")
    if not res["flood"].get("drained", True):
        failures.append(
            f"flood: {res['flood']['inflight_after_drain']} inflight bytes "
            f"never returned after the consumer caught up")
    for f in failures:
        print(f"GATE FAILED — {f}")
    if not failures:
        print("all transport gates met")
    return 1 if failures else 0


def run():
    res = _bench()
    OUT.parent.mkdir(parents=True, exist_ok=True)
    OUT.write_text(json.dumps(res, indent=1))
    fl, co = res["flood"], res["coalesce"]
    return [
        ("net/parcel_round_trip", res["parcel_round_trip_us"],
         f"{res['remote_actions_per_s']:.0f} actions/s overlapped "
         f"({res['throughput']['speedup_vs_baseline']}x baseline)"),
        ("net/rtt_sweep", res["latency"]["rtt_us_by_size"][str(16 << 10)],
         "us at 16KB; " + ", ".join(
             f"{k}B={v}us" for k, v in
             res["latency"]["rtt_us_by_size"].items())),
        ("net/array_round_trip", 0.0,
         f"{res['array_round_trip_MB_per_s']:.0f} MB/s at 8MB "
         f"({res['bulk']['speedup_vs_baseline']}x baseline); "
         + ", ".join(f"{k}MB={v}" for k, v in
                     res["bulk"]["MB_per_s_by_size"].items())),
        ("net/codec_encode", res["codec"]["array_8k"]["encode_us"],
         f"{res['codec']['array_8k']['speedup']}x vs BytesIO (array), "
         f"{res['codec']['small']['speedup']}x (small)"),
        ("net/flood_backpressure", 0.0,
         f"max inflight {fl['max_inflight_bytes']}B <= budget "
         f"{fl['send_budget']}B, {fl['blocked_events']} blocks, "
         f"drained to {fl['inflight_after_drain']}B"),
        ("net/coalesce", 0.0,
         f"{co['parcels_per_container']} parcels/container over "
         f"{co['container_flushes']} containers"),
    ]


def main() -> None:
    import repro.core as core

    if "--check" in sys.argv:
        sys.exit(check())
    # run through the canonically-imported module, not __main__: worker
    # localities resolve actions by dotted module name
    from benchmarks import bench_net as canonical

    core.init(num_workers=4)
    try:
        for name, us, derived in canonical.run():
            print(f"{name},{us:.2f},{derived}")
        print(json.dumps(json.loads(OUT.read_text()), indent=1))
    finally:
        core.finalize()
    sys.exit(canonical.check())


if __name__ == "__main__":
    main()
