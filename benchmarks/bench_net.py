"""Multi-locality runtime benchmark: parcel round-trip latency, remote
action throughput, zero-copy array bandwidth, and the headline the
subsystem exists for — router tokens/s over 2 OS-process localities vs 1.

The router comparison uses a deliberately *CPU-bound, GIL-holding*
synthetic engine (pure-Python hash loop per token): the workload class a
single Python process cannot scale past one core no matter how many
scheduler workers it has.  Both configurations run TWO engines behind the
least-loaded router; only the placement differs:

- **1 locality**  — both engines in this process (one GIL: the ceiling);
- **2 localities** — one engine here + one on a worker locality reached
  over the parcelport (two processes, two GILs).

Acceptance (ISSUE 4): 2-locality tokens/s ≥ 1.6× 1-locality.  Because a
wall-clock ratio can never beat what the host actually grants two
concurrent processes (shared/oversubscribed CI boxes are often far below
2.0), the bench first *measures* that ceiling through the stack itself
(``_host_parallel_ceiling``) and records speedup, ceiling, and their
ratio (parallel efficiency ≈ how much of the achievable parallelism the
runtime delivers).  Clients are closed-loop so least-loaded routing
adapts instead of freezing a 50/50 split.  Results →
``results/BENCH_net.json``.  Real-model multi-locality serving is
exercised by ``launch/serve.py --localities N`` and the net test suite;
XLA already releases the GIL + multithreads, so the synthetic engine is
the honest carrier of the claim, not a stand-in for it.
"""
import json
import time
from pathlib import Path

import numpy as np

REPO = Path(__file__).resolve().parents[1]
OUT = REPO / "results" / "BENCH_net.json"

LOCALITIES = 2
ROUND_TRIPS = 200
THROUGHPUT_ACTIONS = 256
ARRAY_MB = 8
CPU_REQUESTS = 32
CPU_MAX_NEW = 8
CPU_WORK = 60_000  # hash-loop iterations per generated token


# ------------------------------------------------------- CPU-bound engine
class CPUEngine:
    """GIL-bound token generator with the Engine submit/load protocol, so
    both LocalHandle and serve.router.RemoteEngine can front it."""

    def __init__(self, name: str, work: int = CPU_WORK):
        self.name = name
        self.work = work
        self._load = 0

    def generate(self, prompt, max_new):
        h, out = len(prompt), []
        for _ in range(max_new):
            for i in range(self.work):  # pure-Python: holds the GIL
                h = (h * 1103515245 + i + 12345) & 0x7FFFFFFF
            out.append(h & 0x3FF)
        return out

    def submit(self, prompt, max_new=None, sampling=None, stream=None):
        from repro.core.future import make_ready_future

        self._load += 1
        try:
            return make_ready_future(
                self.generate(prompt, max_new or CPU_MAX_NEW))
        finally:
            self._load -= 1

    def load(self):
        return float(self._load)


class LocalHandle:
    """In-process async front for a CPUEngine (router engine protocol)."""

    def __init__(self, engine: CPUEngine):
        import repro.core as core

        self.engine = engine
        self.name = engine.name
        self._ex = core.get_runtime().get_executor("default")
        self._inflight = 0

    def submit(self, prompt, max_new=None, sampling=None, stream=None):
        import threading

        if not hasattr(self, "_lock"):
            self._lock = threading.Lock()
        with self._lock:
            self._inflight += 1
        fut = self._ex.async_execute(self.engine.generate, prompt,
                                     max_new or CPU_MAX_NEW)

        def dec(_f):
            with self._lock:
                self._inflight -= 1

        fut.on_ready(dec)
        return fut

    def load(self):
        return float(self._inflight)


def _spawn_cpu_engine(rt, name, work):
    """Runs at a worker locality: register a CPUEngine in its AGAS."""
    from benchmarks.bench_net import CPUEngine
    from repro.core import agas
    from repro.net.locality import _gid_key

    gid = agas.default().register(CPUEngine(name, work),
                                  name=f"/engines/{name}")
    return list(_gid_key(gid))


def _echo_bytes(rt, arr):
    return arr


def _burn(rt, iters):
    h = 0
    for i in range(iters):
        h = (h * 1103515245 + i + 12345) & 0x7FFFFFFF
    return h


def _host_parallel_ceiling():
    """What THIS host actually gives two GIL-bound processes, measured
    through the stack itself: the same burn run at locality 0 and
    locality 1, sequentially vs concurrently.  Shared/oversubscribed CI
    boxes often deliver well under 2.0 — the router speedup below must be
    read against this ceiling, not against an assumed one."""
    import repro.core as core
    from repro.net import remote as _remote

    iters = CPU_WORK * CPU_MAX_NEW * 4
    ex = core.get_runtime().get_executor("default")
    _remote.run_on(1, _burn, 1000).get(timeout=60)  # warm the path
    t0 = time.perf_counter()
    ex.async_execute(_burn, None, iters).get(timeout=600)
    _remote.run_on(1, _burn, iters).get(timeout=600)
    t_seq = time.perf_counter() - t0
    t0 = time.perf_counter()
    here = ex.async_execute(_burn, None, iters)
    there = _remote.run_on(1, _burn, iters)
    here.get(timeout=600)
    there.get(timeout=600)
    t_par = time.perf_counter() - t0
    return t_seq / t_par


def _router_tokens_per_s(handles, requests=CPU_REQUESTS, clients=8):
    """Closed-loop clients (submit-on-completion) through the least-loaded
    router — throughput self-balances toward the faster replica."""
    import threading

    from repro.serve.router import Router

    router = Router(handles)
    for h in handles:  # untimed warmup: lazy imports, caches, route state
        h.submit(list(range(8)), max_new=1).get(timeout=600)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(1, 512, size=8).tolist() for _ in range(requests)]
    counts = []

    def client(k):
        for j in range(k, requests, clients):
            counts.append(len(router.submit(prompts[j]).get(timeout=600)))

    threads = [threading.Thread(target=client, args=(k,), daemon=True)
               for k in range(clients)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    return sum(counts) / wall, wall, sum(counts)


def _bench():
    import repro.core as core
    from repro import net as rnet
    from repro.core.agas import GID
    from repro.net import remote as _remote
    from repro.serve.router import RemoteEngine

    pools = {"default": 4, "io": 1}
    net = rnet.bootstrap(LOCALITIES, pools=pools, worker_pools=pools)
    try:
        # -- parcel round-trip latency (tiny payload) ---------------------
        rnet.run_on(1, _echo_bytes, b"warm").get(timeout=60)
        t0 = time.perf_counter()
        for _ in range(ROUND_TRIPS):
            rnet.run_on(1, _echo_bytes, b"x").get(timeout=60)
        rt_us = (time.perf_counter() - t0) / ROUND_TRIPS * 1e6

        # -- remote-action throughput (overlapped) ------------------------
        t0 = time.perf_counter()
        futs = [rnet.run_on(1, _echo_bytes, i)
                for i in range(THROUGHPUT_ACTIONS)]
        assert sorted(f.get(timeout=120) for f in futs) == \
            list(range(THROUGHPUT_ACTIONS))
        actions_per_s = THROUGHPUT_ACTIONS / (time.perf_counter() - t0)

        # -- zero-copy array bandwidth (round trip) -----------------------
        arr = np.random.default_rng(0).integers(
            0, 255, size=ARRAY_MB * 1024 * 1024, dtype=np.uint8)
        rnet.run_on(1, _echo_bytes, arr[:1024]).get(timeout=60)  # warm
        t0 = time.perf_counter()
        back = rnet.run_on(1, _echo_bytes, arr).get(timeout=120)
        wall = time.perf_counter() - t0
        assert back[0] == arr[0] and back[-1] == arr[-1]
        mb_per_s = 2 * ARRAY_MB / wall  # there and back

        # -- what can this host even do? (two GIL-bound processes) --------
        ceiling = _host_parallel_ceiling()

        # -- router throughput: 1 locality (two local engines, one GIL) ---
        local = [LocalHandle(CPUEngine("cpu#0a")),
                 LocalHandle(CPUEngine("cpu#0b"))]
        tps_1loc, wall_1, total_1 = _router_tokens_per_s(local)

        # -- router throughput: 2 localities (local + remote engine) ------
        key = _remote.run_on(1, _spawn_cpu_engine, "cpu#1",
                             CPU_WORK).get(timeout=120)
        mixed = [LocalHandle(CPUEngine("cpu#0")),
                 RemoteEngine(net, 1, GID(*key), "cpu#1")]
        tps_2loc, wall_2, total_2 = _router_tokens_per_s(mixed)
        remote_share = dict(core.counters.query(
            "/serve{router}/dispatch/cpu#1"))
        speedup = tps_2loc / tps_1loc
        return {
            "localities": LOCALITIES,
            "parcel_round_trip_us": round(rt_us, 1),
            "remote_actions_per_s": round(actions_per_s, 1),
            "array_round_trip_MB_per_s": round(mb_per_s, 1),
            "router_cpu_bound": {
                "requests": CPU_REQUESTS, "max_new": CPU_MAX_NEW,
                "work_per_token": CPU_WORK,
                "tokens_per_s_1_locality": round(tps_1loc, 1),
                "tokens_per_s_2_localities": round(tps_2loc, 1),
                "wall_s_1_locality": round(wall_1, 3),
                "wall_s_2_localities": round(wall_2, 3),
                "speedup_2_localities": round(speedup, 3),
                "remote_dispatch_share": sum(remote_share.values())
                / CPU_REQUESTS,
                # honest context: wall-clock speedup cannot beat what the
                # host gives two concurrent processes (shared CI boxes are
                # often well under 2.0); efficiency is speedup / ceiling
                "host_two_process_ceiling": round(ceiling, 3),
                "parallel_efficiency": round(min(speedup / ceiling, 1.0), 3)
                if ceiling > 0 else 0.0,
                "target_1_6x_met": bool(speedup >= 1.6),
            },
        }
    finally:
        net.shutdown()


def run():
    res = _bench()
    OUT.parent.mkdir(parents=True, exist_ok=True)
    OUT.write_text(json.dumps(res, indent=1))
    rb = res["router_cpu_bound"]
    return [
        ("net/parcel_round_trip", res["parcel_round_trip_us"],
         f"{res['remote_actions_per_s']:.0f} actions/s overlapped"),
        ("net/array_round_trip", 0.0,
         f"{res['array_round_trip_MB_per_s']:.0f} MB/s ({ARRAY_MB}MB x2)"),
        ("net/router_1loc_cpu", 1e6 / max(rb["tokens_per_s_1_locality"], 1e-9),
         f"{rb['tokens_per_s_1_locality']:.1f} tok/s"),
        ("net/router_2loc_cpu", 1e6 / max(rb["tokens_per_s_2_localities"], 1e-9),
         f"{rb['tokens_per_s_2_localities']:.1f} tok/s"),
        ("net/router_speedup", 0.0,
         f"{rb['speedup_2_localities']:.2f}x (host 2-proc ceiling "
         f"{rb['host_two_process_ceiling']:.2f}x; efficiency "
         f"{rb['parallel_efficiency']:.0%})"),
    ]


def main() -> None:
    import repro.core as core

    # run through the canonically-imported module, not __main__: worker
    # localities resolve actions by dotted module name
    from benchmarks import bench_net as canonical

    core.init(num_workers=4)
    for name, us, derived in canonical.run():
        print(f"{name},{us:.2f},{derived}")
    print(json.dumps(json.loads(OUT.read_text()), indent=1))
    core.finalize()


if __name__ == "__main__":
    main()
