"""Paper claim: HPX schedules 'billions of lightweight threads' with µs-scale
overheads.  Measures: task spawn+complete latency, sustained task throughput
per policy, future-chain (.then) latency, dataflow-node overhead."""
import time

import repro.core as core
from repro.core.dataflow import dataflow
from repro.core.scheduler import Runtime


def _timeit(fn, n):
    t0 = time.perf_counter()
    fn()
    return (time.perf_counter() - t0) / n * 1e6  # µs per op


def run():
    rows = []
    n = 20_000
    with Runtime(num_workers=4, policy="local", pool_name="bench-local") as rt:
        futs = None

        def spawn_all():
            nonlocal futs
            futs = [rt.spawn(lambda: None) for _ in range(n)]
            for f in futs:
                f.get()

        us = _timeit(spawn_all, n)
        rows.append(("tasks/spawn_get_local", us, f"{1e6 / us:.0f} tasks/s"))

        chain_len = 2_000
        def chain():
            f = core.make_ready_future(0)
            for _ in range(chain_len):
                f = f.then_value(lambda x: x + 1)
            assert f.get() == chain_len

        rows.append(("tasks/then_chain", _timeit(chain, chain_len), "per link"))

        def flow():
            fs = [dataflow(lambda a, b: a + b,
                           core.make_ready_future(i), core.make_ready_future(i))
                  for i in range(5_000)]
            for f in fs:
                f.get()

        rows.append(("tasks/dataflow_node", _timeit(flow, 5_000), "2-input node"))

    for policy in ("static", "hierarchical"):
        with Runtime(num_workers=4, policy=policy, pool_name=f"bench-{policy}") as rt:
            def burst():
                fs = [rt.spawn(lambda: None) for _ in range(n)]
                for f in fs:
                    f.get()

            us = _timeit(burst, n)
            rows.append((f"tasks/spawn_get_{policy}", us, f"{1e6 / us:.0f} tasks/s"))
    return rows
