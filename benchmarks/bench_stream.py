"""Paper claim (HPX.Compute): porting STREAM to the single-source abstraction
costs no performance.  Our analogue: the Pallas triad wrapper vs the native
jnp fused triad — identical results, and on CPU we report the native path's
effective bandwidth (the kernel path is interpret-mode, correctness-only;
on TPU the same call site runs the Mosaic kernel)."""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref


def run():
    rows = []
    N = 4_000_000
    a = jnp.arange(N, dtype=jnp.float32)
    b = jnp.ones((N,), jnp.float32)

    native = jax.jit(lambda a, b: a + 3.0 * b)
    native(a, b).block_until_ready()
    t0 = time.perf_counter()
    reps = 20
    for _ in range(reps):
        native(a, b).block_until_ready()
    dt = (time.perf_counter() - t0) / reps
    gbps = 3 * N * 4 / dt / 1e9  # 2 reads + 1 write
    rows.append(("stream/native_jnp", dt * 1e6, f"{gbps:.2f} GB/s"))

    # kernel path at reduced size (interpret mode = Python per block)
    Nk = 262_144
    ak, bk = a[:Nk], b[:Nk]
    out = ops.stream_triad(ak, bk, 3.0)
    err = float(jnp.max(jnp.abs(out - ref.triad(ak, bk, 3.0))))
    t0 = time.perf_counter()
    ops.stream_triad(ak, bk, 3.0).block_until_ready()
    dt_k = time.perf_counter() - t0
    rows.append(("stream/pallas_interpret", dt_k * 1e6,
                 f"max_err={err:.1e} (parity oracle)"))
    return rows
