"""Paper claim: futurization beats BSP by overlapping communication with
compute.  On a 1×1 host mesh there are no collectives to overlap, so this
measures the *step structure itself* — the BSP plan's bulk gather + full
remat vs the futurized per-layer schedule — and records the ratio to
``results/BENCH_dist.json`` so the perf trajectory is tracked per PR
(DESIGN.md §7)."""
import json
import time
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]
OUT = REPO / "results" / "BENCH_dist.json"

ARCH = "qwen25_3b"
STEPS = 8  # timed steps after one compile/warmup step


def _step_time_us(plan_name: str) -> float:
    import jax

    from repro.configs import get_config
    from repro.data.pipeline import DataConfig, synth_batch
    from repro.dist.plan import get_plan
    from repro.models.model import build_model
    from repro.optim import adamw
    from repro.train import step as step_mod

    cfg = get_config(ARCH, smoke=True)
    model = build_model(cfg, get_plan(plan_name))
    params = model.init(jax.random.PRNGKey(0))
    opt_state = adamw.init(params)
    batch = synth_batch(cfg, DataConfig(batch_size=4, seq_len=64), step=0)
    step = jax.jit(step_mod.make_train_step(model, adamw.AdamWConfig(lr=1e-3)),
                   donate_argnums=(0, 1))
    params, opt_state, m = step(params, opt_state, batch)  # compile + warmup
    jax.block_until_ready(m["loss"])
    t0 = time.perf_counter()
    for _ in range(STEPS):
        params, opt_state, m = step(params, opt_state, batch)
    jax.block_until_ready(m["loss"])
    return (time.perf_counter() - t0) / STEPS * 1e6


def run():
    rows = []
    us = {plan: _step_time_us(plan) for plan in ("bsp", "futurized")}
    ratio = us["bsp"] / us["futurized"] if us["futurized"] else 0.0
    for plan, t in us.items():
        rows.append((f"dist/{plan}_step", t, f"{ARCH} smoke 1x1 mesh"))
    rows.append(("dist/bsp_over_futurized", 0.0, f"{ratio:.2f}x"))

    OUT.parent.mkdir(parents=True, exist_ok=True)
    OUT.write_text(json.dumps({
        "arch": ARCH, "mesh": "1x1", "steps": STEPS,
        "bsp_us_per_step": round(us["bsp"], 1),
        "futurized_us_per_step": round(us["futurized"], 1),
        "bsp_over_futurized": round(ratio, 3),
    }, indent=1))
    return rows
