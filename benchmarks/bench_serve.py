"""Serving-stack benchmark: the paged, task-pipelined engine vs the seed
engine (dense per-slot cache + inline-prefill barrier) on a mixed-length
workload.

The seed engine pays twice on mixed lengths: every distinct prompt length
recompiles prefill (dynamic shapes), and every admission stalls the whole
decode batch (the barrier).  The paged stack buckets prompts to static
shapes and prefills on PRIORITY_HIGH tasks overlapped with the decode
continuation chain.  Records tokens/s, p50/p99 request latency, p50 first-
token latency, the speedup ratio, and the zero-recompile check to
``results/BENCH_serve.json`` (acceptance: ≥ 1.5× tokens/s, zero decode
recompiles after warmup, first streamed token before completion).
"""
import json
import time
from pathlib import Path

import numpy as np

REPO = Path(__file__).resolve().parents[1]
OUT = REPO / "results" / "BENCH_serve.json"

ARCH = "starcoder2_3b"
MAX_BATCH = 8
CACHE_LEN = 128
MAX_NEW = 12
REQUESTS = 16


def _workload(vocab: int, n: int):
    """Mixed-length prompts (4..60 tokens) — the continuous-batching case."""
    rng = np.random.default_rng(7)
    lens = rng.integers(4, 61, size=n)
    return [rng.integers(1, vocab, size=int(L)).tolist() for L in lens]


def _run_engine(model, params, vocab, *, paged: bool, pipelined: bool,
                name: str, requests: int):
    from repro.serve.engine import Engine, ServeConfig

    eng = Engine(model, params,
                 ServeConfig(max_batch=MAX_BATCH, cache_len=CACHE_LEN,
                             max_new_tokens=MAX_NEW, page_size=16,
                             paged=paged, pipeline_admission=pipelined,
                             name=name))
    prompts = _workload(vocab, requests)
    # warmup: one request through, then snapshot the decode compile count
    eng.submit(prompts[0]).get(timeout=600)
    compiles_warm = eng.decode_compile_count()
    # streaming probe: first token must arrive while the request is live
    ch, fut = eng.submit_stream(prompts[1])
    tok0 = ch.get(timeout=600)
    first_before_done = not fut.is_ready()
    assert [tok0] + list(ch) == fut.get(timeout=600)

    t0 = time.perf_counter()
    pending = []
    for p in prompts:
        pending.append((time.perf_counter(), eng.submit(p)))
    lat, total_tokens = [], 0
    for sub_t, fut in pending:
        out = fut.get(timeout=600)
        lat.append(time.perf_counter() - sub_t)
        total_tokens += len(out)
    wall = time.perf_counter() - t0
    first = eng.t_first.stats()  # engine-side submit→first-token timer
    return {
        "tokens_per_s": total_tokens / wall,
        "wall_s": wall,
        "total_tokens": total_tokens,
        "p50_latency_s": float(np.percentile(lat, 50)),
        "p99_latency_s": float(np.percentile(lat, 99)),
        "mean_first_token_s": first["mean"],
        "first_token_before_completion": first_before_done,
        "decode_recompiles_after_warmup": eng.decode_compile_count() - compiles_warm,
    }


def _bench(requests: int = REQUESTS):
    import jax

    from repro.configs import get_config
    from repro.dist.plan import get_plan
    from repro.models.model import build_model

    cfg = get_config(ARCH, smoke=True)
    model = build_model(cfg, get_plan("futurized"))
    params = model.init(jax.random.PRNGKey(0))
    # paged first: any process-global warmup then favors the baseline
    paged = _run_engine(model, params, cfg.vocab_size, paged=True,
                        pipelined=True, name="bench-paged#0",
                        requests=requests)
    seed = _run_engine(model, params, cfg.vocab_size, paged=False,
                       pipelined=False, name="bench-seed#0",
                       requests=requests)
    speedup = (paged["tokens_per_s"] / seed["tokens_per_s"]
               if seed["tokens_per_s"] else 0.0)
    return {
        "arch": ARCH, "max_batch": MAX_BATCH, "cache_len": CACHE_LEN,
        "max_new": MAX_NEW, "requests": requests,
        "paged_pipelined": {k: round(v, 4) if isinstance(v, float) else v
                            for k, v in paged.items()},
        "seed_baseline": {k: round(v, 4) if isinstance(v, float) else v
                          for k, v in seed.items()},
        "speedup_tokens_per_s": round(speedup, 3),
    }


def run(requests: int = REQUESTS):
    res = _bench(requests)
    OUT.parent.mkdir(parents=True, exist_ok=True)
    OUT.write_text(json.dumps(res, indent=1))
    p, s = res["paged_pipelined"], res["seed_baseline"]
    return [
        ("serve/paged_tokens_per_s", 1e6 / max(p["tokens_per_s"], 1e-9),
         f"{p['tokens_per_s']:.2f} tok/s"),
        ("serve/seed_tokens_per_s", 1e6 / max(s["tokens_per_s"], 1e-9),
         f"{s['tokens_per_s']:.2f} tok/s"),
        ("serve/speedup", 0.0, f"{res['speedup_tokens_per_s']:.2f}x"),
        ("serve/paged_p99_latency", p["p99_latency_s"] * 1e6,
         f"recompiles={p['decode_recompiles_after_warmup']}"),
    ]


def main() -> None:
    import repro.core as core

    core.init(num_workers=4)
    for name, us, derived in run():
        print(f"{name},{us:.2f},{derived}")
    print(json.dumps(json.loads(OUT.read_text()), indent=1))
    core.finalize()


if __name__ == "__main__":
    main()
