"""Distributed-container benchmark: work-to-data vs fetch-all.

The claim the subsystem exists for (ISSUE 5 acceptance): a segmented
``reduce`` over a block-distributed :class:`PartitionedVector` moves
**≥10x fewer wire bytes** than fetching every element to the caller and
reducing there — counter-verified through the parcelport's own
``/net{...}/bytes/sent`` counters, summed over every locality.

At each locality count (1, 2, 3) the bench creates an N-element float64
vector, fills it *in place at the owners* (``fill_with`` — the generator
crosses the wire, the elements don't), then measures wall-clock and wire
bytes for:

- ``reduce``          — segmented (per-segment partial + tiny result
  frames) vs fetch-all (``to_array`` + local sum);
- ``inclusive_scan``  — segmented two-pass (local cumsum per segment,
  carry combine, offset fixup; result segments stay put) vs fetch-all
  (gather + local cumsum; result stays at the caller).

At 1 locality both paths are wire-free (the degenerate bootstrap) — only
wall-clock is reported there; the bytes ratio is judged at 3 localities.
Results → ``results/BENCH_container.json``.
"""
import json
import time
from pathlib import Path

import numpy as np

REPO = Path(__file__).resolve().parents[1]
OUT = REPO / "results" / "BENCH_container.json"

N = 200_000          # float64 elements → 1.6 MB of payload
REPS = 5
TARGET_RATIO = 10.0


def _iota(idx):
    return idx.astype(np.float64) * 0.5


def _wire_bytes(net):
    from repro import net as rnet

    total = 0.0
    for loc in range(net.n_localities):
        snap = rnet.query_counters(loc, "/net{*}/bytes/sent")
        total += sum(v for _k, v in snap)
    return total


def _timed(fn, reps=REPS):
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _measure(net, fn):
    """(min wall seconds, wire bytes of ONE call) — bytes measured on a
    dedicated call so timing reps don't inflate them."""
    wall = _timed(fn)
    before = _wire_bytes(net)
    fn()
    bytes_used = _wire_bytes(net) - before
    return wall, bytes_used


def _bench_at(n_localities: int, uid: str):
    from repro import net as rnet
    from repro.container import PartitionedVector
    from repro.core import algorithms as alg
    from repro.core.executor import par

    with rnet.running(n_localities, pools={"default": 4, "io": 1}) as net:
        pv = PartitionedVector.create(f"bench/{uid}", N).fill_with(_iota)
        oracle = _iota(np.arange(N))

        def fetch_all_reduce():
            return float(pv.to_array().sum())

        def seg_reduce():
            return float(alg.reduce(par, pv))

        def fetch_all_scan():
            return np.cumsum(pv.to_array())

        def seg_scan():
            return alg.inclusive_scan(par, pv)

        assert abs(seg_reduce() - oracle.sum()) < 1e-6 * abs(oracle.sum())
        assert np.allclose(seg_scan().to_array(), np.cumsum(oracle))

        res = {}
        for name, fn in [("reduce_fetch_all", fetch_all_reduce),
                         ("reduce_segmented", seg_reduce),
                         ("scan_fetch_all", fetch_all_scan),
                         ("scan_segmented", seg_scan)]:
            wall, wire = _measure(net, fn)
            res[name] = {"wall_s": round(wall, 6),
                         "wire_bytes": int(wire)}
        return res


def run():
    """benchmarks.run entry: (name, us_per_call, derived) rows."""
    import repro.net.locality as _loc

    results = {"n_elements": N, "element_bytes": N * 8,
               "per_localities": {}}
    rows = []
    for nloc in (1, 2, 3):
        if _loc.current() is not None:  # pragma: no cover - defensive
            raise RuntimeError("a net runtime is already up")
        res = _bench_at(nloc, f"L{nloc}")
        results["per_localities"][str(nloc)] = res
        for name, m in res.items():
            rows.append((f"container/{nloc}loc/{name}",
                         m["wall_s"] * 1e6,
                         f"wire={m['wire_bytes']}B"))

    at3 = results["per_localities"]["3"]
    fetch_b = at3["reduce_fetch_all"]["wire_bytes"]
    seg_b = max(at3["reduce_segmented"]["wire_bytes"], 1)
    ratio = fetch_b / seg_b
    scan_ratio = (at3["scan_fetch_all"]["wire_bytes"]
                  / max(at3["scan_segmented"]["wire_bytes"], 1))
    results["acceptance"] = {
        "reduce_bytes_ratio_at_3loc": round(ratio, 2),
        "scan_bytes_ratio_at_3loc": round(scan_ratio, 2),
        "target": TARGET_RATIO,
        "met": ratio >= TARGET_RATIO,
    }
    OUT.parent.mkdir(parents=True, exist_ok=True)
    OUT.write_text(json.dumps(results, indent=1))
    rows.append(("container/reduce_bytes_ratio_3loc", 0.0,
                 f"{ratio:.1f}x (target {TARGET_RATIO}x, "
                 f"met={results['acceptance']['met']})"))
    return rows


def main():
    for name, us, derived in run():
        print(f"{name},{us:.2f},{derived}")
    print(json.dumps(json.loads(OUT.read_text()), indent=1))
    if not json.loads(OUT.read_text())["acceptance"]["met"]:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
